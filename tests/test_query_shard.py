"""Sharded-FlashQL unit coverage: per-shard plan-cache invalidation,
plan-aware batching, scheduler stat accounting under multi-shard
admission, and the fleet projection."""

import numpy as np
import pytest

from repro.core.store import IDENTITY_SLOT, ZERO_SLOT, PackedStore
from repro.query import (
    Agg,
    Eq,
    In,
    Query,
    Range,
    build_sharded_flashql,
)
from repro.query.ast import and_ as qand
from repro.query.shard import ShardedBitmapStore, stripe_rows


def _table(rng, n):
    return {
        "country": rng.integers(0, 8, n),
        "device": rng.integers(0, 4, n),
    }


# ---------------------------------------------------------------------------
# plan-cache invalidation is per device
# ---------------------------------------------------------------------------


def test_packed_store_epoch_bumps_on_writes_not_scratch():
    st = PackedStore()
    assert st.epoch == 0
    st["a"] = np.zeros(4, np.uint32)
    e1 = st.epoch
    assert e1 > 0
    st["a"] = np.ones(4, np.uint32)  # reprogram: content changed
    assert st.epoch > e1
    e2 = st.epoch
    st["__scratch0"] = np.zeros(4, np.uint32)  # plan-internal temporary
    st["__scratch0"] = np.ones(4, np.uint32)
    assert st.epoch == e2


def test_mutating_one_shard_recompiles_only_that_shards_region():
    """Reprogramming a page invalidates exactly the cached plans that
    sense that page's REGION (column) on that device: the other shards
    stay fully warm, and even on the mutated shard plans over other
    columns survive (region-granular plan-cache epochs)."""
    rng = np.random.default_rng(0)
    sq = build_sharded_flashql(_table(rng, 300), 3, num_planes=1)
    qs = [Query(Eq("country", 1)), Query(In("device", [0, 2]))]
    sq.serve(qs)
    assert [c.misses for c in sq.compilers] == [2, 2, 2]
    sq.serve(qs)
    assert [c.misses for c in sq.compilers] == [2, 2, 2]
    assert [c.hits for c in sq.compilers] == [2, 2, 2]

    # mutate shard 1's packed store (reprogram one page in place)
    dev = sq.devices[1]
    page = "country=1"
    dev.fc_write(page, sq.store.shards[1].logical[page], esp=True)

    sq.serve(qs)
    # only shard 1 recompiles, and only its country plan; the device
    # query re-keys and hits the surviving plan
    assert [c.misses for c in sq.compilers] == [2, 3, 2]
    assert [c.hits for c in sq.compilers] == [4, 3, 4]
    # ... and results stay correct after the recompile
    (r,) = sq.serve([Query(Eq("country", 1))])
    want = int((_table(np.random.default_rng(0), 300)["country"] == 1).sum())
    assert r.count == want


def test_scratch_spills_keep_shard_caches_warm():
    """Range plans spill (ESP scratch writes mid-plan); those writes must
    NOT bump the device epoch, or every flush would recompile the fleet."""
    rng = np.random.default_rng(1)
    table = {"age": rng.integers(0, 64, 400)}
    sq = build_sharded_flashql(table, 2, num_planes=1)
    q = Query(Range("age", 13, 37))
    sq.serve([q])
    misses = [c.misses for c in sq.compilers]
    sq.serve([q])
    assert [c.misses for c in sq.compilers] == misses
    assert all(c.hits >= 1 for c in sq.compilers)


# ---------------------------------------------------------------------------
# scheduler accounting under multi-shard admission
# ---------------------------------------------------------------------------


def test_stats_count_tickets_once_not_per_shard():
    rng = np.random.default_rng(2)
    sq = build_sharded_flashql(_table(rng, 500), 3, queue_depth=4)
    queries = [Query(Eq("country", c % 8)) for c in range(10)]
    res = sq.serve(queries)
    assert len(res) == 10
    s = sq.stats()
    assert s["queries_served"] == 10  # tickets, not shard-partials (30)
    assert s["flushes"] == 3  # 4 + 4 + 2 under queue_depth=4
    assert s["mean_latency_s"] > 0
    assert s["queries_per_sec"] > 0
    # latency is accumulated once per completed ticket
    assert s["mean_latency_s"] * 10 == pytest.approx(sq.total_latency_s)
    # every query ran on every shard
    assert s["mws_commands"] >= 10 * 3


def test_latency_monotone_in_queue_position():
    """Tickets admitted earlier wait through later flushes: a ticket served
    in flush k has latency >= its own flush time (sanity of accounting)."""
    rng = np.random.default_rng(3)
    sq = build_sharded_flashql(_table(rng, 200), 2, queue_depth=2)
    tickets = [sq.submit(Query(Eq("country", c % 8))) for c in range(6)]
    results = {}
    while sq.pending:
        results.update(sq.flush())
    lats = [results[t].latency_s for t in tickets]
    assert all(v > 0 for v in lats)
    # the last-flushed ticket waited at least as long as the first-flushed
    assert max(lats[4:]) >= min(lats[:2])


def test_plan_aware_batching_merges_shapes():
    """Eq over differently-sized columns yields different gather shapes of
    one family; padding must merge them into one vmap group."""
    rng = np.random.default_rng(4)
    sq = build_sharded_flashql(_table(rng, 400), 2, num_planes=1)
    # country has 8 wordlines co-located, device 4 -> different idx widths
    qs = [Query(In("country", [0, 1, 2])), Query(In("device", [0, 1]))]
    sq.serve(qs)
    s = sq.stats()
    assert s["distinct_signatures"] == 2
    assert s["vmap_batches"] == 1, "family padding should merge the group"
    assert s["fused_flushes"] == 1, "cross-shard fusion should engage"
    # correctness under padding
    t = _table(np.random.default_rng(4), 400)
    r1, r2 = sq.serve(qs)
    assert r1.count == int(np.isin(t["country"], [0, 1, 2]).sum())
    assert r2.count == int(np.isin(t["device"], [0, 1]).sum())


def test_zero_slot_is_or_neutral_under_inverse_read():
    """The ZERO_SLOT block-padding row must be OR-neutral also for
    inverse-read commands (complement happens after the cross-block OR)."""
    from repro.core.engine import fused_block_reduce
    import jax.numpy as jnp

    rng = np.random.default_rng(5)
    words = rng.integers(0, 2**32, (2, 3, 4), dtype=np.uint32)
    cube = jnp.asarray(words)
    ones = jnp.full((1, 3, 4), 0xFFFFFFFF, dtype=jnp.uint32)
    zero_block = jnp.concatenate(
        [jnp.zeros((1, 1, 4), jnp.uint32), ones[:, :2]], axis=1
    )
    padded = jnp.concatenate([cube, zero_block], axis=0)
    for inverse in (False, True):
        np.testing.assert_array_equal(
            np.asarray(fused_block_reduce(cube, inverse)),
            np.asarray(fused_block_reduce(padded, inverse)),
        )


def test_unknown_column_rejected_at_submit_without_poisoning_queues():
    """A bad query must fail at admission; failing inside flush() would
    leave shard queues out of lockstep (popped on some shards only)."""
    rng = np.random.default_rng(10)
    sq = build_sharded_flashql(_table(rng, 100), 2)
    with pytest.raises(KeyError, match="nope"):
        sq.submit(Query(qand(Eq("country", 1), Eq("nope", 1))))
    assert sq.pending == 0
    # the fleet keeps serving normally afterwards
    (r,) = sq.serve([Query(Eq("country", 1))])
    t = _table(np.random.default_rng(10), 100)
    assert r.count == int((t["country"] == 1).sum())


def test_per_device_fallback_matches_fused():
    """With cross-shard fusion disabled every shard runs its own vmap
    batches; results must be identical to the fused path."""
    rng = np.random.default_rng(8)
    table = _table(rng, 257)
    qs = [
        Query(Eq("country", 2)),
        Query(In("device", [1, 3]), agg=Agg.MASK),
    ]
    fused = build_sharded_flashql(table, 3).serve(qs)
    sq = build_sharded_flashql(table, 3)
    sq.fuse_across_shards = False
    fallback = sq.serve(qs)
    assert sq.fused_flushes == 0 and sq.stats()["vmap_batches"] >= 3
    assert fallback[0].count == fused[0].count
    np.testing.assert_array_equal(
        np.asarray(fallback[1].mask.words), np.asarray(fused[1].mask.words)
    )


def test_non_esp_page_routes_shard_to_guarded_path():
    """A non-ESP page on one shard device must disable the fused path (it
    never injects errors) and fall back to execute_batch's guard."""
    import jax.numpy as jnp

    rng = np.random.default_rng(9)
    sq = build_sharded_flashql(_table(rng, 200), 2)
    w = sq.store.shards[0].words
    sq.devices[0].fc_write(
        "telemetry",
        jnp.asarray(rng.integers(0, 2**32, (w,), dtype=np.uint32)),
        esp=False,
    )
    (r,) = sq.serve([Query(Eq("country", 1))])
    t = _table(np.random.default_rng(9), 200)
    assert r.count == int((t["country"] == 1).sum())
    assert sq.fused_flushes == 0


# ---------------------------------------------------------------------------
# striping / store mechanics
# ---------------------------------------------------------------------------


def test_stripe_rows_partitions_exactly():
    for n in (0, 1, 7, 64, 97):
        for s in (1, 2, 3, 5):
            for policy in ("roundrobin", "range"):
                parts = stripe_rows(n, s, policy)
                assert len(parts) == s
                merged = np.sort(np.concatenate(parts))
                np.testing.assert_array_equal(merged, np.arange(n))


def test_sharded_store_forces_global_schema():
    """A value present only on one stripe still gets an (all-zero) page on
    every other shard, so lowering/placement agree across the fleet."""
    table = {"c": np.array([5, 0, 0, 0])}  # round-robin: 5 lands on shard 0
    store = ShardedBitmapStore(num_shards=2)
    store.ingest(table)
    for st in store.shards:
        assert st.columns["c"].values == (0, 5)
        assert "c=5" in st.logical
    # shard 1 never saw value 5: its page must be all-zero
    assert int(np.asarray(store.shards[1].logical["c=5"]).sum()) == 0


def test_shard_devices_share_canonical_layout():
    rng = np.random.default_rng(6)
    sq = build_sharded_flashql(
        _table(rng, 300), 3, warmup=[Query(In("country", [0, 1, 2]))]
    )
    ref = sq.devices[0].layout.placements
    for dev in sq.devices[1:]:
        assert dev.layout.placements == ref
    # warmup steered placement: the In() group is co-located inverted
    pl = [sq.devices[2].layout[f"country={v}"] for v in (0, 1, 2)]
    assert all(p.inverted for p in pl) and len({p.block for p in pl}) == 1


def test_identity_and_zero_slots_always_present():
    st = PackedStore(planes=2)
    st["p"] = np.arange(6, dtype=np.uint32)
    snap = np.asarray(st.snapshot())
    assert snap[IDENTITY_SLOT].min() == 0xFFFFFFFF
    assert snap[ZERO_SLOT].max() == 0


def test_fleet_projection_aggregates_devices():
    rng = np.random.default_rng(7)
    sq = build_sharded_flashql(_table(rng, 600), 2)
    sq.serve([Query(qand(Eq("country", 1), Eq("device", 2)))] * 4)
    proj = sq.projection()
    assert proj["num_devices"] == 2
    assert len(proj["per_shard"]) == 2
    # fleet time is the max over concurrent devices, energy the sum
    assert proj["fc_time_s"] == pytest.approx(
        max(p["fc_time_s"] for p in proj["per_shard"])
    )
    assert proj["fc_energy_j"] == pytest.approx(
        sum(p["fc_energy_j"] for p in proj["per_shard"])
    )
    assert proj["speedup_vs_osp"] > 0
