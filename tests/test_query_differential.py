"""Differential test harness: sharded vs unsharded FlashQL vs oracles.

A seeded generator draws random ``Eq``/``In``/``Range``/``And``/``Or``/
``Not`` trees over mixed equality + BSI columns — each paired with COUNT,
MASK, and a randomly drawn aggregate (SUM/AVG/MIN/MAX/TOP-K/GROUP BY) —
and every query executes on

* unsharded FlashQL (``BatchScheduler`` over one ``FlashDevice``), on both
  the fused one-dispatch flush and the per-reduce-group legacy flush,
* sharded FlashQL (``ShardedFlashQL``) for shard counts {1, 2, 3} under
  both stripe policies (plus a ``stripe_key``-sorted range fleet, which
  exercises shard routing), including row counts that do not divide
  evenly,
* the asynchronous per-shard pipelined flush (``pipeline=True``) against
  the lockstep oracle — composed with routing and, in the append stream,
  with coalesced appends,

and the results are checked **bit-exact** (exact-integer for SUM and the
AVG numerator) against the ``eval_expr`` oracle on the logical bitmap
pages and a plain-numpy oracle on the raw table.

Property-style execution goes through ``tests/_hypothesis_compat``: with
`hypothesis` installed, seeds/shapes are drawn adversarially; without it,
the deterministic ``CORPUS`` below keeps the same coverage running.
"""

import numpy as np
import pytest

from repro.core.engine import eval_expr
from repro.query import (
    Agg,
    AtLeast,
    Avg,
    BatchScheduler,
    BitmapStore,
    Count,
    Eq,
    FlashDevice,
    GroupBy,
    In,
    Majority,
    Mask,
    Max,
    Min,
    Not,
    Query,
    Range,
    Sum,
    TopK,
    build_sharded_flashql,
    lower,
)
from repro.query.oracle import np_select as _np_oracle
from repro.query.ast import and_ as qand, normalize_agg, or_ as qor

from tests._hypothesis_compat import given, settings, st

SHARD_COUNTS = (1, 2, 3)
# ragged on purpose: 97 is prime (never divides), 130 straddles a word
# boundary (128 = 4 words), 31 is below one packed word
ROW_COUNTS = (97, 130, 31)

# deterministic fallback corpus: (seed, num_rows, policy)
CORPUS = [
    (11, 97, "roundrobin"),
    (12, 97, "range"),
    (13, 130, "roundrobin"),
    (14, 130, "range"),
    (15, 31, "roundrobin"),
    (16, 31, "range"),
]


def _table(rng, n):
    """Mixed-index table: low-cardinality equality columns + a BSI column."""
    return {
        "country": rng.integers(0, 6, n),
        "device": rng.integers(0, 4, n),
        "age": rng.integers(0, 90, n),
    }


def _random_pred(rng, depth=0):
    kind = rng.integers(0, 6 if depth < 2 else 4)
    if kind == 0:
        return Eq("country", int(rng.integers(0, 7)))  # 6 may be absent
    if kind == 1:
        return In(
            "device", [int(v) for v in rng.choice(5, rng.integers(1, 4))]
        )
    if kind == 2:
        lo = int(rng.integers(0, 70))
        return Range("age", lo, lo + int(rng.integers(0, 40)))
    if kind == 3:
        return Not(_random_pred(rng, depth + 1))
    children = [
        _random_pred(rng, depth + 1) for _ in range(rng.integers(2, 4))
    ]
    return qand(*children) if kind == 4 else qor(*children)


def _random_agg(rng):
    """Draw one of the non-trivial aggregates over a random column."""
    col = ("country", "device", "age")[int(rng.integers(0, 3))]
    kind = int(rng.integers(0, 6))
    if kind == 0:
        return Sum(col)
    if kind == 1:
        return Avg(col)
    if kind == 2:
        return Min(col)
    if kind == 3:
        return Max(col)
    if kind == 4:
        return TopK(col, int(rng.integers(1, 5)))
    key = ("country", "device")[int(rng.integers(0, 2))]
    inner = (Count(), Sum("age"), Avg("age"))[int(rng.integers(0, 3))]
    return GroupBy(key, inner)


def _np_agg_oracle(spec, sel, table):
    """Plain-numpy aggregate over the selected-row mask ``sel``."""
    if isinstance(spec, Sum):
        return int(table[spec.column][sel].sum())
    if isinstance(spec, Avg):
        c = int(sel.sum())
        return int(table[spec.column][sel].sum()) / c if c else None
    if isinstance(spec, Min):
        v = table[spec.column][sel]
        return int(v.min()) if len(v) else None
    if isinstance(spec, Max):
        v = table[spec.column][sel]
        return int(v.max()) if len(v) else None
    if isinstance(spec, TopK):
        vals, counts = np.unique(table[spec.column][sel], return_counts=True)
        ranked = sorted(
            zip(vals.tolist(), counts.tolist()),
            key=lambda vc: (-vc[1], vc[0]),
        )
        return tuple((int(v), int(c)) for v, c in ranked)[: spec.k]
    assert isinstance(spec, GroupBy)
    out = {}
    for v in np.unique(table[spec.key]):
        m = sel & (table[spec.key] == v)
        c = int(m.sum())
        if not c:
            continue
        if isinstance(spec.value, Count):
            out[int(v)] = c
        else:
            out[int(v)] = _np_agg_oracle(spec.value, m, table)
    return out




def _run_differential(seed: int, n: int, policy: str) -> None:
    rng = np.random.default_rng(seed)
    table = _table(rng, n)
    preds = [_random_pred(rng) for _ in range(5)]
    queries = (
        [Query(p) for p in preds]
        + [Query(p, agg=Agg.MASK) for p in preds]
        + [Query(p, agg=_random_agg(rng)) for p in preds]
    )

    # unsharded reference (fused one-dispatch flush), checked against the
    # per-reduce-group legacy flush on the same device
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    ref = BatchScheduler(dev, store).serve(queries)
    legacy = BatchScheduler(dev, store, fuse_flush=False).serve(queries)
    for a, b in zip(ref, legacy):
        if isinstance(normalize_agg(a.query.agg), Mask):
            np.testing.assert_array_equal(
                np.asarray(a.mask.words), np.asarray(b.mask.words)
            )
        else:
            assert a.value == b.value, (seed, n, policy, a.query)

    sharded = {
        s: build_sharded_flashql(
            table, s, policy=policy, num_planes=2
        ).serve(queries)
        for s in SHARD_COUNTS
    }
    # asynchronous per-shard fused flushing vs the lockstep oracle above
    # (submission order is preserved by construction of serve())
    sharded["pipelined"] = build_sharded_flashql(
        table, 3, policy=policy, num_planes=2, pipeline=True
    ).serve(queries)
    if policy == "range":
        # stripe_key-sorted fleet: same results, but shard routing prunes
        sharded["routed"] = build_sharded_flashql(
            table, 3, policy="range", stripe_key="age", num_planes=2
        ).serve(queries)
        # routing + async pipelining composed
        sharded["routed-pipelined"] = build_sharded_flashql(
            table,
            3,
            policy="range",
            stripe_key="age",
            num_planes=2,
            pipeline=True,
        ).serve(queries)

    for i, q in enumerate(queries):
        want_bits = _np_oracle(q.where, table, n)
        # eval_expr oracle on the unsharded logical pages
        oracle_words = np.asarray(eval_expr(lower(q.where, store), store.logical))
        oracle_bits = np.asarray(
            np.unpackbits(
                oracle_words.view(np.uint8), bitorder="little"
            )[:n]
        ).astype(bool)
        np.testing.assert_array_equal(oracle_bits, want_bits)
        spec = normalize_agg(q.agg)
        if isinstance(spec, Count):
            want = int(want_bits.sum())
            assert ref[i].count == want
            for s, res in sharded.items():
                assert res[i].count == want, (seed, n, policy, s, q)
        elif isinstance(spec, Mask):
            ref_bits = np.asarray(ref[i].mask.to_bits()).astype(bool)
            np.testing.assert_array_equal(ref_bits, want_bits)
            for s, res in sharded.items():
                got = np.asarray(res[i].mask.to_bits()).astype(bool)
                np.testing.assert_array_equal(
                    got, want_bits, err_msg=f"{(seed, n, policy, s, q)}"
                )
        else:
            # SUM/AVG are exact-integer (numerator), so == is the right
            # comparison even for the float AVG: both sides divide the
            # same two Python ints
            want = _np_agg_oracle(spec, want_bits, table)
            assert ref[i].value == want, (seed, n, policy, q, ref[i].value)
            for s, res in sharded.items():
                assert res[i].value == want, (
                    seed, n, policy, s, q, res[i].value,
                )


@pytest.mark.parametrize("seed,n,policy", CORPUS)
def test_differential_corpus(seed, n, policy):
    """Deterministic corpus: always runs, with or without hypothesis."""
    _run_differential(seed, n, policy)


# ---------------------------------------------------------------------------
# incremental ingest: interleaved append/query streams vs rebuild oracle
# ---------------------------------------------------------------------------

# (seed, num_rows, policy) for the append stream; both policies and the
# stripe_key-routed fleet are exercised for every entry
APPEND_CORPUS = [
    (21, 97, "roundrobin"),
    (22, 97, "range"),
    (23, 130, "range"),
    (24, 31, "roundrobin"),
]


def _check_round(queries, results, table, n):
    """Assert one system's results bit-exact vs the numpy oracle on the
    rows resident so far (exact integers for SUM / the AVG numerator)."""
    for q, r in zip(queries, results):
        want_bits = _np_oracle(q.where, table, n)
        spec = normalize_agg(q.agg)
        if isinstance(spec, Count):
            assert r.count == int(want_bits.sum()), (q, r.count)
        elif isinstance(spec, Mask):
            got = np.asarray(r.mask.to_bits()).astype(bool)
            np.testing.assert_array_equal(got, want_bits, err_msg=f"{q}")
        else:
            want = _np_agg_oracle(spec, want_bits, table)
            assert r.value == want, (q, r.value, want)


def _run_append_differential(seed: int, n: int, policy: str) -> None:
    """Interleaved append/query stream, checked bit-exactly after every
    round against (a) a numpy oracle on the resident prefix and (b) a
    BitmapStore REBUILT from scratch on the same prefix — across shard
    counts {1, 2, 3}, both striping policies, and a stripe_key fleet."""
    rng = np.random.default_rng(seed)
    table = _table(rng, n)
    n0 = max(8, (2 * n) // 3)
    cut = n0 + max(1, (n - n0) // 2)
    # force index-metadata growth mid-stream: a country value and an age
    # bit width that FIRST appear in an append (GROUP BY must grow a
    # group; Range lowering must pick up the new BSI slice)
    table["country"][n0] = 11
    table["age"][cut] = 300
    prefixes = [n0, cut, n]

    def prefix(m):
        return {c: v[:m] for c, v in table.items()}

    reserve = n - n0
    store = BitmapStore()
    store.ingest(prefix(n0), reserve_rows=reserve)
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    systems: dict[object, object] = {
        "unsharded": BatchScheduler(dev, store),
        **{
            s: build_sharded_flashql(
                prefix(n0), s, policy=policy, num_planes=2,
                reserve_rows=reserve,
            )
            for s in SHARD_COUNTS
        },
    }
    # async fused flushing and append coalescing ride the same stream
    systems["pipelined"] = build_sharded_flashql(
        prefix(n0), 2, policy=policy, num_planes=2,
        reserve_rows=reserve, pipeline=True,
    )
    systems["coalesced"] = build_sharded_flashql(
        prefix(n0), 2, policy=policy, num_planes=2,
        reserve_rows=reserve, pipeline=True, coalesce_appends=True,
    )
    if policy == "range":
        systems["routed"] = build_sharded_flashql(
            prefix(n0), 3, policy="range", stripe_key="age",
            num_planes=2, reserve_rows=reserve,
        )

    warm_queries = [_random_pred(rng) for _ in range(2)]
    for round_i, m in enumerate(prefixes):
        if round_i:
            lo = prefixes[round_i - 1]
            batch = {c: v[lo:m] for c, v in table.items()}
            for sys in systems.values():
                sys.append(batch)
        preds = [_random_pred(rng) for _ in range(2)] + warm_queries
        queries = (
            [Query(p) for p in preds[:2]]
            + [Query(p, agg=Agg.MASK) for p in preds[2:3]]
            + [Query(p, agg=_random_agg(rng)) for p in preds]
            + [Query(Eq("country", 11), agg=GroupBy("country", Count()))]
        )
        # rebuild-from-scratch oracle on the same resident prefix
        rstore = BitmapStore()
        rstore.ingest(prefix(m))
        rdev = FlashDevice(num_planes=2)
        rstore.program(rdev)
        rebuilt = BatchScheduler(rdev, rstore).serve(queries)
        _check_round(queries, rebuilt, prefix(m), m)
        for name, sys in systems.items():
            got = sys.serve(queries)
            _check_round(queries, got, prefix(m), m)
            for want, have in zip(rebuilt, got):
                if isinstance(normalize_agg(want.query.agg), Mask):
                    np.testing.assert_array_equal(
                        np.asarray(want.mask.to_bits()),
                        np.asarray(have.mask.to_bits()),
                        err_msg=f"{(seed, n, policy, name)}",
                    )
                else:
                    assert want.value == have.value, (
                        seed, n, policy, name, want.query,
                    )


@pytest.mark.parametrize("seed,n,policy", APPEND_CORPUS)
def test_append_differential_corpus(seed, n, policy):
    """Deterministic append-stream corpus: always runs."""
    _run_append_differential(seed, n, policy)


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.sampled_from(ROW_COUNTS),
    policy=st.sampled_from(["roundrobin", "range"]),
)
def test_append_differential_property(seed, n, policy):
    """Property-style append streams: hypothesis drives seeds when
    installed; the shim skips this (the corpus above still runs)."""
    _run_append_differential(seed, n, policy)


# ---------------------------------------------------------------------------
# full CRUD: interleaved append/delete/update/query streams + compaction
# ---------------------------------------------------------------------------

LIFECYCLE_CORPUS = [
    (31, 97, "roundrobin"),
    (32, 97, "range"),
    (33, 130, "range"),
    (34, 31, "roundrobin"),
]


def _check_live_round(queries, results, resident, live):
    """One system's results vs the numpy oracle restricted to LIVE rows:
    tombstoned rows must never appear in any COUNT, MASK, or aggregate."""
    n = len(live)
    for q, r in zip(queries, results):
        want_bits = _np_oracle(q.where, resident, n) & live
        spec = normalize_agg(q.agg)
        if isinstance(spec, Count):
            assert r.count == int(want_bits.sum()), (q, r.count)
        elif isinstance(spec, Mask):
            got = np.asarray(r.mask.to_bits()).astype(bool)
            np.testing.assert_array_equal(got, want_bits, err_msg=f"{q}")
        else:
            want = _np_agg_oracle(spec, want_bits, resident)
            assert r.value == want, (q, r.value, want)


def _run_lifecycle_differential(seed: int, n: int, policy: str) -> None:
    """Random interleaved append/delete/update/query stream, bit-exact
    after every round vs a live-row numpy oracle — across shard counts
    {1, 2, 3}, both striping policies, a stripe_key fleet, and the
    unsharded scheduler; compaction fires mid-stream and must preserve
    results exactly while bumping epochs ONLY on rewritten stripes."""
    rng = np.random.default_rng(seed)
    resident = _table(rng, n)
    live = np.ones(n, bool)
    reserve = n  # headroom for the appended/updated rows

    def build_unsharded():
        store = BitmapStore()
        store.ingest(dict(resident), reserve_rows=reserve)
        dev = FlashDevice(num_planes=2)
        store.program(dev)
        return BatchScheduler(dev, store)

    systems: dict[object, object] = {
        "unsharded": build_unsharded(),
        **{
            s: build_sharded_flashql(
                dict(resident), s, policy=policy, num_planes=2,
                reserve_rows=reserve,
            )
            for s in SHARD_COUNTS
        },
    }
    if policy == "range":
        systems["routed"] = build_sharded_flashql(
            dict(resident), 3, policy="range", stripe_key="age",
            num_planes=2, reserve_rows=reserve,
        )

    def apply_all(op):
        for sys in systems.values():
            op(sys)

    warm = [_random_pred(rng) for _ in range(2)]
    for round_i in range(5):
        # -- one random mutation per round, mirrored into the model
        kind = ("append", "delete", "update", "compact", "delete")[round_i]
        if kind == "append":
            b = int(rng.integers(3, 10))
            batch = _table(rng, b)
            apply_all(lambda s: s.append(batch))
            resident = {
                c: np.concatenate([v, batch[c]]) for c, v in resident.items()
            }
            live = np.concatenate([live, np.ones(b, bool)])
        elif kind == "delete":
            pool = np.flatnonzero(live)
            ids = rng.choice(pool, min(len(pool) // 3, 25), replace=False)
            apply_all(lambda s: s.delete(ids))
            live[ids] = False
        elif kind == "update":
            pool = np.flatnonzero(live)
            ids = rng.choice(pool, min(len(pool), 6), replace=False)
            rows = _table(rng, len(ids))
            apply_all(lambda s: s.update(ids, rows))
            live[ids] = False
            resident = {
                c: np.concatenate([v, rows[c]]) for c, v in resident.items()
            }
            live = np.concatenate([live, np.ones(len(ids), bool)])
        else:  # compact — epochs may move ONLY on rewritten stripes
            probe = systems[3]
            pre = [d.store.epoch for d in probe.devices]
            dirty = [sh.deleted_rows > 0 for sh in probe.store.shards]
            apply_all(lambda s: s.compact())
            post = [d.store.epoch for d in probe.devices]
            for was_dirty, a, b in zip(dirty, pre, post):
                assert (b > a) == was_dirty, (seed, n, policy, dirty)
            resident = {c: v[live] for c, v in resident.items()}
            live = np.ones(int(live.sum()), bool)
            assert systems["unsharded"].store.num_rows == len(live)
            assert systems[3].store.num_rows == len(live)

        # -- every system answers every query identically to the oracle
        preds = [_random_pred(rng) for _ in range(2)] + warm
        queries = (
            [Query(p) for p in preds[:2]]
            + [Query(p, agg=Agg.MASK) for p in preds]
            + [Query(preds[0], agg=_random_agg(rng))]
        )
        for name, sys in systems.items():
            got = sys.serve(queries)
            try:
                _check_live_round(queries, got, resident, live)
            except AssertionError as err:
                raise AssertionError(
                    f"{(seed, n, policy, name, round_i, kind)}: {err}"
                ) from err


@pytest.mark.parametrize("seed,n,policy", LIFECYCLE_CORPUS)
def test_lifecycle_differential_corpus(seed, n, policy):
    """Deterministic CRUD-stream corpus: always runs."""
    _run_lifecycle_differential(seed, n, policy)


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.sampled_from(ROW_COUNTS),
    policy=st.sampled_from(["roundrobin", "range"]),
)
def test_lifecycle_differential_property(seed, n, policy):
    """Property-style CRUD streams: hypothesis drives seeds when
    installed; the shim skips this (the corpus above still runs)."""
    _run_lifecycle_differential(seed, n, policy)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.sampled_from(ROW_COUNTS),
    policy=st.sampled_from(["roundrobin", "range"]),
)
def test_differential_property(seed, n, policy):
    """Property-style: hypothesis drives seeds when installed; the shim
    skips this (the corpus above still runs) when it is not."""
    _run_differential(seed, n, policy)


# ---------------------------------------------------------------------------
# optimizer stream: Zipf-skewed repeated predicates + appends/deletes
# ---------------------------------------------------------------------------

OPTIMIZER_CORPUS = [
    (41, 97, "roundrobin"),
    (42, 130, "range"),
    (43, 31, "range"),
]


def _hot_pool(rng):
    """A small predicate pool whose hottest member is guaranteed
    composite (multi-page after lowering), so a skewed stream crosses the
    materialization threshold on every seed."""
    return [qand(Range("age", 10, 60), In("device", [0, 1]))] + [
        _random_pred(rng) for _ in range(5)
    ]


def _run_optimizer_differential(seed: int, n: int, policy: str) -> None:
    """Zipf-skewed repeated-predicate stream under a LOW materialization
    threshold: hot predicates materialize mid-stream, and the interleaved
    appends/deletes drive their cached pages through the epoch guards —
    appends must invalidate (the cached bitmap would zero-miss new rows),
    deletes must not (the valid page composes at read time).  Every round
    is bit-exact vs the live-row numpy oracle on the unsharded scheduler,
    the lockstep fleet, and the pipelined fleet, all with CSE active."""
    rng = np.random.default_rng(seed)
    resident = _table(rng, n)
    live = np.ones(n, bool)
    reserve = n

    def build_unsharded():
        store = BitmapStore()
        store.ingest(dict(resident), reserve_rows=reserve)
        dev = FlashDevice(num_planes=2)
        store.program(dev)
        return BatchScheduler(dev, store, materialize_after=2)

    systems: dict[object, object] = {
        "unsharded": build_unsharded(),
        "lockstep": build_sharded_flashql(
            dict(resident), 3, policy=policy, num_planes=2,
            reserve_rows=reserve, materialize_after=2,
        ),
        "pipelined": build_sharded_flashql(
            dict(resident), 2, policy=policy, num_planes=2,
            reserve_rows=reserve, pipeline=True, materialize_after=2,
        ),
    }

    pool = _hot_pool(rng)
    for round_i in range(4):
        kind = (None, "append", "delete", "append")[round_i]
        if kind == "append":
            b = int(rng.integers(3, 8))
            batch = _table(rng, b)
            for sys in systems.values():
                sys.append(batch)
            resident = {
                c: np.concatenate([v, batch[c]]) for c, v in resident.items()
            }
            live = np.concatenate([live, np.ones(b, bool)])
        elif kind == "delete":
            rows = np.flatnonzero(live)
            ids = rng.choice(rows, min(len(rows) // 4, 15), replace=False)
            for sys in systems.values():
                sys.delete(ids)
            live[ids] = False
        # Zipf-skewed draw over the pool: rank 1 (by far the most likely)
        # maps to the composite hot predicate, so duplicates recur within
        # AND across flushes — exercising dedup, CSE, and materialization
        ranks = (rng.zipf(1.5, size=10).astype(int) - 1) % len(pool)
        preds = [pool[r] for r in ranks]
        queries = [Query(p) for p in preds] + [
            Query(pool[0], agg=Agg.MASK)
        ]
        for name, sys in systems.items():
            got = sys.serve(queries)
            try:
                _check_live_round(queries, got, resident, live)
            except AssertionError as err:
                raise AssertionError(
                    f"{(seed, n, policy, name, round_i, kind)}: {err}"
                ) from err

    # the stream is hot enough that every system materialized the hot
    # predicate, and both appends invalidated its cached page (the delete
    # round must NOT have: tombstones compose at read time)
    for name, sys in systems.items():
        comps = (
            [sys.compiler] if name == "unsharded" else list(sys.compilers)
        )
        builds = sum(c.mat_builds for c in comps)
        invals = sum(c.mat_invalidations for c in comps)
        assert builds >= 1, (seed, n, policy, name, builds)
        assert invals >= 1, (seed, n, policy, name, invals)
        assert sys.stats()["materializations"] == builds


@pytest.mark.parametrize("seed,n,policy", OPTIMIZER_CORPUS)
def test_optimizer_differential_corpus(seed, n, policy):
    """Deterministic skewed-stream corpus: always runs."""
    _run_optimizer_differential(seed, n, policy)


@settings(max_examples=3, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.sampled_from(ROW_COUNTS),
    policy=st.sampled_from(["roundrobin", "range"]),
)
def test_optimizer_differential_property(seed, n, policy):
    """Property-style skewed streams: hypothesis drives seeds when
    installed; the shim skips this (the corpus above still runs)."""
    _run_optimizer_differential(seed, n, policy)


def test_sharded_handles_rows_fewer_than_shards():
    """n < num_shards leaves range-policy shards empty; results must still
    be exact and the empty shard must not join execution."""
    table = {"c": np.array([1, 0])}
    sq = build_sharded_flashql(table, 3, policy="range", num_planes=1)
    assert len(sq.store.active) == 2
    r_count, r_mask = sq.serve(
        [Query(Eq("c", 1)), Query(Eq("c", 1), agg=Agg.MASK)]
    )
    assert r_count.count == 1
    np.testing.assert_array_equal(
        np.asarray(r_mask.mask.to_bits()), [1, 0]
    )


def test_roundrobin_mask_unstripes_row_order():
    """Round-robin striping permutes rows across shards; MASK gather must
    restore global row order exactly (row j lives on shard j % S)."""
    n = 10
    table = {"c": np.arange(n) % 3}
    sq = build_sharded_flashql(table, 3, policy="roundrobin", num_planes=1)
    (r,) = sq.serve([Query(Eq("c", 0), agg=Agg.MASK)])
    np.testing.assert_array_equal(
        np.asarray(r.mask.to_bits()).astype(bool), (np.arange(n) % 3) == 0
    )


# ---------------------------------------------------------------------------
# threshold stream: random k-of-N AtLeast predicates + appends/deletes
# ---------------------------------------------------------------------------

THRESHOLD_CORPUS = [
    (61, 97, "roundrobin"),
    (62, 130, "range"),
    (63, 31, "roundrobin"),
]


def _random_atleast(rng, depth=0):
    """Random k-of-N threshold predicate over mixed leaf/compound children.

    Deliberately includes the degenerate k values (1 => Or, N => And) so
    the canonicalization path is exercised alongside genuine thresholds,
    and nests thresholds under Not/And/Or (and inside each other one level
    deep) so every planner lowering — native ThresholdCommand, polarity
    inversion, chain expansion — gets hit by the stream.
    """
    n_kids = int(rng.integers(2, 6))
    kids = []
    for _ in range(n_kids):
        if depth < 1 and rng.integers(0, 4) == 0:
            kids.append(_random_atleast(rng, depth + 1))
        else:
            kids.append(_random_pred(rng, depth=2))
    k = int(rng.integers(1, len(kids) + 1))
    pred = AtLeast(k, kids)
    wrap = rng.integers(0, 4)
    if wrap == 0:
        return Not(pred)
    if wrap == 1:
        return qand(pred, _random_pred(rng, depth=2))
    if wrap == 2:
        return qor(pred, _random_pred(rng, depth=2))
    return pred


def _run_threshold_differential(seed: int, n: int, policy: str) -> None:
    """Interleaved append/delete/query stream of AtLeast predicates,
    bit-exact after every round vs the live-row numpy oracle — across the
    unsharded scheduler and shard counts {1, 2, 3}."""
    rng = np.random.default_rng(seed)
    resident = _table(rng, n)
    live = np.ones(n, bool)
    reserve = n

    def build_unsharded():
        store = BitmapStore()
        store.ingest(dict(resident), reserve_rows=reserve)
        dev = FlashDevice(num_planes=2)
        store.program(dev)
        return BatchScheduler(dev, store)

    systems: dict[object, object] = {
        "unsharded": build_unsharded(),
        **{
            s: build_sharded_flashql(
                dict(resident), s, policy=policy, num_planes=2,
                reserve_rows=reserve,
            )
            for s in SHARD_COUNTS
        },
    }

    warm = [_random_atleast(rng) for _ in range(2)]
    for round_i in range(4):
        kind = ("append", "delete", "append", "delete")[round_i]
        if kind == "append":
            b = int(rng.integers(3, 10))
            batch = _table(rng, b)
            for sys in systems.values():
                sys.append(batch)
            resident = {
                c: np.concatenate([v, batch[c]]) for c, v in resident.items()
            }
            live = np.concatenate([live, np.ones(b, bool)])
        else:
            pool = np.flatnonzero(live)
            ids = rng.choice(pool, min(len(pool) // 4, 20), replace=False)
            for sys in systems.values():
                sys.delete(ids)
            live[ids] = False

        preds = [_random_atleast(rng) for _ in range(3)] + warm
        queries = (
            [Query(p) for p in preds[:3]]
            + [Query(p, agg=Agg.MASK) for p in preds[3:]]
            + [Query(Majority([
                Eq("country", 1), Eq("device", 2), Range("age", 20, 60),
            ]))]
        )
        for name, sys in systems.items():
            got = sys.serve(queries)
            try:
                _check_live_round(queries, got, resident, live)
            except AssertionError as err:
                raise AssertionError(
                    f"{(seed, n, policy, name, round_i, kind)}: {err}"
                ) from err


@pytest.mark.parametrize("seed,n,policy", THRESHOLD_CORPUS)
def test_threshold_differential_corpus(seed, n, policy):
    """Deterministic k-of-N threshold stream corpus: always runs."""
    _run_threshold_differential(seed, n, policy)


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.sampled_from(ROW_COUNTS),
    policy=st.sampled_from(["roundrobin", "range"]),
)
def test_threshold_differential_property(seed, n, policy):
    """Property-style threshold streams: hypothesis drives seeds when
    installed; the shim skips this (the corpus above still runs)."""
    _run_threshold_differential(seed, n, policy)
