"""Differential test harness: sharded vs unsharded FlashQL vs oracles.

A seeded generator draws random ``Eq``/``In``/``Range``/``And``/``Or``/
``Not`` trees over mixed equality + BSI columns; every query executes on

* unsharded FlashQL (``BatchScheduler`` over one ``FlashDevice``),
* sharded FlashQL (``ShardedFlashQL``) for shard counts {1, 2, 3} under
  both stripe policies, including row counts that do not divide evenly,

and the results are checked **bit-exact** against the ``eval_expr`` oracle
on the logical bitmap pages and a plain-numpy oracle on the raw table.

Property-style execution goes through ``tests/_hypothesis_compat``: with
`hypothesis` installed, seeds/shapes are drawn adversarially; without it,
the deterministic ``CORPUS`` below keeps the same coverage running.
"""

import numpy as np
import pytest

from repro.core.engine import eval_expr
from repro.query import (
    Agg,
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    In,
    Not,
    Query,
    Range,
    build_sharded_flashql,
    lower,
)
from repro.query.ast import And, Or, and_ as qand, or_ as qor

from tests._hypothesis_compat import given, settings, st

SHARD_COUNTS = (1, 2, 3)
# ragged on purpose: 97 is prime (never divides), 130 straddles a word
# boundary (128 = 4 words), 31 is below one packed word
ROW_COUNTS = (97, 130, 31)

# deterministic fallback corpus: (seed, num_rows, policy)
CORPUS = [
    (11, 97, "roundrobin"),
    (12, 97, "range"),
    (13, 130, "roundrobin"),
    (14, 130, "range"),
    (15, 31, "roundrobin"),
    (16, 31, "range"),
]


def _table(rng, n):
    """Mixed-index table: low-cardinality equality columns + a BSI column."""
    return {
        "country": rng.integers(0, 6, n),
        "device": rng.integers(0, 4, n),
        "age": rng.integers(0, 90, n),
    }


def _random_pred(rng, depth=0):
    kind = rng.integers(0, 6 if depth < 2 else 4)
    if kind == 0:
        return Eq("country", int(rng.integers(0, 7)))  # 6 may be absent
    if kind == 1:
        return In(
            "device", [int(v) for v in rng.choice(5, rng.integers(1, 4))]
        )
    if kind == 2:
        lo = int(rng.integers(0, 70))
        return Range("age", lo, lo + int(rng.integers(0, 40)))
    if kind == 3:
        return Not(_random_pred(rng, depth + 1))
    children = [
        _random_pred(rng, depth + 1) for _ in range(rng.integers(2, 4))
    ]
    return qand(*children) if kind == 4 else qor(*children)


def _np_oracle(pred, table, n):
    if isinstance(pred, Eq):
        return table[pred.column] == pred.value
    if isinstance(pred, In):
        return np.isin(table[pred.column], pred.values)
    if isinstance(pred, Range):
        m = np.ones(n, bool)
        if pred.lo is not None:
            m &= table[pred.column] >= pred.lo
        if pred.hi is not None:
            m &= table[pred.column] <= pred.hi
        return m
    if isinstance(pred, Not):
        return ~_np_oracle(pred.child, table, n)
    if isinstance(pred, And):
        m = np.ones(n, bool)
        for c in pred.children:
            m &= _np_oracle(c, table, n)
        return m
    assert isinstance(pred, Or)
    m = np.zeros(n, bool)
    for c in pred.children:
        m |= _np_oracle(c, table, n)
    return m


def _run_differential(seed: int, n: int, policy: str) -> None:
    rng = np.random.default_rng(seed)
    table = _table(rng, n)
    preds = [_random_pred(rng) for _ in range(5)]
    queries = [Query(p) for p in preds] + [
        Query(p, agg=Agg.MASK) for p in preds
    ]

    # unsharded reference
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    ref = BatchScheduler(dev, store).serve(queries)

    sharded = {
        s: build_sharded_flashql(
            table, s, policy=policy, num_planes=2
        ).serve(queries)
        for s in SHARD_COUNTS
    }

    for i, q in enumerate(queries):
        want_bits = _np_oracle(q.where, table, n)
        # eval_expr oracle on the unsharded logical pages
        oracle_words = np.asarray(eval_expr(lower(q.where, store), store.logical))
        oracle_bits = np.asarray(
            np.unpackbits(
                oracle_words.view(np.uint8), bitorder="little"
            )[:n]
        ).astype(bool)
        np.testing.assert_array_equal(oracle_bits, want_bits)
        if q.agg is Agg.COUNT:
            want = int(want_bits.sum())
            assert ref[i].count == want
            for s in SHARD_COUNTS:
                assert sharded[s][i].count == want, (seed, n, policy, s, q)
        else:
            ref_bits = np.asarray(ref[i].mask.to_bits()).astype(bool)
            np.testing.assert_array_equal(ref_bits, want_bits)
            for s in SHARD_COUNTS:
                got = np.asarray(sharded[s][i].mask.to_bits()).astype(bool)
                np.testing.assert_array_equal(
                    got, want_bits, err_msg=f"{(seed, n, policy, s, q)}"
                )


@pytest.mark.parametrize("seed,n,policy", CORPUS)
def test_differential_corpus(seed, n, policy):
    """Deterministic corpus: always runs, with or without hypothesis."""
    _run_differential(seed, n, policy)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.sampled_from(ROW_COUNTS),
    policy=st.sampled_from(["roundrobin", "range"]),
)
def test_differential_property(seed, n, policy):
    """Property-style: hypothesis drives seeds when installed; the shim
    skips this (the corpus above still runs) when it is not."""
    _run_differential(seed, n, policy)


def test_sharded_handles_rows_fewer_than_shards():
    """n < num_shards leaves range-policy shards empty; results must still
    be exact and the empty shard must not join execution."""
    table = {"c": np.array([1, 0])}
    sq = build_sharded_flashql(table, 3, policy="range", num_planes=1)
    assert len(sq.store.active) == 2
    r_count, r_mask = sq.serve(
        [Query(Eq("c", 1)), Query(Eq("c", 1), agg=Agg.MASK)]
    )
    assert r_count.count == 1
    np.testing.assert_array_equal(
        np.asarray(r_mask.mask.to_bits()), [1, 0]
    )


def test_roundrobin_mask_unstripes_row_order():
    """Round-robin striping permutes rows across shards; MASK gather must
    restore global row order exactly (row j lives on shard j % S)."""
    n = 10
    table = {"c": np.arange(n) % 3}
    sq = build_sharded_flashql(table, 3, policy="roundrobin", num_planes=1)
    (r,) = sq.serve([Query(Eq("c", 0), agg=Agg.MASK)])
    np.testing.assert_array_equal(
        np.asarray(r.mask.to_bits()).astype(bool), (np.arange(n) % 3) == 0
    )
