"""Fused MWS-reduce+popcount kernel vs oracle (the one-pass BMI query)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.core.bitops import BitOp
from repro.kernels.mws_count import mws_count, mws_count_ref

ALL_OPS = list(BitOp)


def _stack(rng, n, w):
    return jnp.array(rng.integers(0, 2**32, (n, w), dtype=np.uint32))


@pytest.mark.parametrize("op", ALL_OPS, ids=[o.value for o in ALL_OPS])
@pytest.mark.parametrize("n,w", [(1, 1), (3, 200), (48, 2048), (70, 2049)])
def test_fused_count_matches_ref(op, n, w):
    rng = np.random.default_rng(n * 7 + w)
    x = _stack(rng, n, w)
    assert int(mws_count(x, op)) == int(mws_count_ref(x, op))


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 60),
    w=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
    op=st.sampled_from(ALL_OPS),
)
def test_fused_count_property(n, w, seed, op):
    rng = np.random.default_rng(seed)
    x = _stack(rng, n, w)
    assert int(mws_count(x, op)) == int(mws_count_ref(x, op))


def test_bmi_query_one_pass():
    """End-to-end: exact active-user count in one fused pass."""
    rng = np.random.default_rng(0)
    users, days = 65536, 48
    daily = (rng.random((days, users)) < 0.95).astype(np.uint8)
    from repro.core.bitops import pack_bits

    stack = jnp.stack([pack_bits(jnp.asarray(d)) for d in daily])
    got = int(mws_count(stack, BitOp.AND))
    want = int(daily.all(axis=0).sum())
    assert got == want
