"""Planner + engine: command plans must compute the right function with the
right number of sensing operations — including the paper's Fig. 16 example."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.core.commands import ISCM, MAX_INTER_BLOCKS, MWSCommand
from repro.core.engine import FlashArray, eval_expr
from repro.core.expr import Page, and_, nand_, nor_, not_, or_, xnor_, xor_
from repro.core.placement import auto_layout
from repro.core.planner import Planner

W = 16  # words per page in these tests


def _make_array(names, *, inverted=(), spread=(), seed=0):
    """FlashArray with pages placed per-group and random logical contents."""
    rng = np.random.default_rng(seed)
    arr = FlashArray()
    logical = {}
    plain = [n for n in names if n not in inverted and n not in spread]
    if plain:
        arr.layout.place_colocated(plain, inverted=False)
    if inverted:
        arr.layout.place_colocated(list(inverted), inverted=True)
    if spread:
        arr.layout.place_spread(list(spread))
    for n in names:
        words = jnp.array(rng.integers(0, 2**32, (W,), dtype=np.uint32))
        logical[n] = words
        arr.fc_write(n, words)
    return arr, logical


def _check(arr, logical, expr, expect_sensing=None):
    plan = Planner(arr.layout).compile(expr)
    got = arr.execute(plan)
    want = eval_expr(expr, logical)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if expect_sensing is not None:
        assert plan.num_sensing_ops == expect_sensing, plan
    return plan


# ---------------------------------------------------------------------------
# Flat multi-operand ops
# ---------------------------------------------------------------------------


def test_and_colocated_single_sensing():
    """48-operand AND in ONE sensing op — the paper's headline capability."""
    names = [f"a{i}" for i in range(48)]
    arr, logical = _make_array(names)
    _check(arr, logical, and_(*map(Page, names)), expect_sensing=1)


def test_or_demorgan_single_sensing():
    """48-operand OR via inverse-stored pages + inverse read: ONE sensing."""
    names = [f"a{i}" for i in range(48)]
    arr, logical = _make_array(names, inverted=tuple(names))
    plan = _check(arr, logical, or_(*map(Page, names)), expect_sensing=1)
    (cmd,) = [c for c in plan.commands if isinstance(c, MWSCommand)]
    assert cmd.iscm.inverse_read


def test_nand_nor_single_sensing():
    names = ["x", "y", "z"]
    arr, logical = _make_array(names)
    _check(arr, logical, nand_(*map(Page, names)), expect_sensing=1)
    arr2, logical2 = _make_array(names, inverted=tuple(names))
    _check(arr2, logical2, nor_(*map(Page, names)), expect_sensing=1)


def test_or_interblock_plain():
    """OR of plain pages in different blocks: inter-block MWS, ≤4 blocks per
    command (power budget) with C-latch accumulation beyond that."""
    names = [f"v{i}" for i in range(6)]
    arr, logical = _make_array(names, spread=tuple(names))
    plan = _check(arr, logical, or_(*map(Page, names)), expect_sensing=2)
    cmds = [c for c in plan.commands if isinstance(c, MWSCommand)]
    assert cmds[0].num_blocks == MAX_INTER_BLOCKS
    assert cmds[1].num_blocks == 2


def test_and_across_blocks_accumulates_in_s_latch():
    """AND spanning blocks: one intra-block MWS per block, S-accumulated
    (paper §6.1 'Increasing Maximum Number of Operands')."""
    names = [f"a{i}" for i in range(96)]  # 2 full blocks of 48
    arr, logical = _make_array(names)
    plan = _check(arr, logical, and_(*map(Page, names)), expect_sensing=2)
    cmds = [c for c in plan.commands if isinstance(c, MWSCommand)]
    assert cmds[0].iscm.init_s_latch and not cmds[1].iscm.init_s_latch


def test_xor_chain():
    names = ["p", "q", "r"]
    arr, logical = _make_array(names)
    _check(arr, logical, xor_(*map(Page, names)), expect_sensing=3)
    arr2, logical2 = _make_array(names)
    _check(arr2, logical2, xnor_(*map(Page, names)), expect_sensing=3)


def test_not_single_page():
    arr, logical = _make_array(["a"])
    _check(arr, logical, not_(Page("a")), expect_sensing=1)


# ---------------------------------------------------------------------------
# The paper's worked example (Fig. 16 / Eq. 4)
# ---------------------------------------------------------------------------


def test_fig16_eq4_example():
    """{A1 + (B1·B2·B3·B4)} · (C1+C3) · (D2+D4) with the paper's placement:
    A in Blk1, B in Blk2, C̄ in Blk3, D̄ in Blk4 — exactly TWO MWS commands,
    inverse-read command first, second command accumulating (no latch init).
    """
    rng = np.random.default_rng(42)
    arr = FlashArray()
    logical = {}
    for blk, (prefix, n, inv) in enumerate(
        [("A", 4, False), ("B", 4, False), ("C", 4, True), ("D", 4, True)]
    ):
        for wl in range(n):
            name = f"{prefix}{wl + 1}"
            arr.layout.place(name, blk, wl, inverted=inv)
    for name in list(arr.layout.placements):
        words = jnp.array(rng.integers(0, 2**32, (W,), dtype=np.uint32))
        logical[name] = words
        arr.fc_write(name, words)

    A1, C1, C3, D2, D4 = (Page(n) for n in ["A1", "C1", "C3", "D2", "D4"])
    Bs = and_(*(Page(f"B{i}") for i in range(1, 5)))
    expr = and_(or_(A1, Bs), or_(C1, C3), or_(D2, D4))

    plan = _check(arr, logical, expr, expect_sensing=2)
    cmds = [c for c in plan.commands if isinstance(c, MWSCommand)]
    # first command: inverse read over (C̄1,C̄3) and (D̄2,D̄4) = two blocks
    assert cmds[0].iscm.inverse_read and cmds[0].num_blocks == 2
    assert cmds[0].iscm.init_s_latch
    # second command: A1 + B-block string-AND, inter-block, accumulating
    assert not cmds[1].iscm.inverse_read and cmds[1].num_blocks == 2
    assert not cmds[1].iscm.init_s_latch  # accumulation (Fig. 16 note)


def test_eq1_or_of_string_ands_single_sensing():
    """Eq. 1: (A1·…·AN) + (B1·…·BN) in ONE inter-block sensing."""
    names = [f"A{i}" for i in range(4)] + [f"B{i}" for i in range(4)]
    arr = FlashArray()
    rng = np.random.default_rng(0)
    logical = {}
    for wl in range(4):
        arr.layout.place(f"A{wl}", 0, wl)
        arr.layout.place(f"B{wl}", 1, wl)
    for n in names:
        words = jnp.array(rng.integers(0, 2**32, (W,), dtype=np.uint32))
        logical[n] = words
        arr.fc_write(n, words)
    expr = or_(
        and_(*(Page(f"A{i}") for i in range(4))),
        and_(*(Page(f"B{i}") for i in range(4))),
    )
    _check(arr, logical, expr, expect_sensing=1)


def test_inverse_groups_distinct_blocks_merge_no_spill():
    """(c1+c2)·(d1+d2)·e1 with the OR groups in different blocks: the
    De Morgan merge folds both inverse units into ONE inter-block inverse
    command (Fig. 16 pattern) — no spill required."""
    arr = FlashArray()
    rng = np.random.default_rng(3)
    logical = {}
    arr.layout.place_colocated(["c1", "c2"], inverted=True)
    arr.layout.place_colocated(["d1", "d2"], inverted=True)
    arr.layout.place_colocated(["e1"], inverted=False)
    for n in ["c1", "c2", "d1", "d2", "e1"]:
        words = jnp.array(rng.integers(0, 2**32, (W,), dtype=np.uint32))
        logical[n] = words
        arr.fc_write(n, words)
    expr = and_(
        or_(Page("c1"), Page("c2")), or_(Page("d1"), Page("d2")), Page("e1")
    )
    plan = _check(arr, logical, expr, expect_sensing=2)
    assert plan.num_spills == 0


def test_same_block_inverse_groups_force_spill():
    """Two OR-groups co-located in the SAME block cannot be merged into one
    inverse sensing (their strings would AND together) — the planner must
    spill the extra group via an ESP-programmed scratch page."""
    arr = FlashArray()
    rng = np.random.default_rng(4)
    logical = {}
    for wl, n in enumerate(["c1", "c2", "c3", "c4"]):
        arr.layout.place(n, 0, wl, inverted=True)
    for n in ["c1", "c2", "c3", "c4"]:
        words = jnp.array(rng.integers(0, 2**32, (W,), dtype=np.uint32))
        logical[n] = words
        arr.fc_write(n, words)
    expr = and_(or_(Page("c1"), Page("c2")), or_(Page("c3"), Page("c4")))
    plan = _check(arr, logical, expr)
    assert plan.num_spills >= 1


# ---------------------------------------------------------------------------
# Properties: random expressions with auto-layout
# ---------------------------------------------------------------------------


@st.composite
def _expressions(draw, max_leaves=10):
    ops = draw(
        st.lists(
            st.sampled_from(["and", "or", "xor", "nand", "nor", "xnor"]),
            min_size=1,
            max_size=3,
        )
    )
    counter = [0]

    def leaf():
        counter[0] += 1
        return Page(f"p{counter[0]}")

    def build(depth):
        op = ops[depth % len(ops)]
        n = draw(st.integers(2, 4))
        children = []
        for _ in range(n):
            if depth + 1 < len(ops) and draw(st.booleans()):
                children.append(build(depth + 1))
            else:
                children.append(leaf())
        fn = {
            "and": and_,
            "or": or_,
            "xor": xor_,
            "nand": nand_,
            "nor": nor_,
            "xnor": xnor_,
        }[op]
        return fn(*children)

    return build(0)


@settings(max_examples=25, deadline=None)
@given(expr=_expressions(), seed=st.integers(0, 2**31 - 1))
def test_random_expressions_plan_correctly(expr, seed):
    from repro.core.expr import leaves

    rng = np.random.default_rng(seed)
    arr = FlashArray()
    arr.layout = auto_layout(expr)
    logical = {}
    for p in leaves(expr):
        if p.name in logical:
            continue
        words = jnp.array(rng.integers(0, 2**32, (W,), dtype=np.uint32))
        logical[p.name] = words
        arr.fc_write(p.name, words)
    plan = Planner(arr.layout).compile(expr)
    got = arr.execute(plan)
    want = eval_expr(expr, logical)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_iscm_ordering_rule_enforced():
    with pytest.raises(ValueError):
        ISCM(inverse_read=True, init_s_latch=False)


def test_esp_pages_read_error_free_nonesp_noisy():
    """ESP-programmed pages read back exactly; regular-programmed pages at
    high P/E cycles do not (the paper's reliability motivation)."""
    rng = np.random.default_rng(9)
    words = jnp.array(rng.integers(0, 2**32, (2048,), dtype=np.uint32))
    arr = FlashArray()
    arr.fc_write("good", words, esp=True)
    arr.fc_write("bad", words, esp=False)
    arr.pec[arr.layout["bad"].block] = 10_000
    assert (arr.fc_read(Page("good")) == words).all()
    noisy = arr.fc_read(Page("bad"))
    assert not bool((noisy == words).all())
