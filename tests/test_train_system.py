"""End-to-end system tests: training loop convergence, checkpoint/restart,
elastic re-mesh restore, straggler detection, bitmap-index data pipeline,
sign-compressed training."""

import os
import time

import jax
import jax.numpy as jnp
import jax.sharding
import numpy as np
import pytest

# The checkpoint/elastic-remesh tests exercise repro.launch.mesh, which
# needs jax.sharding.AxisType (jax >= 0.5); on older jax these are known
# seed failures, not regressions — skip the module so tier-1
# `pytest -x -q` completes instead of dying here.
pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType missing (jax too old for launch.mesh)",
)

from repro.configs import get_config
from repro.data.pipeline import BitmapIndex, SyntheticCorpus
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import StragglerWatchdog, Trainer, TrainerConfig


def _tiny_cfg():
    return get_config("granite-8b").reduced().with_(n_layers=2)


def _corpus(cfg, seq=16):
    return SyntheticCorpus(vocab=cfg.vocab, seq_len=seq, num_samples=512)


def test_training_loss_decreases():
    cfg = _tiny_cfg()
    tr = Trainer(cfg, TrainerConfig(opt=OptimizerConfig(lr=1e-2)))
    # overfit a single repeated batch: loss must drop markedly
    corpus = _corpus(cfg)
    batch = next(corpus.batches(4))
    hist = tr.train(iter(lambda: batch, None), num_steps=30, log_every=0)
    assert hist[-1] < hist[0] - 1.0, (hist[0], hist[-1])


def test_signsgd_compressed_training_decreases():
    cfg = _tiny_cfg()
    tr = Trainer(
        cfg,
        TrainerConfig(
            opt=OptimizerConfig(lr=1e-2, mode="signsgd", weight_decay=0.0),
            compress_grads="signsgd",
        ),
    )
    corpus = _corpus(cfg)
    batch = next(corpus.batches(4))
    hist = tr.train(iter(lambda: batch, None), num_steps=30, log_every=0)
    assert hist[-1] < hist[0] - 0.3, (hist[0], hist[-1])


def test_checkpoint_restart_resumes(tmp_path):
    cfg = _tiny_cfg()
    tcfg = TrainerConfig(
        opt=OptimizerConfig(lr=1e-3),
        ckpt_dir=str(tmp_path),
        ckpt_every=5,
        ckpt_async=False,
    )
    corpus = _corpus(cfg)
    batch = next(corpus.batches(4))
    tr = Trainer(cfg, tcfg)
    tr.train(iter(lambda: batch, None), num_steps=10, log_every=0)
    ref_params = jax.tree.leaves(tr.params)

    # simulate a node failure: brand-new trainer process restores
    tr2 = Trainer(cfg, tcfg)
    assert tr2.maybe_restore()
    assert tr2.step_num == 10
    for a, b in zip(ref_params, jax.tree.leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and continues training
    hist = tr2.train(iter(lambda: batch, None), num_steps=3, log_every=0)
    assert np.isfinite(hist[-1])


def test_checkpoint_atomicity_keeps_complete_only(tmp_path):
    cfg = _tiny_cfg()
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(8.0)}
    m.save(1, tree)
    m.save(2, tree)
    m.save(3, tree)
    assert m.steps() == [2, 3]  # keep=2, gc'd step_1
    # a stale staging dir must not be listed as a checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_9.tmp-abc"))
    assert 9 not in m.steps()


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint saved unsharded restores onto a 2×1 host mesh with the
    logical specs re-resolved (elastic re-mesh path)."""
    from repro.launch.mesh import make_host_mesh

    cfg = _tiny_cfg()
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_async=False)
    tr = Trainer(cfg, tcfg)
    tr.step_num = 7
    tr.save(block=True)

    mesh = make_host_mesh(data=1, model=1)  # 1-device "new cluster"
    tr2 = Trainer(cfg, tcfg, mesh=mesh)
    assert tr2.maybe_restore()
    assert tr2.step_num == 7
    for leaf in jax.tree.leaves(tr2.params):
        assert leaf.sharding is not None  # placed with resolved sharding


def test_straggler_watchdog_detects():
    events = []
    wd = StragglerWatchdog(
        factor=2.0, warmup=2, on_straggler=lambda s, dt, e: events.append(s)
    )
    for i in range(10):
        wd.observe(i, 0.1)
    wd.observe(10, 0.5)  # 5× the EWMA -> straggler
    assert events == [10]
    wd.observe(11, 0.1)  # recovery: no event
    assert events == [10]


def test_bitmap_index_filtering_correctness():
    idx = BitmapIndex.synthesize(1000, seed=3)
    sel = idx.eligible_indices(["lang_en", "quality_high", "not_toxic"])
    # oracle via unpacked numpy
    from repro.core.bitops import unpack_bits

    planes = np.stack(
        [
            np.asarray(unpack_bits(idx.planes[i], idx.num_samples))
            for i in range(len(idx.names))
        ]
    )
    want = np.nonzero(
        planes[idx.names.index("lang_en")]
        & planes[idx.names.index("quality_high")]
        & planes[idx.names.index("not_toxic")]
    )[0]
    np.testing.assert_array_equal(sel, want)
    assert idx.count(["lang_en"]) == int(
        planes[idx.names.index("lang_en")].sum()
    )


def test_pipeline_batches_are_filtered_and_deterministic():
    cfg = _tiny_cfg()
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=8, num_samples=256)
    b1 = next(corpus.batches(4))
    corpus2 = SyntheticCorpus(vocab=cfg.vocab, seq_len=8, num_samples=256)
    b2 = next(corpus2.batches(4))
    np.testing.assert_array_equal(
        np.asarray(b1["inputs"]["tokens"]), np.asarray(b2["inputs"]["tokens"])
    )
    assert b1["inputs"]["tokens"].shape == (4, 8)
