"""Aggregate pipeline tests: weighted-popcount identities, batching, and
admission-time validation.

Covers the :mod:`repro.query.aggregate` pluggable pipeline:

* property tests (via ``tests/_hypothesis_compat``) for the bit-slice
  arithmetic identities — SUM as Σ 2^b · popcount(mask ∧ slice_b) and the
  MIN/MAX slice walk — against plain numpy;
* the batching invariant: a flush mixing every aggregate kind dispatches
  exactly as many jit-of-vmap signature groups as the same flush with
  COUNT only (aggregation must not multiply vmap groups);
* submit-time validation on both schedulers (bad aggregate columns can
  never throw mid-flush and desync shard queues);
* empty selections, TOP-K tie-breaking, shard-routing pruning, and the
  absence of per-Agg ladders in the scheduler sources.
"""

import inspect

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.bitops import pack_bits
from repro.query import (
    Avg,
    BatchScheduler,
    BitmapStore,
    Count,
    Eq,
    FlashDevice,
    GroupBy,
    In,
    Mask,
    Max,
    Min,
    Query,
    Range,
    Sum,
    TopK,
    build_sharded_flashql,
)
from repro.query.aggregate import bsi_extreme, sliced_counts
from repro.query.ast import Not, and_ as qand

from tests._hypothesis_compat import given, settings, st

ALL_AGGS = (
    Count(),
    Mask(),
    Sum("sales"),
    Avg("sales"),
    Min("sales"),
    Max("sales"),
    TopK("device", 3),
    GroupBy("device"),
    GroupBy("device", Sum("sales")),
    GroupBy("device", Avg("sales")),
)


def _table(rng, n):
    return {
        "country": rng.integers(0, 6, n),
        "device": rng.integers(0, 4, n),
        "sales": rng.integers(0, 500, n),
    }


def _scheduler(table, planes=2):
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=planes)
    store.program(dev)
    return BatchScheduler(dev, store)


# -- weighted-popcount identities --------------------------------------------


def _pack_rows(bits_rows) -> jnp.ndarray:
    return jnp.stack(
        [pack_bits(jnp.asarray(r.astype(np.uint8))) for r in bits_rows]
    )


def _check_sum_identity(seed: int, n: int, bits: int) -> None:
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 1 << bits, n)
    sel = rng.integers(0, 2, n).astype(bool)
    mask = _pack_rows([sel])  # (1, W)
    slices = _pack_rows([(vals >> b) & 1 for b in range(bits)])[None]
    counts = np.asarray(sliced_counts(mask, slices, interpret=True))[0]
    got = sum(int(c) << b for b, c in enumerate(counts))
    assert got == int(vals[sel].sum())


def _check_extreme_identity(seed: int, n: int, bits: int) -> None:
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 1 << bits, n)
    sel = rng.integers(0, 2, n).astype(bool)
    mask = _pack_rows([sel])
    slices = _pack_rows([(vals >> b) & 1 for b in range(bits)])[None]
    for maximize in (False, True):
        dec, nonempty = bsi_extreme(mask, slices, maximize=maximize)
        dec, nonempty = np.asarray(dec)[0], bool(np.asarray(nonempty)[0])
        assert nonempty == bool(sel.any())
        if nonempty:
            got = sum(int(d) << b for b, d in enumerate(dec))
            want = int(vals[sel].max() if maximize else vals[sel].min())
            assert got == want, (seed, maximize, got, want)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_sum_weighted_popcount_identity_corpus(seed):
    _check_sum_identity(seed, n=97 + seed, bits=7)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_minmax_slice_walk_identity_corpus(seed):
    _check_extreme_identity(seed, n=97 + seed, bits=7)


@settings(max_examples=16, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.integers(min_value=1, max_value=200),
    bits=st.integers(min_value=1, max_value=10),
)
def test_sum_weighted_popcount_identity_property(seed, n, bits):
    _check_sum_identity(seed, n, bits)


@settings(max_examples=16, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n=st.integers(min_value=1, max_value=200),
    bits=st.integers(min_value=1, max_value=10),
)
def test_minmax_slice_walk_identity_property(seed, n, bits):
    _check_extreme_identity(seed, n, bits)


# -- batching: aggregation must not multiply vmap groups ---------------------


def test_mixed_aggregate_flush_keeps_count_only_vmap_groups():
    """One flush holding EVERY aggregate kind over the same predicate
    shapes must dispatch exactly the signature groups of the COUNT-only
    flush: aggregation rides on the predicate execution, it never forks
    the vmap batch."""
    rng = np.random.default_rng(7)
    table = _table(rng, 513)
    preds = [qand(Eq("country", c), Eq("device", c % 4)) for c in range(4)]

    base = _scheduler(table)
    base.serve([Query(p) for p in preds])
    count_only_groups = base.device.last_signature_groups
    assert count_only_groups >= 1

    mixed = _scheduler(table)
    queries = [Query(p, agg=a) for p in preds for a in ALL_AGGS]
    results = mixed.serve(queries)
    assert mixed.device.last_signature_groups == count_only_groups
    # plan cache must not fork per aggregate either
    assert mixed.compiler.misses == base.compiler.misses

    # spot-check values against numpy while we're here
    for q, r in zip(queries, results):
        sel = np.ones(513, bool)
        for leaf in q.where.children:
            sel &= table[leaf.column] == leaf.value
        if isinstance(q.agg, Count):
            assert r.value == int(sel.sum())
        elif isinstance(q.agg, Sum):
            assert r.value == int(table["sales"][sel].sum())


def test_sharded_mixed_aggregates_keep_vmap_groups():
    rng = np.random.default_rng(8)
    table = _table(rng, 257)
    preds = [Query(Eq("country", c)) for c in range(3)]
    base = build_sharded_flashql(table, 3, num_planes=2)
    base.serve(preds)
    g0 = base.stats()["vmap_batches"]

    mixed = build_sharded_flashql(table, 3, num_planes=2)
    mixed.serve(
        [Query(Eq("country", c), agg=a) for c in range(3) for a in ALL_AGGS]
    )
    assert mixed.stats()["vmap_batches"] == g0


# -- admission-time validation ----------------------------------------------


def test_bad_aggregate_rejected_at_submit_both_schedulers():
    rng = np.random.default_rng(9)
    table = _table(rng, 100)
    sched = _scheduler(table)
    sq = build_sharded_flashql(table, 2, num_planes=2)
    for bad in (
        Sum("nope"),
        Avg("nope"),
        Min("nope"),
        TopK("nope", 2),
        GroupBy("nope"),
        GroupBy("device", Sum("nope")),
    ):
        with pytest.raises(KeyError, match="nope"):
            sched.submit(Query(Eq("country", 1), agg=bad))
        with pytest.raises(KeyError, match="nope"):
            sq.submit(Query(Eq("country", 1), agg=bad))
    with pytest.raises(ValueError, match="k >= 1"):
        sched.submit(Query(Eq("country", 1), agg=TopK("device", 0)))
    with pytest.raises(TypeError, match="Count/Sum/Avg"):
        sq.submit(Query(Eq("country", 1), agg=GroupBy("device", Mask())))
    # unknown predicate columns are caught at submit too (symmetric with
    # the sharded scheduler since PR 2)
    with pytest.raises(KeyError, match="ghost"):
        sched.submit(Query(Eq("ghost", 1)))
    # nothing was admitted, queues are in lockstep, serving still works
    assert sched.pending == 0 and sq.pending == 0
    (r,) = sq.serve([Query(Eq("country", 1), agg=Sum("sales"))])
    sel = table["country"] == 1
    assert r.value == int(table["sales"][sel].sum())


# -- semantics edge cases ----------------------------------------------------


def test_empty_selection_aggregates():
    """MIN/MAX/AVG of an empty selection are None; TOP-K/GROUP BY empty."""
    rng = np.random.default_rng(10)
    table = _table(rng, 64)
    # contradiction: executes (not prunable — Not is never pruned) but
    # selects nothing
    empty = qand(Eq("country", 1), Not(Eq("country", 1)))
    sched = _scheduler(table)
    sq = build_sharded_flashql(table, 2, num_planes=2)
    for serve in (sched.serve, sq.serve):
        rs = serve(
            [
                Query(empty, agg=a)
                for a in (
                    Count(),
                    Sum("sales"),
                    Avg("sales"),
                    Min("sales"),
                    Max("sales"),
                    TopK("device", 2),
                    GroupBy("device"),
                )
            ]
        )
        assert [r.value for r in rs] == [0, 0, None, None, None, (), {}]


def test_topk_tie_break_deterministic_across_shards():
    """Equal counts rank by smaller value — identically for unsharded,
    sharded, and merged-after-routing results."""
    table = {
        "device": np.array([0, 1, 2, 3] * 8),  # all counts equal (8)
        "sales": np.arange(32),
    }
    want = ((0, 8), (1, 8), (2, 8))
    (r,) = _scheduler(table).serve(
        [Query(In("device", [0, 1, 2, 3]), agg=TopK("device", 3))]
    )
    assert r.value == want
    for shards in (2, 3):
        (r,) = build_sharded_flashql(table, shards, num_planes=2).serve(
            [Query(In("device", [0, 1, 2, 3]), agg=TopK("device", 3))]
        )
        assert r.value == want


# -- shard routing -----------------------------------------------------------


def test_range_stripe_routing_prunes_shards():
    rng = np.random.default_rng(11)
    n = 400
    table = {"uid": rng.integers(0, 1000, n), "sales": rng.integers(0, 50, n)}
    sq = build_sharded_flashql(
        table, 4, policy="range", stripe_key="uid", num_planes=2
    )
    lo, hi = 0, 99  # first decile: lives on one stripe of the sorted key
    (r,) = sq.serve([Query(Range("uid", lo, hi), agg=Sum("sales"))])
    sel = (table["uid"] >= lo) & (table["uid"] <= hi)
    assert r.value == int(table["sales"][sel].sum())
    assert sq.stats()["shards_pruned"] >= 2  # most stripes cannot match

    # a fully-pruned query (key outside every stripe) completes without
    # touching any device queue
    before = sq.stats()["mws_commands"]
    rs = sq.serve(
        [
            Query(Eq("uid", 10**6), agg=Count()),
            Query(Eq("uid", 10**6), agg=Mask()),
            Query(Eq("uid", 10**6), agg=Min("sales")),
        ]
    )
    assert rs[0].value == 0
    assert int(np.asarray(rs[1].value.to_bits()).sum()) == 0
    assert rs[2].value is None
    assert sq.stats()["mws_commands"] == before  # nothing was sensed


def test_stripe_key_mask_unstripes_sorted_rows():
    """stripe_key striping permutes rows across shards by key order; MASK
    results must come back in global (ingest) row order."""
    rng = np.random.default_rng(12)
    n = 130
    table = {"uid": rng.permutation(n), "sales": rng.integers(0, 9, n)}
    sq = build_sharded_flashql(
        table, 3, policy="range", stripe_key="uid", num_planes=2
    )
    (r,) = sq.serve([Query(Range("uid", 10, 40), agg=Mask())])
    want = (table["uid"] >= 10) & (table["uid"] <= 40)
    np.testing.assert_array_equal(
        np.asarray(r.value.to_bits()).astype(bool), want
    )


# -- aggregate traffic reaches the SSD projection ----------------------------


def test_aggregate_slice_reads_counted_in_projection():
    from repro.query.scheduler import AGG_READ_SHAPE

    rng = np.random.default_rng(13)
    table = _table(rng, 100)
    # the predicate plan itself senses single-wordline commands that land
    # in the same shape bucket, so compare against a COUNT-only baseline
    base = _scheduler(table)
    base.serve([Query(Eq("country", 1))])
    sched = _scheduler(table)
    sched.serve([Query(Eq("country", 1), agg=Sum("sales"))])
    bits = sched.store.columns["sales"].bits
    extra = (
        sched.command_shape_counts[AGG_READ_SHAPE]
        - base.command_shape_counts[AGG_READ_SHAPE]
    )
    assert extra == bits
    assert (
        sched.wordlines_sensed - base.wordlines_sensed == bits
    )
    proj = sched.projection()  # host postprocess flagged, model runs
    assert proj["fc_time_s"] > 0


# -- the ladders are gone ----------------------------------------------------


def test_no_per_agg_ladders_in_schedulers():
    """The acceptance criterion of the aggregate-pipeline refactor: no
    per-Agg special cases survive in either scheduler — everything flows
    through the Aggregator interface."""
    import repro.query.scheduler as scheduler_mod
    import repro.query.shard as shard_mod

    for mod in (scheduler_mod, shard_mod):
        src = inspect.getsource(mod)
        assert "Agg.COUNT" not in src and "Agg.MASK" not in src, mod
