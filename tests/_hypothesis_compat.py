"""Optional-`hypothesis` shim.

The seed suite hard-errored at collection when `hypothesis` was missing
(seven modules import it at top level), which killed `pytest -x -q`
entirely.  Import `given`/`settings`/`st` from here instead: with
hypothesis installed (CI does: see pyproject.toml) the real library is
used; without it, property-based tests are skipped at collection while
every example-based test in the same module still runs.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools

    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert stand-in: every attribute/call yields another strategy."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

        @staticmethod
        def composite(fn):
            return _Strategy()

    st = _Strategies()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def skipper(*a, **k):  # pragma: no cover
                pass

            return pytest.mark.skip(reason="hypothesis not installed")(
                skipper
            )

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
