"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement),
plus decode-vs-full-forward consistency for every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import demo_batch
from repro.models.registry import get_model
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.steps import make_train_step

B, S = 2, 16


def _setup(arch_id):
    cfg = get_config(arch_id).reduced()
    model = get_model(cfg)
    params, specs = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = demo_batch(cfg, B, S)
    return cfg, model, params, specs, batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_no_nan(arch_id):
    cfg, model, params, _, batch = _setup(arch_id)
    logits = model.forward(cfg, params, **batch["inputs"])
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_decreases_nothing_nan(arch_id):
    cfg, model, params, _, batch = _setup(arch_id)
    opt_cfg = OptimizerConfig(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    opt_state = init_opt_state(params, opt_cfg)
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert changed
    # a second step keeps the loss finite and (almost always) lower
    _, _, m2 = step(params2, opt_state, batch)
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(metrics["loss"]) + 0.5


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_matches_full_forward(arch_id):
    cfg, model, params, _, batch = _setup(arch_id)
    inputs = dict(batch["inputs"])
    tokens = inputs.pop("tokens")
    n_ctx = S + getattr(cfg, "num_patch_tokens", 0)  # absolute cache offset
    logits_full = model.forward(cfg, params, tokens, **inputs)
    pl, cache = model.prefill(
        cfg, params, tokens, **inputs, max_len=n_ctx + 4
    )
    np.testing.assert_allclose(
        np.asarray(pl[:, -1]),
        np.asarray(logits_full[:, -1]),
        rtol=3e-3,
        atol=3e-3,
    )
    nxt = jnp.argmax(pl[:, -1:], -1).astype(tokens.dtype)
    dl, _ = model.decode_step(cfg, params, cache, nxt, jnp.int32(n_ctx))
    full2 = model.forward(
        cfg, params, jnp.concatenate([tokens, nxt], axis=1), **inputs
    )
    np.testing.assert_allclose(
        np.asarray(dl[:, -1]),
        np.asarray(full2[:, -1]),
        rtol=3e-3,
        atol=3e-3,
    )


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """The full configs carry the exact assigned numbers."""
    table = {
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }
    cfg = get_config(arch_id)
    L, d, h, kv, ff, v = table[arch_id]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab == v


def test_moe_assignment_numbers():
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.moe.num_experts == 64 and ds.moe.top_k == 6
    assert ds.moe.num_shared == 2 and ds.mla.kv_lora_rank == 512
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.moe.num_experts == 384 and kimi.moe.top_k == 8


def test_param_counts_in_expected_range():
    """Sanity: full configs have roughly the advertised parameter counts."""
    import math

    def count(cfg):
        model = get_model(cfg)
        shapes = jax.eval_shape(
            lambda k: model.init_params(cfg, k)[0], jax.random.PRNGKey(0)
        )
        return sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))

    # name -> (min, max) in billions
    bands = {
        "xlstm-350m": (0.2, 0.6),
        "starcoder2-3b": (2.5, 3.8),
        "yi-34b": (30, 38),
        "granite-8b": (7, 9.5),
        "command-r-plus-104b": (95, 115),
        "deepseek-v2-lite-16b": (12, 18),
        "kimi-k2-1t-a32b": (900, 1150),
        "internvl2-26b": (19, 27),  # LLM backbone (ViT stubbed)
        "recurrentgemma-2b": (2, 3.4),
    }
    for name, (lo, hi) in bands.items():
        c = count(get_config(name)) / 1e9
        assert lo <= c <= hi, f"{name}: {c:.2f}B outside [{lo},{hi}]"
