"""Per-kernel correctness: MWS fused reduce vs pure-jnp oracle.

Sweeps shapes/dtypes and asserts bit-exact equality (interpret=True executes
the kernel body on CPU; the BlockSpec tiling logic is exercised for real).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.core.bitops import BitOp, pack_bits, reduce_words, unpack_bits
from repro.kernels.mws import mws_reduce, mws_reduce_ref, parabit_reduce

ALL_OPS = list(BitOp)


def _rand_stack(rng, n, w, dtype):
    hi = int(jnp.iinfo(dtype).max)
    return jnp.array(
        rng.integers(0, hi, (n, w), dtype=np.uint64).astype(dtype)
    )


@pytest.mark.parametrize("op", ALL_OPS, ids=[o.value for o in ALL_OPS])
@pytest.mark.parametrize(
    "n,w",
    [
        (1, 1),
        (2, 128),
        (3, 200),
        (48, 2048),  # the paper's intra-block maximum (48 WLs/string)
        (64, 4096),  # one full fan-in block
        (65, 2049),  # operand + word padding paths
        (200, 300),  # multi-operand-block accumulation
    ],
)
def test_mws_matches_ref(op, n, w):
    rng = np.random.default_rng(n * 1000 + w)
    x = _rand_stack(rng, n, w, jnp.uint32)
    got = mws_reduce(x, op)
    want = mws_reduce_ref(x, op)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.uint32, jnp.int32])
@pytest.mark.parametrize("op", [BitOp.AND, BitOp.OR, BitOp.XOR, BitOp.NAND])
def test_mws_dtypes(dtype, op):
    rng = np.random.default_rng(7)
    x = _rand_stack(rng, 17, 513, dtype)
    np.testing.assert_array_equal(
        np.asarray(mws_reduce(x, op)), np.asarray(mws_reduce_ref(x, op))
    )


@pytest.mark.parametrize("op", ALL_OPS, ids=[o.value for o in ALL_OPS])
def test_parabit_matches_mws(op):
    """The serial baseline and the fused kernel must agree (paper: ParaBit
    and Flash-Cosmos compute the same function; FC is just one sensing)."""
    rng = np.random.default_rng(3)
    x = _rand_stack(rng, 31, 777, jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(parabit_reduce(x, op)), np.asarray(mws_reduce(x, op))
    )


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 80),
    w=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
    op=st.sampled_from(ALL_OPS),
)
def test_mws_property_matches_ref(n, w, seed, op):
    rng = np.random.default_rng(seed)
    x = _rand_stack(rng, n, w, jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(mws_reduce(x, op)), np.asarray(mws_reduce_ref(x, op))
    )


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 40), w=st.integers(1, 100), seed=st.integers(0, 2**31 - 1))
def test_de_morgan(n, w, seed):
    """(A1 + ... + An) == NOT(NOT A1 · ... · NOT An) — the paper's §6.1 trick
    for OR-inside-a-block via inverse-stored operands + NAND."""
    rng = np.random.default_rng(seed)
    x = _rand_stack(rng, n, w, jnp.uint32)
    or_direct = mws_reduce(x, BitOp.OR)
    nand_of_inverse = mws_reduce(~x, BitOp.NAND)
    np.testing.assert_array_equal(
        np.asarray(or_direct), np.asarray(nand_of_inverse)
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 30),
    split=st.integers(1, 29),
    w=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
    op=st.sampled_from([BitOp.AND, BitOp.OR, BitOp.XOR]),
)
def test_accumulation_associativity(n, split, w, seed, op):
    """Splitting an MWS into two commands + latch accumulation is lossless
    (paper §6.1: accumulate results of multiple intra-block MWS ops)."""
    split = min(split, n - 1)
    rng = np.random.default_rng(seed)
    x = _rand_stack(rng, n, w, jnp.uint32)
    whole = mws_reduce(x, op)
    parts = jnp.stack([mws_reduce(x[:split], op), mws_reduce(x[split:], op)])
    np.testing.assert_array_equal(
        np.asarray(mws_reduce(parts, op)), np.asarray(whole)
    )


@settings(max_examples=20, deadline=None)
@given(bits=st.lists(st.integers(0, 1), min_size=1, max_size=200))
def test_pack_unpack_roundtrip(bits):
    b = jnp.array(bits, dtype=jnp.uint8)
    words = pack_bits(b)
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(words, len(bits))), np.asarray(b)
    )


def test_reduce_words_matches_kernel_ref():
    rng = np.random.default_rng(11)
    x = _rand_stack(rng, 9, 40, jnp.uint32)
    for op in ALL_OPS:
        np.testing.assert_array_equal(
            np.asarray(reduce_words(x, op)), np.asarray(mws_reduce_ref(x, op))
        )
