"""Distributed correctness on a multi-device host mesh.

These tests need >1 device, so they re-exec a small script in a subprocess
with ``--xla_force_host_platform_device_count=8`` — the main test process
keeps seeing 1 device (required: dry-run only gets 512 devices).
"""

import os
import subprocess
import sys
import textwrap

import jax.sharding
import pytest

# repro.launch.mesh needs jax.sharding.AxisType (jax >= 0.5); on older jax
# these are known seed failures, not regressions — skip the module so
# tier-1 `pytest -x -q` runs the rest of the suite instead of dying here.
pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType missing (jax too old for launch.mesh)",
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(snippet)],
        capture_output=True,
        text=True,
        env=env,
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """Same batch + params: loss on a 2×4 mesh == loss on 1 device."""
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed.sharding import active_mesh, shardings_tree
        from repro.launch.mesh import make_host_mesh
        from repro.launch.specs import demo_batch
        from repro.models.registry import get_model
        from repro.train.steps import make_loss_fn

        cfg = get_config('granite-8b').reduced().with_(n_layers=2, n_heads=4)
        model = get_model(cfg)
        params, specs = model.init_params(cfg, jax.random.PRNGKey(0))
        batch = demo_batch(cfg, 4, 16)
        loss_fn = make_loss_fn(cfg)
        ref = float(jax.jit(loss_fn)(params, batch))

        mesh = make_host_mesh(data=2, model=4)
        with active_mesh(mesh):
            sh = shardings_tree(specs, mesh)
            params_sh = jax.tree.map(jax.device_put, params, sh)
            got = float(jax.jit(loss_fn)(params_sh, batch))
        print('REF', ref, 'GOT', got)
        assert abs(ref - got) < 1e-4, (ref, got)
        """
    )
    assert "REF" in out


def test_grad_allreduce_consistency():
    """Gradients computed with FSDP-sharded params match unsharded grads."""
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed.sharding import active_mesh, shardings_tree
        from repro.launch.mesh import make_host_mesh
        from repro.launch.specs import demo_batch
        from repro.models.registry import get_model
        from repro.train.steps import make_loss_fn

        cfg = get_config('starcoder2-3b').reduced().with_(n_layers=2)
        model = get_model(cfg)
        params, specs = model.init_params(cfg, jax.random.PRNGKey(1))
        batch = demo_batch(cfg, 4, 8)
        gfn = jax.jit(jax.grad(make_loss_fn(cfg)))
        ref = gfn(params, batch)

        mesh = make_host_mesh(data=4, model=2)
        with active_mesh(mesh):
            sh = shardings_tree(specs, mesh)
            params_sh = jax.tree.map(jax.device_put, params, sh)
            got = gfn(params_sh, batch)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
        print('GRADS-MATCH')
        """
    )


def test_majority_vote_across_mesh_replicas():
    """The packed-majority gradient vote is replica-consistent: packing on
    shards then voting equals voting on the gathered planes."""
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels.signcomp import (
            compress_signs, decompress_signs, majority_vote)
        rng = np.random.default_rng(0)
        k, n = 8, 65536
        grads = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        packed = jnp.stack([compress_signs(grads[i]) for i in range(k)])
        maj = decompress_signs(majority_vote(packed), n)
        votes = np.where(np.asarray(grads) >= 0, 1, -1).sum(0)
        np.testing.assert_array_equal(
            np.asarray(maj), np.where(votes >= 0, 1.0, -1.0))
        print('VOTE-OK')
        """
    )


def test_dryrun_single_cell_small_mesh():
    """The dry-run machinery itself works on an 8-device host (2x4 mesh),
    exercising build_cell + sanitized shardings end to end."""
    _run(
        """
        import jax
        from repro.configs import get_config
        from repro.distributed.sharding import active_mesh
        from repro.launch.dryrun import build_cell
        from repro.models.config import ShapeConfig
        from jax.sharding import AxisType

        cfg = get_config('granite-8b').reduced().with_(n_layers=2)
        shape = ShapeConfig('tiny_train', 64, 8, 'train')
        mesh = jax.make_mesh((2, 4), ('data', 'model'),
                             axis_types=(AxisType.Auto,)*2)
        with active_mesh(mesh):
            step, args, sh = build_cell(cfg, shape, mesh)
            compiled = jax.jit(step, in_shardings=sh).lower(*args).compile()
            assert compiled.memory_analysis() is not None
        print('DRYRUN-8DEV-OK')
        """
    )
