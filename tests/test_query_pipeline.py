"""One-dispatch flush invariants: fused programs, single host transfers,
async shard pipelining, routed queue depths, and append coalescing.

The contract of the pipelined serving stack, asserted piece by piece:

* a mixed-kind flush on ``BatchScheduler`` performs EXACTLY ONE host
  transfer (``jax.device_get`` counted by monkeypatch, mirroring the PR-3
  vmap-group assertion) and at most one fused dispatch per flush
  signature — recurring compositions reuse one jitted program;
* spilling (deep-range) plans join the fused flush instead of running
  eagerly, and their scratch stays device-resident;
* the asynchronous sharded flush matches the lockstep path bit-exactly,
  spends one transfer per shard program, and preserves submission order;
* routing-aware queue depths let range-pruned shards donate their slots,
  draining a hot stripe in one flush;
* coalesced appends program one delta per touched page for a whole queue
  of small batches, with the tickets-in-flight refusal intact.
"""

import numpy as np
import pytest

import jax

from repro.query import (
    Avg,
    BatchScheduler,
    BitmapStore,
    Count,
    Eq,
    FlashDevice,
    GroupBy,
    In,
    Mask,
    Max,
    Min,
    Query,
    Range,
    Sum,
    TopK,
    build_sharded_flashql,
)
from repro.query.ast import and_ as qand
from repro.query.oracle import np_select as _np_select

ALL_AGGS = (
    Count(),
    Mask(),
    Sum("sales"),
    Avg("sales"),
    Min("sales"),
    Max("sales"),
    TopK("device", 3),
    GroupBy("device", Sum("sales")),
)


def _table(rng, n):
    return {
        "country": rng.integers(0, 6, n),
        "device": rng.integers(0, 4, n),
        "sales": rng.integers(0, 500, n),
    }


def _scheduler(table, planes=2, **kw):
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=planes)
    store.program(dev)
    return BatchScheduler(dev, store, **kw)


def _mixed_queries(include_spill=True):
    preds = [
        Eq("country", 1),
        qand(Eq("country", 2), Eq("device", 1)),
        In("device", [0, 2]),
    ]
    if include_spill:
        preds.append(Range("sales", 13, 437))  # deep range: spills
    return [Query(p, agg=a) for p in preds for a in ALL_AGGS]




class _TransferCounter:
    """Counts real ``jax.device_get`` calls inside a with-block."""

    def __init__(self, monkeypatch):
        self.calls = 0
        real = jax.device_get

        def counted(x):
            self.calls += 1
            return real(x)

        monkeypatch.setattr(jax, "device_get", counted)


# ---------------------------------------------------------------------------
# one transfer, one dispatch per flush signature
# ---------------------------------------------------------------------------


def test_mixed_flush_is_one_transfer_one_dispatch(monkeypatch):
    """A flush mixing EVERY aggregate kind (and a spilling range) costs
    exactly one device_get and one fused program execution."""
    rng = np.random.default_rng(0)
    n = 700
    table = _table(rng, n)
    sched = _scheduler(table)
    queries = _mixed_queries()
    for q in queries:
        sched.submit(q)
    counter = _TransferCounter(monkeypatch)
    results = sched.flush()
    assert counter.calls == 1, "fused flush must device_get exactly once"
    assert sched.host_transfers == 1
    assert sched.fused_dispatches == 1
    assert sched.flushes == 1
    assert len(results) == len(queries)
    # spot-check against numpy while the results are here
    by_ticket = [results[t] for t in sorted(results)]
    for q, r in zip(queries, by_ticket):
        sel = _np_select(q.where, table, n)
        if isinstance(q.agg, Count):
            assert r.value == int(sel.sum())
        elif isinstance(q.agg, Sum):
            assert r.value == int(table["sales"][sel].sum())
        elif isinstance(q.agg, Mask):
            np.testing.assert_array_equal(
                np.asarray(r.value.to_bits()).astype(bool), sel
            )


def test_flush_signature_programs_are_reused():
    """Recurring flush compositions reuse ONE jitted program: the runner
    cache holds a single entry however many times the flush repeats (<=1
    fused dispatch per flush signature)."""
    rng = np.random.default_rng(1)
    sched = _scheduler(_table(rng, 300))
    queries = _mixed_queries()
    sched.serve(queries)
    programs = len(sched._flush_programs)
    runners = len(sched._runner_cache)
    assert programs == 1 and runners == 1
    for _ in range(3):
        sched.serve(queries)
    assert len(sched._flush_programs) == 1
    assert len(sched._runner_cache) == 1
    assert sched.fused_dispatches == sched.flushes == 4
    assert sched.host_transfers == 4  # still exactly one per flush


def test_legacy_path_matches_fused():
    """fuse_flush=False (the per-reduce-group oracle) returns identical
    values and strictly more host transfers."""
    rng = np.random.default_rng(2)
    table = _table(rng, 513)
    queries = _mixed_queries()
    fused = _scheduler(table)
    legacy = _scheduler(table, fuse_flush=False)
    a = fused.serve(queries)
    b = legacy.serve(queries)
    for x, y in zip(a, b):
        if isinstance(x.query.agg, Mask):
            np.testing.assert_array_equal(
                np.asarray(x.value.words), np.asarray(y.value.words)
            )
        else:
            assert x.value == y.value, x.query
    assert fused.host_transfers == 1
    assert legacy.host_transfers > 1  # one per reduce signature


def test_same_predicate_different_aggregates_across_flushes():
    """Flush programs must key on the aggregates too: plan-cache keys
    cover only the predicate, so Min then Max (or Count then Sum) over
    the SAME predicate in separate flushes must not reuse each other's
    compiled program (regression: the cached Min program silently
    answered the Max query)."""
    rng = np.random.default_rng(10)
    n = 300
    table = _table(rng, n)
    sel = table["country"] == 1
    sched = _scheduler(table)
    (r_min,) = sched.serve([Query(Eq("country", 1), agg=Min("sales"))])
    (r_max,) = sched.serve([Query(Eq("country", 1), agg=Max("sales"))])
    (r_cnt,) = sched.serve([Query(Eq("country", 1), agg=Count())])
    (r_sum,) = sched.serve([Query(Eq("country", 1), agg=Sum("sales"))])
    assert r_min.value == int(table["sales"][sel].min())
    assert r_max.value == int(table["sales"][sel].max())
    assert r_cnt.value == int(sel.sum())
    assert r_sum.value == int(table["sales"][sel].sum())
    # pipelined sharded path keys per-shard programs the same way
    sq = build_sharded_flashql(table, 2, num_planes=2, pipeline=True)
    (r_min,) = sq.serve([Query(Eq("country", 1), agg=Min("sales"))])
    (r_max,) = sq.serve([Query(Eq("country", 1), agg=Max("sales"))])
    assert r_min.value == int(table["sales"][sel].min())
    assert r_max.value == int(table["sales"][sel].max())


# ---------------------------------------------------------------------------
# async sharded flushing
# ---------------------------------------------------------------------------


def test_pipelined_sharded_matches_lockstep_one_transfer_per_shard():
    rng = np.random.default_rng(3)
    n = 1003
    table = _table(rng, n)
    queries = _mixed_queries()
    lock = build_sharded_flashql(table, 3, num_planes=2)
    pipe = build_sharded_flashql(table, 3, num_planes=2, pipeline=True)
    a = lock.serve(queries)
    b = pipe.serve(queries)
    # submission order preserved on both paths
    assert [r.query for r in b] == queries
    for x, y in zip(a, b):
        if isinstance(x.query.agg, Mask):
            np.testing.assert_array_equal(
                np.asarray(x.value.words), np.asarray(y.value.words)
            )
        else:
            assert x.value == y.value, x.query
    s = pipe.stats()
    assert s["pipelined_flushes"] == s["flushes"]
    # one fused program and one payload transfer per shard per flush
    active = len(pipe.store.active)
    assert s["fused_dispatches"] == s["flushes"] * active
    assert s["host_transfers"] == s["flushes"] * active
    # the lockstep oracle spends one transfer per reduce signature instead
    assert lock.stats()["host_transfers"] > lock.stats()["flushes"]


def test_pipelined_non_esp_shard_falls_back_per_group():
    """A shard device holding a non-ESP page must leave the fused path
    (it never injects read errors) and still serve exact results."""
    import jax.numpy as jnp

    rng = np.random.default_rng(4)
    n = 200
    table = _table(rng, n)
    sq = build_sharded_flashql(table, 2, pipeline=True)
    w = sq.store.shards[0].words
    sq.devices[0].fc_write(
        "telemetry",
        jnp.asarray(rng.integers(0, 2**32, (w,), dtype=np.uint32)),
        esp=False,
    )
    (r,) = sq.serve([Query(Eq("country", 1))])
    assert r.count == int((table["country"] == 1).sum())


def test_routed_queue_depth_drains_hot_stripe_in_one_flush():
    """Range-pruned shards donate their queue slots: a stripe_key fleet
    whose traffic routes to one stripe drains queue_depth * shards from
    that stripe per flush instead of serializing over many flushes."""
    rng = np.random.default_rng(5)
    n = 400
    table = {
        "uid": rng.integers(0, 1000, n),
        "sales": rng.integers(0, 50, n),
    }
    hot = [
        Query(Range("uid", 0, 99), agg=a)
        for a in (Count(), Sum("sales"), Min("sales"), Max("sales"))
    ] * 2  # 8 queries, all routed to the first stripe
    sq = build_sharded_flashql(
        table,
        4,
        policy="range",
        stripe_key="uid",
        num_planes=2,
        queue_depth=2,
        pipeline=True,
    )
    res = sq.serve(hot)
    sel = (table["uid"] >= 0) & (table["uid"] <= 99)
    assert res[0].value == int(sel.sum())
    assert res[1].value == int(table["sales"][sel].sum())
    assert sq.stats()["shards_pruned"] > 0
    # budget = queue_depth * 4 active shards = 8 slots: one flush drains
    # the hot stripe's 8 queries (lockstep at depth 2 would need 4)
    assert sq.flushes == 1, sq.flushes
    lock = build_sharded_flashql(
        table,
        4,
        policy="range",
        stripe_key="uid",
        num_planes=2,
        queue_depth=2,
    )
    lock.serve(hot)
    assert lock.flushes == 4


# ---------------------------------------------------------------------------
# device-resident scratch (spill push-down)
# ---------------------------------------------------------------------------


def test_spilling_plans_share_the_fused_flush(monkeypatch):
    """Deep ranges (spilling plans) execute inside the fused program: one
    transfer for a flush of nothing but spilling aggregates, correct
    values, zero eager fallbacks, and no snapshot re-upload when warm."""
    rng = np.random.default_rng(6)
    n = 900
    table = {"age": rng.integers(0, 64, n)}
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    sched = BatchScheduler(dev, store)
    queries = [
        Query(Range("age", 13, 37), agg=Count()),
        Query(Range("age", 5, 60), agg=Sum("age")),
        Query(Range("age", 13, 37), agg=Max("age")),
    ]
    sched.serve(queries)  # warm (jit + caches)
    uploads = dev.store.snapshot_uploads
    for q in queries:
        sched.submit(q)
    counter = _TransferCounter(monkeypatch)
    results = sched.flush()
    assert counter.calls == 1
    assert sched.eager_plans == 0
    assert dev.store.snapshot_uploads == uploads
    vals = [results[t].value for t in sorted(results)]
    sel1 = (table["age"] >= 13) & (table["age"] <= 37)
    sel2 = (table["age"] >= 5) & (table["age"] <= 60)
    assert vals[0] == int(sel1.sum())
    assert vals[1] == int(table["age"][sel2].sum())
    assert vals[2] == int(table["age"][sel1].max())


# ---------------------------------------------------------------------------
# append coalescing
# ---------------------------------------------------------------------------


def test_coalesced_appends_program_one_delta_per_page():
    rng = np.random.default_rng(7)
    n = 400
    table = _table(rng, n)
    half = {c: v[: n // 2] for c, v in table.items()}

    def build(**kw):
        store = BitmapStore()
        store.ingest(half, reserve_rows=n)
        dev = FlashDevice(num_planes=2)
        store.program(dev)
        return BatchScheduler(dev, store, **kw)

    imm = build()
    co = build(coalesce_appends=True)
    one = build()
    step = n // 20
    batches = [
        {c: v[n // 2 + i * step : n // 2 + (i + 1) * step] for c, v in table.items()}
        for i in range(10)
    ]
    imm_pages = sum(imm.append(b) for b in batches)
    for b in batches:
        assert co.append(b) == 0  # queued, nothing programmed yet
    assert co.appends_queued == 10
    co_pages = co.apply_appends()
    # the coalesced queue programs exactly what ONE combined batch would
    combined = {
        c: np.concatenate([b[c] for b in batches]) for c in batches[0]
    }
    one_pages = one.append(combined)
    assert co_pages == one_pages
    assert co_pages < imm_pages
    assert co.stats()["append_batches_coalesced"] == 10
    # identical serving results afterwards
    qs = [Query(Eq("country", 2), agg=a) for a in (Count(), Sum("sales"))]
    assert [r.value for r in imm.serve(qs)] == [
        r.value for r in co.serve(qs)
    ]


def test_coalesced_appends_keep_inflight_refusal_and_validation():
    rng = np.random.default_rng(8)
    n = 200
    table = _table(rng, n)
    half = {c: v[: n // 2] for c, v in table.items()}
    store = BitmapStore()
    store.ingest(half, reserve_rows=n // 2)
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    sched = BatchScheduler(dev, store, coalesce_appends=True)
    batch = {c: v[n // 2 : n // 2 + 10] for c, v in table.items()}
    sched.submit(Query(Eq("country", 1)))
    with pytest.raises(RuntimeError, match="pending"):
        sched.append(batch)
    sched.flush()
    sched.append(batch)
    # a later batch with unknown/missing columns must reject (the merge
    # is built from the first batch's columns — regression: an unknown
    # column was silently dropped)
    with pytest.raises(ValueError, match="bogus"):
        sched.append({**batch, "bogus": np.zeros(10, int)})
    with pytest.raises(ValueError, match="missing"):
        sched.append({"country": batch["country"]})
    # cumulative capacity: a queued stream must not overflow the reserve
    big = {c: np.concatenate([v] * 3) for c, v in table.items()}
    with pytest.raises(ValueError, match="overflow"):
        sched.append(big)
    assert sched.appends_queued == 1  # the bad batch was never queued
    # a flush applies the queue; queries see the appended rows
    m = n // 2 + 10
    (r,) = sched.serve([Query(Eq("country", 1))])
    assert r.value == int((table["country"][:m] == 1).sum())


def test_sharded_coalesced_appends_match_immediate():
    rng = np.random.default_rng(9)
    n = 300
    table = _table(rng, n)
    half = {c: v[: n // 2] for c, v in table.items()}
    step = n // 10
    batches = [
        {c: v[n // 2 + i * step : n // 2 + (i + 1) * step] for c, v in table.items()}
        for i in range(4)
    ]
    imm = build_sharded_flashql(half, 3, num_planes=2, reserve_rows=n)
    co = build_sharded_flashql(
        half,
        3,
        num_planes=2,
        reserve_rows=n,
        pipeline=True,
        coalesce_appends=True,
    )
    for b in batches:
        imm.append(b)
        assert co.append(b) == 0
    m = n // 2 + 4 * step
    qs = [
        Query(Eq("country", 2), agg=a)
        for a in (Count(), Sum("sales"), Mask())
    ]
    a = imm.serve(qs)
    b = co.serve(qs)  # flush applies the queued appends first
    sel = table["country"][:m] == 2
    assert a[0].value == b[0].value == int(sel.sum())
    assert a[1].value == b[1].value == int(table["sales"][:m][sel].sum())
    np.testing.assert_array_equal(
        np.asarray(a[2].value.to_bits()), np.asarray(b[2].value.to_bits())
    )
    assert co.esp_delta_programs < imm.esp_delta_programs
