"""CRUD-lifecycle unit coverage: tombstone deletes, updates, compaction.

The full-CRUD contract, asserted piece by piece:

* the compiler splices the tombstone (``__valid``) page into EVERY plan
  as exactly ONE extra sensed wordline — deleted rows can never appear
  in a COUNT, MASK, or aggregate, and the reserved tail of a
  ``reserve_rows`` store is masked out of NOT plans by the same page;
* ``delete()`` programs one delta page, keeps every cached plan warm,
  and refuses bad batches (out of range, duplicates, double deletes)
  before any page state mutates; ``update()`` validates both halves
  before either applies;
* ``compact()`` is erase-unit-aware: it charges block erases + a full
  ESP reprogram, restores append headroom (``capacity_rows``), and
  surfaces write amplification through ``stats()``/``snapshot()``;
* a rejected coalesced append must not poison already-queued batches on
  either scheduler (the queue stays applyable after the raise);
* empty telemetry sample sets summarize to ``None``/omitted quantiles
  instead of raising (``percentile``, ``Histogram``, ``snapshot``,
  ``latency_summary``).
"""

import numpy as np
import pytest

from repro.core.planner import Planner
from repro.query import (
    VALID_PAGE,
    Agg,
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    Histogram,
    In,
    Not,
    Query,
    Range,
    Telemetry,
    build_sharded_flashql,
    lower,
    percentile,
)
from repro.query.ast import and_ as qand
from repro.query.compile import _lower
from repro.query.oracle import np_select
from repro.query.scheduler import plan_traffic

from _hypothesis_compat import given, settings, st  # noqa: F401


def _table(rng, n):
    return {
        "c": rng.integers(0, 6, n),
        "v": rng.integers(0, 32, n),
    }


def _scheduler(table, reserve=64, planes=2, **kw):
    store = BitmapStore()
    store.ingest(table, reserve_rows=reserve)
    dev = FlashDevice(num_planes=planes)
    store.program(dev)
    return BatchScheduler(dev, store, **kw)


# ---------------------------------------------------------------------------
# the tombstone splice: one extra wordline, every plan, every aggregate
# ---------------------------------------------------------------------------


def test_valid_page_costs_exactly_one_extra_wordline():
    """Acceptance criterion: the spliced tombstone page adds exactly ONE
    sensed wordline to every plan vs the raw (unspliced) lowering."""
    rng = np.random.default_rng(0)
    store = BitmapStore()
    store.ingest(_table(rng, 400), reserve_rows=32)
    dev = FlashDevice(num_planes=1)
    store.program(dev)
    preds = [
        Eq("c", 2),
        In("c", [0, 3, 5]),
        Range("v", 5, 20),
        Not(Eq("c", 1)),
        qand(Eq("c", 2), Not(Range("v", 0, 10))),
    ]
    for pred in preds:
        spliced = Planner(dev.layout).compile(lower(pred, store))
        raw = Planner(dev.layout).compile(_lower(pred, store))
        assert (
            plan_traffic(spliced)[1] == plan_traffic(raw)[1] + 1
        ), pred


def test_deleted_rows_never_match_any_aggregate():
    rng = np.random.default_rng(1)
    n = 500
    table = _table(rng, n)
    sched = _scheduler(table)
    dead = rng.choice(n, 120, replace=False)
    sched.delete(dead)
    live = np.ones(n, bool)
    live[dead] = False
    for pred in (Eq("c", 3), Range("v", 0, 31), Not(Eq("c", 0))):
        want = np_select(pred, table, n) & live
        r_count, r_mask = sched.serve(
            [Query(pred, agg=Agg.COUNT), Query(pred, agg=Agg.MASK)]
        )
        assert r_count.count == int(want.sum())
        np.testing.assert_array_equal(
            np.asarray(r_mask.mask.to_bits()).astype(bool), want
        )


def test_delete_keeps_plans_warm_and_programs_one_page():
    rng = np.random.default_rng(2)
    n = 300
    table = _table(rng, n)
    sched = _scheduler(table)
    qs = [Query(Eq("c", 1)), Query(Range("v", 4, 9))]
    sched.serve(qs)
    misses = sched.compiler.misses
    before = sched.device.esp_programs
    pages = sched.delete(np.arange(0, 50))
    assert pages == sched.device.esp_programs - before == 1
    sched.serve(qs)
    # the tombstone page carries no column region: no plan recompiles
    assert sched.compiler.misses == misses


def test_delete_validation_rejects_before_mutating():
    rng = np.random.default_rng(3)
    sched = _scheduler(_table(rng, 100))
    with pytest.raises(ValueError, match="outside"):
        sched.delete([5, 100])
    with pytest.raises(ValueError, match="duplicate"):
        sched.delete([5, 5])
    with pytest.raises(ValueError, match="integers"):
        sched.delete(np.array([1.5]))  # would truncate to row 1
    sched.delete([5])
    with pytest.raises(ValueError, match="already deleted"):
        sched.delete([5, 6])
    # the failed batches left no tombstones behind
    assert sched.store.deleted_rows == 1
    assert sched.stats()["rows_deleted"] == 1


def test_update_validates_both_halves_first():
    rng = np.random.default_rng(4)
    n = 200
    table = _table(rng, n)
    sched = _scheduler(table)
    with pytest.raises(ValueError, match="replacement"):
        sched.update([1, 2, 3], {"c": np.array([1]), "v": np.array([2])})
    with pytest.raises(ValueError):
        sched.update([1, n + 5], {c: v[:2] for c, v in table.items()})
    assert sched.store.deleted_rows == 0  # neither half applied
    sched.update([1, 2], {"c": np.array([5, 5]), "v": np.array([7, 7])})
    (r,) = sched.serve([Query(qand(Eq("c", 5), Eq("v", 7)))])
    want = ((table["c"] == 5) & (table["v"] == 7))
    want[[1, 2]] = False
    assert r.count == int(want.sum()) + 2


def test_compact_reclaims_capacity_and_charges_erases():
    rng = np.random.default_rng(5)
    n = 400
    table = _table(rng, n)
    sched = _scheduler(table, reserve=100)
    cap = sched.store.capacity_rows
    sched.delete(np.arange(0, 150))
    assert sched.store.live_rows == n - 150
    stats = sched.compact()
    assert stats["rows_dropped"] == 150
    assert stats["blocks_erased"] > 0
    # headroom restored: same capacity, fewer resident rows
    assert sched.store.capacity_rows == cap
    assert sched.store.num_rows == n - 150
    assert sched.store.deleted_rows == 0
    # post-compact serving is bit-exact on the renumbered rows
    live_table = {c: v[150:] for c, v in table.items()}
    (r,) = sched.serve([Query(Eq("c", 2), agg=Agg.MASK)])
    np.testing.assert_array_equal(
        np.asarray(r.mask.to_bits()).astype(bool),
        live_table["c"] == 2,
    )
    # the erase-unit costs are first-class telemetry
    s = sched.stats()
    assert s["compactions"] == 1 and s["block_erases"] > 0
    assert s["write_amplification"] > 1.0
    snap = sched.telemetry.snapshot()
    assert snap["counters"]["block_erases"] == s["block_erases"]
    assert snap["counters"]["words_programmed"] > snap["counters"].get(
        "words_written", 0
    )
    proj = sched.projection()
    assert proj["block_erases"] == s["block_erases"]
    # wear is visible per block
    assert snap["gauges"]["max_pec"] >= 1


def test_auto_compaction_policy_fires_at_threshold():
    rng = np.random.default_rng(6)
    n = 200
    sched = _scheduler(_table(rng, n), compact_density=0.3)
    sched.delete(np.arange(0, 30))  # 15% < 30%: no compaction
    assert sched.stats()["compactions"] == 0
    sched.delete(np.arange(30, 70))  # 35% >= 30%: compacts
    assert sched.stats()["compactions"] == 1
    assert sched.store.num_rows == n - 70


def test_grow_on_overflow_rides_the_rebuild():
    rng = np.random.default_rng(7)
    n = 100
    table = _table(rng, n)
    sched = _scheduler(table, reserve=4, grow_on_overflow=True)
    big = _table(rng, 300)
    sched.append(big)  # overflows the 4-row reserve -> grow + retry
    assert sched.stats()["compactions"] == 1
    merged = {c: np.concatenate([v, big[c]]) for c, v in table.items()}
    (r,) = sched.serve([Query(Eq("c", 0))])
    assert r.count == int((merged["c"] == 0).sum())


# ---------------------------------------------------------------------------
# satellite 1: reserved tail rows never leak into NOT/MASK plans
# ---------------------------------------------------------------------------


def test_not_plan_cached_before_append_stays_exact():
    """Differential regression: compile-and-cache a NOT plan on a store
    with reserve_rows headroom, append rows, re-serve the SAME plan — no
    row >= num_rows (at either point) may leak into COUNT or MASK."""
    rng = np.random.default_rng(8)
    n = 150
    table = _table(rng, n)
    for sq_builder in (
        lambda: _scheduler(table, reserve=128),
        lambda: build_sharded_flashql(
            dict(table), 2, num_planes=1, reserve_rows=128
        ),
    ):
        sched = sq_builder()
        pred = Not(Eq("c", 2))
        (r0,) = sched.serve([Query(pred, agg=Agg.MASK)])
        bits0 = np.asarray(r0.mask.to_bits()).astype(bool)
        assert bits0.shape[0] == n
        np.testing.assert_array_equal(bits0, table["c"] != 2)
        batch = _table(rng, 40)
        sched.append(batch)
        merged = {c: np.concatenate([v, batch[c]]) for c, v in table.items()}
        r1, r2 = sched.serve(
            [Query(pred, agg=Agg.MASK), Query(pred, agg=Agg.COUNT)]
        )
        bits1 = np.asarray(r1.mask.to_bits()).astype(bool)
        assert bits1.shape[0] == n + 40
        np.testing.assert_array_equal(bits1, merged["c"] != 2)
        assert r2.count == int((merged["c"] != 2).sum())


# ---------------------------------------------------------------------------
# satellite 2: a rejected coalesced append never poisons queued batches
# ---------------------------------------------------------------------------


def test_rejected_coalesced_append_leaves_queue_applyable():
    rng = np.random.default_rng(9)
    n = 100
    table = _table(rng, n)

    def check(sched, sq=False):
        good1 = _table(rng, 5)
        sched.append(good1)
        # cumulative batch would overflow the reserve: rejected
        with pytest.raises(ValueError, match="overflows"):
            sched.append(_table(rng, 5000))
        # schema violation in the cumulative batch: rejected
        with pytest.raises(ValueError):
            sched.append({"c": np.array([1]), "wrong": np.array([2])})
        good2 = _table(rng, 5)
        sched.append(good2)
        assert sched.appends_queued == 2
        sched.apply_appends()
        assert sched.appends_queued == 0
        merged = {
            c: np.concatenate([v, good1[c], good2[c]])
            for c, v in table.items()
        }
        res = sched.serve([Query(Eq("c", 1), agg=Agg.MASK)])
        bits = np.asarray(res[0].mask.to_bits()).astype(bool)
        np.testing.assert_array_equal(bits, merged["c"] == 1)

    check(_scheduler(table, reserve=32, coalesce_appends=True))
    check(
        build_sharded_flashql(
            dict(table),
            2,
            num_planes=1,
            reserve_rows=32,
            coalesce_appends=True,
        ),
        sq=True,
    )


# ---------------------------------------------------------------------------
# satellite 3: empty sample sets summarize, never raise
# ---------------------------------------------------------------------------


def test_empty_samples_summarize_without_raising():
    assert percentile([], 50) is None
    assert Histogram().summary() == {"count": 0}
    tele = Telemetry()
    tele.hists["empty"] = Histogram()
    snap = tele.snapshot()  # must stay total on a fresh registry
    assert snap["histograms"]["empty"] == {"count": 0}
    h = Histogram(capacity=4)
    h.observe(1.0)
    s = h.summary()
    assert s["count"] == 1 and s["p50"] == 1.0  # non-empty keeps quantiles

    import importlib.util
    import pathlib

    spec = importlib.util.spec_from_file_location(
        "_harness",
        pathlib.Path(__file__).parent.parent / "benchmarks" / "_harness.py",
    )
    harness = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(harness)
    assert harness.latency_summary([]) is None
    assert harness.latency_summary([0.5]) == {
        "p50": 0.5,
        "p95": 0.5,
        "mean": 0.5,
        "n": 1,
    }


# ---------------------------------------------------------------------------
# sharded lifecycle units (the differential stream lives in
# tests/test_query_differential.py)
# ---------------------------------------------------------------------------


def test_sharded_partial_compaction_rebuilds_only_tombstoned_stripes():
    rng = np.random.default_rng(10)
    n = 240
    table = _table(rng, n)
    sq = build_sharded_flashql(dict(table), 3, num_planes=1, reserve_rows=32)
    # roundrobin: rows 0,3,6,… live on stripe 0 — tombstone only those
    sq.delete(np.arange(0, 60, 3))
    pre = [d.store.epoch for d in sq.devices]
    touched = [s.deleted_rows > 0 for s in sq.store.shards]
    assert touched == [True, False, False]
    stats = sq.compact()
    assert stats["shards_rebuilt"] == 1
    post = [d.store.epoch for d in sq.devices]
    assert post[0] > pre[0]
    assert post[1:] == pre[1:]  # untouched stripes: epochs never move
    live = np.ones(n, bool)
    live[np.arange(0, 60, 3)] = False
    (r,) = sq.serve([Query(Eq("c", 1), agg=Agg.MASK)])
    np.testing.assert_array_equal(
        np.asarray(r.mask.to_bits()).astype(bool),
        (table["c"] == 1)[live],
    )


def test_mutations_refused_while_tickets_in_flight():
    rng = np.random.default_rng(11)
    table = _table(rng, 100)
    sq = build_sharded_flashql(dict(table), 2, num_planes=1, reserve_rows=16)
    sq.submit(Query(Eq("c", 1)))
    for call in (
        lambda: sq.delete([0]),
        lambda: sq.update([0], {c: v[:1] for c, v in table.items()}),
        lambda: sq.compact(),
    ):
        with pytest.raises(RuntimeError, match="in flight"):
            call()
    sq.flush()
    sq.delete([0])  # drained fleet: fine
