"""Reliability model: the paper's stated anchors must hold exactly."""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.core.bitops import BitOp
from repro.core.reliability import (
    ESP_ZERO_TESP,
    UBER_TARGET,
    CellMode,
    ProgramConfig,
    block_quality_quantile,
    inject_bit_errors,
    randomize_words,
    rber,
)
from repro.kernels.mws import mws_reduce


def _r(mode, rand=True, tesp=1.0, **kw):
    return rber(ProgramConfig(mode, rand, tesp), **kw)


def test_randomization_off_factors():
    """Fig. 8: disabling randomization => 1.91× (SLC) / 4.92× (MLC)."""
    assert _r(CellMode.SLC, False) / _r(CellMode.SLC, True) == pytest.approx(
        1.91
    )
    assert _r(CellMode.MLC, False) / _r(CellMode.MLC, True) == pytest.approx(
        4.92
    )


def test_mlc_over_slc_factor():
    """Fig. 8: MLC-mode RBER up to 4× SLC-mode."""
    assert _r(CellMode.MLC) / _r(CellMode.SLC) == pytest.approx(4.0)


def test_mlc_range_spans_paper_values():
    """§3.2: MLC RBER range across Fig. 8(b) is 8.6e-4 … 1.6e-2."""
    lo = _r(CellMode.MLC, True, pec=1_000, retention_days=1)
    hi = _r(CellMode.MLC, False, pec=10_000, retention_days=365)
    assert lo == pytest.approx(8.6e-4, rel=0.02)
    assert hi == pytest.approx(1.6e-2, rel=0.02)


def test_slc_rand_is_orders_above_uber():
    """§3.2: even SLC+rand is ~12 orders of magnitude above the UBER target."""
    orders = math.log10(_r(CellMode.SLC, True) / UBER_TARGET)
    assert 10.0 <= orders <= 13.0


def test_esp_zero_errors_at_1_9x():
    """Fig. 11: tESP >= 1.9×tPROG => zero bit errors (all blocks)."""
    worst = block_quality_quantile(0.999)
    assert (
        rber(
            ProgramConfig(CellMode.SLC, False, ESP_ZERO_TESP),
            block_quality=worst,
        )
        == 0.0
    )


def test_esp_median_block_order_of_magnitude_at_1_6x():
    """Fig. 11: +60% tESP => ~1 order of magnitude RBER reduction (median)."""
    base = _r(CellMode.SLC, False, 1.0)
    better = _r(CellMode.SLC, False, 1.6)
    assert base / better == pytest.approx(10.0, rel=0.15)


def test_esp_monotone_in_tesp():
    vals = [_r(CellMode.SLC, False, t) for t in np.linspace(1.0, 1.9, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


@settings(max_examples=20, deadline=None)
@given(
    pec=st.integers(100, 50_000),
    ret=st.floats(0.1, 1000),
    q=st.floats(0.01, 0.99),
)
def test_rber_monotone_properties(pec, ret, q):
    """More PEC, more retention, worse block => RBER non-decreasing."""
    cfg = ProgramConfig(CellMode.SLC, True, 1.0)
    bq = block_quality_quantile(q)
    r0 = rber(cfg, pec=pec, retention_days=ret, block_quality=bq)
    assert rber(cfg, pec=pec * 2, retention_days=ret, block_quality=bq) >= r0
    assert rber(cfg, pec=pec, retention_days=ret * 2, block_quality=bq) >= r0


def test_tlc_worse_than_mlc():
    assert _r(CellMode.TLC) > _r(CellMode.MLC)


def test_randomize_involutive():
    rng = np.random.default_rng(0)
    w = jnp.array(rng.integers(0, 2**32, (4, 64), dtype=np.uint32))
    assert (randomize_words(randomize_words(w, 7), 7) == w).all()
    assert not (randomize_words(w, 7) == w).all()


def test_mws_on_randomized_data_is_wrong():
    """The paper's key incompatibility claim (§3.2): bitwise ops on scrambled
    operands, de-randomized afterwards, do NOT equal the true result."""
    rng = np.random.default_rng(1)
    x = jnp.array(rng.integers(0, 2**32, (8, 128), dtype=np.uint32))
    scrambled = jnp.stack([randomize_words(x[i], i) for i in range(8)])
    wrong = randomize_words(mws_reduce(scrambled, BitOp.AND), 0)
    right = mws_reduce(x, BitOp.AND)
    assert not bool((wrong == right).all())


def test_error_injection_rate():
    rng = np.random.default_rng(2)
    w = jnp.array(rng.integers(0, 2**32, (64, 256), dtype=np.uint32))
    p = 1e-2
    noisy = inject_bit_errors(w, p, seed=3)
    flipped = int(
        np.asarray(
            jnp.sum(jnp.bitwise_count((w ^ noisy).astype(jnp.uint32)))
        )
    )
    nbits = 64 * 256 * 32
    assert abs(flipped / nbits - p) < 0.2 * p
    assert (inject_bit_errors(w, 0.0, seed=3) == w).all()


# -- multi-level (MLC/TLC) plane packing -------------------------------------
def test_rber_monotone_in_levels():
    """Packing more bitmap pages per cell shrinks every level margin:
    RBER must rise strictly and monotonically with the level count."""
    for mode in (CellMode.SLC, CellMode.MLC):
        for rand in (True, False):
            vals = [
                rber(ProgramConfig(mode, rand, 1.0, levels=lv))
                for lv in (1, 2, 3)
            ]
            assert vals[0] < vals[1] < vals[2]


def test_rber_levels_quadratic_margin_penalty():
    """The per-level margin shrinks ~1/L and the neighbor count grows ~L:
    the model charges L^2 — TLC packing is 9x SLC at equal tESP."""
    base = rber(ProgramConfig(CellMode.SLC, True, 1.0, levels=1))
    assert rber(
        ProgramConfig(CellMode.SLC, True, 1.0, levels=3)
    ) == pytest.approx(9.0 * base)


def test_esp_zero_point_scales_with_levels():
    """ESP restores zero-error reads at every packing level — the margin
    just costs proportionally more program time: tESP >= 1 + 0.9*L."""
    worst = block_quality_quantile(0.999)
    for lv in (1, 2, 3):
        zero_at = 1.0 + (ESP_ZERO_TESP - 1.0) * lv
        assert (
            rber(
                ProgramConfig(CellMode.SLC, False, zero_at, levels=lv),
                block_quality=worst,
            )
            == 0.0
        )
        # just short of the stretched margin is NOT error-free
        assert (
            rber(
                ProgramConfig(CellMode.SLC, False, zero_at - 0.05, levels=lv),
                block_quality=worst,
            )
            > 0.0
        )


def test_esp_one_level_parity_with_slc():
    """levels=1 is plain SLC: the packed model must reproduce the paper's
    single-level ESP anchors bit-for-bit (Fig. 11 zero point included)."""
    for tesp in np.linspace(1.0, 1.9, 7):
        assert rber(
            ProgramConfig(CellMode.SLC, False, float(tesp), levels=1)
        ) == _r(CellMode.SLC, False, float(tesp))
