"""Blockwise (online-softmax) attention must match the naive path
bit-closely across causal/window/cache/GQA configurations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.models.layers import attention_fwd, init_attention


def _setup(B, S, H, KV, hd, key=0):
    k = jax.random.PRNGKey(key)
    p, _ = init_attention(k, H * hd, H, KV, hd, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(k, 1), (B, S, H * hd))
    return p, x


@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2)])
def test_blockwise_matches_naive_causal(window, H, KV):
    p, x = _setup(2, 50, H, KV, 16)
    kw = dict(n_heads=H, n_kv_heads=KV, window=window)
    ref, _ = attention_fwd(p, x, impl="naive", **kw)
    got, _ = attention_fwd(p, x, impl="blockwise", **kw)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5
    )


def test_blockwise_matches_naive_with_cache():
    B, S, H, KV, hd = 2, 9, 4, 2, 16
    p, x = _setup(B, S, H, KV, hd)
    cache = (
        jnp.zeros((B, 32, KV, hd)),
        jnp.zeros((B, 32, KV, hd)),
    )
    kw = dict(n_heads=H, n_kv_heads=KV, kv_cache=cache, cache_offset=0)
    ref, ref_cache = attention_fwd(p, x, impl="naive", **kw)
    got, got_cache = attention_fwd(p, x, impl="blockwise", **kw)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5
    )
    for a, b in zip(ref_cache, got_cache):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_blockwise_non_causal_cross_attention():
    B, S, H, hd = 2, 12, 4, 16
    p, x = _setup(B, S, H, H, hd)
    kv_x = jax.random.normal(jax.random.PRNGKey(9), (B, 20, H * hd))
    kw = dict(n_heads=H, n_kv_heads=H, causal=False, kv_x=kv_x, use_rope=False)
    ref, _ = attention_fwd(p, x, impl="naive", **kw)
    got, _ = attention_fwd(p, x, impl="blockwise", **kw)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5
    )


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(1, 40),
    kvlen=st.integers(0, 30),
    window=st.one_of(st.none(), st.integers(1, 16)),
    seed=st.integers(0, 100),
)
def test_blockwise_property(s, kvlen, window, seed):
    """Random shapes incl. decode-like (S=1, big cache offset)."""
    B, H, KV, hd = 1, 2, 2, 8
    p, x = _setup(B, s, H, KV, hd, key=seed)
    total = s + kvlen + 3
    cache = (
        jax.random.normal(jax.random.PRNGKey(seed + 1), (B, total, KV, hd)),
        jax.random.normal(jax.random.PRNGKey(seed + 2), (B, total, KV, hd)),
    )
    kw = dict(
        n_heads=H,
        n_kv_heads=KV,
        kv_cache=cache,
        cache_offset=kvlen,
        window=window,
    )
    ref, _ = attention_fwd(p, x, impl="naive", **kw)
    got, _ = attention_fwd(p, x, impl="blockwise", **kw)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=3e-5, atol=3e-5
    )


def test_model_forward_same_with_blockwise():
    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = get_config("yi-34b").reduced()
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 24))
    )
    ref = T.forward(cfg, params, tokens)
    got = T.forward(cfg.with_(attention_impl="blockwise"), params, tokens)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=3e-4, atol=3e-4
    )
