"""Incremental-ingest unit coverage: delta-page programming + epochs.

The mutable-FlashQL contract, asserted piece by piece:

* appending B rows to an N-row store programs O(B) pages — the flashsim
  ESP-program counter must report the SAME page count for the same batch
  on a 10x bigger store, and far fewer pages than a full reprogram;
* appends that introduce no new index metadata leave EVERY cached plan
  warm, and a first-seen value in column A invalidates only plans that
  sense column A (region-granular plan-cache epochs);
* a bad append batch (schema mismatch, ragged, negative, over capacity)
  is rejected at the call site on BOTH schedulers before any shard queue
  or page state mutates;
* appends route correctly on sharded fleets (round-robin tail striping,
  stripe-key owning/overflow stripes) and keep range pruning sound;
* `Layout` regions keep appended pages co-located with their column and
  fork in lockstep for shard-canonical layouts.
"""

import numpy as np
import pytest

from repro.core.placement import Layout
from repro.core.store import PackedStore, page_region
from repro.query import (
    Agg,
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    GroupBy,
    In,
    Query,
    Range,
    Sum,
    build_sharded_flashql,
)
from repro.query.ast import Count
from repro.query.bitmap import bsi_region, eq_region


def _scheduler(table, reserve=128, planes=2):
    store = BitmapStore()
    store.ingest(table, reserve_rows=reserve)
    dev = FlashDevice(num_planes=planes)
    store.program(dev)
    return BatchScheduler(dev, store)


# ---------------------------------------------------------------------------
# core epoch/region plumbing
# ---------------------------------------------------------------------------


def test_page_region_naming():
    assert page_region("country=3") == "country"
    assert page_region("age#5") == "age"
    assert page_region("__all") == "__all"
    assert page_region("__scratch0") is None


def test_packed_store_region_epochs_vs_append_words():
    st = PackedStore()
    st["a=1"] = np.zeros(4, np.uint32)
    st["b=1"] = np.zeros(4, np.uint32)
    assert st.region_epochs == {"a": 1, "b": 1}
    e = st.epoch
    # full reprogram bumps the page's region (plan caches invalidate)
    st["a=1"] = np.ones(4, np.uint32)
    assert st.region_epochs == {"a": 2, "b": 1}
    assert st.epoch > e
    # delta append bumps ONLY the content version: compiled plans gather
    # by slot and stay valid, snapshot-level caches must refresh
    e = st.epoch
    st.append_words("b=1", np.asarray([7], np.uint32), start=3)
    assert st.region_epochs == {"a": 2, "b": 1}
    assert st.epoch == e + 1
    assert int(np.asarray(st["b=1"])[3]) == 7
    # scratch writes bump neither
    st["__scratch0"] = np.zeros(4, np.uint32)
    assert st.epoch == e + 1


def test_append_words_rejects_out_of_range():
    st = PackedStore()
    st["a=1"] = np.zeros(4, np.uint32)
    with pytest.raises(ValueError, match="out of range"):
        st.append_words("a=1", np.zeros(2, np.uint32), start=3)


def test_layout_regions_append_colocated_and_fork_in_lockstep():
    lay = Layout()
    lay.place_colocated(["c=0", "c=1"], inverted=True, region=eq_region("c"))
    block = lay["c=0"].block
    fork = lay.fork()
    # appended pages continue the region's block on BOTH layouts
    (p1,) = lay.place_colocated(
        ["c=2"], inverted=True, region=eq_region("c")
    )
    (p2,) = fork.place_colocated(
        ["c=2"], inverted=True, region=eq_region("c")
    )
    assert p1 == p2
    assert p1.block == block and p1.wordline == 2 and p1.inverted
    # a different region never shares the block
    (p3,) = lay.place_colocated(["c#0"], region=bsi_region("c"))
    assert p3.block != block


# ---------------------------------------------------------------------------
# O(B) delta programming — the flashsim ESP-program counter
# ---------------------------------------------------------------------------


def _counted_append(n, batch, seed=3):
    rng = np.random.default_rng(seed)
    table = {"c": rng.integers(0, 8, n), "v": rng.integers(0, 64, n)}
    table["c"][:8] = np.arange(8)  # same value universe at every n
    table["v"][:2] = [0, 63]
    sched = _scheduler(table)
    before = sched.device.esp_programs
    sched.append(batch)
    return sched, sched.device.esp_programs - before


def test_append_programs_scale_with_delta_not_total_rows():
    rng = np.random.default_rng(4)
    batch = {"c": rng.integers(0, 8, 16), "v": rng.integers(0, 64, 16)}
    _, p_small = _counted_append(400, batch)
    large, p_large = _counted_append(4000, batch)
    # O(B), not O(N): the same 16-row batch programs the SAME page count
    # on a 10x bigger store
    assert p_small == p_large > 0
    # and each append touches at most the pages the batch can set bits in:
    # the all-rows + tombstone pages + per column min(B, cardinality)
    # equality tails + its BSI slices — never the whole index
    bound = 2 + (min(16, 8) + 3) + (min(16, 64) + 6)
    assert p_large <= bound
    assert p_large < len(large.store.logical) // 2
    assert large.stats()["esp_delta_programs"] == p_large
    assert large.stats()["rows_appended"] == 16


def test_zero_delta_pages_are_not_programmed():
    table = {"c": np.array([0, 1, 2, 3] * 10)}
    sched = _scheduler(table)
    before = sched.device.esp_programs
    # batch holds only value 0: pages c=1..3 keep their erased tails and
    # slices #0/#1 have no set bits -> only __all + __valid + c=0 program
    pages = sched.append({"c": np.zeros(4, np.int64)})
    assert pages == sched.device.esp_programs - before == 3


def test_projection_counts_delta_esp_programs():
    table = {"c": np.arange(40) % 5}
    sched = _scheduler(table)
    sched.serve([Query(Eq("c", 1))])
    sched.append({"c": np.array([1, 1, 4])})
    proj = sched.projection()
    assert proj["esp_programs"] == sched.esp_delta_programs > 0


# ---------------------------------------------------------------------------
# region-granular plan-cache warmth (the acceptance assertion)
# ---------------------------------------------------------------------------


def test_append_to_one_column_leaves_disjoint_plans_warm():
    rng = np.random.default_rng(5)
    table = {"a": rng.integers(0, 4, 80), "b": rng.integers(0, 4, 80)}
    sched = _scheduler(table)
    qa, qb = Query(Eq("a", 1)), Query(In("b", [0, 2]))
    sched.serve([qa, qb])
    assert sched.compiler.misses == 2

    # value-stable append: no column metadata moves, EVERY plan stays warm
    sched.append({"a": np.array([1, 2]), "b": np.array([0, 3])})
    res = sched.serve([qa, qb])
    assert sched.compiler.misses == 2
    assert all(r.cache_hit for r in res)

    # first-seen value in column a: only the a-plan recompiles
    sched.append({"a": np.array([9]), "b": np.array([0])})
    res = sched.serve([qa, qb])
    assert sched.compiler.misses == 3
    assert [r.cache_hit for r in res] == [False, True]

    # and the recompiled plan serves the appended rows
    (r,) = sched.serve([Query(Eq("a", 9))])
    assert r.count == 1


def test_sharded_stable_append_keeps_every_shard_warm():
    rng = np.random.default_rng(6)
    table = {"a": rng.integers(0, 4, 90), "b": rng.integers(0, 4, 90)}
    sq = build_sharded_flashql(table, 3, num_planes=2, reserve_rows=96)
    qs = [Query(Eq("a", 1)), Query(In("b", [0, 2]))]
    sq.serve(qs)
    misses = [c.misses for c in sq.compilers]
    sq.append({"a": np.array([1, 0, 2]), "b": np.array([3, 3, 1])})
    sq.serve(qs)
    assert [c.misses for c in sq.compilers] == misses
    assert all(c.hits >= 2 for c in sq.compilers)


# ---------------------------------------------------------------------------
# validation: reject at the call site, before any state mutates
# ---------------------------------------------------------------------------


def _assert_untouched(sched, num_rows, esp, epoch):
    assert sched.store.num_rows == num_rows
    assert sched.device.esp_programs == esp
    assert sched.device.store.epoch == epoch


@pytest.mark.parametrize(
    "bad,match",
    [
        ({"a": np.array([1])}, "missing"),
        (
            {"a": np.array([1]), "b": np.array([2]), "x": np.array([3])},
            "unknown",
        ),
        ({"a": np.array([1, 2]), "b": np.array([0])}, "ragged"),
        ({"a": np.array([1]), "b": np.array([-3])}, "negative"),
        ({"a": np.zeros(10_000, np.int64), "b": np.zeros(10_000, np.int64)},
         "reserve_rows"),
    ],
)
def test_batch_scheduler_rejects_bad_appends_before_mutation(bad, match):
    table = {"a": np.arange(20) % 3, "b": np.arange(20) % 2}
    sched = _scheduler(table, reserve=32)
    state = (
        sched.store.num_rows,
        sched.device.esp_programs,
        sched.device.store.epoch,
    )
    with pytest.raises(ValueError, match=match):
        sched.append(bad)
    _assert_untouched(sched, *state)


@pytest.mark.parametrize(
    "bad,match",
    [
        ({"a": np.array([1])}, "missing"),
        (
            {"a": np.array([1]), "b": np.array([2]), "x": np.array([3])},
            "unknown",
        ),
        ({"a": np.array([1, 2]), "b": np.array([0])}, "ragged"),
        ({"a": np.array([1]), "b": np.array([-3])}, "negative"),
        ({"a": np.zeros(10_000, np.int64), "b": np.zeros(10_000, np.int64)},
         "reserve_rows"),
    ],
)
def test_sharded_rejects_bad_appends_before_any_shard_mutates(bad, match):
    table = {"a": np.arange(21) % 3, "b": np.arange(21) % 2}
    sq = build_sharded_flashql(table, 3, num_planes=1, reserve_rows=16)
    state = [
        (st.num_rows, st.epoch, dev.esp_programs, dev.store.epoch)
        for st, dev in zip(sq.store.shards, sq.devices)
    ]
    rows = sq.store.num_rows
    with pytest.raises(ValueError, match=match):
        sq.append(bad)
    assert sq.store.num_rows == rows
    assert state == [
        (st.num_rows, st.epoch, dev.esp_programs, dev.store.epoch)
        for st, dev in zip(sq.store.shards, sq.devices)
    ]


def test_append_rejected_while_queries_pending():
    table = {"a": np.arange(20) % 3}
    sched = _scheduler(table)
    sched.submit(Query(Eq("a", 1)))
    with pytest.raises(RuntimeError, match="pending"):
        sched.append({"a": np.array([1])})
    sched.flush()
    sched.append({"a": np.array([1])})  # drained fleet: fine

    sq = build_sharded_flashql(table, 2, num_planes=1, reserve_rows=16)
    sq.submit(Query(Eq("a", 1)))
    with pytest.raises(RuntimeError, match="in flight"):
        sq.append({"a": np.array([1])})
    sq.flush()
    sq.append({"a": np.array([1])})


def test_append_before_ingest_is_rejected():
    with pytest.raises(ValueError, match="ingested"):
        BitmapStore().append({"a": np.array([1])})


# ---------------------------------------------------------------------------
# sharded routing of appends
# ---------------------------------------------------------------------------


def test_roundrobin_append_continues_stripe_sequence():
    n0, b, s = 10, 5, 3
    table = {"c": np.arange(n0) % 4}
    sq = build_sharded_flashql(
        table, s, policy="roundrobin", num_planes=1, reserve_rows=32
    )
    sq.append({"c": (np.arange(n0, n0 + b)) % 4})
    for shard in range(s):
        np.testing.assert_array_equal(
            sq.store.row_maps[shard], np.arange(shard, n0 + b, s)
        )
    # MASK un-striping stays exact over the appended tail
    (r,) = sq.serve([Query(Eq("c", 0), agg=Agg.MASK)])
    np.testing.assert_array_equal(
        np.asarray(r.mask.to_bits()).astype(bool),
        (np.arange(n0 + b) % 4) == 0,
    )


def test_stripe_key_append_routes_to_owning_or_overflow_stripe():
    table = {"k": np.sort(np.arange(0, 60)), "v": np.arange(60) % 3}
    sq = build_sharded_flashql(
        table, 3, policy="range", stripe_key="k",
        num_planes=1, reserve_rows=32,
    )
    sizes = [len(m) for m in sq.store.row_maps]
    # key 5 -> stripe 0 (owns 0..19); key 25 -> stripe 1; key 999 is past
    # every range -> overflow into the last stripe
    sq.append({"k": np.array([5, 25, 999]), "v": np.array([0, 0, 0])})
    assert [len(m) for m in sq.store.row_maps] == [
        sizes[0] + 1, sizes[1] + 1, sizes[2] + 1,
    ]
    assert sq.store.stripe_bounds[2][1] == 999

    # pruning stays sound: the appended key is found on its owning stripe,
    # and the other stripes are pruned without sensing
    pruned = sq.shards_pruned
    (r,) = sq.serve([Query(Eq("k", 999))])
    assert r.count == 1
    assert sq.shards_pruned == pruned + 2


def test_append_updates_present_values_so_pruning_stays_sound():
    table = {"k": np.sort(np.arange(0, 30)), "v": np.arange(30) % 2}
    sq = build_sharded_flashql(
        table, 3, policy="range", stripe_key="k",
        num_planes=1, reserve_rows=16,
    )
    # key 7 exists only via the append; without shard_values maintenance
    # the owning stripe would claim "cannot match" for the new value 77
    sq.append({"k": np.array([77]), "v": np.array([1])})
    (r,) = sq.serve([Query(Eq("k", 77))])
    assert r.count == 1


def test_plain_range_append_extends_tail_stripe():
    table = {"c": np.arange(12) % 4}
    sq = build_sharded_flashql(
        table, 3, policy="range", num_planes=1, reserve_rows=16
    )
    sizes = [len(m) for m in sq.store.row_maps]
    sq.append({"c": np.array([1, 2])})
    assert [len(m) for m in sq.store.row_maps] == [
        sizes[0], sizes[1], sizes[2] + 2,
    ]
    (r,) = sq.serve([Query(Eq("c", 1))])
    assert r.count == int((np.r_[table["c"], [1, 2]] == 1).sum())


# ---------------------------------------------------------------------------
# aggregate correctness over appended state
# ---------------------------------------------------------------------------


def test_fleet_projection_charges_program_only_stripes():
    """A stripe that absorbed appends but never sensed (every query was
    routed away from it) still did real programming work: the fleet
    projection must charge its delta ESP programs, not drop the shard."""
    table = {"k": np.arange(30), "v": np.arange(30) % 2}
    sq = build_sharded_flashql(
        table, 3, policy="range", stripe_key="k",
        num_planes=1, reserve_rows=16,
    )
    # appends land on the overflow (last) stripe only
    sq.append({"k": np.array([999, 1000]), "v": np.array([1, 1])})
    # queries route to stripe 0 only; stripes 1 and 2 never sense
    sq.serve([Query(Eq("k", 3))])
    proj = sq.projection()
    assert sum(p["esp_programs"] for p in proj["per_shard"]) == (
        sq.esp_delta_programs
    )
    assert sq.shard_esp_programs[2] > 0  # the program-only stripe


def test_group_by_sees_values_that_first_appear_in_an_append():
    table = {"g": np.array([0, 0, 1, 1, 1]), "v": np.array([3, 1, 2, 2, 4])}
    sched = _scheduler(table, reserve=32, planes=1)
    (r,) = sched.serve([Query(Range("v", 0, 100), agg=GroupBy("g", Count()))])
    assert r.value == {0: 2, 1: 3}
    sched.append({"g": np.array([5, 5, 0]), "v": np.array([9, 1, 2])})
    r_group, r_sum = sched.serve(
        [
            Query(Range("v", 0, 100), agg=GroupBy("g", Count())),
            Query(Eq("g", 5), agg=Sum("v")),
        ]
    )
    assert r_group.value == {0: 3, 1: 3, 5: 2}
    assert r_sum.value == 10  # v=9 needs a grown BSI slice (4 bits)


def test_bsi_width_growth_keeps_ranges_exact():
    table = {"v": np.array([1, 2, 3, 4, 5])}
    sched = _scheduler(table, reserve=32, planes=1)
    sched.append({"v": np.array([200, 9])})
    r_low, r_high = sched.serve(
        [Query(Range("v", 0, 9)), Query(Range("v", 10, None))]
    )
    assert r_low.count == 6
    assert r_high.count == 1
