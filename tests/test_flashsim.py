"""FlashSim: timing/power anchors and platform-model invariants."""

import pytest
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.flashsim import (
    DEFAULT_SSD,
    Platform,
    bmi_workload,
    ims_workload,
    inter_block_tmws_ratio,
    intra_block_tmws_ratio,
    kcs_workload,
    mws_power_ratio,
    run_workload,
)
from repro.flashsim.geometry import FIG7_SSD
from repro.flashsim.platforms import fig7_timeline
from repro.flashsim.timing import ERASE_POWER_RATIO, mws_energy_j


# ---------------------------------------------------------------------------
# §5.2 measurement anchors
# ---------------------------------------------------------------------------


def test_intra_block_anchors():
    assert intra_block_tmws_ratio(1) == pytest.approx(1.0)
    assert intra_block_tmws_ratio(8) <= 1.01  # "< 1% for ≤ 8 WLs"
    assert intra_block_tmws_ratio(48) == pytest.approx(1.033)  # "+3.3%"


def test_inter_block_anchors():
    assert inter_block_tmws_ratio(1) == pytest.approx(1.0)
    assert inter_block_tmws_ratio(4) == pytest.approx(1.033)
    assert inter_block_tmws_ratio(32) == pytest.approx(1.363)  # "+36.3%"
    # far below 32 serial reads
    assert inter_block_tmws_ratio(32) < 32


def test_power_anchors():
    assert mws_power_ratio(1) == pytest.approx(1.0)
    assert mws_power_ratio(2) == pytest.approx(1.34)  # "+34%"
    assert mws_power_ratio(4) == pytest.approx(1.80)  # "about 80%"
    assert mws_power_ratio(4) < ERASE_POWER_RATIO + 0.001  # below erase power


def test_intra_mws_cheaper_than_read():
    """§4.1: intra-block MWS power is *lower* than a regular read."""
    assert mws_power_ratio(1, n_wls_intra=48) < 1.0


def test_four_block_mws_energy_saving():
    """§5.2: 4-block MWS ≈ 53% less energy than 4 individual reads."""
    ssd = DEFAULT_SSD
    e_mws = mws_energy_j(ssd.t_r_us, ssd.p_read_w, 4, 1)
    e_serial = 4 * ssd.e_sense_page
    saving = 1 - e_mws / e_serial
    assert saving == pytest.approx(0.53, abs=0.03)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 64))
def test_tmws_monotone(n):
    assert inter_block_tmws_ratio(n + 1) >= inter_block_tmws_ratio(n)
    assert intra_block_tmws_ratio(min(n + 1, 48)) >= intra_block_tmws_ratio(
        min(n, 48)
    )
    assert mws_power_ratio(n + 1) >= mws_power_ratio(n)


# ---------------------------------------------------------------------------
# Workload construction (FC command counts come from the real planner)
# ---------------------------------------------------------------------------


def test_bmi_operand_counts():
    assert bmi_workload(1).num_operands == 30  # paper: 30 … 1095
    assert bmi_workload(36).num_operands == 1095


def test_bmi_fc_commands_are_ceil_d_over_48():
    for m in (1, 12, 36):
        wl = bmi_workload(m)
        assert wl.fc_sensing_ops == -(-wl.num_operands // 48)


def test_ims_single_command():
    assert ims_workload(10_000).fc_sensing_ops == 1


def test_kcs_single_command_upto_48():
    """AND of ≤48 adjacency vectors + OR with the clique vector in ONE
    inter-block MWS (paper §7: 'both ops simultaneously')."""
    for k in (8, 16, 32, 48):
        wl = kcs_workload(k)
        assert wl.fc_sensing_ops == 1, k
        assert wl.fc_commands[0].n_blocks == 2


def test_kcs_large_k_chains_without_spill():
    wl = kcs_workload(64)
    assert wl.fc_sensing_ops == 3  # 2-cmd AND chain + clique OR


# ---------------------------------------------------------------------------
# Platform model invariants + headline reproduction bands
# ---------------------------------------------------------------------------

WORKLOADS = (
    [bmi_workload(m) for m in (1, 6, 12, 24, 36)]
    + [ims_workload(i) for i in (10_000, 100_000, 200_000)]
    + [kcs_workload(k) for k in (8, 16, 32, 64)]
)


@pytest.mark.parametrize("wl", WORKLOADS, ids=[w.name for w in WORKLOADS])
def test_platform_ordering(wl):
    """FC ≤ PB ≤ ISP ≤ OSP in time; reverse in energy efficiency."""
    r = {p: run_workload(wl, p) for p in Platform}
    assert r[Platform.FC].time_s <= r[Platform.PB].time_s * 1.001
    assert r[Platform.PB].time_s <= r[Platform.ISP].time_s * 1.001
    assert r[Platform.ISP].time_s <= r[Platform.OSP].time_s * 1.001
    assert r[Platform.FC].energy_j <= r[Platform.PB].energy_j * 1.001


def _geomean(xs):
    import statistics

    return statistics.geometric_mean(xs)


def test_headline_speedups_in_band():
    """Paper: FC vs OSP/ISP/PB = 32×/25×/3.5× average speedup.  Our model
    must land in the same regime (±50% band — modelling constants differ)."""
    fc_osp, fc_isp, fc_pb = [], [], []
    for wl in WORKLOADS:
        r = {p: run_workload(wl, p) for p in Platform}
        fc_osp.append(r[Platform.OSP].time_s / r[Platform.FC].time_s)
        fc_isp.append(r[Platform.ISP].time_s / r[Platform.FC].time_s)
        fc_pb.append(r[Platform.PB].time_s / r[Platform.FC].time_s)
    assert 16 <= _geomean(fc_osp) <= 64, _geomean(fc_osp)
    assert 12 <= _geomean(fc_isp) <= 50, _geomean(fc_isp)
    assert 1.8 <= _geomean(fc_pb) <= 7, _geomean(fc_pb)


def test_headline_energy_in_band():
    """Paper: FC vs OSP/PB = 95×/3.3× average energy improvement."""
    fc_osp, fc_pb = [], []
    for wl in WORKLOADS:
        r = {p: run_workload(wl, p) for p in Platform}
        fc_osp.append(r[Platform.OSP].energy_j / r[Platform.FC].energy_j)
        fc_pb.append(r[Platform.PB].energy_j / r[Platform.FC].energy_j)
    assert 48 <= _geomean(fc_osp) <= 190, _geomean(fc_osp)
    assert 1.6 <= _geomean(fc_pb) <= 6.6, _geomean(fc_pb)


def test_bmi_benefit_grows_with_operands():
    """§8.1 observation 4: FC's benefit grows with operand count; PB's
    flattens (serial sensing bottleneck)."""
    s_small = run_workload(bmi_workload(1), Platform.OSP).time_s / run_workload(
        bmi_workload(1), Platform.FC
    ).time_s
    s_big = run_workload(bmi_workload(36), Platform.OSP).time_s / run_workload(
        bmi_workload(36), Platform.FC
    ).time_s
    assert s_big > 4 * s_small
    pb_small = run_workload(bmi_workload(6), Platform.OSP).time_s / run_workload(
        bmi_workload(6), Platform.PB
    ).time_s
    pb_big = run_workload(bmi_workload(36), Platform.OSP).time_s / run_workload(
        bmi_workload(36), Platform.PB
    ).time_s
    assert pb_big == pytest.approx(pb_small, rel=0.1)  # PB flat


def test_ims_fc_equals_pb():
    """§8.1 observation 6: FC ≈ PB for IMS (result transfer dominates)."""
    wl = ims_workload(100_000)
    t_fc = run_workload(wl, Platform.FC).time_s
    t_pb = run_workload(wl, Platform.PB).time_s
    assert t_fc == pytest.approx(t_pb, rel=0.05)


def test_kcs_pb_flatlines_fc_grows():
    """§8.1 observation 4 (KCS): PB stops improving beyond k≈16."""
    pb16 = run_workload(kcs_workload(16), Platform.OSP).time_s / run_workload(
        kcs_workload(16), Platform.PB
    ).time_s
    pb64 = run_workload(kcs_workload(64), Platform.OSP).time_s / run_workload(
        kcs_workload(64), Platform.PB
    ).time_s
    fc16 = run_workload(kcs_workload(16), Platform.OSP).time_s / run_workload(
        kcs_workload(16), Platform.FC
    ).time_s
    fc64 = run_workload(kcs_workload(64), Platform.OSP).time_s / run_workload(
        kcs_workload(64), Platform.FC
    ).time_s
    assert pb64 <= pb16 * 1.05
    assert fc64 > 2.5 * fc16


def test_fig7_tdma_text_anchors():
    """Fig. 7: tDMA = 27 µs and tEXT = 4 µs for 32 KiB per die."""
    tl = fig7_timeline(FIG7_SSD)
    assert tl["tDMA_us"] == pytest.approx(27.3, abs=0.5)
    assert tl["tEXT_us"] == pytest.approx(4.1, abs=0.2)
    # OSP is external-IO bound; IFP is sense bound
    assert tl["osp_round_us"] > tl["isp_round_us"] >= tl["ifp_round_us"]


def test_esp_write_bandwidth():
    """§8.3: ESP writes ≈ 4.7 GB/s — faster than MLC (121.4%) and TLC
    (166.7%) mode programming, i.e. ESP does not degrade write bandwidth
    vs the MLC/TLC modes it displaces.  One page per program op per plane.
    """
    ssd = DEFAULT_SSD

    def bw(t_us):
        return ssd.num_planes * ssd.page_bytes / (t_us * 1e-6)

    bw_esp = bw(ssd.t_esp_us)
    bw_slc = bw(ssd.t_prog_slc_us)
    bw_mlc = bw(ssd.t_prog_mlc_us)
    bw_tlc = bw(ssd.t_prog_tlc_us)
    assert bw_esp == pytest.approx(4.7e9, rel=0.15)  # paper: 4.7 GB/s
    assert bw_esp / bw_slc == pytest.approx(0.5, abs=0.01)  # 2× tPROG
    assert bw_esp / bw_mlc == pytest.approx(1.214, abs=0.05)  # paper 121.4%
    assert bw_esp / bw_tlc == pytest.approx(1.667, abs=0.1)  # paper 166.7%
