"""Guard the generated dry-run/roofline artifacts (when present).

These tests validate the *products* of the 512-device sweeps so a
regression that breaks a cell shows up in CI even though the sweeps
themselves run out-of-band.  Skipped when results/ hasn't been generated.
"""

import glob
import json
import os

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(sub):
    files = glob.glob(os.path.join(RESULTS, sub, "*.json"))
    return [json.load(open(f)) for f in files]


dryrun = pytest.mark.skipif(
    not glob.glob(os.path.join(RESULTS, "dryrun", "*.json")),
    reason="dry-run results not generated",
)
roofline = pytest.mark.skipif(
    not glob.glob(os.path.join(RESULTS, "roofline", "*.json")),
    reason="roofline results not generated",
)


@dryrun
def test_all_dryrun_cells_ok():
    cells = _load("dryrun")
    assert cells, "no dry-run cells"
    bad = [c for c in cells if c["status"] != "ok"]
    assert not bad, [(c["arch"], c["shape"], c["mesh"]) for c in bad]


@dryrun
def test_dryrun_covers_both_meshes():
    cells = _load("dryrun")
    meshes = {c["mesh"] for c in cells}
    assert {"16x16", "2x16x16"} <= meshes


@dryrun
def test_multipod_halves_per_chip_arguments():
    """Doubling chips should not increase per-chip argument bytes; for
    sharded-dominated cells it should shrink them (the pod axis shards)."""
    cells = {
        (c["arch"], c["shape"], c["mesh"]): c
        for c in _load("dryrun")
        if c["status"] == "ok"
    }
    checked = 0
    for (arch, shape, mesh), c in cells.items():
        if mesh != "16x16":
            continue
        mp = cells.get((arch, shape, "2x16x16"))
        if mp is None:
            continue
        a1 = c["memory"]["argument_bytes"]
        a2 = mp["memory"]["argument_bytes"]
        if a1 and a2:
            assert a2 <= a1 * 1.05, (arch, shape, a1, a2)
            checked += 1
    assert checked >= 10


@roofline
def test_roofline_terms_sane():
    cells = [c for c in _load("roofline") if c["status"] == "ok"]
    assert cells
    for c in cells:
        ro = c["roofline"]
        assert ro["compute_s"] >= 0, c["arch"]
        assert ro["memory_s"] > 0, c["arch"]
        assert ro["collective_s"] >= 0, c["arch"]
        assert ro["dominant"] in ("compute", "memory", "collective")
        assert 0 < c["useful_ratio"] <= 1.2, (
            c["arch"],
            c["shape"],
            c["useful_ratio"],
        )


@roofline
def test_kimi_is_collective_bound_at_baseline():
    """The §Perf-2 premise, pinned: baseline kimi train is collective-bound."""
    for c in _load("roofline"):
        if c["arch"] == "kimi-k2-1t-a32b" and c["shape"] == "train_4k":
            assert c["roofline"]["dominant"] == "collective"
            return
    pytest.skip("cell missing")
