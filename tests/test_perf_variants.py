"""§Perf optimization variants must be semantics-preserving: the gather
MoE dispatch and blockwise attention are drop-in equal to the baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.specs import demo_batch
from repro.models import moe as M
from repro.train.steps import make_loss_fn


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "kimi-k2-1t-a32b"])
def test_gather_dispatch_matches_scatter_forward(arch):
    cfg = get_config(arch).reduced()
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 16))
    )
    ref = M.forward(cfg, params, tokens)
    got = M.forward(cfg.with_(moe_dispatch="gather"), params, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)


def test_gather_dispatch_matches_scatter_grads():
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    params, _ = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = demo_batch(cfg, 2, 16)
    g_ref = jax.grad(make_loss_fn(cfg))(params, batch)
    g_got = jax.grad(make_loss_fn(cfg.with_(moe_dispatch="gather")))(
        params, batch
    )
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_got)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5
        )


def test_baselines_are_defaults():
    """The recorded §Roofline baselines use naive attention + scatter
    dispatch; optimized variants are explicit opt-ins."""
    cfg = get_config("yi-34b")
    assert cfg.attention_impl == "naive"
    assert get_config("kimi-k2-1t-a32b").moe_dispatch == "scatter"


def test_blockwise_flag_train_loss_equal():
    cfg = get_config("granite-8b").reduced().with_(n_layers=2)
    from repro.models.registry import get_model

    model = get_model(cfg)
    params, _ = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = demo_batch(cfg, 2, 16)
    ref = float(make_loss_fn(cfg)(params, batch))
    got = float(
        make_loss_fn(cfg.with_(attention_impl="blockwise"))(params, batch)
    )
    assert abs(ref - got) < 1e-4
