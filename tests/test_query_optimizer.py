"""Optimizer correctness: canonicalization, cost-based reordering, CSE,
and hot-predicate materialization.

Every optimizer stage must be *semantically invisible* — the optimized
system returns bit-identical results to the unoptimized one, it just
senses less.  The tests here check each stage against the ``eval_expr``
and plain-numpy oracles in isolation, then end-to-end with the optimizer
on vs off on twin systems over one table, plus the satellite
regressions: operand-order variants of one predicate must share a single
plan-cache entry, and materialized pages must invalidate on appends but
never on deletes.

Property-style execution goes through ``tests/_hypothesis_compat``: with
`hypothesis` installed, predicates are drawn adversarially; without it,
the seeded corpus loops keep the same coverage running.
"""

import numpy as np
import pytest

from repro.core.commands import MWSCommand, SpillCommand
from repro.core.engine import eval_expr
from repro.core.planner import Planner
from repro.flashsim.geometry import DEFAULT_SSD
from repro.flashsim.timing import mws_latency_us
from repro.query import (
    Agg,
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    In,
    Not,
    Query,
    Range,
    build_sharded_flashql,
    lower,
)
from repro.query.ast import and_ as qand, canonicalize, or_ as qor, pred_key
from repro.query.optimize import best_plan, plan_cost_us, reorder_expr
from repro.query.oracle import np_select

from tests._hypothesis_compat import given, settings, st


def _table(rng, n):
    return {
        "country": rng.integers(0, 6, n),
        "device": rng.integers(0, 4, n),
        "age": rng.integers(0, 90, n),
    }


def _random_pred(rng, depth=0):
    kind = rng.integers(0, 6 if depth < 2 else 4)
    if kind == 0:
        return Eq("country", int(rng.integers(0, 7)))
    if kind == 1:
        return In(
            "device", [int(v) for v in rng.choice(5, rng.integers(1, 4))]
        )
    if kind == 2:
        lo = int(rng.integers(0, 70))
        return Range("age", lo, lo + int(rng.integers(0, 40)))
    if kind == 3:
        return Not(_random_pred(rng, depth + 1))
    children = [
        _random_pred(rng, depth + 1) for _ in range(rng.integers(2, 4))
    ]
    return qand(*children) if kind == 4 else qor(*children)


def _build(table, **kw):
    store = BitmapStore()
    store.ingest(table, reserve_rows=kw.pop("reserve_rows", 0))
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    return BatchScheduler(dev, store, **kw)


def _bits(result, n):
    return np.asarray(result.mask.to_bits()).astype(bool)[:n]


# ---------------------------------------------------------------------------
# canonicalization: structural identity without semantic drift
# ---------------------------------------------------------------------------


def _check_canonicalize(seed):
    rng = np.random.default_rng(seed)
    table = _table(rng, 64)
    for _ in range(8):
        p = _random_pred(rng)
        c = canonicalize(p)
        # bit-exact vs the numpy oracle on the raw table
        np.testing.assert_array_equal(
            np_select(c, table, 64), np_select(p, table, 64), err_msg=f"{p}"
        )
        # idempotent: a canonical predicate is its own canonical form
        assert pred_key(canonicalize(c)) == pred_key(c), p


def test_canonicalize_bit_exact_corpus():
    for seed in (1, 2, 3, 4):
        _check_canonicalize(seed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_canonicalize_bit_exact_property(seed):
    _check_canonicalize(seed)


def test_canonicalize_structural_identities():
    a, b = Eq("country", 1), Eq("device", 2)
    # commuted chains hash equal
    assert pred_key(canonicalize(qand(a, b))) == pred_key(
        canonicalize(qand(b, a))
    )
    assert pred_key(canonicalize(qor(a, b))) == pred_key(
        canonicalize(qor(b, a))
    )
    # double negation collapses
    assert pred_key(canonicalize(Not(Not(a)))) == pred_key(a)
    # Or-of-Eq over one column merges with In, order/duplicates ignored
    assert pred_key(
        canonicalize(qor(Eq("device", 2), Eq("device", 1), Eq("device", 2)))
    ) == pred_key(canonicalize(In("device", [1, 2, 1])))


# ---------------------------------------------------------------------------
# satellite: plan-cache keying on the canonical form
# ---------------------------------------------------------------------------


def test_plan_cache_merges_operand_orders():
    """Operand-order variants of one predicate are ONE cache entry: the
    second serve is a pure hit, with zero additional compiles."""
    rng = np.random.default_rng(5)
    table = _table(rng, 80)
    a, b = Eq("country", 1), Range("age", 20, 50)

    sched = _build(table)
    r1 = sched.serve([Query(qand(a, b))])
    assert sched.compiler.misses == 1
    r2 = sched.serve([Query(qand(b, a))])
    assert sched.compiler.misses == 1, "commuted operands must share a plan"
    assert sched.compiler.hits >= 1
    assert sched.compiler.cache_size == 1
    assert r1[0].count == r2[0].count

    # Or-of-Eq vs the equivalent In: same canonical form, same entry
    sched.serve([Query(qor(Eq("device", 3), Eq("device", 0)))])
    assert sched.compiler.misses == 2
    sched.serve([Query(In("device", [0, 3]))])
    assert sched.compiler.misses == 2

    # the unoptimized compiler keys on the raw structure: two entries
    plain = _build(table, optimize=False)
    plain.serve([Query(qand(a, b))])
    plain.serve([Query(qand(b, a))])
    assert plain.compiler.misses == 2


# ---------------------------------------------------------------------------
# cost model + reordering
# ---------------------------------------------------------------------------


def test_plan_cost_matches_timing_model():
    rng = np.random.default_rng(7)
    table = _table(rng, 80)
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    expr = lower(qand(Range("age", 5, 60), In("device", [0, 2])), store)
    plan = Planner(dev.layout).compile(expr)
    want = 0.0
    for cmd in plan.commands:
        if isinstance(cmd, MWSCommand):
            want += mws_latency_us(
                DEFAULT_SSD.t_r_us,
                len(cmd.targets),
                max(len(t.wordlines) for t in cmd.targets),
            )
        elif isinstance(cmd, SpillCommand):
            want += DEFAULT_SSD.t_esp_us
    assert want > 0
    assert plan_cost_us(plan) == pytest.approx(want)


def _check_reorder(seed):
    rng = np.random.default_rng(seed)
    table = _table(rng, 64)
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    for _ in range(6):
        expr = lower(_random_pred(rng), store)
        alt = reorder_expr(expr, dev.layout)
        np.testing.assert_array_equal(
            np.asarray(eval_expr(alt, store.logical)),
            np.asarray(eval_expr(expr, store.logical)),
        )
        # best_plan never returns a plan pricier than the naive one, and
        # the winning candidate evaluates identically
        snap = dev.layout.snapshot()
        naive = plan_cost_us(Planner(dev.layout).compile(expr))
        dev.layout.restore(snap)
        plan, cand, cost = best_plan(expr, dev.layout)
        assert cost <= naive + 1e-9
        np.testing.assert_array_equal(
            np.asarray(eval_expr(cand, store.logical)),
            np.asarray(eval_expr(expr, store.logical)),
        )


def test_reorder_bit_exact_corpus():
    for seed in (11, 12, 13):
        _check_reorder(seed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_reorder_bit_exact_property(seed):
    _check_reorder(seed)


# ---------------------------------------------------------------------------
# cross-query CSE: sense once, answer many
# ---------------------------------------------------------------------------


def test_flush_dedups_identical_queries():
    rng = np.random.default_rng(21)
    table = _table(rng, 80)
    sched = _build(table)
    p = qand(Range("age", 10, 60), Eq("country", 2))
    got = sched.serve([Query(p), Query(p), Query(p)])
    want = int(np_select(p, table, 80).sum())
    assert [r.count for r in got] == [want] * 3
    assert sched.stats()["cse_plan_hits"] == 2


def test_cse_shares_subtree_and_stays_exact():
    """Six queries AND one expensive Range subtree with different Eq
    leaves: the optimized flush senses the subtree once (shared plan +
    scratch splice), answers bit-identically to the unoptimized twin,
    and needs >= 1.5x fewer sensings per query."""
    rng = np.random.default_rng(22)
    table = _table(rng, 96)
    shared = Range("age", 12, 57)
    queries = [
        Query(qand(Eq("country", c), shared)) for c in range(6)
    ] + [Query(qand(Eq("country", 0), shared), agg=Agg.MASK)]

    on = _build(table, materialize_after=None)
    off = _build(table, optimize=False)
    got_on = on.serve(queries)
    got_off = off.serve(queries)
    for a, b in zip(got_on[:6], got_off[:6]):
        assert a.count == b.count
    np.testing.assert_array_equal(_bits(got_on[6], 96), _bits(got_off[6], 96))
    np.testing.assert_array_equal(
        _bits(got_on[6], 96), np_select(queries[6].where, table, 96)
    )

    s_on, s_off = on.stats(), off.stats()
    assert s_on["cse_shared_senses"] >= 1
    assert s_off["cse_shared_senses"] == 0
    assert s_off["sensings_per_query"] >= 1.5 * s_on["sensings_per_query"]
    # the shared scratch program is charged as device wear + ESP traffic
    assert on.telemetry.snapshot()["projection"]["esp_programs"] >= 1


def _check_on_off_equivalence(seed):
    rng = np.random.default_rng(seed)
    table = _table(rng, 48)
    preds = [_random_pred(rng) for _ in range(3)]
    # duplicates + commuted composites make sharing opportunities likely
    preds += [qand(preds[0], preds[1]), qand(preds[1], preds[0]), preds[0]]
    queries = [Query(p) for p in preds] + [
        Query(p, agg=Agg.MASK) for p in preds[:2]
    ]
    on = _build(table)
    off = _build(table, optimize=False)
    got_on = on.serve(queries)
    got_off = off.serve(queries)
    for q, a, b in zip(queries, got_on, got_off):
        if q.agg is Agg.MASK:
            np.testing.assert_array_equal(
                _bits(a, 48), _bits(b, 48), err_msg=f"{seed} {q}"
            )
        else:
            assert a.count == b.count, (seed, q)


def test_optimizer_on_off_equivalence_corpus():
    for seed in (31, 32, 33):
        _check_on_off_equivalence(seed)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_optimizer_on_off_equivalence_property(seed):
    _check_on_off_equivalence(seed)


def test_sharded_optimizer_exact_and_cheaper():
    """Pipelined fleets, optimizer on vs off, duplicate-heavy workload:
    identical results, strictly fewer sensings per query with CSE on."""
    rng = np.random.default_rng(41)
    table = _table(rng, 90)
    shared = Range("age", 15, 70)
    queries = [
        Query(qand(Eq("country", c % 4), shared)) for c in range(8)
    ] + [Query(qand(Eq("country", 1), shared), agg=Agg.MASK)]
    on = build_sharded_flashql(
        table, 2, policy="roundrobin", num_planes=2, pipeline=True
    )
    off = build_sharded_flashql(
        table, 2, policy="roundrobin", num_planes=2, pipeline=True,
        optimize=False,
    )
    got_on = on.serve(queries)
    got_off = off.serve(queries)
    for a, b in zip(got_on[:8], got_off[:8]):
        assert a.count == b.count
    np.testing.assert_array_equal(_bits(got_on[8], 90), _bits(got_off[8], 90))
    assert (
        off.stats()["sensings_per_query"]
        > on.stats()["sensings_per_query"]
    )
    assert on.stats()["cse_plan_hits"] >= 4  # 8 queries, 4 distinct


# ---------------------------------------------------------------------------
# hot-predicate materialization: cached bitmap pages + epoch guards
# ---------------------------------------------------------------------------


def test_materialization_hits_then_append_invalidates():
    rng = np.random.default_rng(51)
    n = 80
    table = _table(rng, n)
    sched = _build(table, reserve_rows=40, materialize_after=2)
    hot = qand(Range("age", 10, 60), In("device", [0, 1]))

    def check(resident, live):
        (r,) = sched.serve([Query(hot, agg=Agg.MASK)])
        m = len(live)
        want = np_select(hot, resident, m) & live
        np.testing.assert_array_equal(_bits(r, m), want)

    live = np.ones(n, bool)
    for _ in range(4):  # past the threshold: built once, then pure hits
        check(table, live)
    comp = sched.compiler
    assert comp.mat_builds == 1
    assert comp.mat_hits >= 1
    assert comp.mat_invalidations == 0

    # deletes must NOT invalidate: the valid page composes at read time
    sched.delete(np.asarray([3, 17, 44]))
    live[[3, 17, 44]] = False
    check(table, live)
    assert comp.mat_invalidations == 0
    assert comp.mat_builds == 1

    # appends MUST: the cached bitmap would zero-miss the new rows
    batch = _table(rng, 9)
    sched.append(batch)
    table = {c: np.concatenate([v, batch[c]]) for c, v in table.items()}
    live = np.concatenate([live, np.ones(9, bool)])
    hits_before = comp.mat_hits
    for _ in range(4):  # invalidate, re-earn the threshold, rebuild, hit
        check(table, live)
    assert comp.mat_invalidations == 1
    assert comp.mat_builds == 2
    assert comp.mat_hits > hits_before
    s = sched.stats()
    assert s["materializations"] == 2
    assert s["materialization_hits"] == comp.mat_hits


def test_materialization_reprograms_stable_page():
    """Rebuilds after invalidation reuse the predicate's page name, so
    plan-cache entries gathering its slot stay coherent."""
    rng = np.random.default_rng(52)
    table = _table(rng, 60)
    sched = _build(table, reserve_rows=30, materialize_after=1)
    hot = qand(Range("age", 0, 45), Eq("country", 1))
    # heat accrues during a flush; the build fires at the NEXT boundary
    sched.serve([Query(hot)] * 2)
    sched.serve([Query(hot)] * 2)
    comp = sched.compiler
    assert comp.mat_builds == 1
    (name0,) = comp._mat_names.values()
    sched.append(_table(rng, 5))
    sched.serve([Query(hot)] * 2)  # invalidates + re-earns the threshold
    sched.serve([Query(hot)] * 2)
    assert comp.mat_builds == 2
    (name1,) = comp._mat_names.values()
    assert name0 == name1


# ---------------------------------------------------------------------------
# telemetry exposure
# ---------------------------------------------------------------------------


def test_snapshot_exposes_optimizer_stats():
    rng = np.random.default_rng(61)
    table = _table(rng, 64)
    sched = _build(table, materialize_after=2)
    p = qand(Range("age", 10, 50), Eq("device", 1))
    for _ in range(3):
        sched.serve([Query(p), Query(p)])
    opt = sched.telemetry.snapshot()["optimizer"]
    assert opt["enabled"] is True
    assert opt["sensings_per_query"] > 0
    assert opt["cse_plan_hits"] >= 1
    assert opt["materializations"] >= 1
    for k in (
        "cse_shared_senses",
        "cse_rewritten_members",
        "materialization_hits",
        "materialization_invalidations",
    ):
        assert k in opt

    sq = build_sharded_flashql(table, 2, num_planes=2)
    sq.serve([Query(p), Query(p)])
    sopt = sq.telemetry.snapshot()["optimizer"]
    assert sopt["enabled"] is True
    assert sopt["sensings_per_query"] > 0
    assert sopt["cse_plan_hits"] >= 1
