"""Per-kernel correctness: popcount + sign-compression vs jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.kernels.popcount import popcount, popcount_ref
from repro.kernels.popcount.popcount import popcount_pallas
from repro.kernels.signcomp import (
    compress_signs,
    decompress_signs,
    majority_ref,
    majority_vote,
    pack_signs_ref,
    unpack_signs_ref,
)
from repro.kernels.signcomp.signcomp import (
    majority_pallas,
    pack_signs_pallas,
    unpack_signs_pallas,
)


@pytest.mark.parametrize(
    "shape", [(1,), (100,), (3, 1000), (8, 2048), (16, 5000), (1, 1)]
)
def test_popcount_matches_ref(shape):
    rng = np.random.default_rng(sum(shape))
    x = jnp.array(rng.integers(0, 2**32, shape, dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(popcount(x)), np.asarray(popcount_ref(x))
    )


@settings(max_examples=30, deadline=None)
@given(
    r=st.integers(1, 20), w=st.integers(1, 200), seed=st.integers(0, 2**31 - 1)
)
def test_popcount_property(r, w, seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.integers(0, 2**32, (r, w), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(popcount(x)), np.asarray(popcount_ref(x))
    )


def test_popcount_exact_values():
    x = jnp.array([[0, 1, 3, 0xFFFFFFFF]], dtype=jnp.uint32)
    assert int(popcount(x)[0]) == 0 + 1 + 2 + 32


@pytest.mark.parametrize("rows,words", [(8, 2048), (16, 4096)])
def test_popcount_pallas_kernel_matches_ref(rows, words):
    """The SWAR Pallas kernel itself (the public op folds with plain XLA
    under interpret-mode emulation, so this exercises the kernel path the
    way real hardware would, just through the interpreter)."""
    rng = np.random.default_rng(rows * words)
    x = jnp.array(rng.integers(0, 2**32, (rows, words), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(popcount_pallas(x, interpret=True)),
        np.asarray(popcount_ref(x)),
    )


@pytest.mark.parametrize("rows,words", [(8, 512), (16, 1024), (4, 512)])
def test_pack_unpack_kernels_match_ref(rows, words):
    rng = np.random.default_rng(rows)
    x = jnp.array(rng.normal(size=(32 * rows, words)).astype(np.float32))
    packed = pack_signs_pallas(x, block_rows=min(8, rows), block_words=512)
    np.testing.assert_array_equal(
        np.asarray(packed), np.asarray(pack_signs_ref(x))
    )
    unpacked = unpack_signs_pallas(
        packed, block_rows=min(8, rows), block_words=512
    )
    np.testing.assert_array_equal(
        np.asarray(unpacked), np.asarray(unpack_signs_ref(packed))
    )


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 100_000), seed=st.integers(0, 2**31 - 1))
def test_sign_roundtrip_property(n, seed):
    rng = np.random.default_rng(seed)
    g = jnp.array(rng.normal(size=(n,)).astype(np.float32))
    back = decompress_signs(compress_signs(g), n)
    np.testing.assert_array_equal(
        np.asarray(back), np.asarray(jnp.where(g >= 0, 1.0, -1.0))
    )


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 9), seed=st.integers(0, 2**31 - 1))
def test_majority_matches_ref(k, seed):
    rng = np.random.default_rng(seed)
    s = jnp.array(rng.integers(0, 2**32, (k, 8, 512), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(majority_vote(s)), np.asarray(majority_ref(s))
    )


def test_majority_semantics_small():
    """Bit-level majority semantics, odd K: strict majority; ties impossible."""
    k = 3
    rng = np.random.default_rng(0)
    raw = rng.integers(0, 2, size=(k, 64)).astype(np.uint8)
    from repro.core.bitops import pack_bits, unpack_bits

    stacks = jnp.stack([pack_bits(jnp.array(r)) for r in raw])[:, None, :]
    stacks = jnp.pad(stacks, ((0, 0), (0, 0), (0, 510)))
    maj = majority_pallas(stacks, block_rows=1, block_words=512)
    got = np.asarray(unpack_bits(maj[0, :2], 64))
    want = (raw.sum(axis=0) * 2 >= k).astype(np.uint8)
    np.testing.assert_array_equal(got, want)


def test_majority_is_signsgd_vote():
    """End-to-end: majority of compressed sign planes == sign of the sum of
    signs (odd K) — the signSGD-with-majority-vote aggregation rule."""
    k, n = 5, 3000
    rng = np.random.default_rng(42)
    grads = jnp.array(rng.normal(size=(k, n)).astype(np.float32))
    packed = jnp.stack([compress_signs(grads[i]) for i in range(k)])
    maj = majority_vote(packed)
    got = decompress_signs(maj, n)
    votes = np.where(np.asarray(grads) >= 0, 1, -1).sum(axis=0)
    want = np.where(votes >= 0, 1.0, -1.0)
    np.testing.assert_array_equal(np.asarray(got), want)
