"""Multi-level packing + one-shot threshold sensing (MCFlash-style).

Covers the PR's two device-level additions end to end:

* **MLC/TLC plane packing** — 2/3 bitmap pages co-resident in one
  physical page at distinct voltage levels: physical wordline density,
  bit-identical accounting at ``levels == 1``, and the programmed-word
  reduction the packing buys on ingest and append deltas.
* **k-of-N threshold sensing** — ``AtLeast``/``Majority`` predicates
  lower to a single :class:`ThresholdCommand`; the cost model prices the
  staircase sense against the equivalent And/Or combination chain and
  ``best_plan`` provably picks the chain when C(N, k) is small and the
  native sense when the chain would explode.
"""

import numpy as np
import pytest

from repro.core.commands import MWSCommand, SpillCommand, ThresholdCommand
from repro.core.engine import eval_expr
from repro.core.expr import Threshold
from repro.core.placement import Layout
from repro.core.planner import Planner
from repro.flashsim.geometry import DEFAULT_SSD
from repro.flashsim.timing import mws_latency_us, threshold_latency_us
from repro.kernels.threshold import bitslice_threshold, threshold_reduce
from repro.query import (
    AtLeast,
    BatchScheduler,
    BitmapStore,
    Count,
    Eq,
    FlashDevice,
    Majority,
    Query,
    lower,
)
from repro.query.ast import And, Or, canonicalize, pred_key
from repro.query.compile import QueryCompiler
from repro.query.optimize import best_plan, plan_cost_us

import jax.numpy as jnp


def _table(rng, n, cols=5, card=4):
    return {
        chr(ord("a") + i): rng.integers(0, card, n) for i in range(cols)
    }


def _store_device(table, levels=1, **ingest_kw):
    store = BitmapStore()
    store.ingest(table, **ingest_kw)
    dev = FlashDevice(
        num_planes=2, interpret=True, layout=Layout(levels=levels)
    )
    store.program(dev)
    return store, dev


def _contains_threshold(e) -> bool:
    if isinstance(e, Threshold):
        return True
    return any(
        _contains_threshold(c) for c in getattr(e, "children", ())
    )


# ---------------------------------------------------------------------------
# cost model: threshold sensings are first-class citizens
# ---------------------------------------------------------------------------


def test_plan_cost_prices_threshold_senses():
    """plan_cost_us must charge ThresholdCommands the staircase latency —
    NOT the plain MWS read of the same shape."""
    rng = np.random.default_rng(3)
    store, dev = _store_device(_table(rng, 96))
    expr = lower(
        AtLeast(3, [Eq(c, 1) for c in "abcde"]), store
    )
    plan = Planner(dev.layout).compile(expr)
    thr_cmds = [
        c for c in plan.commands if isinstance(c, ThresholdCommand)
    ]
    assert len(thr_cmds) == 1, plan.commands
    want = 0.0
    for cmd in plan.commands:
        if isinstance(cmd, ThresholdCommand):
            want += threshold_latency_us(
                DEFAULT_SSD.t_r_us,
                len(cmd.targets),
                max(len(t.wordlines) for t in cmd.targets),
            )
        elif isinstance(cmd, MWSCommand):
            want += mws_latency_us(
                DEFAULT_SSD.t_r_us,
                len(cmd.targets),
                max(len(t.wordlines) for t in cmd.targets),
            )
        elif isinstance(cmd, SpillCommand):
            want += DEFAULT_SSD.t_esp_us
    assert want > 0
    assert plan_cost_us(plan) == pytest.approx(want)
    # the staircase premium is real: swapping the threshold price for the
    # plain-MWS price must yield a strictly smaller number
    cheat = want - sum(
        threshold_latency_us(
            DEFAULT_SSD.t_r_us,
            len(c.targets),
            max(len(t.wordlines) for t in c.targets),
        )
        - mws_latency_us(
            DEFAULT_SSD.t_r_us,
            len(c.targets),
            max(len(t.wordlines) for t in c.targets),
        )
        for c in thr_cmds
    )
    assert cheat < plan_cost_us(plan)


# ---------------------------------------------------------------------------
# best_plan crossover: chain when C(N, k) is small, native when it explodes
# ---------------------------------------------------------------------------


def test_best_plan_picks_chain_when_n_small():
    """2-of-3 over inverted co-located equality pages: C(3, 2) = 3 pairs
    merge into 3 cheap inter-block sensings — the And/Or chain must beat
    the staircase threshold sense, and best_plan must pick it."""
    rng = np.random.default_rng(5)
    store, dev = _store_device(_table(rng, 96, cols=3))
    expr = lower(AtLeast(2, [Eq(c, 1) for c in "abc"]), store)
    assert _contains_threshold(expr)

    snap = dev.layout.snapshot()
    native_cost = plan_cost_us(Planner(dev.layout).compile(expr))
    dev.layout.restore(snap)
    plan, cand, cost = best_plan(expr, dev.layout)
    assert not _contains_threshold(cand), cand
    assert cost < native_cost
    assert not any(
        isinstance(c, ThresholdCommand) for c in plan.commands
    )
    np.testing.assert_array_equal(
        np.asarray(eval_expr(cand, store.logical)),
        np.asarray(eval_expr(expr, store.logical)),
    )


def test_best_plan_picks_native_when_chain_explodes():
    """3-of-5: C(5, 3) = 10 combination sensings can't beat ONE staircase
    threshold sense — best_plan must keep the native Threshold form."""
    rng = np.random.default_rng(7)
    store, dev = _store_device(_table(rng, 96))
    expr = lower(AtLeast(3, [Eq(c, 1) for c in "abcde"]), store)

    plan, cand, cost = best_plan(expr, dev.layout)
    assert _contains_threshold(cand)
    thr = [c for c in plan.commands if isinstance(c, ThresholdCommand)]
    assert len(thr) == 1
    # the whole 3-of-5 fuzzy match costs at most 2 sensing ops
    assert plan.num_sensing_ops <= 2
    np.testing.assert_array_equal(
        np.asarray(eval_expr(cand, store.logical)),
        np.asarray(eval_expr(expr, store.logical)),
    )


# ---------------------------------------------------------------------------
# canonicalization: degenerate thresholds share the And/Or plan cache
# ---------------------------------------------------------------------------


def test_atleast_degenerate_forms_canonicalize():
    kids = [Eq("a", 1), Eq("b", 2), Eq("c", 3)]
    assert pred_key(canonicalize(AtLeast(3, kids))) == pred_key(
        canonicalize(And(tuple(kids)))
    )
    assert pred_key(canonicalize(AtLeast(1, kids))) == pred_key(
        canonicalize(Or(tuple(kids)))
    )
    # genuine thresholds stay thresholds, and Majority is 2-of-3 sugar
    assert pred_key(canonicalize(AtLeast(2, kids))) == pred_key(
        canonicalize(Majority(kids))
    )
    assert pred_key(canonicalize(AtLeast(2, kids))) != pred_key(
        canonicalize(And(tuple(kids)))
    )


def test_atleast_rejects_out_of_range_k():
    """A dataclass with a hand-written __init__ never runs __post_init__ —
    the k/arity validation must fire from __init__ itself."""
    kids = [Eq(c, 1) for c in "abcde"]
    for bad in (0, -1, 6):
        with pytest.raises(ValueError, match="1 <= k"):
            AtLeast(bad, kids)
    with pytest.raises(ValueError, match="1 <= k"):
        AtLeast(1, [])
    with pytest.raises(ValueError, match="at most 8"):
        AtLeast(2, [Eq("a", i) for i in range(9)])


def test_degenerate_atleast_shares_plan_cache_entry():
    rng = np.random.default_rng(9)
    store, dev = _store_device(_table(rng, 96, cols=3))
    comp = QueryCompiler(store, dev)
    kids = [Eq("a", 1), Eq("b", 2), Eq("c", 3)]
    first = comp.compile(Query(And(tuple(kids))))
    assert not first.cache_hit
    again = comp.compile(Query(AtLeast(3, kids)))
    assert again.cache_hit
    assert again.plan is first.plan
    assert comp.compile(Query(Or(tuple(kids)))).cache_hit is False
    assert comp.compile(Query(AtLeast(1, kids))).cache_hit


# ---------------------------------------------------------------------------
# threshold kernel: bit-sliced counter vs numpy, all (N, k)
# ---------------------------------------------------------------------------


def test_threshold_kernel_matches_numpy_all_k():
    rng = np.random.default_rng(11)
    for n in (1, 2, 5, 8):
        stack = rng.integers(0, 2**32, (n, 96), dtype=np.uint32)
        bits = np.unpackbits(
            stack.view(np.uint8), bitorder="little"
        ).reshape(n, -1)
        for k in range(1, n + 1):
            want = np.packbits(
                (bits.sum(axis=0) >= k).astype(np.uint8),
                bitorder="little",
            ).view(np.uint32)
            got = np.asarray(
                threshold_reduce(jnp.asarray(stack), k, interpret=True)
            )
            np.testing.assert_array_equal(got, want, err_msg=f"{n},{k}")
            # the shared pure-jnp helper is the same function the Pallas
            # kernel body runs on its tile — spot-check it directly too
            direct = np.asarray(
                bitslice_threshold(jnp.asarray(stack), k, n)[0]
            )
            np.testing.assert_array_equal(direct, want)


# ---------------------------------------------------------------------------
# MLC/TLC packing: density, accounting parity, bit-exact serving
# ---------------------------------------------------------------------------


def test_packing_shrinks_physical_wordlines():
    # cardinality 6 => six-page equality regions, so every level count
    # rounds to a DIFFERENT physical footprint (ceil(6/L) = 6, 3, 2)
    rng = np.random.default_rng(13)
    table = _table(rng, 96, card=6)
    used = {}
    for levels in (1, 2, 3):
        _, dev = _store_device(table, levels=levels)
        used[levels] = dev.layout.physical_wordlines()
    assert used[1] > used[2] > used[3]
    assert used[1] / used[3] >= 1.8


def test_level_one_accounting_is_slc_identical():
    """levels=1 must be bit-for-bit the pre-packing accounting: every
    physical-page group is a singleton, so words_programmed on a pure
    append stream equals words_written exactly."""
    rng = np.random.default_rng(15)
    table = _table(rng, 64)
    store, dev = _store_device(table, levels=1, reserve_rows=64)
    sch = BatchScheduler(dev, store)
    sch.append(_table(rng, 40))
    assert sch.words_programmed == sch.words_written
    assert sch.stats()["write_amplification"] == 1.0


def test_packing_cuts_delta_program_traffic():
    """The tentpole claim at the accounting level: the same append stream
    programs measurably fewer physical words (and pages) at TLC than at
    SLC, while serving stays bit-exact."""
    rng = np.random.default_rng(17)
    table = _table(rng, 64)
    batches = [_table(rng, 24) for _ in range(3)]
    queries = [
        Query(AtLeast(2, [Eq(c, 1) for c in "abc"]), agg=Count()),
        Query(AtLeast(3, [Eq(c, 2) for c in "abcde"]), agg=Count()),
    ]
    stats, answers = {}, {}
    for levels in (1, 3):
        store, dev = _store_device(
            table, levels=levels, reserve_rows=3 * 24
        )
        sch = BatchScheduler(dev, store)
        for b in batches:
            sch.append(b)
        answers[levels] = [r.value for r in sch.serve(queries)]
        stats[levels] = (sch.words_programmed, sch.esp_delta_programs)
    assert answers[1] == answers[3]
    assert stats[3][0] < stats[1][0]  # fewer physical words
    assert stats[3][1] < stats[1][1]  # fewer physical page programs
    assert stats[1][0] / stats[3][0] >= 1.5


def test_snapshot_exposes_threshold_senses():
    rng = np.random.default_rng(19)
    store, dev = _store_device(_table(rng, 96))
    sch = BatchScheduler(dev, store)
    [r] = sch.serve(
        [Query(AtLeast(3, [Eq(c, 1) for c in "abcde"]), agg=Count())]
    )
    mask = (
        sum(
            (np.asarray(v) == 1).astype(int)
            for v in _table(np.random.default_rng(19), 96).values()
        )
        >= 3
    )
    assert r.value == int(mask.sum())
    st = sch.stats()
    assert st["threshold_senses"] == 1
    # the projection prices the staircase sense without erroring
    assert sch.projection()["fc_time_s"] > 0
