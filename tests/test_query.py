"""FlashQL subsystem tests: query results must match the ``eval_expr``
oracle bit-exactly (error injection disabled — every page ESP-programmed),
plus targeted coverage for planner spill paths, ``auto_layout``, the packed
store, and the plan cache."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.bitops import valid_mask
from repro.core.engine import FlashArray, eval_expr
from repro.core.expr import Page, and_, nand_, nor_, not_, or_, leaves
from repro.core.placement import Layout, auto_layout
from repro.core.planner import Planner
from repro.core.store import PackedStore
from repro.query import (
    VALID_PAGE,
    Agg,
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    In,
    Not,
    Query,
    QueryCompiler,
    Range,
    lower,
)
from repro.query.oracle import np_select as _np_oracle
from repro.query.ast import and_ as qand, or_ as qor

W = 8  # words per page for expression-level tests


def _rand_table(rng, n):
    return {
        "country": rng.integers(0, 8, n),
        "device": rng.integers(0, 4, n),
        "age": rng.integers(0, 100, n),
    }




def _random_pred(rng, depth=0):
    kind = rng.integers(0, 6 if depth < 2 else 4)
    if kind == 0:
        return Eq("country", int(rng.integers(0, 8)))
    if kind == 1:
        return In(
            "device", [int(v) for v in rng.choice(4, rng.integers(1, 4))]
        )
    if kind == 2:
        lo = int(rng.integers(0, 80))
        return Range("age", lo, lo + int(rng.integers(0, 40)))
    if kind == 3:
        return Not(_random_pred(rng, depth + 1))
    children = [_random_pred(rng, depth + 1) for _ in range(rng.integers(2, 4))]
    return qand(*children) if kind == 4 else qor(*children)


# ---------------------------------------------------------------------------
# FlashQL end to end
# ---------------------------------------------------------------------------


def test_flashql_random_queries_match_oracles():
    """Every query result matches BOTH the numpy oracle on the raw table and
    the eval_expr oracle on the logical bitmap pages (acceptance criterion)."""
    rng = np.random.default_rng(11)
    n = 3000
    table = _rand_table(rng, n)
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=4)
    store.program(dev)
    sched = BatchScheduler(dev, store)

    queries = [Query(_random_pred(rng), agg=Agg.MASK) for _ in range(20)]
    results = sched.serve(queries)
    for q, r in zip(queries, results):
        want_np = _np_oracle(q.where, table, n)
        got = np.asarray(r.mask.to_bits()).astype(bool)
        np.testing.assert_array_equal(got, want_np)
        # bit-exact vs eval_expr on the *unmasked* packed words
        expr = lower(q.where, store)
        want_words = np.asarray(eval_expr(expr, store.logical))
        got_words = np.asarray(r.mask.words)
        mask = valid_mask(n)
        np.testing.assert_array_equal(got_words & mask, want_words & mask)


def test_flashql_count_matches_mask_popcount():
    rng = np.random.default_rng(3)
    n = 1000
    table = _rand_table(rng, n)
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    sched = BatchScheduler(dev, store)
    pred = qand(Eq("country", 3), Not(Eq("device", 1)))
    (r_count, r_mask) = sched.serve(
        [Query(pred, agg=Agg.COUNT), Query(pred, agg=Agg.MASK)]
    )
    assert r_count.count == int(
        np.asarray(r_mask.mask.to_bits()).astype(bool).sum()
    )
    assert r_count.count == int(_np_oracle(pred, table, n).sum())


def test_batched_execution_equals_sequential():
    """execute_batch (vmap path) and FlashArray.execute agree bit-exactly."""
    rng = np.random.default_rng(5)
    n = 2000
    table = _rand_table(rng, n)
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=4)
    store.program(dev)
    arr = FlashArray()
    store.program(arr)

    compiler = QueryCompiler(store, dev)
    queries = [Query(Eq("country", c)) for c in range(8)]
    plans = [compiler.compile(q).plan for q in queries]
    batch = dev.execute_batch(plans)

    arr_compiler = QueryCompiler(store, arr)
    for q, words in zip(queries, batch):
        seq = arr.execute(arr_compiler.compile(q).plan)
        np.testing.assert_array_equal(np.asarray(words), np.asarray(seq))


def test_plan_cache_hits_on_repeated_shapes():
    rng = np.random.default_rng(8)
    table = _rand_table(rng, 500)
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=1)
    store.program(dev)
    sched = BatchScheduler(dev, store)
    qs = [Query(Eq("country", 1)), Query(Range("age", 10, 20))]
    sched.serve(qs)
    assert sched.compiler.misses == 2 and sched.compiler.hits == 0
    sched.serve(qs)
    assert sched.compiler.misses == 2 and sched.compiler.hits == 2
    # a new ingest (possibly new distinct values) invalidates the cache key
    store.ingest(_rand_table(rng, 500))
    store2_pages = [p for p in store.logical if p not in dev.layout]
    for p in store2_pages:
        dev.fc_write(p, store.logical[p])
    sched.serve([Query(Eq("country", 1))])
    assert sched.compiler.misses == 3


def test_scheduler_stats_and_projection():
    rng = np.random.default_rng(2)
    table = _rand_table(rng, 800)
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    sched = BatchScheduler(dev, store, max_batch=4)
    res = sched.serve([Query(Eq("country", c % 8)) for c in range(10)])
    assert len(res) == 10
    s = sched.stats()
    assert s["queries_served"] == 10
    assert s["flushes"] == 3  # 4 + 4 + 2 under max_batch=4
    assert s["plan_cache_hits"] == 2  # c=0,1 repeat as c=8,9
    proj = sched.projection()
    assert proj["fc_time_s"] > 0 and proj["speedup_vs_osp"] > 0


def test_warmup_placement_uses_auto_layout():
    """Pages named by a warmup query get §6.3 context placement: the OR
    group lands co-located inverted, enabling a single-sensing In()."""
    rng = np.random.default_rng(4)
    table = {"c": rng.integers(0, 4, 300)}
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=1)
    q = Query(In("c", [0, 1, 2]))
    store.program(dev, warmup=[q])
    compiler = QueryCompiler(store, dev)
    plan = compiler.compile(q).plan
    placements = [dev.layout[f"c={v}"] for v in (0, 1, 2)]
    assert all(p.inverted for p in placements)
    assert len({p.block for p in placements}) == 1
    # the OR group itself resolves in ONE sensing; the second senses the
    # spliced tombstone (live-row) wordline the compiler ANDs into every
    # plan — it lives in the plain-page block, outside the inverted group
    assert plan.num_sensing_ops == 2


def test_spilling_plans_join_the_batched_flush():
    """Range plans spill; since the one-dispatch flush they lower to
    batchable ExecPlans (device-resident scratch) instead of falling back
    to eager per-query execution — and repeated flushes must not thrash
    the device snapshot (the pre-pipeline engine re-uploaded the packed
    buffer after every scratch ESP write)."""
    rng = np.random.default_rng(6)
    n = 1200
    table = {"age": rng.integers(0, 64, n)}
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=2)
    store.program(dev)
    sched = BatchScheduler(dev, store)
    q = Query(Range("age", 13, 37))
    (r,) = sched.serve([q])
    assert sched.eager_plans == 0  # spilling plans batch now
    assert r.count == int(((table["age"] >= 13) & (table["age"] <= 37)).sum())
    uploads = dev.store.snapshot_uploads
    (r2,) = sched.serve([q])
    assert r2.count == r.count
    assert dev.store.snapshot_uploads == uploads, (
        "a warm spilling flush must not re-upload the packed store"
    )


# ---------------------------------------------------------------------------
# Planner spill paths and auto_layout (satellite coverage)
# ---------------------------------------------------------------------------


def _write_random(arr, expr, rng):
    logical = {}
    for p in leaves(expr):
        if p.name in logical:
            continue
        words = jnp.array(rng.integers(0, 2**32, (W,), dtype=np.uint32))
        logical[p.name] = words
        arr.fc_write(p.name, words)
    return logical


def _check_auto(expr, seed=0, min_spills=None):
    rng = np.random.default_rng(seed)
    arr = FlashArray()
    arr.layout = auto_layout(expr)
    logical = _write_random(arr, expr, rng)
    plan = Planner(arr.layout).compile(expr)
    got = arr.execute(plan)
    want = eval_expr(expr, logical)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if min_spills is not None:
        assert plan.num_spills >= min_spills, plan
    return plan


def test_nested_nand_nor_spills():
    a, b, c, d, e = map(Page, "abcde")
    _check_auto(and_(nand_(a, b), nor_(c, d), e), seed=1)
    _check_auto(or_(nand_(a, b), nor_(c, d)), seed=2)
    _check_auto(nand_(nor_(a, b), nand_(c, d), e), seed=3)
    _check_auto(nor_(nand_(a, or_(b, c)), and_(d, e)), seed=4)


def test_inverse_chunks_beyond_four_blocks_spill():
    """AND over >4 single-block inverse groups: the De Morgan merge hits the
    ≤4-block power budget, so the 5th+ group forces extra inverse chunks
    that must spill and re-sense (paper §6.2 ordering rule)."""
    groups = [
        or_(Page(f"g{i}a"), Page(f"g{i}b")) for i in range(6)
    ]  # 6 OR groups -> 6 inverse blocks under auto_layout
    expr = and_(*groups)
    plan = _check_auto(expr, seed=7, min_spills=1)
    assert plan.num_sensing_ops >= 3  # 4-block chunk + spill chunk + resense


def test_or_of_spilling_and_chains():
    """OR whose AND children themselves spill (the planner bug found by
    FlashQL's bit-sliced range queries: a spill-chunk command inside an
    inlined AND chain must never initialize the C-latch)."""
    a, b, c, d, e, f = map(Page, "abcdef")
    expr = or_(
        and_(not_(a), not_(b), not_(c)),
        and_(not_(a), not_(b), d),
        and_(e, f),
    )
    rng = np.random.default_rng(12)
    arr = FlashArray()
    arr.layout.place_colocated(list("abcdef"))  # all plain, one block
    logical = _write_random(arr, expr, rng)
    plan = Planner(arr.layout).compile(expr)
    got = arr.execute(plan)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(eval_expr(expr, logical))
    )


def test_rejected_inline_trial_does_not_leak_scratch():
    """An OR child that cannot be inlined (its AND chain spills a C-latch
    subexpression) is trial-compiled and rolled back: the trial's scratch
    placements must not leak into the layout."""
    from repro.core.expr import xor_

    a, b, c, d = map(Page, "abcd")
    expr = or_(and_(a, xor_(b, c)), d)
    rng = np.random.default_rng(13)
    arr = FlashArray()
    arr.layout.place_colocated(list("abc"))
    arr.layout.place_spread(["d"])
    logical = _write_random(arr, expr, rng)
    plan = Planner(arr.layout).compile(expr)
    placed = set(arr.layout.placements)
    used = {
        cmd.page_name
        for cmd in plan.commands
        if hasattr(cmd, "page_name")
    }
    scratch_placed = {n for n in placed if n.startswith("__scratch")}
    assert scratch_placed == used, (scratch_placed, used)
    got = arr.execute(plan)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(eval_expr(expr, logical))
    )


def test_batch_allows_unrelated_non_esp_pages():
    """A non-ESP page the batch never senses must not disable batching,
    but sensing it from the batch path must raise."""
    rng = np.random.default_rng(14)
    table = {"c": rng.integers(0, 4, 200)}
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=1)
    store.program(dev)
    dev.fc_write(
        "telemetry",
        jnp.array(rng.integers(0, 2**32, (store.words,), dtype=np.uint32)),
        esp=False,
    )
    compiler = QueryCompiler(store, dev)
    plan = compiler.compile(Query(Eq("c", 1))).plan
    (out,) = dev.execute_batch([plan])  # unrelated noisy page: fine
    assert out is not None
    noisy_plan = Planner(dev.layout).compile(Page("telemetry"))
    with pytest.raises(ValueError, match="non-ESP"):
        dev.execute_batch([noisy_plan])


def test_auto_layout_or_context_inverts_nested_leaves():
    expr = and_(or_(Page("x"), Page("y")), Page("z"))
    layout = auto_layout(expr)
    assert layout["x"].inverted and layout["y"].inverted
    assert not layout["z"].inverted
    assert layout["x"].block == layout["y"].block


def test_auto_layout_shared_page_keeps_first_placement():
    shared = Page("s")
    expr = or_(shared, and_(shared, Page("u")))
    layout = auto_layout(expr)
    # 's' first appears as a direct OR leaf -> inverted; the nested AND
    # reusing it must not re-place it
    assert layout["s"].inverted
    assert not layout["u"].inverted
    _check_auto(expr, seed=9)


# ---------------------------------------------------------------------------
# Packed store / layout index / determinism
# ---------------------------------------------------------------------------


def test_packed_store_roundtrip_and_planes():
    rng = np.random.default_rng(0)
    st = PackedStore(planes=4)
    pages = {}
    for i in range(5):
        w = rng.integers(0, 2**32, (10,), dtype=np.uint32)
        pages[f"p{i}"] = w
        st[f"p{i}"] = w
    for name, w in pages.items():
        np.testing.assert_array_equal(np.asarray(st[name]), w)
    # 10 words pad to 12 over 4 planes -> 3 words per plane
    assert st.padded_words == 12 and st.words_per_plane == 3
    pv = st.plane_view()
    assert pv.shape == (4, st.num_slots, 3)
    # identity row present at slot 0
    assert np.asarray(st.snapshot())[0].min() == 0xFFFFFFFF


def test_packed_store_rejects_ragged_pages():
    st = PackedStore()
    st["a"] = np.zeros(4, np.uint32)
    with pytest.raises(ValueError):
        st["b"] = np.zeros(5, np.uint32)


def test_layout_reverse_index():
    layout = Layout()
    layout.place("a", 3, 7)
    layout.place("b", 3, 8)
    assert layout.page_at(3, 7) == "a"
    assert layout.page_at(3, 8) == "b"
    with pytest.raises(KeyError):
        layout.page_at(3, 9)
    with pytest.raises(ValueError):
        layout.place("c", 3, 7)  # location occupied


def test_error_injection_reproducible_across_runs():
    """The per-page error seed must be PYTHONHASHSEED-independent: same
    page name + seed => identical injected errors (zlib.crc32, not hash)."""
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = (
        "import numpy as np, jax.numpy as jnp;"
        "from repro.core.engine import FlashArray;"
        "from repro.core.expr import Page;"
        "rng = np.random.default_rng(0);"
        "w = jnp.array(rng.integers(0, 2**32, (256,), dtype=np.uint32));"
        "a = FlashArray(); a.fc_write('noisy', w, esp=False);"
        "a.pec[a.layout['noisy'].block] = 10_000;"
        "print(np.asarray(a.fc_read(Page('noisy'))).sum())"
    )
    outs = set()
    for hashseed in ("0", "12345"):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(repo, "src")
        env["PYTHONHASHSEED"] = hashseed
        r = subprocess.run(
            [sys.executable, "-c", code],
            capture_output=True,
            text=True,
            env=env,
            cwd=repo,
            timeout=300,
        )
        assert r.returncode == 0, r.stderr
        outs.add(r.stdout.strip())
    assert len(outs) == 1, f"hash-seed-dependent injection: {outs}"


def test_range_bsi_uses_logarithmic_pages():
    """Range lowering must touch only BSI slices, not per-value bitmaps."""
    rng = np.random.default_rng(1)
    store = BitmapStore()
    store.ingest({"v": rng.integers(0, 256, 400)})
    expr = lower(Range("v", 10, 200), store)
    names = {p.name for p in leaves(expr)} - {VALID_PAGE}
    assert all("#" in n for n in names), names
    assert len(names) <= 8  # 8 BSI slices for 8-bit values


def test_in_unknown_values_and_empty():
    rng = np.random.default_rng(1)
    table = {"c": rng.integers(0, 3, 100)}
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=1)
    store.program(dev)
    sched = BatchScheduler(dev, store)
    r1, r2, r3 = sched.serve(
        [
            Query(In("c", [0, 99])),  # 99 never occurs
            Query(In("c", [77])),  # no member occurs
            Query(Not(In("c", [77]))),  # complement of empty = all rows
        ]
    )
    assert r1.count == int((table["c"] == 0).sum())
    assert r2.count == 0
    assert r3.count == 100
