"""Telemetry contract: unified registry, conservation invariants, bounded
memory, trace export/nesting, attribution, and the disabled no-op path.

The load-bearing guarantees:

* both schedulers' ``stats()`` are thin views over the telemetry counter
  registry — every counter key in ``stats()`` matches the registry value
  exactly (the bit-compat contract of the migration);
* counter conservation: the fused path spends exactly 1 host transfer and
  <= 1 fused dispatch per flush signature (cross-checked against real
  ``jax.device_get`` calls), and fleet totals equal the sum of the
  per-shard ``shard{j}.*`` mirror counters;
* ``Telemetry(enabled=False)`` changes NO query result (differential
  fleet) and records no spans/histograms/attribution, while counters —
  ``stats()``/projection inputs — keep counting;
* long-running serving keeps bounded memory: per-ticket records are
  popped as tickets complete, and every telemetry buffer is a ring;
* the exported Chrome trace parses, spans nest laminarly per row, and
  overlapping ticket lifetimes export as async pairs.
"""

import json

import numpy as np
import pytest

import jax

from repro.query import (
    BatchScheduler,
    BitmapStore,
    Count,
    Eq,
    FlashDevice,
    Histogram,
    In,
    Query,
    Range,
    Sum,
    Telemetry,
    build_sharded_flashql,
    percentile,
    validate_trace,
)
from repro.query.ast import and_ as qand


def _table(rng, n):
    return {
        "country": rng.integers(0, 6, n),
        "device": rng.integers(0, 4, n),
        "sales": rng.integers(0, 500, n),
    }


def _scheduler(table, planes=2, **kw):
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=planes)
    store.program(dev)
    return BatchScheduler(dev, store, **kw)


def _queries():
    return [
        Query(Eq("country", 1)),
        Query(qand(Eq("country", 2), Eq("device", 1)), agg=Sum("sales")),
        Query(In("device", [0, 2]), agg=Count()),
        Query(Range("sales", 13, 437)),  # deep range: spills
    ]


class _TransferCounter:
    """Counts real ``jax.device_get`` calls after construction."""

    def __init__(self, monkeypatch):
        self.calls = 0
        real = jax.device_get

        def counted(x):
            self.calls += 1
            return real(x)

        monkeypatch.setattr(jax, "device_get", counted)


# ---------------------------------------------------------------------------
# percentile / histogram: the single quantile codepath
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    assert percentile([1, 2, 3, 4, 5], 50) == 3
    assert percentile([1, 2, 3, 4, 5], 95) == 5
    assert percentile([5, 1, 3], 0) == 1
    assert percentile([7], 99) == 7
    # an empty sample set has no distribution — None, never a raise
    # (snapshot() must stay total on a fresh registry)
    assert percentile([], 50) is None


def test_harness_percentile_is_the_telemetry_one():
    import importlib.util
    import pathlib

    spec = importlib.util.spec_from_file_location(
        "_harness",
        pathlib.Path(__file__).parent.parent / "benchmarks" / "_harness.py",
    )
    harness = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(harness)
    assert harness.percentile is percentile
    s = harness.latency_summary([0.4, 0.1, 0.3, 0.2])
    assert s == {"p50": 0.2, "p95": 0.4, "mean": 0.25, "n": 4}


def test_histogram_ring_is_bounded():
    h = Histogram(capacity=4)
    for v in range(100):
        h.observe(float(v))
    assert len(h.samples) == 4
    s = h.summary()
    assert s["count"] == 100  # count/mean cover everything ever observed
    assert s["mean"] == pytest.approx(sum(range(100)) / 100)
    assert s["p50"] == 97.0  # quantiles cover the retained ring
    assert s["max"] == 99.0
    assert Histogram().summary() == {"count": 0}


# ---------------------------------------------------------------------------
# stats() is a thin view over the registry (bit-compat)
# ---------------------------------------------------------------------------


def test_batch_scheduler_stats_mirror_registry():
    rng = np.random.default_rng(0)
    table = _table(rng, 600)
    sched = _scheduler(table, max_batch=3)
    sched.serve(_queries())
    s = sched.stats()
    c = sched.telemetry.snapshot()["counters"]
    for key in (
        "queries_served",
        "flushes",
        "vmap_batches",
        "fused_dispatches",
        "host_transfers",
        "rows_appended",
        "esp_delta_programs",
        "append_batches_coalesced",
    ):
        assert s[key] == c.get(key, 0), key
    assert s["queries_served"] == 4
    assert s["plan_cache_hits"] == sched.compiler.hits
    assert s["plan_cache_misses"] == sched.compiler.misses
    assert s["mean_latency_s"] == pytest.approx(
        c["total_latency_s"] / s["queries_served"]
    )
    assert s["queries_per_sec"] == pytest.approx(
        s["queries_served"] / c["serve_time_s"]
    )
    # snapshot provider sections: plan cache + projection read out together
    snap = sched.telemetry.snapshot()
    assert snap["plan_cache"]["hits"] == sched.compiler.hits
    assert snap["projection"]["fc_time_s"] > 0


def test_sharded_stats_mirror_registry():
    rng = np.random.default_rng(1)
    table = _table(rng, 400)
    sq = build_sharded_flashql(table, 3, queue_depth=4, pipeline=True)
    sq.serve(_queries())
    s = sq.stats()
    c = sq.telemetry.snapshot()["counters"]
    for key in (
        "queries_served",
        "flushes",
        "pipelined_flushes",
        "fused_dispatches",
        "host_transfers",
        "shards_pruned",
        "distinct_signatures",
    ):
        assert s[key] == c.get(key, 0), key
    assert s["vmap_batches"] == c.get("signature_groups", 0)
    assert s["plan_cache_hits"] == sum(x.hits for x in sq.compilers)
    snap = sq.telemetry.snapshot()
    assert snap["plan_cache"]["misses"] == sum(
        x.misses for x in sq.compilers
    )
    assert snap["projection"]["num_devices"] == 3


# ---------------------------------------------------------------------------
# counter conservation
# ---------------------------------------------------------------------------


def test_fused_flush_counters_match_real_transfers(monkeypatch):
    """Registry counters must agree with actual device_get traffic: one
    transfer and one fused dispatch per flush signature."""
    rng = np.random.default_rng(2)
    table = _table(rng, 500)
    sched = _scheduler(table)
    queries = _queries()
    for q in queries:
        sched.submit(q)
    counter = _TransferCounter(monkeypatch)
    sched.flush()
    assert counter.calls == 1
    assert sched.host_transfers == 1
    assert sched.fused_dispatches == 1
    # recurring composition: same flush signature, still 1 transfer each
    for q in queries:
        sched.submit(q)
    sched.flush()
    assert counter.calls == 2
    assert sched.host_transfers == 2
    assert sched.fused_dispatches == 2
    assert len(sched._flush_programs) == 1  # one program, re-dispatched


def test_sharded_totals_equal_per_shard_sums(monkeypatch):
    rng = np.random.default_rng(3)
    table = _table(rng, 600)
    sq = build_sharded_flashql(table, 4, queue_depth=8, pipeline=True)
    counter = _TransferCounter(monkeypatch)
    sq.serve(_queries())
    c = sq.telemetry.snapshot()["counters"]
    n = sq.store.num_shards
    for total, shard_key in (
        ("host_transfers", "host_transfers"),
        ("fused_dispatches", "fused_dispatches"),
        ("esp_delta_programs", "esp_programs"),
    ):
        assert c.get(total, 0) == sum(
            c.get(f"shard{s}.{shard_key}", 0) for s in range(n)
        ), total
    assert c["host_transfers"] == counter.calls
    # the legacy list attributes read the same per-shard mirrors
    assert sq.shard_wordlines == [
        int(c.get(f"shard{s}.wordlines_sensed", 0)) for s in range(n)
    ]
    assert sum(sq.shard_wordlines) > 0


# ---------------------------------------------------------------------------
# disabled telemetry: no-op recorders, identical results
# ---------------------------------------------------------------------------


def test_disabled_telemetry_changes_no_result():
    rng = np.random.default_rng(4)
    table = _table(rng, 500)
    queries = _queries()
    on = build_sharded_flashql(table, 3, queue_depth=4, pipeline=True)
    off = build_sharded_flashql(table, 3, queue_depth=4, pipeline=True)
    off.telemetry.enabled = False
    res_on = on.serve(queries)
    res_off = off.serve(queries)
    for a, b in zip(res_on, res_off):
        if hasattr(a.value, "words"):
            np.testing.assert_array_equal(
                np.asarray(a.value.words), np.asarray(b.value.words)
            )
        else:
            assert a.value == b.value
        assert a.attribution is not None
        assert b.attribution is None
    # same on the unsharded scheduler, against its own disabled twin
    s_on = _scheduler(table)
    s_off = _scheduler(table, telemetry=Telemetry(enabled=False))
    for a, b in zip(s_on.serve(queries), s_off.serve(queries)):
        if hasattr(a.value, "words"):
            np.testing.assert_array_equal(
                np.asarray(a.value.words), np.asarray(b.value.words)
            )
        else:
            assert a.value == b.value
    # disabled: no per-event machinery ran, but counters kept counting
    snap = off.telemetry.snapshot()
    assert snap["enabled"] is False
    assert snap["histograms"] == {}
    assert snap["trace_events"] == 0
    assert snap["slow_queries"] == []
    assert snap["counters"]["queries_served"] == len(queries)
    assert off.stats()["host_transfers"] == on.stats()["host_transfers"]
    assert snap["projection"]["fc_time_s"] > 0  # projection still works


# ---------------------------------------------------------------------------
# bounded memory over long-running serving
# ---------------------------------------------------------------------------


def test_long_running_serving_keeps_bounded_state():
    rng = np.random.default_rng(5)
    table = _table(rng, 300)
    sq = build_sharded_flashql(table, 2, queue_depth=2, pipeline=True)
    sq.telemetry = type(sq.telemetry)(
        trace_capacity=16, hist_capacity=8, slow_capacity=4,
        slow_latency_s=0.0,
    )
    sq.__post_init__()  # rewire the smaller registry through the stack
    queries = _queries()
    for _ in range(12):  # 12 serve cycles, multiple flushes each
        sq.serve(queries)
    # per-ticket records are popped as tickets complete
    assert sq._meta == {}
    assert sq._partials == {}
    assert sq._cache_hits == {}
    assert sq._attr == {}
    # every telemetry buffer is a ring at its configured capacity
    tele = sq.telemetry
    assert len(tele.trace) <= 16
    assert len(tele.slow_queries) <= 4
    assert all(len(h.samples) <= 8 for h in tele.hists.values())
    assert tele.hists["query_latency_s"].count == 12 * len(queries)


def test_unsharded_pending_drains():
    rng = np.random.default_rng(6)
    table = _table(rng, 300)
    sched = _scheduler(table, max_batch=2)
    for _ in range(6):
        sched.serve(_queries())
    assert sched._pending == []
    assert sched.pending == 0


# ---------------------------------------------------------------------------
# trace export + nesting
# ---------------------------------------------------------------------------


def test_trace_export_parses_and_nests(tmp_path):
    rng = np.random.default_rng(7)
    table = _table(rng, 500)
    sq = build_sharded_flashql(table, 4, queue_depth=4, pipeline=True)
    sq.serve(_queries())
    sq.serve(_queries())
    path = tmp_path / "trace.json"
    sq.telemetry.export_trace(str(path))
    trace = json.loads(path.read_text())
    n = validate_trace(trace)
    assert n > 0
    names = {e["name"] for e in trace["traceEvents"]}
    # the flush lifecycle is visible: per-shard compile/dispatch/transfer
    # rows, the merge row, the enclosing flush spans, and ticket asyncs
    for expected in ("flush", "compile", "dispatch", "transfer", "merge",
                     "ticket"):
        assert expected in names, expected
    rows = {
        e["args"]["name"]
        for e in trace["traceEvents"]
        if e["ph"] == "M" and e["name"] == "thread_name"
    }
    assert {"shard 0", "shard 3", "merge", "flush", "tickets"} <= rows
    # ticket lifetimes export as async pairs (they legitimately overlap)
    assert any(e["ph"] == "b" for e in trace["traceEvents"])


def test_batch_scheduler_trace_nests():
    rng = np.random.default_rng(8)
    table = _table(rng, 400)
    sched = _scheduler(table, max_batch=2)
    sched.serve(_queries())
    trace = sched.telemetry.export_trace()
    assert validate_trace(trace) > 0
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"flush", "compile", "dispatch", "transfer", "reduce"} <= names


def test_validate_trace_rejects_partial_overlap():
    bad = {
        "traceEvents": [
            {"name": "a", "ph": "X", "pid": 0, "tid": 0, "ts": 0.0,
             "dur": 10.0},
            {"name": "b", "ph": "X", "pid": 0, "tid": 0, "ts": 5.0,
             "dur": 10.0},
        ]
    }
    with pytest.raises(ValueError, match="overlaps"):
        validate_trace(bad)
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({})
    # same spans on DIFFERENT rows are fine — that overlap is pipelining
    ok = {
        "traceEvents": [
            {"name": "a", "ph": "X", "pid": 0, "tid": 0, "ts": 0.0,
             "dur": 10.0},
            {"name": "b", "ph": "X", "pid": 0, "tid": 1, "ts": 5.0,
             "dur": 10.0},
        ]
    }
    assert validate_trace(ok) == 2


# ---------------------------------------------------------------------------
# attribution + slow-query log
# ---------------------------------------------------------------------------


def test_attribution_contents_unsharded():
    rng = np.random.default_rng(9)
    table = _table(rng, 500)
    sched = _scheduler(table)
    results = sched.serve(_queries())
    for r in results:
        a = r.attribution
        assert a["sensings"] >= 1
        assert a["wordlines"] >= 1
        for phase in ("queue_s", "compile_s", "device_s", "transfer_s",
                      "reduce_s"):
            assert a[phase] >= 0.0
    # the deep range spills; the equality queries don't
    spill = results[3].attribution
    assert spill["spill_steps"] > 0
    assert results[0].attribution["spill_steps"] == 0
    # a SUM senses extra BSI planes, attributed as aggregate slice reads
    assert results[1].attribution["agg_plane_reads"] > 0
    assert results[0].attribution["agg_plane_reads"] == 0


def test_attribution_counts_serving_shards():
    rng = np.random.default_rng(10)
    n = 300
    table = {
        "k": np.arange(n),
        "v": rng.integers(0, 4, n),
    }
    sq = build_sharded_flashql(
        table, 3, policy="range", stripe_key="k", queue_depth=8,
        pipeline=True,
    )
    # key-range query routes to one stripe; the broad one hits all three
    res = sq.serve([
        Query(Range("k", 0, 10), agg=Count()),
        Query(In("v", [0, 1, 2, 3]), agg=Count()),
    ])
    assert res[0].attribution["shards"] == 1
    assert res[1].attribution["shards"] == 3
    assert sq.shards_pruned == 2


def test_slow_query_log_thresholds():
    rng = np.random.default_rng(11)
    table = _table(rng, 400)
    queries = _queries()
    # latency threshold 0: every ticket is "slow"
    sched = _scheduler(table, telemetry=Telemetry(slow_latency_s=0.0))
    sched.serve(queries)
    log = list(sched.telemetry.slow_queries)
    assert len(log) == len(queries)
    assert log[0]["predicate"] == repr(queries[0].where)
    assert log[0]["attribution"]["sensings"] >= 1
    assert log[0]["latency_s"] > 0
    # unreachable thresholds: nothing logged
    quiet = _scheduler(
        table,
        telemetry=Telemetry(slow_latency_s=1e9, slow_sensings=10**9),
    )
    quiet.serve(queries)
    assert list(quiet.telemetry.slow_queries) == []
    # sensing threshold alone also triggers
    sensed = _scheduler(table, telemetry=Telemetry(slow_sensings=1))
    sensed.serve(queries)
    assert len(sensed.telemetry.slow_queries) == len(queries)
