"""Pluggable aggregation pipeline: every aggregate is an :class:`Aggregator`.

The result path of FlashQL used to special-case ``Agg.COUNT`` / ``Agg.MASK``
by hand in both schedulers; this module replaces those ladders with one
interface.  An :class:`Aggregator` declares

* **extra sensed planes** (:meth:`Aggregator.extra_pages`) — the BSI slices
  and/or equality bitmaps of its target column, fetched through
  :func:`repro.query.bitmap.fetch_pages`;
* **a batched device-side reduce** (:meth:`Aggregator.batch_reduce`) — one
  jit'd (weighted-)popcount over the stacked result bitmaps of a flush:
  ``SUM = Σ_b 2^b · popcount(mask ∧ slice_b)`` (Pinatubo/DrAcc-style
  bit-slice arithmetic), ``MIN``/``MAX`` walk slices MSB→LSB narrowing a
  candidate mask, ``AVG = SUM / COUNT``, and ``TOP-K`` / ``GROUP BY``
  reduce per-group masks from the equality bitmaps;
* **a shard-merge rule** (:meth:`Aggregator.merge`) — sum partials, take
  the min/max, merge count vectors, or un-stripe bitmaps — so
  ``ShardedFlashQL`` gathers any aggregate without per-kind branches.

``COUNT`` and ``MASK`` are trivial instances of the same interface.
:func:`reduce_flush` is the shared driver both schedulers call: it groups a
flush's members by *reduce signature* (aggregator kind + static shapes), so
a flush mixing every aggregate kind still costs O(distinct signatures)
kernel dispatches and ONE host transfer per group.

Exact-integer guarantee: device kernels only ever produce popcounts
(int32); the 2^b weighting happens host-side in Python integers, so SUM
and the AVG numerator are exact at any bit width.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import BitVector
from repro.kernels.popcount import popcount
from repro.query.ast import (
    AggSpec,
    Avg,
    Count,
    GroupBy,
    Mask,
    Max,
    Min,
    Query,
    Sum,
    TopK,
    columns_of,
    normalize_agg,
)
from repro.query.bitmap import BitmapStore, bsi_pages, eq_pages, fetch_pages

# -- jitted batched reduces --------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def sliced_counts(
    masks: jax.Array, extras: jax.Array, *, interpret: bool
) -> jax.Array:
    """``(B, P)`` popcounts of ``mask ∧ page`` for every member × page.

    The weighted-popcount workhorse: one fused intersect + ONE batched
    popcount dispatch covers every (member, slice) pair of a flush group.
    """
    b, p, w = extras.shape
    inter = masks[:, None, :] & extras
    return popcount(inter.reshape(b * p, w), interpret=interpret).reshape(
        b, p
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def sliced_counts_with_total(
    masks: jax.Array, extras: jax.Array, *, interpret: bool
) -> jax.Array:
    """``(B, P+1)``: per-slice popcounts plus the plain mask popcount in
    the last column (AVG's numerator slices + denominator, one dispatch)."""
    aug = jnp.concatenate([extras, masks[:, None, :]], axis=1)
    return sliced_counts(masks, aug, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("maximize",))
def bsi_extreme(
    masks: jax.Array, extras: jax.Array, *, maximize: bool
) -> tuple[jax.Array, jax.Array]:
    """Bit-sliced MIN/MAX walk over ``(B, bits, W)`` BSI slices.

    MSB→LSB, a candidate mask narrows to the rows still extremal: for MAX,
    if any candidate has bit b set, the extremum does too and candidates
    without it drop out; MIN walks the complemented slice.  Returns the
    per-bit decisions ``(B, bits)`` (LSB first) and a per-member non-empty
    flag — the host assembles the exact integer, so any bit width works.
    """
    bits = extras.shape[1]
    cand = masks
    decisions = []
    for b in range(bits - 1, -1, -1):
        sl = extras[:, b, :]
        # cand has no padding bits (masks are validity-masked), so the
        # complement's padding ones never enter the candidate set
        t = cand & (sl if maximize else ~sl)
        nz = (t != 0).any(axis=-1)
        cand = jnp.where(nz[:, None], t, cand)
        decisions.append(nz if maximize else ~nz)
    dec = jnp.stack(decisions[::-1], axis=1)
    return dec, (masks != 0).any(axis=-1)


@functools.partial(jax.jit, static_argnames=("groups", "bits", "interpret"))
def pervalue_counts(
    masks: jax.Array,
    extras: jax.Array,
    *,
    groups: int,
    bits: int,
    interpret: bool,
):
    """Per-group popcounts for TOP-K / GROUP BY.

    ``extras`` stacks ``groups`` equality bitmaps then ``bits`` BSI slices
    of the inner-aggregate column (``bits == 0`` for plain counts).  Group
    counts and per-(group, slice) counts run as ONE batched popcount.
    """
    b, _, w = extras.shape
    gm = masks[:, None, :] & extras[:, :groups, :]  # (B, G, W)
    if not bits:
        return popcount(
            gm.reshape(b * groups, w), interpret=interpret
        ).reshape(b, groups)
    sl = extras[:, groups:, :]
    inter = gm[:, :, None, :] & sl[:, None, :, :]  # (B, G, bits, W)
    rows = jnp.concatenate(
        [gm.reshape(b * groups, w), inter.reshape(b * groups * bits, w)]
    )
    counts = popcount(rows, interpret=interpret)
    return (
        counts[: b * groups].reshape(b, groups),
        counts[b * groups :].reshape(b, groups, bits),
    )


def _weighted(counts: Iterable) -> int:
    """Exact Σ 2^b · count_b in Python integers (LSB first)."""
    return sum(int(c) << b for b, c in enumerate(counts))


# -- the interface -----------------------------------------------------------


@dataclass(frozen=True)
class Aggregator:
    """One aggregate's execution semantics (see module docstring).

    Stateless and cached per spec (:func:`get_aggregator`); everything a
    flush needs is parameterized on the :class:`BitmapStore` whose pages
    the member's predicate was evaluated against.
    """

    spec: AggSpec
    kind = "abstract"
    # does the SSD projection model host-side postprocessing (popcounts /
    # arithmetic) for this aggregate, or does the bitmap stream out raw?
    host_postprocess = True

    # -- admission-time validation
    def validate(self, columns: Mapping[str, object]) -> None:
        """Raise before a bad query can enter a flush (queue poisoning)."""

    # -- extra sensed planes
    def extra_pages(self, store: BitmapStore) -> tuple[str, ...]:
        return ()

    def reduce_sig(self, store: BitmapStore) -> tuple:
        """Static part of the batched reduce: members of one flush with
        equal ``(kind,) + reduce_sig`` reduce together in one dispatch."""
        return ()

    # -- batched device-side reduce
    def batch_reduce(self, masks, extras, sig: tuple, *, interpret: bool):
        """Reduce ``(B, W)`` result bitmaps (+ ``(B, P, W)`` extra planes)
        to per-member device values; one jit'd dispatch per group.  ``sig``
        is the group's :meth:`reduce_sig` (static shape info).

        Implementations depend only on ``(masks, extras, sig, interpret)``
        — never on ``self.spec`` — so the fused flush program can dispatch
        a reduce from ``(kind, sig)`` alone (:func:`kind_reduce`).
        """
        raise NotImplementedError

    def payload_leaves(
        self, sig: tuple, b: int, w: int
    ) -> tuple[tuple[tuple[int, ...], object], ...]:
        """``(shape, dtype)`` of every :meth:`batch_reduce` output leaf for
        a ``b``-member group over ``w``-word bitmaps — the static layout of
        this group's slice of a fused flush's single ``uint32`` payload
        (:func:`unpack_group` re-assembles the host structure from it)."""
        raise NotImplementedError

    def member_partial(self, host, j: int):
        """Slice member ``j``'s partial out of the host-transferred reduce
        output (the per-shard unit :meth:`merge` combines)."""
        raise NotImplementedError

    def empty_partial(self, store: BitmapStore):
        """The partial of a shard whose stripe provably cannot match (range
        routing prunes it before scatter, no sensing at all)."""
        raise NotImplementedError

    # -- gather
    def finalize(self, partial, store: BitmapStore):
        """Partial -> final value on a single (unsharded) store."""
        raise NotImplementedError

    def merge(self, parts: dict[int, object], sstore) -> object:
        """Shard partials -> final value (``sstore``: ShardedBitmapStore)."""
        raise NotImplementedError

    # -- helpers
    def _column(self) -> str:
        return self.spec.column

    def _require(self, columns: Mapping[str, object], name: str) -> None:
        if name not in columns:
            raise KeyError(
                f"unknown aggregate column {name!r} for {self.kind.upper()}"
            )

    def _first_store(self, parts: dict[int, object], sstore) -> BitmapStore:
        return sstore.shards[next(iter(parts))]


class CountAggregator(Aggregator):
    kind = "count"

    def batch_reduce(self, masks, extras, sig, *, interpret):
        return popcount(masks, interpret=interpret)

    def payload_leaves(self, sig, b, w):
        return (((b,), np.int64),)

    def member_partial(self, host, j):
        return int(host[j])

    def empty_partial(self, store):
        return 0

    def finalize(self, partial, store):
        return partial

    def merge(self, parts, sstore):
        return sum(parts.values())


class MaskAggregator(Aggregator):
    kind = "mask"
    host_postprocess = False  # the bitmap streams out as-is

    def batch_reduce(self, masks, extras, sig, *, interpret):
        return masks

    def payload_leaves(self, sig, b, w):
        return (((b, w), np.uint32),)

    def member_partial(self, host, j):
        return host[j]  # (W,) uint32 words

    def empty_partial(self, store):
        return np.zeros((store.words,), np.uint32)

    def finalize(self, partial, store):
        # keep the host words as-is: BitVector's jnp ops auto-convert on
        # use, so no eager host->device re-upload on the serving path
        return BitVector(partial, store.num_rows)

    def merge(self, parts, sstore):
        # un-stripe per-shard bitmaps back into global row order — pure
        # numpy: partials arrive host-side (payload words), and the jnp
        # unpack/pack round-trip cost ~a dispatch per shard per MASK here
        bits = np.zeros((sstore.num_rows,), dtype=np.uint8)
        for s, words in parts.items():
            n_s = sstore.shards[s].num_rows
            w = np.ascontiguousarray(np.asarray(words))
            bits[sstore.row_maps[s]] = np.unpackbits(
                w.view(np.uint8), bitorder="little"
            )[:n_s]
        span = np.zeros((len(bits) + 31) // 32 * 32, dtype=np.uint8)
        span[: len(bits)] = bits
        packed = np.packbits(span, bitorder="little").view(np.uint32)
        return BitVector(packed, sstore.num_rows)


def merge_mask_batch(parts_list, sstore) -> list:
    """Un-stripe a whole flush's MASK tickets in one fused numpy pass.

    ``parts_list`` holds each MASK ticket's shard partials (the dicts
    :meth:`MaskAggregator.merge` takes).  The per-ticket merge pays an
    unpack/scatter pass per (ticket x shard) plus a packbits per ticket;
    here every shard's words stack across tickets first, so the flush
    costs ONE unpackbits + scatter per shard and ONE packbits total —
    the dominant host-side cost of MASK-heavy sharded flushes.
    Returns one :class:`BitVector` per ticket, in ``parts_list`` order.
    """
    t_count = len(parts_list)
    bits = np.zeros((t_count, sstore.num_rows), dtype=np.uint8)
    shards = sorted({s for parts in parts_list for s in parts})
    for s in shards:
        n_s = sstore.shards[s].num_rows
        rows = [t for t in range(t_count) if s in parts_list[t]]
        words = np.ascontiguousarray(
            np.stack([np.asarray(parts_list[t][s]) for t in rows])
        )
        unpacked = np.unpackbits(
            words.view(np.uint8), axis=1, bitorder="little"
        )[:, :n_s]
        bits[
            np.asarray(rows, np.intp)[:, None],
            sstore.row_maps[s][None, :],
        ] = unpacked
    pad = (sstore.num_rows + 31) // 32 * 32
    span = np.zeros((t_count, pad), dtype=np.uint8)
    span[:, : sstore.num_rows] = bits
    packed = np.packbits(span, axis=1, bitorder="little").view(np.uint32)
    return [BitVector(packed[t], sstore.num_rows) for t in range(t_count)]


class SumAggregator(Aggregator):
    kind = "sum"

    def validate(self, columns):
        self._require(columns, self._column())

    def extra_pages(self, store):
        return bsi_pages(store, self._column())

    def reduce_sig(self, store):
        return (store.columns[self._column()].bits,)

    def batch_reduce(self, masks, extras, sig, *, interpret):
        return sliced_counts(masks, extras, interpret=interpret)

    def payload_leaves(self, sig, b, w):
        return (((b, sig[0]), np.int64),)

    def member_partial(self, host, j):
        return host[j]  # (bits,) per-slice popcounts

    def empty_partial(self, store):
        return np.zeros((store.columns[self._column()].bits,), np.int64)

    def finalize(self, partial, store):
        return _weighted(partial)

    def merge(self, parts, sstore):
        return sum(_weighted(p) for p in parts.values())


class AvgAggregator(SumAggregator):
    kind = "avg"

    def batch_reduce(self, masks, extras, sig, *, interpret):
        return sliced_counts_with_total(masks, extras, interpret=interpret)

    def payload_leaves(self, sig, b, w):
        return (((b, sig[0] + 1), np.int64),)

    def member_partial(self, host, j):
        return host[j]  # (bits + 1,): slice popcounts + row count

    def empty_partial(self, store):
        return np.zeros(
            (store.columns[self._column()].bits + 1,), np.int64
        )

    def finalize(self, partial, store):
        count = int(partial[-1])
        if not count:
            return None
        return _weighted(partial[:-1]) / count

    def merge(self, parts, sstore):
        total = np.sum(
            np.stack([np.asarray(p) for p in parts.values()]),
            axis=0,
            dtype=np.int64,
        )
        return self.finalize(total, self._first_store(parts, sstore))


class ExtremeAggregator(Aggregator):
    """Shared MIN/MAX implementation (the walk differs by one flag)."""

    maximize = False

    def validate(self, columns):
        self._require(columns, self._column())

    def extra_pages(self, store):
        return bsi_pages(store, self._column())

    def reduce_sig(self, store):
        return (store.columns[self._column()].bits, self.maximize)

    def batch_reduce(self, masks, extras, sig, *, interpret):
        # maximize comes from sig (not self.spec) so the fused flush
        # program can run this reduce from the group key alone
        return bsi_extreme(masks, extras, maximize=sig[1])

    def payload_leaves(self, sig, b, w):
        return (((b, sig[0]), np.bool_), ((b,), np.bool_))

    def member_partial(self, host, j):
        dec, nonempty = host
        return (np.asarray(dec[j]), bool(nonempty[j]))

    def empty_partial(self, store):
        bits = store.columns[self._column()].bits
        return (np.zeros((bits,), bool), False)

    def finalize(self, partial, store):
        dec, nonempty = partial
        if not nonempty:
            return None
        return _weighted(dec)

    def merge(self, parts, sstore):
        store = self._first_store(parts, sstore)
        vals = [
            v
            for v in (self.finalize(p, store) for p in parts.values())
            if v is not None
        ]
        if not vals:
            return None
        return max(vals) if self.maximize else min(vals)


class MinAggregator(ExtremeAggregator):
    kind = "min"
    maximize = False


class MaxAggregator(ExtremeAggregator):
    kind = "max"
    maximize = True


class PerValueAggregator(Aggregator):
    """Shared TOP-K / GROUP BY machinery: per-group masks from the target
    column's equality bitmaps, reduced to per-group (count[, slice-count])
    vectors that merge across shards by elementwise sum — the global
    schema aligns value order on every shard."""

    def _key_column(self) -> str:
        raise NotImplementedError

    def _inner_bits_column(self) -> str | None:
        return None  # BSI slices of the inner SUM/AVG column, if any

    def extra_pages(self, store):
        pages = eq_pages(store, self._key_column())
        inner = self._inner_bits_column()
        if inner is not None:
            pages += bsi_pages(store, inner)
        return pages

    def reduce_sig(self, store):
        groups = len(store.columns[self._key_column()].values)
        inner = self._inner_bits_column()
        bits = store.columns[inner].bits if inner is not None else 0
        return (groups, bits)

    def batch_reduce(self, masks, extras, sig, *, interpret):
        groups, bits = sig
        return pervalue_counts(
            masks, extras, groups=groups, bits=bits, interpret=interpret
        )

    def payload_leaves(self, sig, b, w):
        groups, bits = sig
        if not bits:
            return (((b, groups), np.int64),)
        return (((b, groups), np.int64), ((b, groups, bits), np.int64))

    def member_partial(self, host, j):
        if isinstance(host, tuple):
            return (host[0][j], host[1][j])
        return host[j]

    def empty_partial(self, store):
        groups = len(store.columns[self._key_column()].values)
        inner = self._inner_bits_column()
        if inner is None:
            return np.zeros((groups,), np.int64)
        bits = store.columns[inner].bits
        return (
            np.zeros((groups,), np.int64),
            np.zeros((groups, bits), np.int64),
        )

    def merge(self, parts, sstore):
        vals = list(parts.values())
        if isinstance(vals[0], tuple):
            total = tuple(
                np.sum(
                    np.stack([np.asarray(v[i]) for v in vals]),
                    axis=0,
                    dtype=np.int64,
                )
                for i in range(2)
            )
        else:
            total = np.sum(
                np.stack([np.asarray(v) for v in vals]),
                axis=0,
                dtype=np.int64,
            )
        return self.finalize(total, self._first_store(parts, sstore))


class TopKAggregator(PerValueAggregator):
    kind = "topk"

    def validate(self, columns):
        self._require(columns, self.spec.column)
        if self.spec.k < 1:
            raise ValueError(f"TopK needs k >= 1, got {self.spec.k}")

    def _key_column(self):
        return self.spec.column

    def finalize(self, partial, store):
        values = store.columns[self.spec.column].values
        ranked = sorted(
            ((v, int(c)) for v, c in zip(values, partial)),
            key=lambda vc: (-vc[1], vc[0]),
        )
        return tuple((v, c) for v, c in ranked if c > 0)[: self.spec.k]


class GroupByAggregator(PerValueAggregator):
    kind = "groupby"

    def validate(self, columns):
        self._require(columns, self.spec.key)
        inner = self.spec.value
        if not isinstance(inner, (Count, Sum, Avg)):
            raise TypeError(
                f"GroupBy value must be Count/Sum/Avg, got {inner!r}"
            )
        if isinstance(inner, (Sum, Avg)):
            self._require(columns, inner.column)

    def _key_column(self):
        return self.spec.key

    def _inner_bits_column(self):
        inner = self.spec.value
        return inner.column if isinstance(inner, (Sum, Avg)) else None

    def finalize(self, partial, store):
        values = store.columns[self.spec.key].values
        inner = self.spec.value
        if isinstance(inner, Count):
            return {
                v: int(c) for v, c in zip(values, partial) if int(c) > 0
            }
        counts, slices = partial
        out = {}
        for g, v in enumerate(values):
            c = int(counts[g])
            if not c:
                continue
            num = _weighted(slices[g])
            out[v] = num / c if isinstance(inner, Avg) else num
        return out


_AGGREGATORS: dict[type, type[Aggregator]] = {
    Count: CountAggregator,
    Mask: MaskAggregator,
    Sum: SumAggregator,
    Avg: AvgAggregator,
    Min: MinAggregator,
    Max: MaxAggregator,
    TopK: TopKAggregator,
    GroupBy: GroupByAggregator,
}

# spec-less instances for kind-keyed dispatch: batch_reduce and
# payload_leaves are functions of (kind, sig) only, which is what lets a
# fused flush program be compiled from its static flush signature
_BY_KIND: dict[str, Aggregator] = {
    cls.kind: cls(spec=None) for cls in _AGGREGATORS.values()
}


def kind_reduce(kind: str, masks, extras, sig: tuple, *, interpret: bool):
    """Run one reduce group's device computation from its static group key
    — the traced body :func:`repro.query.device.make_flush_runner` inlines
    per reduce group of a fused flush program."""
    return _BY_KIND[kind].batch_reduce(
        masks, extras, sig, interpret=interpret
    )


def payload_spec(
    kind: str, sig: tuple, b: int, w: int
) -> tuple[tuple[tuple[int, ...], object], ...]:
    """Static ``(shape, dtype)`` leaves of one group's payload slice."""
    return _BY_KIND[kind].payload_leaves(sig, b, w)


def payload_size(leaves) -> int:
    """Flat ``uint32`` words one group contributes to the fused payload."""
    return sum(int(np.prod(shape)) for shape, _ in leaves)


def unpack_group(flat: np.ndarray, leaves):
    """Re-assemble one reduce group's host structure from its payload slice.

    Inverse of the fused runner's ``ravel().astype(uint32)`` flattening:
    counts come back as exact ``int64`` (device popcounts are int32, so the
    uint32 round-trip is lossless), MASK words stay ``uint32``, and the
    MIN/MAX decision/non-empty flags come back as booleans.  Returns the
    same structure ``jax.device_get(batch_reduce(...))`` would have — a
    single array or a tuple — so :meth:`Aggregator.member_partial` applies
    unchanged.
    """
    out = []
    off = 0
    for shape, dtype in leaves:
        n = int(np.prod(shape))
        out.append(flat[off : off + n].reshape(shape).astype(dtype))
        off += n
    return out[0] if len(out) == 1 else tuple(out)


@functools.lru_cache(maxsize=1024)
def get_aggregator(agg) -> Aggregator:
    """Aggregator for a spec (or legacy ``Agg`` enum member); cached."""
    spec = normalize_agg(agg)
    cls = _AGGREGATORS.get(type(spec))
    if cls is None:
        raise TypeError(f"no aggregator registered for {spec!r}")
    return cls(spec)


def validate_query(query: Query, columns: Mapping[str, object]) -> Aggregator:
    """Admission-time validation shared by both schedulers.

    Checks every predicate column and the aggregate's target columns
    against ``columns`` (any mapping keyed on column name) so a bad query
    raises at ``submit()`` — never mid-flush, where a sharded deployment
    would have already popped some shard queues (a poisoned ticket).
    Returns the query's aggregator.
    """
    for col in columns_of(query.where):
        if col not in columns:
            raise KeyError(f"unknown column {col!r}")
    agg = get_aggregator(query.agg)
    agg.validate(columns)
    return agg


# -- the shared flush driver -------------------------------------------------


def _cached_pages(
    agg: Aggregator, store: BitmapStore, store_key, cache: dict, cap: int
) -> tuple[str, ...]:
    """Memoized :meth:`Aggregator.extra_pages`: TopK/GroupBy page tuples
    are O(column cardinality) f-strings, too hot to rebuild per flush."""
    pkey = ("pages", agg.spec, store_key)
    pages = cache.get(pkey)
    if pages is None:
        _evict_one(cache, cap)
        pages = agg.extra_pages(store)
        cache[pkey] = pages
    return pages


def _evict_one(cache: dict, cap: int) -> None:
    """Bound the shared extras cache by evicting the oldest entry —
    wholesale clears would dump every namespace (page tuples, per-member
    stacks, group stacks) mid-flush and force a re-fetch cliff."""
    if len(cache) >= cap:
        cache.pop(next(iter(cache)))


def group_members(
    specs: list, stores: list[BitmapStore]
) -> tuple[list[Aggregator], dict[tuple, list[int]]]:
    """Group a flush's members by reduce signature ``(kind,) + reduce_sig``.

    The shared first step of both reduce drivers: the per-group transfer
    path (:func:`reduce_flush`) and the single-payload fused flush program
    (:func:`repro.query.compile.compile_flush`).
    """
    aggs = [get_aggregator(sp) for sp in specs]
    groups: dict[tuple, list[int]] = {}
    for i, a in enumerate(aggs):
        groups.setdefault((a.kind,) + a.reduce_sig(stores[i]), []).append(i)
    return aggs, groups


def group_extras(
    aggs: list[Aggregator],
    members: list[int],
    stores: list[BitmapStore],
    store_keys: list,
    extras_cache: dict,
    cache_cap: int,
):
    """Stacked ``(B_g, P, W)`` extra sensed planes of one reduce group.

    Returns ``(extras, counts)`` where ``extras`` is the device stack (or
    None when the group's aggregate senses no extra planes) and ``counts``
    maps member index -> planes sensed (the caller's projected-traffic
    accounting).  The group stack is memoized per member composition:
    recurring flush compositions — steady-state serving — skip the
    per-member fetches AND the device concat.
    """
    member_pages = [
        _cached_pages(aggs[i], stores[i], store_keys[i], extras_cache, cache_cap)
        for i in members
    ]
    counts: dict[int, int] = {}
    if not member_pages[0]:
        return None, counts
    cks = []
    for i, pages in zip(members, member_pages):
        counts[i] = len(pages)
        cks.append((store_keys[i], pages))
    gk = ("stack",) + tuple(cks)
    extras = extras_cache.get(gk)
    if extras is None:
        stacks = []
        for i, ck in zip(members, cks):
            stack = extras_cache.get(ck)
            if stack is None:
                _evict_one(extras_cache, cache_cap)
                stack = fetch_pages(stores[i], ck[1])
                extras_cache[ck] = stack
            stacks.append(stack)
        extras = jnp.stack(stacks)  # (B_g, P, W)
        _evict_one(extras_cache, cache_cap)
        extras_cache[gk] = extras
    return extras, counts


def reduce_flush(
    masked: jax.Array,
    specs: list,
    stores: list[BitmapStore],
    store_keys: list,
    *,
    interpret: bool,
    extras_cache: dict,
    cache_cap: int = 128,
) -> tuple[list, list[int], int]:
    """Batched aggregation of one flush (per-group transfer path).

    Returns ``(partials, extra_counts, n_groups)``: the per-member
    partials, how many extra planes each member sensed (for the caller's
    projected-traffic accounting), and the number of reduce groups — i.e.
    device->host transfers — the flush cost.  The fused flush program
    (:func:`repro.query.compile.compile_flush`) replaces this driver on
    the hot path with ONE transfer for the whole flush; this per-group
    path remains for devices holding non-ESP pages (whose reads may
    inject errors) and as the lockstep oracle.

    ``masked``: the flush's ``(B, W)`` validity-masked result bitmaps in
    member order; ``stores[i]`` / ``store_keys[i]``: the store member ``i``'s
    pages live in and a hashable identity for it (shard id + ingest epoch)
    under which page tuples and stacked extra planes are memoized in
    ``extras_cache``.

    Members group by ``(kind,) + reduce_sig``: each group runs ONE jit'd
    batched reduce and ONE device->host transfer regardless of group size,
    so a flush mixing every aggregate kind stays O(distinct kinds) extra
    dispatches on top of the predicate execution.  MASK groups transfer
    too — deliberately: results are consumed host-side (un-striping,
    ``to_bits``, numpy asserts), and one batched copy beats the per-row
    lazy transfers (and per-row ``__getitem__`` dispatches) the
    pre-pipeline path paid at consumption time.
    """
    n = len(specs)
    aggs, groups = group_members(specs, stores)
    partials: list = [None] * n
    extra_counts: list[int] = [0] * n
    for group_key, members in groups.items():
        a0 = aggs[members[0]]
        sig = group_key[1:]
        sub = (
            masked
            if len(members) == n
            else masked[jnp.asarray(np.asarray(members, np.int32))]
        )
        extras, counts = group_extras(
            aggs, members, stores, store_keys, extras_cache, cache_cap
        )
        for i, c in counts.items():
            extra_counts[i] = c
        host = jax.device_get(
            a0.batch_reduce(sub, extras, sig, interpret=interpret)
        )
        for j, i in enumerate(members):
            partials[i] = aggs[i].member_partial(host, j)
    return partials, extra_counts, len(groups)
