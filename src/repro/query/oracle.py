"""Reference numpy oracle for FlashQL predicates.

One implementation of predicate semantics on raw columns, shared by the
test suites and benchmarks (four hand-rolled copies had grown, each
covering a different predicate subset).  This is NOT on any serving path
— it exists so every differential check validates against the same,
complete oracle.
"""

from __future__ import annotations

import numpy as np

from repro.query.ast import And, AtLeast, Eq, In, Not, Or, Pred, Range


def np_select(pred: Pred, table: dict, n: int) -> np.ndarray:
    """Boolean row-selection mask of ``pred`` over raw column arrays."""
    if isinstance(pred, Eq):
        return np.asarray(table[pred.column]) == pred.value
    if isinstance(pred, In):
        return np.isin(np.asarray(table[pred.column]), pred.values)
    if isinstance(pred, Range):
        m = np.ones(n, bool)
        if pred.lo is not None:
            m &= np.asarray(table[pred.column]) >= pred.lo
        if pred.hi is not None:
            m &= np.asarray(table[pred.column]) <= pred.hi
        return m
    if isinstance(pred, Not):
        return ~np_select(pred.child, table, n)
    if isinstance(pred, And):
        m = np.ones(n, bool)
        for c in pred.children:
            m &= np_select(c, table, n)
        return m
    if isinstance(pred, Or):
        m = np.zeros(n, bool)
        for c in pred.children:
            m |= np_select(c, table, n)
        return m
    if isinstance(pred, AtLeast):
        # a duplicated child counts twice toward k, matching the sensed
        # semantics (its wordline group conducts once per block slot)
        count = np.zeros(n, np.int32)
        for c in pred.children:
            count += np_select(c, table, n)
        return count >= pred.k
    raise TypeError(f"not a FlashQL predicate: {pred!r}")
