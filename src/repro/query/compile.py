"""Predicate -> core-expression lowering, the cached query compiler, and
the fused flush compiler.

Lowering rules:

* ``Eq(col, v)``      -> the equality bitmap page ``col=v`` (FALSE if ``v``
  never occurs);
* ``In(col, vs)``     -> OR over the member pages — one inverse-read MWS
  when the column's bitmaps are co-located inverted (§6.3);
* ``Range(col, lo, hi)`` -> the bit-sliced comparison network over the
  column's BSI pages (O'Neil/Quass ``v <= c``: walk slices MSB->LSB keeping
  an equality prefix, OR the strictly-less branches);
* ``And`` / ``Or`` / ``Not`` -> ``and_`` / ``or_`` / ``not_``.

The compiler memoizes :class:`CommandPlan`s keyed on **expression structure
+ leaf placement + leaf-region epochs**: repeated query shapes skip the
Planner, and — because structurally identical plans gather the same slot
patterns — land in the same vectorized batch of
:class:`repro.query.device.FlashDevice`.

The epoch components are *region-granular* (one region per column, see
:func:`repro.core.store.page_region`): a key carries, for every region its
leaves touch, the column's index-metadata epoch (distinct values / BSI
width — what lowering depends on) and the device store's region epoch
(full page reprograms).  Incremental appends bump neither unless they
introduce a new value or bit slice in that column, so appending to column
A leaves plans that only touch column B warm — and delta-page programs
never invalidate any plan at all (plans gather by slot, and appends only
extend page tails).

On top of per-query plans, :func:`compile_flush` compiles a whole flush —
every predicate signature group AND every aggregate reduce — into ONE
jitted device program per *flush signature*: sensing gathers feed the
weighted-popcount reduces device-side, and the flush's complete result set
comes back as a single flat ``uint32`` payload, i.e. one kernel dispatch
and one host transfer per flush however many vmap groups and aggregate
kinds it mixes (MASK un-striping and the exact-integer 2^b weighting stay
host-side, as before).  :class:`FlushProgram` carries the device-resident
inputs (gather indices, order-restoring permutation, extra-plane stacks)
so steady-state serving re-dispatches a memoized program with zero
per-flush host preparation.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.commands import CommandPlan
from repro.core.engine import eval_expr
from repro.core.expr import (
    Expr,
    Node,
    Page,
    Threshold,
    and_,
    leaves,
    not_,
    or_,
)
from repro.core.placement import auto_layout
from repro.core.planner import Planner
from repro.core.store import page_region
from repro.query.aggregate import (
    group_extras,
    group_members,
    payload_size,
    payload_spec,
    unpack_group,
)
from repro.query.ast import (
    And,
    AtLeast,
    Eq,
    In,
    Not,
    Or,
    Pred,
    Query,
    Range,
    canonicalize,
    pred_key,
)
from repro.query.bitmap import (
    FALSE_PAGE,
    TRUE_PAGE,
    VALID_PAGE,
    BitmapStore,
    bsi_page,
    eq_page,
)
from repro.query.device import group_execs, make_flush_runner
from repro.query.optimize import best_plan


def _le_expr(store: BitmapStore, column: str, c: int) -> Expr:
    """Bit-sliced ``column <= c`` over the column's BSI pages."""
    ci = store.columns[column]
    if c < 0:
        return Page(FALSE_PAGE)
    if c >= (1 << ci.bits) - 1:
        return Page(TRUE_PAGE)
    lt_terms: list[Expr] = []
    eq_prefix: list[Expr] = []
    for b in range(ci.bits - 1, -1, -1):
        s = Page(bsi_page(column, b))
        if (c >> b) & 1:
            lt_terms.append(
                and_(*eq_prefix, not_(s)) if eq_prefix else not_(s)
            )
            eq_prefix.append(s)
        else:
            eq_prefix.append(not_(s))
    eq = and_(*eq_prefix) if len(eq_prefix) > 1 else eq_prefix[0]
    return or_(*lt_terms, eq) if lt_terms else eq


def lower(pred: Pred, store: BitmapStore) -> Expr:
    """Lower a FlashQL predicate to a ``core.expr`` tree over bitmap pages.

    The root is ANDed with the store's tombstone (valid-row) page, so
    every compiled plan senses exactly ONE extra wordline and can only
    match live rows: deleted rows are masked inside the MWS itself, and —
    because the valid page's reserved tail bits are erased-zero — so are
    rows between ``num_rows`` and ``capacity_rows`` (a cached NOT/MASK
    plan evaluated after ``reserve_rows`` headroom exists cannot leak the
    reserved tail into COUNT/MASK).  A predicate that lowers to the
    constant FALSE page skips the splice (it matches nothing already);
    constant TRUE lowers to the valid page itself.
    """
    e = _lower(pred, store)
    if isinstance(e, Page):
        if e.name == FALSE_PAGE:
            return e
        if e.name == TRUE_PAGE:
            return Page(VALID_PAGE)
    return and_(e, Page(VALID_PAGE))


def _lower(pred: Pred, store: BitmapStore) -> Expr:
    """The recursive lowering body (no valid-page splice — ``lower``
    splices exactly once, at the root)."""
    if isinstance(pred, Eq):
        ci = store.columns.get(pred.column)
        if ci is None:
            raise KeyError(f"unknown column {pred.column!r}")
        if pred.value not in ci.values:
            return Page(FALSE_PAGE)
        return Page(eq_page(pred.column, pred.value))
    if isinstance(pred, In):
        ci = store.columns.get(pred.column)
        if ci is None:
            raise KeyError(f"unknown column {pred.column!r}")
        members = [
            Page(eq_page(pred.column, v))
            for v in pred.values
            if v in ci.values
        ]
        if not members:
            return Page(FALSE_PAGE)
        if len(members) == 1:
            return members[0]
        return or_(*members)
    if isinstance(pred, Range):
        ci = store.columns.get(pred.column)
        if ci is None:
            raise KeyError(f"unknown column {pred.column!r}")
        le_hi = (
            _le_expr(store, pred.column, pred.hi)
            if pred.hi is not None
            else Page(TRUE_PAGE)
        )
        ge_lo = (
            not_(_le_expr(store, pred.column, pred.lo - 1))
            if pred.lo is not None and pred.lo > 0
            else Page(TRUE_PAGE)
        )
        factors = [
            f
            for f in (le_hi, ge_lo)
            if not (isinstance(f, Page) and f.name == TRUE_PAGE)
        ]
        if not factors:
            return Page(TRUE_PAGE)
        if len(factors) == 1:
            return factors[0]
        return and_(*factors)
    if isinstance(pred, Not):
        return not_(_lower(pred.child, store))
    if isinstance(pred, And):
        return and_(*(_lower(c, store) for c in pred.children))
    if isinstance(pred, Or):
        return or_(*(_lower(c, store) for c in pred.children))
    if isinstance(pred, AtLeast):
        return _fold_atleast(
            pred.k, [_lower(c, store) for c in pred.children]
        )
    raise TypeError(f"not a FlashQL predicate: {pred!r}")


def _fold_atleast(k: int, lowered: list[Expr]) -> Expr:
    """Constant-fold a lowered k-of-N and pick its cheapest expression form.

    Children lowered to the constant FALSE page can never count and drop
    out; TRUE children always count, so they drop AND decrement ``k``.
    The degenerate survivors reuse the existing node shapes — ``k == n``
    is the AND and ``k == 1`` the OR — so plan caching and cross-query CSE
    share entries with queries spelled the boolean way.  Only the strict
    interior becomes a :class:`repro.core.expr.Threshold`.
    """
    kids: list[Expr] = []
    for e in lowered:
        if isinstance(e, Page):
            if e.name == FALSE_PAGE:
                continue
            if e.name == TRUE_PAGE:
                k -= 1
                continue
        kids.append(e)
    if k <= 0:
        return Page(TRUE_PAGE)
    if k > len(kids):
        return Page(FALSE_PAGE)
    if len(kids) == 1:
        return kids[0]
    if k == len(kids):
        return and_(*kids)
    if k == 1:
        return or_(*kids)
    return Threshold(k, tuple(kids))


def lower_shared(
    pred: Pred,
    store: BitmapStore,
    shared: dict[tuple, str],
    used: set[str],
) -> Expr:
    """Lower a predicate, substituting shared-subexpression pages.

    ``shared`` maps :func:`repro.query.ast.pred_key` keys to the page
    names holding (or standing in for) those subtrees' results — the
    cross-query CSE rewrite of :func:`repro.query.optimize.cse_flush`.
    Every substituted name is added to ``used``.  The root is spliced
    with the valid page exactly like :func:`lower`.
    """
    e = _lower_shared(pred, store, shared, used)
    if isinstance(e, Page):
        if e.name == FALSE_PAGE:
            return e
        if e.name == TRUE_PAGE:
            return Page(VALID_PAGE)
    return and_(e, Page(VALID_PAGE))


def _lower_shared(
    pred: Pred,
    store: BitmapStore,
    shared: dict[tuple, str],
    used: set[str],
) -> Expr:
    name = shared.get(pred_key(pred))
    if name is not None:
        used.add(name)
        return Page(name)
    if isinstance(pred, Not):
        return not_(_lower_shared(pred.child, store, shared, used))
    if isinstance(pred, And):
        return and_(
            *(_lower_shared(c, store, shared, used) for c in pred.children)
        )
    if isinstance(pred, Or):
        return or_(
            *(_lower_shared(c, store, shared, used) for c in pred.children)
        )
    if isinstance(pred, AtLeast):
        return _fold_atleast(
            pred.k,
            [_lower_shared(c, store, shared, used) for c in pred.children],
        )
    return _lower(pred, store)


def expr_key(e: Expr) -> tuple:
    """Canonical structural key of a core expression."""
    if isinstance(e, Page):
        return ("p", e.name)
    if isinstance(e, Threshold):
        return ("thr", e.k) + tuple(expr_key(c) for c in e.children)
    assert isinstance(e, Node)
    return (e.op.value,) + tuple(expr_key(c) for c in e.children)


@dataclass(frozen=True)
class CompiledQuery:
    query: Query
    expr: Expr
    plan: CommandPlan
    key: tuple
    cache_hit: bool
    # canonicalized predicate (optimizer on) — the structural identity
    # cross-query CSE and materialization key on; None when optimize=False
    canon: Pred | None = None


@dataclass
class QueryCompiler:
    """Lower + plan queries against one array, memoizing command plans.

    With ``optimize`` (the default), three optimizer stages run in the
    compile path:

    * predicates canonicalize (:func:`repro.query.ast.canonicalize`)
      before lowering, so operand-order variants of one predicate share a
      single plan-cache entry;
    * plan-cache misses compile a small set of candidate chain orderings
      and keep the cheapest under the flashsim timing model
      (:func:`repro.query.optimize.best_plan`);
    * predicates hot enough (``materialize_after`` compiles since the last
      mutation of their columns) have their result bitmap ESP-programmed
      once as a cached page (:meth:`materialize`), after which they lower
      to ``mat_page AND valid_page`` — one sensing, two wordlines.  The
      cache entry is guarded by the source columns' region epochs plus the
      store's row count, so appends/compaction invalidate it, while
      deletes need no invalidation at all: the live ``__valid`` tombstone
      page is composed at read time, never baked into the cached bitmap.
    """

    store: BitmapStore
    array: "object"  # FlashArray / FlashDevice (duck-typed: .layout)
    _plans: dict[tuple, CommandPlan] = field(default_factory=dict)
    hits: int = 0
    misses: int = 0
    optimize: bool = True
    # compiles of one canonical predicate before it is eligible for
    # materialization (heat resets when its cached page is invalidated);
    # None disables the stage
    materialize_after: int | None = None
    mat_limit: int = 32  # distinct materialized pages per device
    mat_hits: int = 0
    mat_invalidations: int = 0
    mat_builds: int = 0
    _live_versions: tuple | None = None
    # canonical-predicate heat: pred_key -> [compile count, canon pred]
    _heat: dict = field(default_factory=dict, repr=False)
    # live materializations: pred_key -> (page name, regions, guard)
    _mat: dict = field(default_factory=dict, repr=False)
    # stable page name per materialized predicate (re-materializing after
    # invalidation reprograms the same page in place, so cached plans that
    # gather its slot stay valid)
    _mat_names: dict = field(default_factory=dict, repr=False)
    # front cache keyed on the (frozen, hashable) Query itself: repeated
    # queries skip lowering + structural keying entirely, not just the
    # Planner.  Cleared whenever either content version moves (cheap to
    # rebuild: the next compile re-lowers and usually hits ``_plans``).
    _by_query: dict = field(default_factory=dict, repr=False)
    # lowered ExecPlans under the same keys (see exec_for): both
    # schedulers used to keep private exec caches with duplicate pruning
    # logic; centralizing them here keeps one freshness rule
    _execs: dict = field(default_factory=dict, repr=False)
    # attached by the owning scheduler (repro.query.telemetry.Telemetry):
    # plan-compile misses report their Planner time as a histogram + span
    telemetry: object = None

    def epoch_sig(self, regions: tuple[str, ...]) -> tuple:
        """Current ``(region, column epoch, device region epoch)`` triple
        per region — the epoch components of a plan-cache key."""
        ce = self.store.column_epochs
        de = self.array.store.region_epochs
        return tuple((r, ce.get(r, 0), de.get(r, 0)) for r in regions)

    def key_fresh(self, key: tuple) -> bool:
        """Whether a plan-cache key's leaf-region epochs are all current.

        Exec/batch caches keyed on plan-cache keys prune through this: a
        stale key can never be produced by ``compile`` again.
        """
        sig = key[2]
        return sig == self.epoch_sig(tuple(r for r, _, _ in sig))

    def compile(self, query: Query) -> CompiledQuery:
        versions = (self.store.epoch, self.array.store.epoch)
        if versions != self._live_versions:
            # some mutation happened (ingest/append/reprogram): evict plans
            # whose leaf regions moved — they are permanently unreachable —
            # and clear the query front cache (its entries bypass lowering,
            # which may now resolve differently).  Plans over untouched
            # regions survive, which is what keeps serving warm across
            # incremental appends.
            self._plans = {
                k: v for k, v in self._plans.items() if self.key_fresh(k)
            }
            self._execs = {
                k: v for k, v in self._execs.items() if self.key_fresh(k)
            }
            self._by_query.clear()
            self._live_versions = versions
        cached = self._by_query.get(query)
        if cached is not None:
            self.hits += 1
            self._note_heat(cached.canon)
            return cached
        if self.optimize:
            canon = canonicalize(query.where)
            self._note_heat(canon)
            expr = self._lower_optimized(canon)
        else:
            canon = None
            expr = lower(query.where, self.store)
        layout = self.array.layout
        if any(p.name not in layout for p in leaves(expr)):
            # late-placed pages (e.g. constants written after warmup) get
            # the §6.3 context-sensitive placement before planning
            auto_layout(expr, layout)
        pages = sorted(set(leaves(expr)), key=lambda p: p.name)
        placements = tuple((p.name, layout[p.name]) for p in pages)
        # The epoch components cover exactly the regions (columns) the
        # plan's leaves touch: mutating one column — or, in a sharded
        # deployment, one device — invalidates only the plans that sense
        # it, while every other cached plan stays warm.
        regions = tuple(
            sorted({page_region(p.name) for p in pages} - {None})
        )
        key = (expr_key(expr), placements, self.epoch_sig(regions))
        plan = self._plans.get(key)
        hit = plan is not None
        if hit:
            self.hits += 1
        else:
            self.misses += 1
            tele = self.telemetry
            timed = tele is not None and tele.enabled
            t0 = time.perf_counter() if timed else 0.0
            if self.optimize:
                # candidate chain orderings, cheapest by the flashsim
                # timing model; the cache key stays the canonical expr's
                plan, _, _ = best_plan(expr, layout)
            else:
                plan = Planner(layout).compile(expr)
            if timed:
                t1 = time.perf_counter()
                tele.observe("plan_compile_s", t1 - t0)
                tele.span(
                    "plan_compile",
                    "compile",
                    t0,
                    t1,
                    tid="compile",
                    args={"key": repr(key[0])},
                )
            self._plans[key] = plan
        cq = CompiledQuery(query, expr, plan, key, hit, canon)
        if len(self._by_query) >= 4096:  # bound high-cardinality params
            self._by_query.clear()
        self._by_query[query] = replace(cq, cache_hit=True)
        return cq

    # -- hot-predicate materialization -----------------------------------

    def _note_heat(self, canon: Pred | None) -> None:
        if canon is None or self.materialize_after is None:
            return
        k = pred_key(canon)
        rec = self._heat.get(k)
        if rec is None:
            if len(self._heat) >= 4096:  # bound high-cardinality params
                self._heat.clear()
            self._heat[k] = [1, canon]
        else:
            rec[0] += 1

    def _mat_guard(self, regions: tuple[str, ...]) -> tuple:
        # region epochs catch column mutations and compaction; the row
        # count catches appends (which extend pages without bumping any
        # region epoch — a stale cached bitmap would zero-miss new rows).
        # Deletes bump neither: the cached page composes with the live
        # valid page at read time, so tombstones need no invalidation.
        return (self.epoch_sig(regions), self.store.num_rows)

    def _lower_optimized(self, canon: Pred) -> Expr:
        """Lower a canonical predicate, via its materialized page if the
        cache entry exists and its guard is still current."""
        k = pred_key(canon)
        m = self._mat.get(k)
        if m is not None:
            name, regions, guard = m
            if guard == self._mat_guard(regions):
                self.mat_hits += 1
                if self.telemetry is not None:
                    self.telemetry.count("materialization_hits")
                return and_(Page(name), Page(VALID_PAGE))
            del self._mat[k]
            self.mat_invalidations += 1
            if self.telemetry is not None:
                self.telemetry.count("materialization_invalidations")
            rec = self._heat.get(k)
            if rec is not None:
                rec[0] = 0  # re-earn the threshold after invalidation
        return lower(canon, self.store)

    def hot_preds(self) -> list[tuple[tuple, Pred]]:
        """``(key, canon)`` for predicates past the heat threshold that
        are not currently materialized."""
        if self.materialize_after is None:
            return []
        return [
            (k, rec[1])
            for k, rec in self._heat.items()
            if rec[0] >= self.materialize_after and k not in self._mat
        ]

    def materialize(self, key: tuple, canon: Pred) -> CommandPlan | None:
        """Evaluate + ESP-program a predicate's bitmap as a cached page.

        Returns the predicate's build plan — the one sensing pass that
        physically produces the latch result being programmed — so the
        caller can charge its traffic; None when the predicate is not
        worth (or not able to be) materialized.  The page is co-located
        with the valid page when its block has room, making the lowered
        ``mat AND valid`` read a single intra-block sensing.
        """
        expr = _lower(canon, self.store)
        if isinstance(expr, Page):
            return None  # already one page — nothing to gain
        pages = sorted(set(leaves(expr)), key=lambda p: p.name)
        regions = tuple(
            sorted({page_region(p.name) for p in pages} - {None})
        )
        name = self._mat_names.get(key)
        if name is None:
            if len(self._mat_names) >= self.mat_limit:
                return None
            name = f"__mat{len(self._mat_names)}"
        layout = self.array.layout
        if any(p.name not in layout for p in pages):
            auto_layout(expr, layout)
        snap = layout.snapshot()
        plan = Planner(layout).compile(expr)
        layout.restore(snap)  # build-plan spill scratch is throwaway
        words = np.asarray(
            eval_expr(expr, self.store.logical), dtype=np.uint32
        )
        block = wordline = None
        if name not in layout and VALID_PAGE in layout:
            pv = layout[VALID_PAGE]
            fill = layout._block_fill.get(pv.block, 0)
            if fill < layout.wls_per_block:
                block, wordline = pv.block, fill
        self.array.fc_write(
            name, words, esp=True, block=block, wordline=wordline
        )
        self._mat_names[key] = name
        self._mat[key] = (name, regions, self._mat_guard(regions))
        self.mat_builds += 1
        if self.telemetry is not None:
            self.telemetry.count("materializations")
        return plan

    def exec_for(self, cq: CompiledQuery):
        """The lowered :class:`repro.query.device.ExecPlan` of a compiled
        query, memoized under its plan-cache key: a hit skips the
        Python-side lowering entirely.  Stale keys are swept together with
        the plan cache (their epochs can never be produced again)."""
        e = self._execs.get(cq.key)
        if e is None:
            e = self.array.build_exec(cq.plan)
            self._execs[cq.key] = e
        return e

    @property
    def cache_size(self) -> int:
        return len(self._plans)


# -- the fused flush compiler -------------------------------------------------


@dataclass(frozen=True)
class FlushProgram:
    """One flush, compiled: a single jitted device program + its inputs.

    ``run(data, mask)`` dispatches the whole flush — every sensing group,
    the order-restoring permutation, validity masking, and every aggregate
    reduce — as ONE device program returning one flat ``uint32`` payload;
    ``unpack`` turns the transferred payload back into per-member partials
    (in flush member order) with :meth:`Aggregator.member_partial`.

    Everything here is device-resident and immutable, so a scheduler can
    memoize the program per batch composition + store epoch and re-run it
    every flush with zero host-side preparation.
    """

    key: tuple  # flush signature: (sense groups, reduce groups, words, cse)
    runner: object  # jitted run(data, group_idxs, inv_perm, mask, sels, extras, cse_idxs)
    n_members: int
    n_sense_groups: int
    n_reduce_groups: int
    group_idxs: tuple  # per sense group: tuple of (B_g, blocks, wls) arrays
    # (B,) int32 member gather over the sensed rows: with whole-plan dedup
    # it maps each member onto its unique representative's row (duplicate
    # queries read one sensing's output), without dedup it is the plain
    # concat-order -> member-order inverse permutation
    inv_perm: jax.Array
    sels: tuple  # per reduce group: (B_r,) member gather, or None if all
    extras: tuple  # per reduce group: (B_r, P, W) plane stack, or None
    reduce_parse: tuple  # per reduce group: (member tuple, payload leaves)
    extra_counts: tuple  # per member: extra planes sensed (traffic accounting)
    cse_idxs: tuple = ()  # per shared plan: tuple of (blocks, wls) arrays

    def run(self, data: jax.Array, mask: jax.Array) -> jax.Array:
        """Dispatch the fused program (async); returns the device payload."""
        return self.runner(
            data,
            self.group_idxs,
            self.inv_perm,
            mask,
            self.sels,
            self.extras,
            self.cse_idxs,
        )

    def unpack(self, flat: np.ndarray, aggs: list) -> list:
        """Payload words -> per-member partials (one host transfer's worth).

        ``aggs`` are the flush members' aggregators in member order (the
        program stores only static structure, so one FlushProgram serves
        any member set with the same flush signature)."""
        partials: list = [None] * self.n_members
        off = 0
        for members, leaves in self.reduce_parse:
            n = payload_size(leaves)
            host = unpack_group(flat[off : off + n], leaves)
            off += n
            for j, i in enumerate(members):
                partials[i] = aggs[i].member_partial(host, j)
        return partials


def compile_flush(
    execs: list,
    specs: list,
    stores: list[BitmapStore],
    store_keys: list,
    *,
    words: int,
    interpret: bool,
    runner_cache: dict,
    extras_cache: dict,
    pad: bool = True,
    cache_cap: int = 128,
    dedup_keys: list | None = None,
    shared_execs: tuple = (),
) -> FlushProgram:
    """Compile one flush into a :class:`FlushProgram`.

    ``execs`` are the members' lowered plans (spill-free or spilling — the
    fused path executes both; callers route flushes over devices holding
    non-ESP pages through the per-group legacy path instead, since the
    fused program never injects read errors).  Jitted runners are shared
    across flushes through ``runner_cache`` keyed on the flush signature,
    so a recurring composition costs zero retraces; extra-plane stacks are
    memoized in ``extras_cache`` exactly like the legacy reduce driver.

    ``dedup_keys`` (one hashable per member — plan-cache keys in practice)
    turns on whole-plan dedup: only the first member of each key is
    sensed, and the member gather points duplicates at the
    representative's row.  ``shared_execs`` are the flush's cross-query
    shared subexpression plans (:func:`repro.query.optimize.cse_flush`),
    sensed once before the member groups; member execs reference their
    stacked results through ``_Step.shared`` substitutions.
    """
    assert all(e is not None for e in execs), "fused flush needs lowered plans"
    n = len(execs)
    if dedup_keys is not None:
        pos: dict = {}
        uix: list[int] = []
        urep: list[int] = []
        for i, k in enumerate(dedup_keys):
            j = pos.get(k)
            if j is None:
                j = pos[k] = len(uix)
                uix.append(i)
            urep.append(j)
        uexecs = [execs[i] for i in uix]
    else:
        uix = list(range(n))
        urep = list(range(n))
        uexecs = execs
    sense: list[tuple] = []
    group_idxs: list[tuple] = []
    order: list[int] = []
    for signature, members, stacked in group_execs(uexecs, pad=pad):
        sense.append((signature, len(members)))
        group_idxs.append(tuple(jnp.asarray(x) for x in stacked))
        order.extend(members)
    # row_of: unique-plan ordinal -> its row in the concatenated group
    # output; composing with urep gives the member gather (duplicates
    # share their representative's row)
    row_of = np.empty(len(uexecs), dtype=np.int32)
    row_of[np.asarray(order)] = np.arange(len(uexecs), dtype=np.int32)
    inv = row_of[np.asarray(urep, dtype=np.int32)]

    aggs, rgroups = group_members(specs, stores)
    reduce_sigs: list[tuple] = []
    sels: list = []
    extras: list = []
    parse: list[tuple] = []
    extra_counts = [0] * n
    for gkey, members in rgroups.items():
        kind, sig = gkey[0], gkey[1:]
        ex, counts = group_extras(
            aggs, members, stores, store_keys, extras_cache, cache_cap
        )
        for i, c in counts.items():
            extra_counts[i] = c
        reduce_sigs.append(
            (kind, sig, len(members), 0 if ex is None else int(ex.shape[1]))
        )
        sels.append(
            None
            if len(members) == n
            else jnp.asarray(np.asarray(members, np.int32))
        )
        extras.append(ex)
        parse.append((tuple(members), payload_spec(kind, sig, len(members), words)))

    cse = tuple(e.signature for e in shared_execs)
    key = (tuple(sense), tuple(reduce_sigs), words, cse)
    # interpret is baked into the traced program, so it joins the cache
    # key: a (hand-built) fleet mixing interpret modes must not share
    # runners across its devices
    rkey = key + (bool(interpret),)
    runner = runner_cache.get(rkey)
    if runner is None:
        if len(runner_cache) >= 128:  # jitted programs hold executables
            runner_cache.clear()
        runner = make_flush_runner(key, bool(interpret))
        runner_cache[rkey] = runner
    return FlushProgram(
        key=key,
        runner=runner,
        n_members=n,
        n_sense_groups=len(sense),
        n_reduce_groups=len(reduce_sigs),
        group_idxs=tuple(group_idxs),
        inv_perm=jnp.asarray(inv),
        sels=tuple(sels),
        extras=tuple(extras),
        reduce_parse=tuple(parse),
        extra_counts=tuple(extra_counts),
        cse_idxs=tuple(
            tuple(jnp.asarray(x) for x in e.idxs) for e in shared_execs
        ),
    )
