"""Predicate -> core-expression lowering and the cached query compiler.

Lowering rules:

* ``Eq(col, v)``      -> the equality bitmap page ``col=v`` (FALSE if ``v``
  never occurs);
* ``In(col, vs)``     -> OR over the member pages — one inverse-read MWS
  when the column's bitmaps are co-located inverted (§6.3);
* ``Range(col, lo, hi)`` -> the bit-sliced comparison network over the
  column's BSI pages (O'Neil/Quass ``v <= c``: walk slices MSB->LSB keeping
  an equality prefix, OR the strictly-less branches);
* ``And`` / ``Or`` / ``Not`` -> ``and_`` / ``or_`` / ``not_``.

The compiler memoizes :class:`CommandPlan`s keyed on **expression structure
+ leaf placement + leaf-region epochs**: repeated query shapes skip the
Planner, and — because structurally identical plans gather the same slot
patterns — land in the same vectorized batch of
:class:`repro.query.device.FlashDevice`.

The epoch components are *region-granular* (one region per column, see
:func:`repro.core.store.page_region`): a key carries, for every region its
leaves touch, the column's index-metadata epoch (distinct values / BSI
width — what lowering depends on) and the device store's region epoch
(full page reprograms).  Incremental appends bump neither unless they
introduce a new value or bit slice in that column, so appending to column
A leaves plans that only touch column B warm — and delta-page programs
never invalidate any plan at all (plans gather by slot, and appends only
extend page tails).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core.commands import CommandPlan
from repro.core.expr import Expr, Node, Page, and_, leaves, not_, or_
from repro.core.placement import auto_layout
from repro.core.planner import Planner
from repro.core.store import page_region
from repro.query.ast import And, Eq, In, Not, Or, Pred, Query, Range
from repro.query.bitmap import (
    FALSE_PAGE,
    TRUE_PAGE,
    BitmapStore,
    bsi_page,
    eq_page,
)


def _le_expr(store: BitmapStore, column: str, c: int) -> Expr:
    """Bit-sliced ``column <= c`` over the column's BSI pages."""
    ci = store.columns[column]
    if c < 0:
        return Page(FALSE_PAGE)
    if c >= (1 << ci.bits) - 1:
        return Page(TRUE_PAGE)
    lt_terms: list[Expr] = []
    eq_prefix: list[Expr] = []
    for b in range(ci.bits - 1, -1, -1):
        s = Page(bsi_page(column, b))
        if (c >> b) & 1:
            lt_terms.append(
                and_(*eq_prefix, not_(s)) if eq_prefix else not_(s)
            )
            eq_prefix.append(s)
        else:
            eq_prefix.append(not_(s))
    eq = and_(*eq_prefix) if len(eq_prefix) > 1 else eq_prefix[0]
    return or_(*lt_terms, eq) if lt_terms else eq


def lower(pred: Pred, store: BitmapStore) -> Expr:
    """Lower a FlashQL predicate to a ``core.expr`` tree over bitmap pages."""
    if isinstance(pred, Eq):
        ci = store.columns.get(pred.column)
        if ci is None:
            raise KeyError(f"unknown column {pred.column!r}")
        if pred.value not in ci.values:
            return Page(FALSE_PAGE)
        return Page(eq_page(pred.column, pred.value))
    if isinstance(pred, In):
        ci = store.columns.get(pred.column)
        if ci is None:
            raise KeyError(f"unknown column {pred.column!r}")
        members = [
            Page(eq_page(pred.column, v))
            for v in pred.values
            if v in ci.values
        ]
        if not members:
            return Page(FALSE_PAGE)
        if len(members) == 1:
            return members[0]
        return or_(*members)
    if isinstance(pred, Range):
        ci = store.columns.get(pred.column)
        if ci is None:
            raise KeyError(f"unknown column {pred.column!r}")
        le_hi = (
            _le_expr(store, pred.column, pred.hi)
            if pred.hi is not None
            else Page(TRUE_PAGE)
        )
        ge_lo = (
            not_(_le_expr(store, pred.column, pred.lo - 1))
            if pred.lo is not None and pred.lo > 0
            else Page(TRUE_PAGE)
        )
        factors = [
            f
            for f in (le_hi, ge_lo)
            if not (isinstance(f, Page) and f.name == TRUE_PAGE)
        ]
        if not factors:
            return Page(TRUE_PAGE)
        if len(factors) == 1:
            return factors[0]
        return and_(*factors)
    if isinstance(pred, Not):
        return not_(lower(pred.child, store))
    if isinstance(pred, And):
        return and_(*(lower(c, store) for c in pred.children))
    if isinstance(pred, Or):
        return or_(*(lower(c, store) for c in pred.children))
    raise TypeError(f"not a FlashQL predicate: {pred!r}")


def expr_key(e: Expr) -> tuple:
    """Canonical structural key of a core expression."""
    if isinstance(e, Page):
        return ("p", e.name)
    assert isinstance(e, Node)
    return (e.op.value,) + tuple(expr_key(c) for c in e.children)


@dataclass(frozen=True)
class CompiledQuery:
    query: Query
    expr: Expr
    plan: CommandPlan
    key: tuple
    cache_hit: bool


@dataclass
class QueryCompiler:
    """Lower + plan queries against one array, memoizing command plans."""

    store: BitmapStore
    array: "object"  # FlashArray / FlashDevice (duck-typed: .layout)
    _plans: dict[tuple, CommandPlan] = field(default_factory=dict)
    hits: int = 0
    misses: int = 0
    _live_versions: tuple | None = None
    # front cache keyed on the (frozen, hashable) Query itself: repeated
    # queries skip lowering + structural keying entirely, not just the
    # Planner.  Cleared whenever either content version moves (cheap to
    # rebuild: the next compile re-lowers and usually hits ``_plans``).
    _by_query: dict = field(default_factory=dict, repr=False)

    def epoch_sig(self, regions: tuple[str, ...]) -> tuple:
        """Current ``(region, column epoch, device region epoch)`` triple
        per region — the epoch components of a plan-cache key."""
        ce = self.store.column_epochs
        de = self.array.store.region_epochs
        return tuple((r, ce.get(r, 0), de.get(r, 0)) for r in regions)

    def key_fresh(self, key: tuple) -> bool:
        """Whether a plan-cache key's leaf-region epochs are all current.

        Exec/batch caches keyed on plan-cache keys prune through this: a
        stale key can never be produced by ``compile`` again.
        """
        sig = key[2]
        return sig == self.epoch_sig(tuple(r for r, _, _ in sig))

    def compile(self, query: Query) -> CompiledQuery:
        versions = (self.store.epoch, self.array.store.epoch)
        if versions != self._live_versions:
            # some mutation happened (ingest/append/reprogram): evict plans
            # whose leaf regions moved — they are permanently unreachable —
            # and clear the query front cache (its entries bypass lowering,
            # which may now resolve differently).  Plans over untouched
            # regions survive, which is what keeps serving warm across
            # incremental appends.
            self._plans = {
                k: v for k, v in self._plans.items() if self.key_fresh(k)
            }
            self._by_query.clear()
            self._live_versions = versions
        cached = self._by_query.get(query)
        if cached is not None:
            self.hits += 1
            return cached
        expr = lower(query.where, self.store)
        layout = self.array.layout
        if any(p.name not in layout for p in leaves(expr)):
            # late-placed pages (e.g. constants written after warmup) get
            # the §6.3 context-sensitive placement before planning
            auto_layout(expr, layout)
        pages = sorted(set(leaves(expr)), key=lambda p: p.name)
        placements = tuple((p.name, layout[p.name]) for p in pages)
        # The epoch components cover exactly the regions (columns) the
        # plan's leaves touch: mutating one column — or, in a sharded
        # deployment, one device — invalidates only the plans that sense
        # it, while every other cached plan stays warm.
        regions = tuple(
            sorted({page_region(p.name) for p in pages} - {None})
        )
        key = (expr_key(expr), placements, self.epoch_sig(regions))
        plan = self._plans.get(key)
        hit = plan is not None
        if hit:
            self.hits += 1
        else:
            self.misses += 1
            plan = Planner(layout).compile(expr)
            self._plans[key] = plan
        cq = CompiledQuery(query, expr, plan, key, hit)
        if len(self._by_query) >= 4096:  # bound high-cardinality params
            self._by_query.clear()
        self._by_query[query] = replace(cq, cache_hit=True)
        return cq

    @property
    def cache_size(self) -> int:
        return len(self._plans)
