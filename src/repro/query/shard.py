"""Sharded FlashQL: one bitmap index striped over a fleet of FlashDevices.

The paper's SSD-level evaluation (§8) spreads an 800M-user bitmap over
many chips; this module is the serving-layer analogue.  A
:class:`ShardedBitmapStore` partitions table *rows* over ``num_shards``
independent :class:`repro.query.device.FlashDevice`s — round-robin
(``policy="roundrobin"``) or contiguous ranges (``policy="range"``) — and
:class:`ShardedFlashQL` serves batched queries against the fleet:

* **scatter** — every admitted query fans out to each shard's queue;
  per-shard :class:`QueryCompiler`s compile it through that shard's plan
  cache (placements and cache keys are per device, so mutating one shard
  recompiles only that shard);
* **execute** — shard batches run under a single ``jit``-of-``vmap`` per
  signature *group*: shards ingest with the global column schema and
  program from one forked canonical layout, so the same query yields the
  same plan signature on every shard, and plan-aware padding
  (:func:`repro.query.device.group_execs`) merges the remaining shape
  variance — shard fan-out does not multiply the vmap group count.  Each
  batch element gathers from its own shard's snapshot of the stacked
  fleet array;
* **gather** — every aggregate flows through the pluggable
  :class:`repro.query.aggregate.Aggregator` pipeline: shard batches reduce
  device-side (one jit'd weighted-popcount per reduce signature), and each
  aggregate's shard-merge rule combines the partials — ``COUNT``/``SUM``
  sum, ``MIN``/``MAX`` take the extremum, ``TOP-K``/``GROUP BY`` merge
  per-value count vectors (the global schema aligns value order across
  shards), ``MASK`` un-stripes bitmaps back into global row order.  The
  all-ones identity rows that pad ragged gathers, the packed word slack,
  and the fleet-width padding words of the last (short) stripe are all
  masked out via each shard's ``valid_words_mask``;
* **routing** — a ``range``-striped store (optionally ``stripe_key``-sorted
  so stripes hold disjoint key ranges) prunes shards whose stripe provably
  cannot match the query root (an ``Eq``/``In``/``Range`` conjunct with no
  overlapping values on that shard) *before* scatter: the shard never
  senses, and its partial is the aggregate's empty value.

* **appends** — :meth:`ShardedFlashQL.append` extends the live fleet:
  round-robin fleets stripe the tail rows onward (row ``j`` -> shard
  ``j % N``), ``stripe_key`` fleets route each row to the stripe owning
  its key range (keys past every range overflow into the last stripe),
  and plain ``range`` fleets extend the tail stripe.  Every stripe
  programs only its delta pages; first-seen values propagate to ALL
  shards as a forced schema update so aggregate shard-merges stay
  value-aligned, and ``shard_values``/``stripe_bounds`` track the new
  rows so range pruning stays sound.

* **pipelining** — with ``pipeline=True`` the fleet flushes
  *asynchronously*: each shard's batch compiles into one fused device
  program (sensing + every aggregate reduce, one payload — see
  :func:`repro.query.compile.compile_flush`) and shard *k+1* is
  dispatched while shard *k*'s program is still in flight, with payloads
  double-buffered and ``device_get`` only at gather.  Routing-aware
  queue depths let range-pruned shards donate their slots to hot
  stripes.  The lockstep path (default) remains the differential oracle.

``projection()`` replays each device's executed traffic through the
flashsim timing/energy model and aggregates over the fleet — wall-clock
as the max over concurrently-serving chips, energy as the sum — charging
appends for exactly the delta pages they ESP-programmed.
"""

from __future__ import annotations

import bisect
import time
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import num_words as _num_words
from repro.core.placement import Layout
from repro.flashsim.geometry import DEFAULT_SSD, SSDConfig
from repro.query.aggregate import (
    get_aggregator,
    merge_mask_batch,
    reduce_flush,
    validate_query,
)
from repro.query.ast import And, Eq, In, Or, Pred, Query, Range
from repro.query.bitmap import BitmapStore, validate_batch
from repro.query.compile import QueryCompiler, compile_flush
from repro.query.device import (
    FlashDevice,
    age_spill_blocks,
    group_execs,
    make_plan_runner,
    reorder_rows,
)
from repro.query.optimize import cse_flush
from repro.query.scheduler import (
    AGG_READ_SHAPE,
    QueryResult,
    attribute_result,
    merge_appends,
    plan_sensings,
    plan_thresholds,
    plan_traffic,
    project_traffic,
    queue_append,
    record_plan_traffic,
    registry_counters,
)
from repro.query.telemetry import (
    TID_FLUSH,
    TID_MERGE,
    TID_TICKETS,
    Telemetry,
)

POLICIES = ("roundrobin", "range")


def _program_grouped(dev: FlashDevice, logical: dict) -> tuple[int, int]:
    """ESP-program a shard's logical pages grouped by PHYSICAL page.

    Under multi-level packing (``dev.layout.levels > 1``) the logical
    pages co-resident in one physical page program in a single ISPP pass:
    the group lead charges the wear/ESP counters, the other levels ride
    along (``charge=False``).  Returns ``(programs, words)`` physical
    stats — identical to per-page accounting at ``levels == 1``.
    """
    levels = dev.layout.levels
    groups: dict[tuple[int, int], list] = {}
    for name, words in logical.items():
        p = dev.layout[name]
        groups.setdefault((p.block, p.wordline // levels), []).append(
            (name, words)
        )
    programs = total = 0
    for group in groups.values():
        charge = True
        for name, words in group:
            dev.fc_write(name, words, esp=True, charge=charge)
            charge = False
        programs += 1
        total += max(int(w.shape[0]) for _, w in group)
    return programs, total


def stripe_rows(
    num_rows: int, num_shards: int, policy: str = "roundrobin"
) -> list[np.ndarray]:
    """Global row indices per shard, each in ascending (shard-local) order.

    ``roundrobin`` assigns row ``j`` to shard ``j % num_shards`` (balanced
    within one row); ``range`` cuts ``ceil(n / num_shards)``-row contiguous
    stripes (trailing shards may be short or empty).
    """
    if policy == "roundrobin":
        return [
            np.arange(s, num_rows, num_shards) for s in range(num_shards)
        ]
    if policy == "range":
        chunk = -(-num_rows // num_shards) if num_rows else 0
        return [
            np.arange(
                min(s * chunk, num_rows), min((s + 1) * chunk, num_rows)
            )
            for s in range(num_shards)
        ]
    raise ValueError(f"unknown stripe policy {policy!r}; use {POLICIES}")


def shard_cannot_match(
    pred: Pred, values: dict[str, tuple[int, ...]]
) -> bool:
    """Conservatively prove ``pred`` selects no rows on a shard that holds
    exactly the (sorted) per-column distinct ``values``.

    Sound, not complete: ``True`` means the shard's stripe provably cannot
    match (the result is empty there, no sensing needed); ``False`` means
    "might match".  ``Not`` is never pruned through — its complement could
    match anything — and ``And``/``Or`` prune if any / every child does.
    This is what makes ``Range``/``Eq`` roots route on a range-striped,
    ``stripe_key``-sorted store: stripes hold disjoint key ranges, so most
    shards fail the overlap test.
    """
    if isinstance(pred, Eq):
        vs = values.get(pred.column, ())
        i = bisect.bisect_left(vs, pred.value)
        return not (i < len(vs) and vs[i] == pred.value)
    if isinstance(pred, In):
        return all(
            shard_cannot_match(Eq(pred.column, v), values)
            for v in pred.values
        )
    if isinstance(pred, Range):
        vs = values.get(pred.column, ())
        i = (
            bisect.bisect_left(vs, pred.lo)
            if pred.lo is not None
            else 0
        )
        # no shard value >= lo, or the smallest such value exceeds hi
        return i >= len(vs) or (
            pred.hi is not None and vs[i] > pred.hi
        )
    if isinstance(pred, And):
        return any(shard_cannot_match(c, values) for c in pred.children)
    if isinstance(pred, Or):
        return all(shard_cannot_match(c, values) for c in pred.children)
    return False  # Not: conservatively assume it can match


@dataclass
class ShardedBitmapStore:
    """Row-striped bitmap index over ``num_shards`` shard-local stores.

    Every shard ingests its row subset with the *global* schema (union of
    distinct values per column), so a value absent from one shard still
    gets an all-zero equality page there: predicate lowering, placement,
    plan-cache keys, and vmap signatures line up across the fleet.  Pages
    are zero-padded to a fleet-wide word count so shard snapshots stack.

    ``stripe_key`` (``range`` policy only) orders rows by that column's
    value before cutting contiguous stripes, so each shard holds a
    disjoint key range and ``Range``/``Eq`` queries on the key route to
    few shards (see :meth:`ShardedFlashQL.submit`).  Global row order —
    what ``MASK`` results and ``row_maps`` refer to — stays the table's
    ingest order.
    """

    num_shards: int
    policy: str = "roundrobin"
    stripe_key: str | None = None
    # row capacity reserved for appends (shared headroom: any stripe may
    # absorb the whole budget, since stripe-key routing is data-dependent)
    reserve_rows: int = 0
    shards: list[BitmapStore] = field(default_factory=list)
    row_maps: list[np.ndarray] = field(default_factory=list)
    num_rows: int = 0
    schema: dict[str, tuple[int, ...]] = field(default_factory=dict)
    # values actually PRESENT on each shard (the shard-local stores carry
    # the forced global schema, so routing needs this recorded separately)
    shard_values: list[dict[str, tuple[int, ...]]] = field(
        default_factory=list
    )
    # per-shard (lo, hi) of the stripe key (stripe_key fleets): appends
    # route to the stripe owning their key range (see :meth:`append`)
    stripe_bounds: list[tuple[int, int] | None] = field(
        default_factory=list
    )

    def __post_init__(self):
        if self.num_shards < 1:
            raise ValueError("need at least one shard")
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown stripe policy {self.policy!r}; use {POLICIES}"
            )
        if self.stripe_key is not None and self.policy != "range":
            raise ValueError("stripe_key requires policy='range'")
        if not self.shards:
            self.shards = [BitmapStore() for _ in range(self.num_shards)]

    @property
    def active(self) -> list[int]:
        """Shards that hold at least one row (a short table can leave
        trailing ``range``-policy shards empty)."""
        return [s for s in range(self.num_shards) if self.shards[s].num_rows]

    # -- ingest -------------------------------------------------------------
    def ingest(self, table: dict[str, np.ndarray]) -> None:
        lengths = {len(v) for v in table.values()}
        if len(lengths) != 1:
            raise ValueError(f"ragged table: row counts {sorted(lengths)}")
        (n,) = lengths
        if self.num_rows and n != self.num_rows:
            raise ValueError("all ingests must share one row count")
        self.num_rows = n
        self.schema = {
            col: tuple(int(v) for v in np.unique(np.asarray(vals)))
            for col, vals in table.items()
        }
        if self.stripe_key is not None:
            if self.stripe_key not in table:
                raise KeyError(
                    f"stripe_key {self.stripe_key!r} not in table"
                )
            # contiguous stripes over the key-sorted order: each shard
            # holds a disjoint (sorted) key range, which is what makes
            # range routing prune; row_maps keep global (ingest) indices
            order = np.argsort(
                np.asarray(table[self.stripe_key]), kind="stable"
            )
            self.row_maps = [
                order[chunk]
                for chunk in stripe_rows(n, self.num_shards, "range")
            ]
        else:
            self.row_maps = stripe_rows(n, self.num_shards, self.policy)
        fleet_words = max(
            (
                _num_words(len(rows) + self.reserve_rows)
                for rows in self.row_maps
            ),
            default=0,
        )
        self.shard_values = [{} for _ in range(self.num_shards)]
        self.stripe_bounds = [None] * self.num_shards
        for s, (store, rows) in enumerate(zip(self.shards, self.row_maps)):
            if not len(rows):
                continue
            sub = {col: np.asarray(v)[rows] for col, v in table.items()}
            self.shard_values[s] = {
                col: tuple(int(v) for v in np.unique(vals))
                for col, vals in sub.items()
            }
            if self.stripe_key is not None:
                keys = sub[self.stripe_key]
                self.stripe_bounds[s] = (int(keys.min()), int(keys.max()))
            store.min_words = fleet_words
            store.ingest(
                sub, schema=self.schema, reserve_rows=self.reserve_rows
            )

    # -- incremental ingest --------------------------------------------------
    def _route_append(self, rows: dict[str, np.ndarray]):
        """Route + validate an append batch WITHOUT mutating anything.

        Returns ``(b, n0, active, subs, new_schema, changed)`` where
        ``subs`` maps each active shard to its (sub-batch, picked row
        positions).  Shared by :meth:`append` (which then mutates) and
        :meth:`check_append` (coalescing schedulers validate each queued
        batch cumulatively before accepting it).
        """
        if not self.num_rows:
            raise ValueError("append() needs an ingested store")
        b = validate_batch(self.schema, rows)
        arrays = {col: np.asarray(v) for col, v in rows.items()}
        n0 = self.num_rows
        active = self.active
        act = np.asarray(active, np.int64)

        # -- destination stripe per appended row
        if self.policy == "roundrobin":
            if len(active) == self.num_shards:
                dest = (n0 + np.arange(b)) % self.num_shards
            else:  # short table left trailing shards empty (never ingested)
                dest = act[(n0 + np.arange(b)) % len(active)]
        elif self.stripe_key is not None:
            his = np.asarray(
                [self.stripe_bounds[s][1] for s in active], np.int64
            )
            keys = arrays[self.stripe_key]
            owner = np.minimum(
                np.searchsorted(his, keys), len(active) - 1
            )  # past every range -> overflow into the last stripe
            dest = act[owner]
        else:  # plain range: the tail stripe owns all new positions
            dest = np.full((b,), active[-1], np.int64)

        new_schema = {
            col: tuple(
                sorted(set(vs) | {int(v) for v in arrays[col]})
            )
            for col, vs in self.schema.items()
        }
        changed = new_schema != self.schema

        # -- validate every destination BEFORE any shard mutates
        subs: dict[int, tuple[dict[str, np.ndarray], np.ndarray]] = {}
        for s in active:
            picked = np.flatnonzero(dest == s)
            subs[s] = (
                {col: arr[picked] for col, arr in arrays.items()},
                picked,
            )
        for s in active:
            sub, picked = subs[s]
            if len(picked) or changed:
                self.shards[s].check_append(sub)
        return b, n0, active, subs, new_schema, changed

    def check_append(self, rows: dict[str, np.ndarray]) -> int:
        """Fleet-wide append validation (no mutation); returns batch size."""
        b, *_ = self._route_append(rows)
        return b

    def append(self, rows: dict[str, np.ndarray]) -> dict[int, object]:
        """Route an append batch to its stripes; returns per-shard deltas.

        Routing by policy: ``roundrobin`` continues the stripe sequence
        (global row ``j`` -> shard ``j % num_shards``); a ``stripe_key``
        fleet routes each row to the stripe *owning* its key (the first
        stripe whose recorded key range reaches the key) with keys beyond
        every range overflowing into the last stripe; plain ``range``
        appends extend the tail stripe (new rows hold the highest global
        positions).  The whole batch — column set, lengths, values, and
        every destination shard's word capacity — is validated before any
        shard mutates.

        New values are propagated to EVERY active shard as a forced
        schema update (all-zero equality pages where absent), keeping
        value order aligned fleet-wide so aggregate shard-merges stay
        correct; ``shard_values`` records only the values actually
        present per stripe, so range routing keeps pruning soundly.
        """
        b, n0, active, subs, new_schema, changed = self._route_append(rows)

        # -- mutate
        deltas: dict[int, object] = {}
        for s in active:
            sub, picked = subs[s]
            if not len(picked) and not changed:
                continue
            deltas[s] = self.shards[s].append(sub, schema_update=new_schema)
            if not len(picked):
                continue
            self.row_maps[s] = np.concatenate(
                [self.row_maps[s], n0 + picked]
            )
            sv = dict(self.shard_values[s])
            for col, arr in sub.items():
                sv[col] = tuple(
                    sorted(set(sv.get(col, ())) | {int(v) for v in arr})
                )
            self.shard_values[s] = sv
            if self.stripe_key is not None:
                lo, hi = self.stripe_bounds[s]
                keys = sub[self.stripe_key]
                self.stripe_bounds[s] = (
                    min(lo, int(keys.min())),
                    max(hi, int(keys.max())),
                )
        self.schema = new_schema
        self.num_rows = n0 + b
        return deltas

    # -- deletes / tombstones ------------------------------------------------
    @property
    def deleted_rows(self) -> int:
        return sum(st.deleted_rows for st in self.shards)

    @property
    def live_rows(self) -> int:
        return self.num_rows - self.deleted_rows

    @property
    def tombstone_density(self) -> float:
        """Fleet-wide tombstone fraction (the auto-compaction trigger)."""
        return self.deleted_rows / self.num_rows if self.num_rows else 0.0

    def locate_rows(self, row_ids) -> dict[int, np.ndarray]:
        """Route global row ids to shard-local positions.

        Returns ``{shard: local positions}``.  ``row_maps`` are NOT
        ascending in global id on ``stripe_key`` fleets (they follow the
        key-sorted stripe order), so routing inverts the maps outright
        instead of binary-searching them.
        """
        raw = np.asarray(row_ids)
        if raw.size and raw.dtype.kind not in "iu":
            raise ValueError(
                f"delete ids must be integers, got dtype {raw.dtype} "
                "(a float id would silently truncate to a neighbour row)"
            )
        ids = np.unique(raw.astype(np.int64, copy=False))
        if ids.size != raw.size:
            raise ValueError("delete batch has duplicate row ids")
        if ids.size and (ids[0] < 0 or ids[-1] >= self.num_rows):
            raise ValueError(
                f"delete ids outside [0, {self.num_rows}): "
                f"{ids[(ids < 0) | (ids >= self.num_rows)][:5]}"
            )
        shard_of = np.full((self.num_rows,), -1, dtype=np.int64)
        pos_of = np.zeros((self.num_rows,), dtype=np.int64)
        for s, rmap in enumerate(self.row_maps):
            shard_of[rmap] = s
            pos_of[rmap] = np.arange(len(rmap))
        groups: dict[int, np.ndarray] = {}
        owners = shard_of[ids]
        for s in np.unique(owners):
            groups[int(s)] = pos_of[ids[owners == s]]
        return groups

    def check_delete(self, row_ids) -> dict[int, np.ndarray]:
        """Fleet-wide delete validation (no mutation); returns the routing.
        Every destination shard validates BEFORE any shard mutates."""
        groups = self.locate_rows(row_ids)
        for s, local in groups.items():
            self.shards[s].check_delete(local)
        return groups

    def delete(self, row_ids) -> dict[int, object]:
        """Tombstone global ``row_ids``; returns per-shard deltas to program.

        Each destination stripe clears its local VALID_PAGE bits — one
        delta-page program per touched stripe, no row renumbering, no
        region epoch moves, plans stay warm fleet-wide.
        """
        groups = self.check_delete(row_ids)
        return {
            s: self.shards[s].delete(local) for s, local in groups.items()
        }

    # -- program ------------------------------------------------------------
    def program(
        self, devices: list[FlashDevice], warmup: Iterable[Query] = ()
    ) -> None:
        """ESP-program every shard into its device from ONE canonical
        layout: placements are computed once (§6.3 rules, warmup-steered)
        against the global schema and forked per device, so physically
        identical pages sit at identical (block, wordline) coordinates on
        every chip."""
        if len(devices) != self.num_shards:
            raise ValueError(
                f"{self.num_shards} shards need {self.num_shards} devices, "
                f"got {len(devices)}"
            )
        if not self.active:
            raise ValueError("ingest a table before programming")
        lead = devices[0].layout
        canonical = Layout(
            wls_per_block=lead.wls_per_block, levels=lead.levels
        )
        self.shards[self.active[0]].place_into(canonical, warmup=warmup)
        for s, dev in enumerate(devices):
            dev.layout = canonical.fork()
            _program_grouped(dev, self.shards[s].logical)


@dataclass
class ShardedFlashQL:
    """Batched query serving over a sharded bitmap store (scatter/gather).

    The sharded counterpart of :class:`repro.query.scheduler.BatchScheduler`:
    ``submit`` fans a query out to every shard's queue; ``flush`` drains up
    to ``queue_depth`` queries from each shard, executes all shard batches
    (one fused ``jit(vmap)`` per cross-shard signature group when shard
    snapshots stack; per-device batches otherwise), and gathers partial
    results into per-ticket :class:`QueryResult`s.
    """

    store: ShardedBitmapStore
    devices: list[FlashDevice]
    queue_depth: int = 256  # per-shard admissions per flush
    fuse_across_shards: bool = True
    # Pipelined (asynchronous per-shard) flushing: every shard's batch
    # compiles into ONE fused device program (sensing + every aggregate
    # reduce, see repro.query.compile.compile_flush) and shards dispatch
    # back-to-back WITHOUT barriering — shard k+1's sensing is dispatched
    # while shard k's reduce is still in flight (double-buffered; the only
    # blocking point is the payload gather).  Routing-aware depths let
    # range-pruned shards donate their queue slots to hot stripes.  False
    # keeps the PR-4 lockstep flush (cross-shard jit-of-vmap groups +
    # per-reduce-signature transfers) — the differential oracle.
    pipeline: bool = False
    coalesce_appends: bool = False
    # -- the cost-based multi-query optimizer (repro.query.optimize) --------
    # per-shard canonicalized plan caching + cost-based chain orderings,
    # whole-plan dedup across the lockstep batch, full subtree CSE inside
    # each shard's fused pipelined flush, and fleet-wide hot-predicate
    # materialization; False serves exactly as before (the optimizer-off
    # baseline the Zipfian benchmark compares against)
    optimize: bool = True
    # compiles of one canonical predicate before its result bitmap is
    # ESP-programmed on EVERY shard (fleet-wide, so device snapshot
    # shapes stay stackable); None disables materialization only
    materialize_after: int | None = 32
    # background-compaction policy: once the fleet's tombstone density
    # crosses this threshold (checked at mutation boundaries, never mid-
    # flush), compact() rebuilds the tombstoned stripes; None disables
    compact_density: float | None = None
    # grow capacity through the compaction rebuild instead of refusing an
    # overflowing append (re-stripes into wider pages, fleet-wide)
    grow_on_overflow: bool = False
    compilers: list[QueryCompiler] = field(default_factory=list)
    # the unified metrics registry + trace recorder shared by the fleet;
    # pass Telemetry(enabled=False) to strip every per-event recorder off
    # the hot path (counters keep counting — stats()/projection read them)
    telemetry: Telemetry = None  # type: ignore[assignment]

    _queues: list[list[tuple[int, Query]]] = field(default_factory=list)
    _meta: dict[int, tuple[Query, float]] = field(default_factory=dict)
    # per-ticket partials: shard -> int popcount (COUNT) / np words (MASK)
    _partials: dict[int, dict[int, object]] = field(default_factory=dict)
    _cache_hits: dict[int, bool] = field(default_factory=dict)
    _next_ticket: int = 0
    _runners: dict = field(default_factory=dict, repr=False)
    _fleet_stack: tuple | None = field(default=None, repr=False)
    _masks: list[np.ndarray] | None = field(default=None, repr=False)
    # fused-path analogue of FlashDevice._batch_cache: memoized grouping,
    # shard indices, and device-resident gather idxs per batch composition
    _group_cache: dict = field(default_factory=dict, repr=False)
    _maskmat_cache: dict = field(default_factory=dict, repr=False)
    # stacked extra sensed planes per (shard, epoch, page tuple) — see
    # repro.query.aggregate.reduce_flush
    _extras_cache: dict = field(default_factory=dict, repr=False)
    # pipelined mode: per-shard fused flush programs keyed on (shard,
    # batch composition, epochs) + shared jitted runners per flush
    # signature (identical shard schemas share one compiled program)
    _flush_programs: dict = field(default_factory=dict, repr=False)
    _runner_cache: dict = field(default_factory=dict, repr=False)
    _mask_rows: dict = field(default_factory=dict, repr=False)
    # per-shard flush-level CSE rewrites, keyed on (shard, batch
    # composition, epochs) — see repro.query.optimize.cse_flush
    _cse_cache: dict = field(default_factory=dict, repr=False)
    # queued (validated) append batches awaiting coalesced programming
    _append_buf: list = field(default_factory=list, repr=False)

    # -- stats --------------------------------------------------------------
    # counter attributes (queries_served, flushes, host_transfers, …) are
    # registry-backed properties — see registry_counters() below the class.
    # Projected-traffic shape counts stay real fields (Counter-valued).
    shard_traffic: list[Counter] = field(default_factory=list)
    # per-ticket attribution under accumulation (telemetry enabled only;
    # popped with the ticket in _collect_done, so in-flight size is
    # bounded by in-flight tickets)
    _attr: dict[int, dict] = field(default_factory=dict, repr=False)
    _host_postprocess: bool = False

    def __post_init__(self):
        if len(self.devices) != self.store.num_shards:
            raise ValueError("one device per shard required")
        if self.telemetry is None:
            self.telemetry = Telemetry()
        if not self.compilers:
            self.compilers = [
                QueryCompiler(st, dev)
                for st, dev in zip(self.store.shards, self.devices)
            ]
        for comp, dev in zip(self.compilers, self.devices):
            comp.telemetry = self.telemetry
            comp.optimize = self.optimize
            comp.materialize_after = (
                self.materialize_after if self.optimize else None
            )
            dev.telemetry = self.telemetry
        for s in range(self.store.num_shards):
            self.telemetry.name_tid(s, f"shard {s}")
        self.telemetry.name_tid(TID_MERGE, "merge")
        self.telemetry.name_tid(TID_FLUSH, "flush")
        self.telemetry.name_tid(TID_TICKETS, "tickets")
        self.telemetry.providers.setdefault("plan_cache", self._plan_cache)
        self.telemetry.providers.setdefault("projection", self.projection)
        self.telemetry.providers.setdefault(
            "optimizer", self._optimizer_stats
        )
        self._queues = [[] for _ in range(self.store.num_shards)]
        self.shard_traffic = [
            Counter() for _ in range(self.store.num_shards)
        ]

    def _plan_cache(self) -> dict:
        return {
            "hits": sum(c.hits for c in self.compilers),
            "misses": sum(c.misses for c in self.compilers),
            "size": sum(c.cache_size for c in self.compilers),
        }

    def _optimizer_stats(self) -> dict:
        tele = self.telemetry
        served = int(self.queries_served)
        mws = sum(sum(c.values()) for c in self.shard_traffic)
        return {
            "enabled": self.optimize,
            "sensings_per_query": (mws / served) if served else None,
            "cse_plan_hits": int(tele.value("cse_plan_hits")),
            "cse_shared_senses": int(tele.value("cse_shared_senses")),
            "cse_rewritten_members": int(
                tele.value("cse_rewritten_members")
            ),
            "materializations": int(tele.value("materializations")),
            "materialization_hits": int(
                tele.value("materialization_hits")
            ),
            "materialization_invalidations": int(
                tele.value("materialization_invalidations")
            ),
        }

    def _maybe_materialize(self) -> None:
        """Fleet-wide materialization: a predicate hot on ANY shard's
        compiler materializes on EVERY shard — device snapshot shapes must
        stay aligned for the cross-shard fused groups, and a fanned-out
        query heats all its unpruned shards anyway.  Each shard's build
        (one sensing pass + one ESP page program) is charged to its own
        traffic mirrors."""
        if not self.optimize:
            return
        hot: dict = {}
        for comp in self.compilers:
            for key, canon in comp.hot_preds():
                hot.setdefault(key, canon)
        for key, canon in hot.items():
            for s, comp in enumerate(self.compilers):
                plan = comp.materialize(key, canon)
                if plan is not None:
                    self.telemetry.count(
                        f"shard{s}.wordlines_sensed",
                        record_plan_traffic(self.shard_traffic[s], plan),
                    )
                    thr = plan_thresholds(plan)
                    if thr:
                        self.telemetry.count("threshold_senses", thr)
                    self.telemetry.count("materialization_programs")
                    self.telemetry.count(
                        f"shard{s}.materialization_programs"
                    )

    # per-shard counter mirrors ("shard{s}.wordlines_sensed", …) live in
    # the registry next to the fleet totals; the legacy list attributes
    # read them out (conservation asserted in tests/test_query_telemetry)
    @property
    def shard_wordlines(self) -> list[int]:
        return [
            int(self.telemetry.value(f"shard{s}.wordlines_sensed"))
            for s in range(self.store.num_shards)
        ]

    @property
    def shard_esp_programs(self) -> list[int]:
        return [
            int(self.telemetry.value(f"shard{s}.esp_programs"))
            for s in range(self.store.num_shards)
        ]

    # -- incremental ingest --------------------------------------------------
    def append(self, rows: dict[str, np.ndarray]) -> int:
        """Append rows to the live fleet; returns pages ESP-programmed.

        The batch is validated — column set against the global ingest
        schema, lengths, values, and every destination stripe's capacity —
        *before* any shard queue or page state mutates, and appends are
        rejected while tickets are in flight (a ticket gathered across
        the mutation could merge partials from different index versions).
        Each stripe programs only its delta pages; plans over columns
        whose index metadata did not change stay warm on every shard.

        With ``coalesce_appends`` the (cumulatively validated) batch is
        queued and returns 0; the next ``flush()`` — or an explicit
        :meth:`apply_appends` — programs the whole queue as ONE delta per
        touched page per stripe.
        """
        if self._meta:
            raise RuntimeError(
                f"append() with {len(self._meta)} tickets in flight; "
                "flush() the fleet first so no ticket spans the mutation"
            )
        try:
            return self._admit_append(rows)
        except ValueError as err:
            if not (self.grow_on_overflow and "overflows" in str(err)):
                raise
            # capacity growth rides the compaction rebuild: every stripe
            # re-ingests into wider pages (the failed attempt validated
            # before mutating, so nothing is half-applied) with headroom
            # for the batch plus the original reserve — or twice the
            # batch, whichever is larger (any one stripe may absorb it)
            b = len(next(iter(rows.values())))
            self.compact(
                reserve_rows=b + max(2 * b, self.store.reserve_rows),
                rebuild_all=True,
            )
            return self._admit_append(rows)

    def _admit_append(self, rows: dict[str, np.ndarray]) -> int:
        if self.coalesce_appends:
            # shared validate+queue logic (per-batch column check, then
            # cumulative schema/stripe-capacity check) — see
            # repro.query.scheduler.queue_append
            queue_append(self.store, self._append_buf, rows)
            return 0
        return self._program_append(rows)

    def _program_append(self, rows: dict[str, np.ndarray]) -> int:
        deltas = self.store.append(rows)  # validates before mutating
        tele = self.telemetry
        pages = words = logical = 0
        for s, delta in deltas.items():
            programs, phys = self.store.shards[s].program_delta(
                self.devices[s], delta, telemetry=tele
            )
            tele.count(f"shard{s}.esp_programs", programs)
            pages += programs
            words += phys
            logical += sum(int(pd.words.shape[0]) for pd in delta.pages)
            tele.count("rows_appended", delta.rows)
        tele.count("esp_delta_programs", pages)
        tele.count("words_programmed", words)
        tele.count("words_written", logical)
        # row counts moved: host-side valid-row masks and their
        # device-resident stacks are stale (the fleet snapshot stack and
        # extras caches invalidate through the stores' content epochs)
        self._masks = None
        self._maskmat_cache.clear()
        self._mask_rows.clear()
        return pages

    @property
    def appends_queued(self) -> int:
        return len(self._append_buf)

    def apply_appends(self) -> int:
        """Program every queued append batch as one coalesced delta: a
        stripe's page touched by many queued batches programs ONCE.  Ran
        automatically at the top of ``flush()``; returns pages programmed.
        """
        if not self._append_buf:
            return 0
        rows = merge_appends(self._append_buf)
        self.telemetry.count(
            "append_batches_coalesced", len(self._append_buf)
        )
        self._append_buf.clear()
        return self._program_append(rows)

    # -- deletes / updates / compaction --------------------------------------
    def delete(self, row_ids) -> int:
        """Tombstone global rows fleet-wide; returns pages ESP-programmed.

        Routing inverts ``row_maps`` (global id -> shard, local position);
        every destination stripe validates before any stripe mutates, then
        each programs ONE tombstone delta page.  Queued appends apply
        first, and — like appends — deletes are refused while tickets are
        in flight.  May trigger the auto-compaction policy.
        """
        if self._meta:
            raise RuntimeError(
                f"delete() with {len(self._meta)} tickets in flight; "
                "flush() the fleet first so no ticket spans the mutation"
            )
        self.apply_appends()
        deltas = self.store.delete(row_ids)
        tele = self.telemetry
        pages = words = logical = 0
        for s, delta in deltas.items():
            programs, phys = self.store.shards[s].program_delta(
                self.devices[s], delta, telemetry=tele
            )
            tele.count(f"shard{s}.esp_programs", programs)
            pages += programs
            words += phys
            logical += sum(int(pd.words.shape[0]) for pd in delta.pages)
        tele.count("rows_deleted", int(np.asarray(row_ids).size))
        tele.count("esp_delta_programs", pages)
        tele.count("words_programmed", words)
        tele.count("words_written", logical)
        tele.gauge("tombstone_density", self.store.tombstone_density)
        self._masks = None
        self._maskmat_cache.clear()
        self._mask_rows.clear()
        self._maybe_compact()
        return pages

    def update(self, row_ids, rows: dict[str, object]) -> int:
        """Update = delete + append (replacement rows get fresh tail ids).

        Both halves validate BEFORE either mutates — a bad update can
        never leave rows deleted but not re-appended.  Returns pages
        programmed (0 pending flush when appends coalesce).
        """
        if self._meta:
            raise RuntimeError(
                f"update() with {len(self._meta)} tickets in flight; "
                "flush() the fleet first so no ticket spans the mutation"
            )
        self.apply_appends()
        groups = self.store.check_delete(row_ids)
        arrays = {c: np.asarray(v) for c, v in rows.items()}
        b = self.store.check_append(arrays)
        nids = sum(len(v) for v in groups.values())
        if b != nids:
            raise ValueError(
                f"update() got {nids} row ids but {b} replacement rows"
            )
        n = self.delete(row_ids)
        n += self.append(arrays)
        self.telemetry.count("rows_updated", nids)
        return n

    def _maybe_compact(self) -> bool:
        if (
            self.compact_density is None
            or self.store.tombstone_density < self.compact_density
        ):
            return False
        self.compact()
        return True

    def compact(
        self, reserve_rows: int | None = None, rebuild_all: bool = False
    ) -> dict:
        """Erase-unit-aware rebuild of the tombstoned stripes; returns stats.

        Only stripes carrying tombstones erase and reprogram (their word
        budget cannot grow: restored headroom never exceeds the stripe's
        old capacity) — untouched stripes keep their devices, layouts, and
        warm plans; their epochs do not move.  Surviving rows are
        renumbered densely fleet-wide (row ``k`` = k-th live row in old
        global order) but never migrate between stripes, so renumbering is
        host-side metadata (``row_maps``) everywhere.  An explicit
        ``reserve_rows`` that widens any stripe's pages — or
        ``rebuild_all`` (the ``grow_on_overflow`` path) — escalates to a
        full-fleet rebuild so shard snapshots keep stacking.  Reprogrammed
        words count toward physical (never logical) write traffic: the
        fleet's write amplification.
        """
        if self._meta:
            raise RuntimeError(
                f"compact() with {len(self._meta)} tickets in flight; "
                "flush() the fleet first so no ticket spans the rebuild"
            )
        self.apply_appends()
        sstore, tele = self.store, self.telemetry
        t0 = time.perf_counter()
        dropped = sstore.deleted_rows
        active = sstore.active
        live_local = {s: sstore.shards[s].live_bits() for s in active}
        live_global = {s: sstore.row_maps[s][live_local[s]] for s in active}
        all_live = np.sort(
            np.concatenate(
                [live_global[s] for s in active]
                or [np.zeros((0,), np.int64)]
            )
        )

        def shard_reserve(s: int) -> int:
            if reserve_rows is not None:
                return reserve_rows
            st = sstore.shards[s]
            return st.capacity_rows - st.live_rows

        rebuild = [
            s
            for s in active
            if rebuild_all or sstore.shards[s].deleted_rows
        ]
        fleet_words = max((st.min_words for st in sstore.shards), default=0)
        needed = max(
            (
                _num_words(int(live_local[s].sum()) + shard_reserve(s))
                for s in rebuild
            ),
            default=0,
        )
        if needed > fleet_words and not rebuild_all:
            # wider pages on one stripe would break fleet stacking —
            # re-stripe everything at the new width
            rebuild_all, rebuild = True, list(active)
        if rebuild_all:
            fleet_words = max(
                (
                    _num_words(int(live_local[s].sum()) + shard_reserve(s))
                    for s in rebuild
                ),
                default=0,
            )

        # rebuilt stripes must share the fleet's canonical page placement
        # (fused cross-shard execution gathers identical (block, wordline)
        # coordinates on every chip): fork an untouched device's layout
        # when one survives, else recompute one canonical layout
        untouched = [s for s in active if s not in set(rebuild)]
        canonical = self.devices[untouched[0]].layout if untouched else None

        erased = pages = words = 0
        for s in rebuild:
            st, dev = sstore.shards[s], self.devices[s]
            keep = live_local[s]
            table = {c: v[keep] for c, v in st.to_table().items()}
            blocks = dev.erase_rebuild()
            st.rebuild(
                table,
                reserve_rows=shard_reserve(s),
                schema=sstore.schema,
                min_words=fleet_words,
            )
            if canonical is None:
                canonical = Layout(
                    wls_per_block=dev.layout.wls_per_block,
                    levels=dev.layout.levels,
                )
                st.place_into(canonical)
            dev.layout = canonical.fork()
            programs, phys = _program_grouped(dev, st.logical)
            dev.reset_after_rebuild()
            erased += blocks
            pages += programs
            words += phys
            tele.count(f"shard{s}.block_erases", blocks)
            tele.count(f"shard{s}.esp_programs", programs)
            sstore.shard_values[s] = {
                col: tuple(int(v) for v in np.unique(vals))
                for col, vals in table.items()
            }
            if sstore.stripe_key is not None:
                keys = table.get(sstore.stripe_key, np.zeros((0,)))
                sstore.stripe_bounds[s] = (
                    (int(keys.min()), int(keys.max())) if len(keys) else None
                )

        # dense global renumbering: rank of each surviving old id (host
        # metadata only — untouched stripes' pages and epochs stay put)
        for s in active:
            sstore.row_maps[s] = np.searchsorted(all_live, live_global[s])
        sstore.num_rows = int(all_live.size)
        if reserve_rows is not None:
            sstore.reserve_rows = reserve_rows

        self._masks = None
        self._fleet_stack = None
        self._maskmat_cache.clear()
        self._mask_rows.clear()
        self._group_cache.clear()
        self._extras_cache.clear()
        self._flush_programs.clear()
        self._cse_cache.clear()

        tele.count("compactions")
        tele.count("block_erases", erased)
        tele.count("words_programmed", words)
        tele.count("compaction_rows_dropped", dropped)
        tele.gauge("tombstone_density", sstore.tombstone_density)
        self._record_wear()
        t1 = time.perf_counter()
        tele.span(
            "compact",
            "flush",
            t0,
            t1,
            args={"erased": erased, "shards": len(rebuild)},
        )
        tele.observe("compact_s", t1 - t0)
        return {
            "rows_dropped": dropped,
            "live_rows": sstore.num_rows,
            "shards_rebuilt": len(rebuild),
            "blocks_erased": erased,
            "words_reprogrammed": words,
            "seconds": t1 - t0,
        }

    def _record_wear(self) -> None:
        """Fleet-wide per-block wear gauges (P/E cycles)."""
        cycles = [
            n for dev in self.devices for n in dev.pec.values()
        ]
        if cycles:
            self.telemetry.gauge("max_pec", max(cycles))
            self.telemetry.gauge("mean_pec", sum(cycles) / len(cycles))

    # -- admission ----------------------------------------------------------
    def submit(self, query: Query) -> int:
        """Admit a query: it is scattered to every active shard's queue and
        executes on the next ``flush()``.

        Validation (predicate columns + the aggregate's target columns,
        via :func:`repro.query.aggregate.validate_query`) happens here: a
        compile error inside ``flush`` would fire after some shard queues
        were popped, leaving the fleet's queues out of lockstep (a
        poisoned ticket).

        Shards whose stripe provably cannot match the query root
        (:func:`shard_cannot_match` against the values actually present on
        the stripe) are pruned *before* scatter: they never sense a page,
        and their partial is the aggregate's empty value.  On a
        ``range``-striped store with a ``stripe_key`` this routes
        key-range queries to the few shards holding the range.
        """
        # queued (coalesced) appends must land before admission: pruning
        # consults per-stripe present values, and a query for a value that
        # only exists in the queued batches would otherwise be pruned on
        # every shard.  Appends arriving back-to-back still coalesce.
        self.apply_appends()
        agg = validate_query(query, self.store.schema)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._meta[ticket] = (query, time.perf_counter())
        self._partials[ticket] = {}
        self._cache_hits[ticket] = True
        for s in self.store.active:
            if shard_cannot_match(
                query.where, self.store.shard_values[s]
            ):
                self._partials[ticket][s] = agg.empty_partial(
                    self.store.shards[s]
                )
                self.telemetry.count("shards_pruned")
            else:
                self._queues[s].append((ticket, query))
        return ticket

    @property
    def pending(self) -> int:
        return max((len(q) for q in self._queues), default=0)

    # -- execution helpers ---------------------------------------------------
    def _snapshots_stack(self, shards: list[int]) -> jax.Array | None:
        """Stacked ``(S, slots, words)`` fleet snapshot, or None when shard
        stores diverge in shape (then per-device execution is used).

        The stack is cached across flushes, keyed on each device's
        (epoch, slot count): steady-state serving reuses one device array.
        Spilled values never enter the store at all (they live as
        device-resident latch scratch inside the traced program), so
        spilling plans cannot stale the cached stack.
        """
        if not self.fuse_across_shards:
            return None
        if any(self.devices[s]._non_esp for s in shards):
            # the fused path never injects read errors; route shards with
            # non-ESP pages through execute_batch, which guards against
            # sensing them
            return None
        key = tuple(
            (s, self.devices[s].store.epoch, self.devices[s].store.num_slots)
            for s in shards
        )
        if self._fleet_stack is not None and self._fleet_stack[0] == key:
            return self._fleet_stack[1]
        snaps = [self.devices[s].store.snapshot() for s in shards]
        if len({sn.shape for sn in snaps}) != 1:
            return None
        data = jnp.stack(snaps)
        self._fleet_stack = (key, data)
        return data

    def _sharded_runner(self, signature):
        fn = self._runners.get(signature)
        if fn is None:
            fn = make_plan_runner(
                signature, self.devices[0].interpret, shard_data=True
            )
            self._runners[signature] = fn
        return fn

    # -- serving -------------------------------------------------------------
    def flush(self) -> dict[int, QueryResult]:
        """Drain pending queries from every shard queue, execute, reduce
        aggregates device-side, and gather completed tickets — including
        tickets completed purely by stripe routing (every shard pruned at
        ``submit``, nothing left to execute).

        ``pipeline=True`` flushes shards *asynchronously*: each shard's
        batch runs as one fused program (sensing + reduces, one payload)
        and the next shard is dispatched while the previous one computes;
        otherwise shards flush in lockstep under cross-shard jit-of-vmap
        groups with per-reduce-signature transfers (the PR-4 path).
        """
        self.apply_appends()
        self._maybe_materialize()
        if self.pipeline:
            return self._flush_pipelined()
        return self._flush_lockstep()

    def _pop_batch(self, s: int, depth: int, record: bool = True):
        """Pop up to ``depth`` queries from shard ``s``'s queue, compiled
        through its plan/exec caches; records plan traffic (fleet total +
        the ``shard{s}.*`` registry mirror) and, when telemetry is
        enabled, accumulates per-ticket sensing attribution.

        ``record=False`` defers the traffic recording to the caller: the
        optimizer paths dedup/CSE the batch first and charge only the
        plans that physically run (per-ticket attribution still reflects
        each query's standalone plan — what the ticket *asked for*)."""
        tele = self.telemetry
        batch, self._queues[s] = (
            self._queues[s][:depth],
            self._queues[s][depth:],
        )
        t_pop = time.perf_counter() if tele.enabled else 0.0
        out = []
        for ticket, q in batch:
            cq = self.compilers[s].compile(q)
            self._cache_hits[ticket] &= cq.cache_hit
            e = self.compilers[s].exec_for(cq)
            out.append((ticket, q, cq, e))
            if record:
                tele.count(
                    f"shard{s}.wordlines_sensed",
                    record_plan_traffic(self.shard_traffic[s], cq.plan),
                )
                thr = plan_thresholds(cq.plan)
                if thr:
                    tele.count("threshold_senses", thr)
            if tele.enabled:
                attr = self._attr.get(ticket)
                if attr is None:
                    attr = self._attr[ticket] = {
                        "sensings": 0,
                        "wordlines": 0,
                        "spill_steps": 0,
                        "agg_plane_reads": 0,
                        "shards": 0,
                        "queue_s": t_pop - self._meta[ticket][1],
                        "compile_s": 0.0,
                        "device_s": 0.0,
                        "transfer_s": 0.0,
                        "merge_s": 0.0,
                    }
                attr["sensings"] += plan_sensings(cq.plan)
                attr["wordlines"] += plan_traffic(cq.plan)[1]
                attr["spill_steps"] += e.spills if e is not None else 0
                attr["shards"] += 1
        tele.gauge(f"shard{s}.queue_depth", len(self._queues[s]))
        return out

    def _attr_phase(self, compiled, phase: str, dt: float) -> None:
        """Charge one shard-batch phase duration to every member ticket's
        attribution (telemetry enabled only).  Phase durations are
        shard-batch granular: a ticket's ``compile_s``/``device_s``/…
        sums the phases of every shard batch that served it — shared
        batch work, so members of one batch each report the full phase."""
        for ticket, _, _, _ in compiled:
            attr = self._attr.get(ticket)
            if attr is not None:
                attr[phase] += dt

    def _collect_done(self, t1: float) -> dict[int, QueryResult]:
        """Gather every ticket whose partials cover all active shards.

        Pops every per-ticket record (_meta / _partials / _cache_hits /
        _attr) as the ticket completes — long-running serving keeps only
        in-flight tickets in memory (asserted in tests)."""
        tele = self.telemetry
        expected = len(self.store.active)
        results: dict[int, QueryResult] = {}
        done = [
            t
            for t in list(self._partials)
            if len(self._partials[t]) == expected
        ]
        popped = []
        for ticket in done:
            q, t_submit = self._meta.pop(ticket)
            parts = self._partials.pop(ticket)
            agg = get_aggregator(q.agg)
            self._host_postprocess |= agg.host_postprocess
            popped.append((ticket, q, t_submit, parts, agg))
        # MASK tickets un-stripe together: one unpack/scatter pass per
        # shard and one packbits for the whole flush, instead of a numpy
        # pass per (ticket x shard) — see merge_mask_batch
        merged: dict[int, object] = {}
        mask_ix = [
            n for n, it in enumerate(popped) if it[4].kind == "mask"
        ]
        if len(mask_ix) > 1:
            vecs = merge_mask_batch(
                [popped[n][3] for n in mask_ix], self.store
            )
            merged = dict(zip(mask_ix, vecs))
            tele.count("mask_batch_merges")
        for n, (ticket, q, t_submit, parts, agg) in enumerate(popped):
            value = (
                merged[n] if n in merged else agg.merge(parts, self.store)
            )
            attr = self._attr.pop(ticket, None)
            results[ticket] = QueryResult(
                ticket,
                q,
                value,
                t1 - t_submit,
                cache_hit=self._cache_hits.pop(ticket),
                attribution=attr,
            )
            tele.count("total_latency_s", t1 - t_submit)
            if tele.enabled:
                attribute_result(tele, ticket, q, attr, t_submit, t1)
        tele.count("queries_served", len(done))
        if done and tele.enabled:
            t_m1 = time.perf_counter()
            tele.span("merge", "flush", t1, t_m1, tid=TID_MERGE)
            for ticket in done:
                a = results[ticket].attribution
                if a is not None:
                    a["merge_s"] = t_m1 - t1
        return results

    # -- pipelined (asynchronous per-shard) flushing -------------------------
    def _routed_depths(self, queued: list[int]) -> dict[int, int]:
        """Per-shard drain depths under a fleet-wide slot budget.

        The budget is ``queue_depth`` slots per *active* shard; shards
        whose queues are short — typically because stripe routing pruned
        their traffic at ``submit`` — donate their unused slots to shards
        with deeper queues.  A hot stripe can therefore drain far beyond
        ``queue_depth`` in one flush instead of serializing over many.
        """
        budget = self.queue_depth * max(len(self.store.active), 1)
        depths = {
            s: min(len(self._queues[s]), self.queue_depth) for s in queued
        }
        leftover = budget - sum(depths.values())
        # donate in equal shares across the shards that still have queue,
        # so two hot stripes split the budget instead of the lower-indexed
        # one absorbing it all
        while leftover > 0:
            needy = [
                s for s in queued if len(self._queues[s]) > depths[s]
            ]
            if not needy:
                break
            share = max(1, leftover // len(needy))
            for s in needy:
                take = min(
                    len(self._queues[s]) - depths[s], share, leftover
                )
                depths[s] += take
                leftover -= take
                if not leftover:
                    break
        return depths

    def _mask_row(self, s: int) -> jax.Array:
        """Device-resident (fleet_words,) valid-row mask of one shard."""
        key = (s, self.store.shards[s].epoch)
        row = self._mask_rows.get(key)
        if row is None:
            if len(self._mask_rows) >= 64:
                self._mask_rows.clear()
            row = jnp.asarray(self.store.shards[s].valid_words_mask())
            self._mask_rows[key] = row
        return row

    def _dispatch_shard(self, s: int, depth: int):
        """Compile + dispatch one shard's fused flush program (async).

        Returns ``(s, compiled, program, payload, aggs)`` — the payload is
        an in-flight device array; nothing blocks here.  Shards whose
        device holds non-ESP pages run the synchronous per-group legacy
        path instead (their reads may inject errors) and return None.
        """
        tele = self.telemetry
        dev = self.devices[s]
        cse_on = self.optimize and not dev._non_esp
        t_d0 = time.perf_counter()
        compiled = self._pop_batch(s, depth, record=not cse_on)
        if not compiled:
            return None
        st = self.store.shards[s]
        aggs = [get_aggregator(q.agg) for _, q, _, _ in compiled]
        execs = [e for _, _, _, e in compiled]
        tele.count(
            "distinct_signatures",
            len({e.signature for e in execs if e is not None}),
        )
        t_d1 = time.perf_counter()
        if dev._non_esp:
            # legacy sync path: error-injecting eager guard + per-group
            # reduce transfers
            masked = dev.execute_batch_stacked(
                [cq.plan for _, _, cq, _ in compiled],
                execs=execs,
                batch_key=tuple((s, cq.key) for _, _, cq, _ in compiled),
            ) & self._mask_row(s)
            tele.count("signature_groups", dev.last_signature_groups)
            tele.count("eager_plans", dev.last_eager_plans)
            partials, extra_counts, n_groups = reduce_flush(
                masked,
                [q.agg for _, q, _, _ in compiled],
                [st] * len(compiled),
                [(s, st.epoch)] * len(compiled),
                interpret=dev.interpret,
                extras_cache=self._extras_cache,
            )
            tele.count("host_transfers", n_groups)
            tele.count(f"shard{s}.host_transfers", n_groups)
            self._record_partials(s, compiled, partials, extra_counts)
            if tele.enabled:
                t_d2 = time.perf_counter()
                tele.span("compile", "shard", t_d0, t_d1, tid=s)
                tele.span("execute+reduce", "shard", t_d1, t_d2, tid=s)
                self._attr_phase(compiled, "compile_s", t_d1 - t_d0)
                self._attr_phase(compiled, "device_s", t_d2 - t_d1)
            return None
        # per-shard CSE: whole-plan dedup + shared-subtree extraction
        # within this shard's fused flush (repro.query.optimize.cse_flush)
        cse = None
        if cse_on:
            ckey = (
                s,
                tuple(cq.key for _, _, cq, _ in compiled),
                st.epoch,
                dev.store.epoch,
            )
            cse = self._cse_cache.get(ckey)
            if cse is None:
                if len(self._cse_cache) >= 64:
                    self._cse_cache.clear()
                cse = cse_flush(
                    [cq for _, _, cq, _ in compiled],
                    self.compilers[s],
                    dev,
                )
                self._cse_cache[ckey] = cse
        # plan keys cover only the predicate side; the aggregate specs
        # join the key so same-predicate flushes under different
        # aggregates never share a program
        key = (
            s,
            tuple(cq.key for _, _, cq, _ in compiled),
            tuple(a.spec for a in aggs),
            st.epoch,
            dev.store.epoch,
        )
        program = self._flush_programs.get(key)
        if program is None:
            if len(self._flush_programs) >= 64:
                self._flush_programs.clear()
            program = compile_flush(
                execs if cse is None else list(cse.member_execs),
                [q.agg for _, q, _, _ in compiled],
                [st] * len(compiled),
                [(s, st.epoch)] * len(compiled),
                words=st.words,
                interpret=dev.interpret,
                runner_cache=self._runner_cache,
                extras_cache=self._extras_cache,
                pad=dev.pad_signatures,
                dedup_keys=(
                    None if cse is None else list(cse.dedup_keys)
                ),
                shared_execs=() if cse is None else cse.shared_execs,
            )
            self._flush_programs[key] = program
        t_d2 = time.perf_counter()
        payload = program.run(dev.store.snapshot(), self._mask_row(s))
        if cse is None:
            age_spill_blocks(dev.pec, execs)
        else:
            # physical traffic + wear after CSE: each UNIQUE member plan
            # runs once (duplicates ride the member gather), each shared
            # subplan senses once and programs one scratch page
            age_spill_blocks(
                dev.pec,
                [cse.member_execs[i] for i in cse.uix]
                + list(cse.shared_execs),
            )
            for b in cse.shared_blocks:
                dev.pec[b] = dev.pec.get(b, 0) + 1
            wls = thr = 0
            for p in list(cse.member_plans) + list(cse.shared_plans):
                wls += record_plan_traffic(self.shard_traffic[s], p)
                thr += plan_thresholds(p)
            tele.count(f"shard{s}.wordlines_sensed", wls)
            if thr:
                tele.count("threshold_senses", thr)
            tele.count("cse_plan_hits", cse.n_dedup_hits)
            tele.count("cse_shared_senses", len(cse.shared_plans))
            tele.count("cse_rewritten_members", cse.n_rewritten)
            tele.count("cse_spill_programs", len(cse.shared_plans))
            tele.count(f"shard{s}.cse_esp_programs", len(cse.shared_plans))
        tele.count("fused_dispatches")
        tele.count(f"shard{s}.fused_dispatches")
        tele.count("signature_groups", program.n_sense_groups)
        if tele.enabled:
            t_d3 = time.perf_counter()
            tele.span("compile", "shard", t_d0, t_d2, tid=s)
            tele.span("dispatch", "shard", t_d2, t_d3, tid=s)
            self._attr_phase(compiled, "compile_s", t_d2 - t_d0)
            self._attr_phase(compiled, "device_s", t_d3 - t_d2)
        return (s, compiled, program, payload, aggs)

    def _record_partials(self, s, compiled, partials, extra_counts):
        tele = self.telemetry
        for i, (ticket, _, _, _) in enumerate(compiled):
            self._partials[ticket][s] = partials[i]
            if extra_counts[i]:
                self.shard_traffic[s][AGG_READ_SHAPE] += extra_counts[i]
                tele.count(
                    f"shard{s}.wordlines_sensed", extra_counts[i]
                )
                attr = self._attr.get(ticket)
                if attr is not None:
                    attr["sensings"] += extra_counts[i]
                    attr["wordlines"] += extra_counts[i]
                    attr["agg_plane_reads"] += extra_counts[i]

    def _gather_shard(self, inflight) -> None:
        """Transfer one in-flight shard payload (the only blocking point)
        and record its partials."""
        tele = self.telemetry
        s, compiled, program, payload, aggs = inflight
        t_g0 = time.perf_counter() if tele.enabled else 0.0
        host = jax.device_get(payload)
        tele.count("host_transfers")
        tele.count(f"shard{s}.host_transfers")
        if tele.enabled:
            t_g1 = time.perf_counter()
            tele.span("transfer", "shard", t_g0, t_g1, tid=s)
            self._attr_phase(compiled, "transfer_s", t_g1 - t_g0)
        partials = program.unpack(host, aggs)
        self._record_partials(s, compiled, partials, program.extra_counts)

    def _flush_pipelined(self) -> dict[int, QueryResult]:
        active = [s for s in self.store.active if self._queues[s]]
        expected = len(self.store.active)
        if not active and not any(
            len(p) == expected for p in self._partials.values()
        ):
            return {}
        tele = self.telemetry
        t0 = time.perf_counter()
        depths = self._routed_depths(active)
        if tele.enabled:
            for s, d in depths.items():
                tele.gauge(f"shard{s}.routed_depth", d)
        inflight: deque = deque()
        for s in active:
            entry = self._dispatch_shard(s, depths[s])
            if entry is not None:
                inflight.append(entry)
            # double buffer: collect shard k's payload only after shard
            # k+1 was dispatched, so the next shard's sensing overlaps the
            # previous shard's in-flight reduce; at most two payloads are
            # ever co-resident
            while len(inflight) >= 2:
                self._gather_shard(inflight.popleft())
        while inflight:
            self._gather_shard(inflight.popleft())
        t1 = time.perf_counter()
        results = self._collect_done(t1)
        tele.count("flushes")
        tele.count("pipelined_flushes")
        tele.count("serve_time_s", t1 - t0)
        tele.span(
            "flush",
            "flush",
            t0,
            t1,
            args={"flush": int(self.flushes), "shards": len(active)},
        )
        tele.observe("flush_latency_s", t1 - t0)
        return results

    # -- lockstep (cross-shard fused) flushing -------------------------------
    def _flush_lockstep(self) -> dict[int, QueryResult]:
        active = [s for s in self.store.active if self._queues[s]]
        expected = len(self.store.active)
        if not active and not any(
            len(p) == expected for p in self._partials.values()
        ):
            return {}
        tele = self.telemetry
        t0 = time.perf_counter()

        # scatter: pop per-shard batches and compile through per-shard caches
        items: list[tuple[int, int, object]] = []  # (shard, ticket, exec|None)
        plans: list = []  # parallel to items
        keys: list[tuple] = []  # (shard, plan-cache key) per item
        popped: list = []  # the _pop_batch tuples, for phase attribution
        for s in active:
            for entry in self._pop_batch(
                s, self.queue_depth, record=not self.optimize
            ):
                ticket, q, cq, e = entry
                items.append((s, ticket, e))
                plans.append(cq.plan)
                keys.append((s, cq.key))
                popped.append(entry)

        # whole-plan dedup across the lockstep batch: members sharing one
        # (shard, canonical plan) sense once and read the same output row.
        # (Subtree CSE stays a pipelined/single-device feature — the
        # cross-shard runners would have to thread shared latch values
        # through every vmap group.)
        uix = list(range(len(items)))
        inv: list[int] = uix
        if self.optimize and items:
            pos: dict = {}
            uix, inv = [], []
            for i, k in enumerate(keys):
                j = pos.get(k)
                if j is None:
                    j = pos[k] = len(uix)
                    uix.append(i)
                inv.append(j)
            tele.count("cse_plan_hits", len(items) - len(uix))
            for i in uix:
                s = items[i][0]
                tele.count(
                    f"shard{s}.wordlines_sensed",
                    record_plan_traffic(self.shard_traffic[s], plans[i]),
                )
                thr = plan_thresholds(plans[i])
                if thr:
                    tele.count("threshold_senses", thr)
        t_sc = time.perf_counter()

        if items:
            # execute: fused cross-shard vmap groups where snapshots stack.
            # Group outputs are concatenated and re-ordered with ONE gather —
            # per-item jax slicing would cost O(shards x batch) dispatches
            # and dominate serving time at realistic batch sizes.
            # Only the UNIQUE items execute; duplicates gather their
            # representative's row below.
            uitems = [items[i] for i in uix]
            uplans = [plans[i] for i in uix]
            ukeys = [keys[i] for i in uix]
            execs = [e for _, _, e in uitems]
            tele.count(
                "distinct_signatures",
                len({e.signature for e in execs if e is not None}),
            )
            fleet_w = self.store.shards[active[0]].words
            pieces: list[jax.Array] = []  # (B_g, fleet_w) per group
            order: list[int] = []  # unique-item index per output row
            data = self._snapshots_stack(active)
            if data is not None:
                cache_key = (tuple(active),) + tuple(ukeys)
                prepared = self._group_cache.get(cache_key)
                if prepared is None:
                    prepared = []
                    for signature, members, stacked in group_execs(
                        execs, pad=True
                    ):
                        sids = np.array(
                            [uitems[i][0] for i in members], np.int32
                        )
                        fleet_ix = jnp.asarray(
                            np.searchsorted(
                                np.asarray(active, np.int32), sids
                            ).astype(np.int32)
                        )
                        prepared.append(
                            (
                                signature,
                                fleet_ix,
                                tuple(jnp.asarray(x) for x in stacked),
                                members,
                            )
                        )
                    if len(self._group_cache) >= 64:
                        self._group_cache.clear()
                    self._group_cache[cache_key] = prepared
                tele.count("signature_groups", len(prepared))
                for signature, fleet_ix, idxs, members in prepared:
                    out = self._sharded_runner(signature)(
                        data, fleet_ix, *idxs
                    )
                    pieces.append(out[:, :fleet_w])
                    order.extend(members)
                for s, _, e in uitems:
                    age_spill_blocks(self.devices[s].pec, (e,))
                tele.count("fused_flushes")
            else:
                # per-device fallback: each shard runs its own vmap batches
                for s in active:
                    ix = [i for i, it in enumerate(uitems) if it[0] == s]
                    pieces.append(
                        self.devices[s].execute_batch_stacked(
                            [uplans[i] for i in ix],
                            execs=[execs[i] for i in ix],
                            batch_key=tuple(ukeys[i] for i in ix),
                        )
                    )
                    order.extend(ix)
                    tele.count(
                        "signature_groups",
                        self.devices[s].last_signature_groups,
                    )
                    tele.count(
                        "eager_plans", self.devices[s].last_eager_plans
                    )
            allout = reorder_rows(pieces, order)  # (U, fleet_w), uix order
            if len(uix) != len(items):
                # fan each duplicate out to its representative's row
                allout = allout[jnp.asarray(np.asarray(inv, np.int32))]

            # reduce: mask shard partials (identity pad rows, word slack,
            # and fleet-width padding of short stripes), then one jit'd
            # (weighted-)popcount reduce + one host transfer per reduce
            # signature across the whole flush, any mix of aggregate kinds
            masked = allout & self._mask_matrix(
                tuple(s for s, _, _ in items)
            )
            specs = [self._meta[t][0].agg for _, t, _ in items]
            partials, extra_counts, n_groups = reduce_flush(
                masked,
                specs,
                [self.store.shards[s] for s, _, _ in items],
                [
                    (s, self.store.shards[s].epoch)
                    for s, _, _ in items
                ],
                interpret=self.devices[0].interpret,
                extras_cache=self._extras_cache,
            )
            tele.count("host_transfers", n_groups)
            jax.block_until_ready(masked)

            for i, (s, ticket, _) in enumerate(items):
                self._partials[ticket][s] = partials[i]
                # extra planes the aggregate sensed on this shard (BSI
                # slices / equality bitmaps): single-wordline reads in
                # the projected traffic
                if extra_counts[i]:
                    self.shard_traffic[s][AGG_READ_SHAPE] += extra_counts[i]
                    tele.count(
                        f"shard{s}.wordlines_sensed", extra_counts[i]
                    )
                    attr = self._attr.get(ticket)
                    if attr is not None:
                        attr["sensings"] += extra_counts[i]
                        attr["wordlines"] += extra_counts[i]
                        attr["agg_plane_reads"] += extra_counts[i]

        t1 = time.perf_counter()
        if tele.enabled and items:
            tele.span("compile", "flush", t0, t_sc)
            tele.span("execute+reduce", "flush", t_sc, t1)
            self._attr_phase(popped, "compile_s", t_sc - t0)
            self._attr_phase(popped, "device_s", t1 - t_sc)
        results = self._collect_done(t1)
        tele.count("flushes")
        tele.count("serve_time_s", t1 - t0)
        tele.span(
            "flush",
            "flush",
            t0,
            t1,
            args={"flush": int(self.flushes), "shards": len(active)},
        )
        tele.observe("flush_latency_s", t1 - t0)
        return results

    def _mask_matrix(self, shard_seq: tuple[int, ...]) -> jax.Array:
        """Device-resident ``(len(shard_seq), fleet_words)`` valid-row mask
        stack, memoized per batch composition — row counts are fixed after
        ingest, so steady-state flushes skip the host build + upload."""
        cached = self._maskmat_cache.get(shard_seq)
        if cached is not None:
            return cached
        if self._masks is None:
            self._masks = [
                self.store.shards[s].valid_words_mask()
                for s in range(self.store.num_shards)
            ]
        mat = jnp.asarray(np.stack([self._masks[s] for s in shard_seq]))
        if len(self._maskmat_cache) >= 64:
            self._maskmat_cache.clear()
        self._maskmat_cache[shard_seq] = mat
        return mat

    def serve(self, queries: list[Query]) -> list[QueryResult]:
        """Submit + flush until drained; results in submission order."""
        tickets = [self.submit(q) for q in queries]
        results: dict[int, QueryResult] = {}
        while self.pending:
            results.update(self.flush())
        # tickets whose every shard was pruned at submit never enter a
        # queue; one more flush gathers them
        results.update(self.flush())
        return [results[t] for t in tickets]

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict:
        served = max(self.queries_served, 1)
        return {
            "num_shards": self.store.num_shards,
            "policy": self.store.policy,
            "queries_served": self.queries_served,
            "flushes": self.flushes,
            "fused_flushes": self.fused_flushes,
            "pipelined_flushes": self.pipelined_flushes,
            "fused_dispatches": self.fused_dispatches,
            "host_transfers": self.host_transfers,
            "shards_pruned": self.shards_pruned,
            "vmap_batches": self.signature_groups,
            "distinct_signatures": self.distinct_signatures,
            "eager_plans": self.eager_plans,
            "plan_cache_hits": sum(c.hits for c in self.compilers),
            "plan_cache_misses": sum(c.misses for c in self.compilers),
            "plan_cache_size": sum(c.cache_size for c in self.compilers),
            "queries_per_sec": (
                self.queries_served / self.serve_time_s
                if self.serve_time_s
                else float("inf")
            ),
            "mean_latency_s": self.total_latency_s / served,
            "mws_commands": sum(
                sum(c.values()) for c in self.shard_traffic
            ),
            "sensings_per_query": (
                sum(sum(c.values()) for c in self.shard_traffic) / served
            ),
            "threshold_senses": self.threshold_senses,
            "cse_plan_hits": self.cse_plan_hits,
            "cse_shared_senses": self.cse_shared_senses,
            "materializations": self.materializations,
            "materialization_hits": self.materialization_hits,
            "rows_appended": self.rows_appended,
            "esp_delta_programs": self.esp_delta_programs,
            "append_batches_coalesced": self.append_batches_coalesced,
            "rows_deleted": self.rows_deleted,
            "rows_updated": self.rows_updated,
            "compactions": self.compactions,
            "block_erases": self.block_erases,
            "live_rows": self.store.live_rows,
            "tombstone_density": self.store.tombstone_density,
            "write_amplification": (
                self.words_programmed / self.words_written
                if self.words_written
                else 0.0
            ),
        }

    def projection(self, ssd: SSDConfig = DEFAULT_SSD) -> dict:
        """Fleet-level SSD time/energy projection of the served traffic.

        Each shard device's MWS traffic is replayed through the paper's
        timing/energy model independently; the fleet serves shards
        concurrently, so projected wall-clock is the max over devices and
        energy is the sum — for Flash-Cosmos and the OSP baseline alike.
        """
        per_shard = [
            project_traffic(
                self.shard_traffic[s],
                wordlines_sensed=self.shard_wordlines[s],
                num_rows=self.store.shards[s].num_rows,
                num_queries=self.queries_served,
                host_postprocess=self._host_postprocess,
                # append deltas + CSE scratch-page programs + hot-predicate
                # materialization programs all ride this shard's ESP path
                esp_programs=self.shard_esp_programs[s]
                + int(self.telemetry.value(f"shard{s}.cse_esp_programs"))
                + int(
                    self.telemetry.value(
                        f"shard{s}.materialization_programs"
                    )
                ),
                block_erases=int(
                    self.telemetry.value(f"shard{s}.block_erases")
                ),
                levels=self.devices[s].layout.levels,
                ssd=ssd,
                name=f"flashql-shard{s}({self.queries_served}q)",
            )
            for s in self.store.active
            # a stripe with appends but no sensed traffic still did real
            # programming work — charge it (project_traffic handles the
            # program-only case)
            if self.shard_traffic[s] or self.shard_esp_programs[s]
        ]
        if not per_shard:
            raise ValueError("no traffic served yet")
        fc_t = max(p["fc_time_s"] for p in per_shard)
        osp_t = max(p["osp_time_s"] for p in per_shard)
        fc_e = sum(p["fc_energy_j"] for p in per_shard)
        osp_e = sum(p["osp_energy_j"] for p in per_shard)
        return {
            "workload": (
                f"flashql-sharded(x{self.store.num_shards}, "
                f"{self.queries_served}q)"
            ),
            "num_devices": self.store.num_shards,
            "fc_time_s": fc_t,
            "fc_energy_j": fc_e,
            "osp_time_s": osp_t,
            "osp_energy_j": osp_e,
            "speedup_vs_osp": osp_t / fc_t,
            "energy_ratio_vs_osp": osp_e / fc_e,
            "block_erases": sum(p.get("block_erases", 0) for p in per_shard),
            "per_shard": per_shard,
        }


registry_counters(
    ShardedFlashQL,
    (
        "queries_served",
        "flushes",
        "signature_groups",  # vmap groups dispatched (post-padding)
        "distinct_signatures",  # exact signatures seen (pre-padding)
        "eager_plans",
        "fused_flushes",
        "pipelined_flushes",
        "fused_dispatches",  # fused flush programs executed
        "host_transfers",  # device->host result copies
        "shards_pruned",  # stripe-routing prunes (shard never sensed)
        "serve_time_s",
        "total_latency_s",
        "rows_appended",
        "esp_delta_programs",
        "append_batches_coalesced",
        "rows_deleted",
        "rows_updated",
        "compactions",
        "block_erases",
        "words_programmed",  # physical ESP traffic (appends+deletes+GC)
        "words_written",  # logical client mutations — WA denominator
        "threshold_senses",  # k-of-N one-shot sensings executed
        "compaction_rows_dropped",
        "cse_plan_hits",  # flush members served by another member's plan
        "cse_shared_senses",  # shared subtree plans sensed (pipelined CSE)
        "cse_rewritten_members",  # member plans spliced onto shared pages
        "cse_spill_programs",  # scratch-page ESP programs for shared results
        "materializations",  # hot-predicate bitmap pages built
        "materialization_hits",  # compiles lowered onto a cached mat page
        "materialization_invalidations",  # mat pages dropped (stale epochs)
        "materialization_programs",  # per-shard mat page ESP programs
    ),
)


def build_sharded_flashql(
    table: dict[str, np.ndarray],
    num_shards: int,
    *,
    policy: str = "roundrobin",
    stripe_key: str | None = None,
    num_planes: int = 4,
    warmup: Iterable[Query] = (),
    queue_depth: int = 256,
    interpret: bool = True,
    reserve_rows: int = 0,
    pipeline: bool = False,
    coalesce_appends: bool = False,
    compact_density: float | None = None,
    grow_on_overflow: bool = False,
    optimize: bool = True,
    materialize_after: int | None = 32,
    levels: int = 1,
) -> ShardedFlashQL:
    """Ingest ``table``, program ``num_shards`` fresh devices, return the
    serving frontend — the one-call path used by tests and benchmarks.
    ``reserve_rows`` leaves per-stripe word capacity for later
    :meth:`ShardedFlashQL.append` batches; ``pipeline`` enables the
    asynchronous per-shard fused flush (see :class:`ShardedFlashQL`);
    ``levels`` sets the multi-level packing factor (1 = SLC, 2 = MLC,
    3 = TLC) every device's layout programs/senses at."""
    store = ShardedBitmapStore(
        num_shards=num_shards,
        policy=policy,
        stripe_key=stripe_key,
        reserve_rows=reserve_rows,
    )
    store.ingest(table)
    devices = [
        FlashDevice(
            num_planes=num_planes,
            interpret=interpret,
            layout=Layout(levels=levels),
        )
        for _ in range(num_shards)
    ]
    store.program(devices, warmup=warmup)
    return ShardedFlashQL(
        store,
        devices,
        queue_depth=queue_depth,
        pipeline=pipeline,
        coalesce_appends=coalesce_appends,
        compact_density=compact_density,
        grow_on_overflow=grow_on_overflow,
        optimize=optimize,
        materialize_after=materialize_after,
    )
