"""FlashDevice: the vectorized multi-plane batch-execution engine.

A :class:`repro.core.engine.FlashArray` executes one plan at a time with a
Python loop over commands.  ``FlashDevice`` extends it for query serving:

* the page store is packed ``(planes, pages, words_per_plane)`` (see
  :class:`repro.core.store.PackedStore`) — a logical bit vector is striped
  across ``num_planes`` planes exactly like the paper's SSD stripes a
  800M-user bitmap, and because planes are word-axis shards, ONE fused
  ``mws_reduce`` dispatch senses a command on every plane at once;
* a :class:`CommandPlan` compiles to an :class:`ExecPlan`: per MWS command
  a static ``(blocks, wordlines)`` slot-index array (ragged wordline sets
  padded with the store's all-ones identity slot) plus the static ISCM
  flags.  Executing is then pure array code — gather, fused reduce, latch
  algebra — with **no Python-level per-page work**;
* plans with identical *signatures* (same command structure and shapes,
  different slot indices) execute as one batch under ``jax.vmap``: the
  whole batch becomes a handful of kernel dispatches regardless of batch
  size.  Runners are jitted and cached per signature;
* **plan-aware batching**: plans of one *family* (same command sequence
  and ISCM flags, narrower gather shapes) pad into the family's widest
  signature — extra wordlines gather the all-ones identity slot, extra
  blocks the all-zeros slot — so shape variance (and, in a sharded fleet,
  device fan-out) does not multiply the vmap group count.

Plans that spill lower too: the spilled latch values stay device-resident
inside the traced program (``"spill"`` steps + static cube substitutions),
so deep-range chains batch and vmap like any other plan instead of running
eagerly one by one.  The eager :meth:`FlashArray.execute` path remains for
plans that sense non-ESP pages (their reads inject modelled bit errors,
which the batch path never does).

:func:`make_flush_runner` goes one step further and fuses a WHOLE flush —
every signature group plus every aggregate reduce — into one jitted
program returning a single host payload (see
:func:`repro.query.compile.compile_flush`).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.commands import (
    CommandPlan,
    ESPCommand,
    MWSCommand,
    SpillCommand,
    ThresholdCommand,
    TransferCommand,
    XORCommand,
)
from repro.core.engine import (
    FlashArray,
    fused_block_reduce,
    threshold_block_reduce,
)
from repro.core.store import IDENTITY_SLOT, ZERO_SLOT, PackedStore


@dataclass(frozen=True)
class _Step:
    """Static (trace-time) part of one executable command."""

    kind: str  # "mws" | "xor" | "xfer" | "spill"
    # "mws": threshold k for a ThresholdCommand sensing, 0 for the plain
    # wired-OR MWS.  Part of the signature AND the family (family erasure
    # rewrites only ``shape``), so a threshold plan never pads into a
    # plain group — the combine semantics differ.
    k: int = 0
    inverse: bool = False
    init_s: bool = True
    init_c: bool = True
    move: bool = False
    source: str = "C"
    invert: bool = False
    shape: tuple[int, int] = (0, 0)  # (blocks, padded wordlines) for "mws"
    # "mws": (block_pos, wordline_pos, spill ordinal) substitutions — the
    # gathered cube rows replaced by device-resident spilled values; the
    # positions are static, so spilling plans stay pure array programs
    subs: tuple[tuple[int, int, int], ...] = ()
    # "mws": (block_pos, wordline_pos, shared ordinal) substitutions into
    # the flush-level shared-value stack (cross-query CSE): the row is a
    # latch result another plan in the same flush already sensed
    shared: tuple[tuple[int, int, int], ...] = ()
    ordinal: int = 0  # "spill": index into the plan's scratch values


@dataclass(frozen=True)
class ExecPlan:
    """A CommandPlan lowered to gather indices + static step descriptors.

    Spilling plans lower too: each :class:`SpillCommand` becomes a
    ``"spill"`` step that parks the latch value in device-resident scratch
    (a plan-local value list inside the traced program — never a store
    write), and later MWS steps that sense the scratch page substitute it
    into the gathered cube at static positions.  Deep-range queries
    therefore batch, vmap, and join the fused flush reduce like any
    spill-free plan.
    """

    steps: tuple[_Step, ...]
    idxs: tuple[np.ndarray, ...]  # one (blocks, wordlines) array per MWS
    spills: int = 0  # scratch values the plan carries device-side
    # scratch blocks the plan's SpillCommands target: a spill is
    # physically an ESP program, so batched executions charge the same
    # P/E wear the eager path does (see age_spill_blocks)
    spill_blocks: tuple[int, ...] = ()

    @property
    def signature(self) -> tuple[_Step, ...]:
        """Batch key: two plans with equal signatures vmap together."""
        return self.steps

    @property
    def family(self) -> tuple[_Step, ...]:
        """Signature with MWS gather shapes erased (plan-aware batching).

        Two plans of one family run the same command sequence with the same
        ISCM flags and differ only in how many (blocks, wordlines) each MWS
        gathers; the narrower plan pads to the wider shape with identity
        slots (see :func:`pad_idx`) and then shares its vmap group.
        Scratch substitution positions are NOT erased — they are part of
        the command sequence, and padding never moves them.
        """
        return tuple(
            replace(st, shape=(0, 0)) if st.kind == "mws" else st
            for st in self.steps
        )


def age_spill_blocks(pec: dict, execs) -> None:
    """Charge P/E wear for the scratch programs of batch-executed plans.

    A SpillCommand is physically an ESP program to a scratch wordline; the
    batched paths run it as device-resident latch scratch, but the wear on
    the scratch block is real — this keeps ``pec`` consistent with the
    eager :meth:`FlashArray.execute`, which bumps per SpillCommand.
    """
    for e in execs:
        if e is not None:
            for b in e.spill_blocks:
                pec[b] = pec.get(b, 0) + 1


def pad_idx(idx: np.ndarray, shape: tuple[int, int]) -> np.ndarray:
    """Pad an MWS gather-index array to ``shape`` without changing results.

    Extra *wordlines* of real blocks gather the all-ones identity slot
    (AND-neutral within a block); extra *blocks* gather the all-zeros slot
    in their first wordline, so they AND to zero and are OR-neutral across
    blocks — and stay so under inverse read, which complements only after
    the cross-block OR.
    """
    b, w = idx.shape
    B, W = shape
    if (b, w) == (B, W):
        return idx
    out = np.full((B, W), IDENTITY_SLOT, dtype=np.int32)
    out[:b, :w] = idx
    if B > b:
        out[b:, 0] = ZERO_SLOT
    return out


def group_execs(
    execs: list["ExecPlan | None"], pad: bool = True
) -> list[tuple[tuple[_Step, ...], list[int], list[np.ndarray]]]:
    """Group batchable plans for vmap execution.

    Returns ``(signature, member_indices, stacked_idxs)`` triples, where
    ``stacked_idxs`` holds one ``(B, blocks, wordlines)`` array per MWS
    step.  With ``pad`` set, plans are grouped by :attr:`ExecPlan.family`
    and padded to the family's widest shapes — fewer, larger vmap groups;
    otherwise grouping is by exact signature.
    """
    groups: dict[tuple, list[int]] = {}
    for i, e in enumerate(execs):
        if e is not None:
            groups.setdefault(e.family if pad else e.signature, []).append(i)
    out = []
    for key, members in groups.items():
        first = execs[members[0]]
        n_mws = len(first.idxs)
        shapes = [
            (
                max(execs[i].idxs[s].shape[0] for i in members),
                max(execs[i].idxs[s].shape[1] for i in members),
            )
            for s in range(n_mws)
        ]
        it = iter(shapes)
        signature = tuple(
            replace(st, shape=next(it)) if st.kind == "mws" else st
            for st in first.steps
        )
        stacked = [
            np.stack([pad_idx(execs[i].idxs[s], shapes[s]) for i in members])
            for s in range(n_mws)
        ]
        out.append((signature, members, stacked))
    return out


def reorder_rows(pieces: list[jax.Array], order: list[int]) -> jax.Array:
    """Concatenate per-group output blocks and restore input order with a
    single inverse-permutation gather (never per-row slicing)."""
    allout = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)
    inv = np.empty(len(order), dtype=np.int32)
    inv[np.asarray(order)] = np.arange(len(order), dtype=np.int32)
    return allout[jnp.asarray(inv)]


def plan_step_fn(signature: tuple[_Step, ...], interpret: bool):
    """Pure single-plan executor for one signature:
    ``run_one(data, shared, *idxs)``.

    The traced body shared by :func:`make_plan_runner` (standalone jitted
    vmap) and :func:`make_flush_runner` (inlined into the fused flush
    program).  ``"spill"`` steps park the latch value in a plan-local
    scratch list; MWS steps with substitutions splice those values into the
    gathered cube at static positions (device-resident scratch — spilling
    plans never touch the store).  ``shared`` is the flush-level CSE value
    stack (``(K, words)`` or None): MWS steps carrying ``shared``
    substitutions splice those rows in the same way, fanning one sensing's
    latch result out to every plan that references it.
    """

    def run_one(data: jax.Array, shared, *idxs: jax.Array) -> jax.Array:
        s = c = out = None
        scratch: list[jax.Array] = []
        it = iter(idxs)
        for st in signature:
            if st.kind == "mws":
                cube = data[next(it)]  # (blocks, wordlines, words)
                for bi, wi, o in st.subs:
                    cube = cube.at[bi, wi].set(scratch[o])
                for bi, wi, k in st.shared:
                    cube = cube.at[bi, wi].set(shared[k])
                raw = (
                    threshold_block_reduce(
                        cube, st.k, st.inverse, interpret=interpret
                    )
                    if st.k
                    else fused_block_reduce(
                        cube, st.inverse, interpret=interpret
                    )
                )
                s = raw if (st.init_s or s is None) else s & raw
                if st.init_c:
                    c = None
                if st.move:
                    c = s if c is None else c | s
            elif st.kind == "spill":
                assert st.ordinal == len(scratch)
                scratch.append(s if st.source == "S" else c)
            elif st.kind == "xor":
                c = s ^ c
            else:
                val = s if st.source == "S" else c
                out = ~val if st.invert else val
        assert out is not None, "plan missing TransferCommand"
        return out

    return run_one


def make_plan_runner(
    signature: tuple[_Step, ...],
    interpret: bool,
    *,
    shard_data: bool = False,
):
    """Build the jitted vmap executor for one plan signature.

    ``shard_data=False``: ``run(data, *idxs)`` with one ``(slots, words)``
    snapshot shared by every batch element (single device).

    ``shard_data=True``: ``run(data, shard_ix, *idxs)`` where ``data`` is a
    stacked ``(shards, slots, words)`` fleet snapshot and ``shard_ix`` maps
    each batch element to its shard — one jit-of-vmap dispatch covers a
    whole signature group across every device of a sharded deployment.
    """
    run_one = plan_step_fn(signature, interpret)
    n_mws = sum(1 for st in signature if st.kind == "mws")
    if shard_data:
        return jax.jit(
            jax.vmap(
                lambda data, si, *ix: run_one(data[si], None, *ix),
                in_axes=(None, 0) + (0,) * n_mws,
            )
        )
    return jax.jit(
        jax.vmap(
            lambda data, *ix: run_one(data, None, *ix),
            in_axes=(None,) + (0,) * n_mws,
        )
    )


def make_flush_runner(key: tuple, interpret: bool):
    """Build the single jitted program executing a whole flush signature.

    ``key`` is the flush signature: ``(sense, reduce, w, cse)`` where
    ``sense`` is a tuple of ``(plan signature, member count)`` per vmap
    group, ``reduce`` a tuple of ``(aggregator kind, reduce_sig, member
    count, extra-plane count)`` per reduce group, ``w`` the store's logical
    word count, and ``cse`` a tuple of shared-plan signatures — the
    cross-query common subexpressions this flush senses ONCE and splices
    into every member plan that references them.  The returned
    ``run(data, group_idxs, inv_perm, mask, sels, extras, cse_idxs)``
    fuses EVERYTHING a flush does device-side — the shared sensings, the
    per-group gather + latch algebra, the member-order-restoring gather
    (``inv_perm`` maps members onto deduplicated unique-plan rows, so two
    queries with one predicate read one sensing's row twice), validity
    masking, and every aggregate's (weighted-)popcount reduce — and
    returns ONE flat ``uint32`` payload (see
    :func:`repro.query.aggregate.unpack_group`): one kernel dispatch and
    one host transfer per flush, however many signature groups and
    aggregate kinds it mixes.
    """
    from repro.query.aggregate import kind_reduce

    sense, reduce_sigs, w, cse = key

    def run(data, group_idxs, inv_perm, mask, sels, extras, cse_idxs):
        shared = None
        if cse:
            # shared subexpressions first: K is small, so these run as
            # plain (unvmapped) plans; members gather their rows below
            vals = [
                plan_step_fn(psig, interpret)(data, None, *idxs)
                for psig, idxs in zip(cse, cse_idxs)
            ]
            shared = jnp.stack(vals)
        pieces = []
        for (psig, _n), idxs in zip(sense, group_idxs):
            one = plan_step_fn(psig, interpret)
            n_mws = len(idxs)
            out = jax.vmap(one, in_axes=(None, None) + (0,) * n_mws)(
                data, shared, *idxs
            )
            pieces.append(out[:, :w])
        allout = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)
        masked = allout[inv_perm] & mask  # member order, padding zeroed
        parts = []
        for (kind, sig, _n, _p), sel, ex in zip(reduce_sigs, sels, extras):
            sub = masked if sel is None else masked[sel]
            out = kind_reduce(kind, sub, ex, sig, interpret=interpret)
            parts.extend(
                jnp.ravel(leaf).astype(jnp.uint32)
                for leaf in jax.tree_util.tree_leaves(out)
            )
        return jnp.concatenate(parts)

    return jax.jit(run)


@dataclass
class FlashDevice(FlashArray):
    """Multi-plane Flash-Cosmos device with batched plan execution."""

    num_planes: int = 4
    # plan-aware batching: pad narrower plans into a family's widest
    # signature so one vmap group covers every shape variant of a family
    pad_signatures: bool = True
    last_signature_groups: int = 0  # groups dispatched by the last batch
    last_eager_plans: int = 0  # noisy-page eager fallbacks in the last batch
    _runners: dict = field(default_factory=dict, repr=False)
    # prepared-batch cache: grouping + device-resident idx uploads per
    # recurring batch composition (see execute_batch_stacked's batch_key)
    _batch_cache: dict = field(default_factory=dict, repr=False)
    # attached by the owning scheduler (repro.query.telemetry.Telemetry):
    # counts jitted-runner builds and prepared-batch cache traffic
    telemetry: object = field(default=None, repr=False)

    def __post_init__(self):
        if self.store.planes != self.num_planes:
            if len(self.store):
                raise ValueError(
                    "cannot re-stripe a non-empty store; construct the "
                    "device with store=PackedStore(planes=num_planes)"
                )
            self.store = PackedStore(planes=self.num_planes)

    def reset_after_rebuild(self) -> None:
        """Drop prepared-batch state after :meth:`erase_rebuild`.

        Batch-cache keys embed plan-cache keys whose epochs can never be
        minted again, so the entries are unreachable — clearing just frees
        them eagerly.  Jitted runners stay: they are keyed on structural
        signatures and serve the rebuilt store unchanged.
        """
        self._batch_cache.clear()
        self.last_signature_groups = 0
        self.last_eager_plans = 0

    # -- plan lowering -----------------------------------------------------
    def build_exec(
        self,
        plan: CommandPlan,
        shared: dict[str, int] | None = None,
        layout=None,
    ) -> ExecPlan | None:
        """Lower a plan (spilling or not) to a batchable ExecPlan.

        Spill commands lower to ``"spill"`` steps whose values stay
        device-resident; MWS commands that re-sense a spilled scratch page
        record a static substitution instead of a store slot, so the whole
        plan — deep-range chains included — is a pure function of the
        packed snapshot and joins the fused/vmap execution paths.

        ``shared`` maps virtual CSE page names to ordinals in the flush's
        shared-value stack: sensing one records a ``shared`` substitution
        instead of a store slot (the value is another plan's latch result,
        resident only inside the fused program).  ``layout`` overrides the
        device layout for name resolution — CSE member plans compile
        against a fork that additionally places the virtual pages.
        """
        lay = self.layout if layout is None else layout
        steps: list[_Step] = []
        idxs: list[np.ndarray] = []
        scratch_ord: dict[str, int] = {}
        spill_blocks: list[int] = []
        for cmd in plan.commands:
            if isinstance(cmd, MWSCommand):
                n_max = max(len(t.wordlines) for t in cmd.targets)
                idx = np.full(
                    (len(cmd.targets), n_max), IDENTITY_SLOT, dtype=np.int32
                )
                subs: list[tuple[int, int, int]] = []
                shared_subs: list[tuple[int, int, int]] = []
                for bi, t in enumerate(cmd.targets):
                    for wi, wl in enumerate(t.wordlines):
                        name = lay.page_at(t.block, wl)
                        if name in scratch_ord:
                            subs.append((bi, wi, scratch_ord[name]))
                            continue  # placeholder gathers the identity row
                        if shared and name in shared:
                            shared_subs.append((bi, wi, shared[name]))
                            continue
                        idx[bi, wi] = self.store.slot(name)
                steps.append(
                    _Step(
                        "mws",
                        k=cmd.k if isinstance(cmd, ThresholdCommand) else 0,
                        inverse=cmd.iscm.inverse_read,
                        init_s=cmd.iscm.init_s_latch,
                        init_c=cmd.iscm.init_c_latch,
                        move=cmd.iscm.move_s_to_c,
                        shape=(len(cmd.targets), n_max),
                        subs=tuple(subs),
                        shared=tuple(shared_subs),
                    )
                )
                idxs.append(idx)
            elif isinstance(cmd, SpillCommand):
                steps.append(
                    _Step(
                        "spill",
                        source=cmd.source,
                        ordinal=len(scratch_ord),
                    )
                )
                scratch_ord[cmd.page_name] = len(scratch_ord)
                spill_blocks.append(cmd.block)
            elif isinstance(cmd, XORCommand):
                steps.append(_Step("xor"))
            elif isinstance(cmd, TransferCommand):
                steps.append(
                    _Step("xfer", source=cmd.source, invert=cmd.invert)
                )
            elif isinstance(cmd, ESPCommand):
                raise AssertionError("data writes flow through fc_write")
        return ExecPlan(
            tuple(steps),
            tuple(idxs),
            spills=len(scratch_ord),
            spill_blocks=tuple(spill_blocks),
        )

    # -- batched execution -------------------------------------------------
    def _runner(self, signature: tuple[_Step, ...]):
        fn = self._runners.get(signature)
        if fn is None:
            fn = make_plan_runner(signature, self.interpret)
            self._runners[signature] = fn
            if self.telemetry is not None:
                self.telemetry.count("runner_builds")
        return fn

    def _prepare_batch(
        self, execs: list[ExecPlan | None], batch_key=None
    ) -> tuple[list[tuple], tuple[int, ...]]:
        """Group + pad execs and upload their gather indices to the device.

        Returns ``(groups, eager_ix)``: the vmap groups plus the indices of
        plans demoted to the eager path — spilling plans that sense a
        non-ESP page keep their pre-pipeline error-injecting execution
        (the batch path never injects read errors); a spill-free plan over
        a non-ESP page still raises, as it always did.

        With ``batch_key`` (any hashable derived from the plan-cache keys,
        whose epoch components make staleness impossible), the prepared
        groups are memoized: a recurring batch composition — the steady
        state of query serving — skips grouping, padding, stacking, AND
        the host->device index transfer on every flush.
        """
        if batch_key is not None:
            prepared = self._batch_cache.get(batch_key)
            if prepared is not None:
                if self.telemetry is not None:
                    self.telemetry.count("batch_cache_hits")
                return prepared
            if self.telemetry is not None:
                self.telemetry.count("batch_cache_misses")
        noisy_slots = {
            self.store.slot(n) for n in self._non_esp if n in self.store
        }
        eager_ix: list[int] = []
        use = list(execs)
        if noisy_slots:
            for i, e in enumerate(execs):
                if e is not None and any(
                    bool(np.isin(idx, list(noisy_slots)).any())
                    for idx in e.idxs
                ):
                    if e.spills:
                        use[i] = None  # eager fallback injects the errors
                        eager_ix.append(i)
                    else:
                        raise ValueError(
                            "batched execution senses a non-ESP page; "
                            "reprogram it with esp=True or execute eagerly"
                        )
        prepared = (
            [
                (signature, members, tuple(jnp.asarray(s) for s in stacked))
                for signature, members, stacked in group_execs(
                    use, pad=self.pad_signatures
                )
            ],
            tuple(eager_ix),
        )
        if batch_key is not None:
            if len(self._batch_cache) >= 64:  # bound recurring compositions
                self._batch_cache.clear()
            self._batch_cache[batch_key] = prepared
        return prepared

    def execute_batch_stacked(
        self,
        plans: list[CommandPlan],
        seed: int = 0,
        execs: list[ExecPlan | None] | None = None,
        batch_key=None,
    ) -> jax.Array:
        """Execute independent plans; returns ``(B, num_words)`` results in
        input order as ONE stacked array.

        The whole batch costs O(signature groups) device dispatches — group
        outputs are concatenated and re-ordered with a single gather, never
        sliced per plan — which is what keeps serving overhead flat as
        batches grow.  The batch path never injects read errors, so every
        page a batched plan senses must be ESP-programmed (`fc_write`
        default) — unrelated non-ESP pages are fine, and spilling plans
        over noisy pages demote to the eager error-injecting path.  Pass
        ``execs`` (from :meth:`build_exec`) to skip re-lowering, and
        ``batch_key`` to memoize the batch grouping (see
        :meth:`_prepare_batch`).
        """
        if execs is None:
            execs = [self.build_exec(p) for p in plans]
        groups, eager_ix = self._prepare_batch(execs, batch_key)
        self.last_signature_groups = len(groups)
        self.last_eager_plans = len(eager_ix) + sum(
            1 for e in execs if e is None
        )

        w = self.store.num_words
        pieces: list[jax.Array] = []  # (B_g, w) per group / eager plan
        order: list[int] = []
        if groups:
            data = self.store.snapshot()
            for signature, members, idxs in groups:
                out = self._runner(signature)(data, *idxs)  # (B_g, Wp)
                pieces.append(out[:, :w])
                order.extend(members)
        for i, e in enumerate(execs):
            if e is None or i in eager_ix:  # noisy-page eager fallback
                # execute() charges its own spill wear
                pieces.append(self.execute(plans[i], seed=seed + i)[None])
                order.append(i)
            elif e.spill_blocks:
                age_spill_blocks(self.pec, (e,))
        if not pieces:
            return jnp.zeros((0, w or 0), jnp.uint32)
        return reorder_rows(pieces, order)

    def execute_batch(
        self,
        plans: list[CommandPlan],
        seed: int = 0,
        execs: list[ExecPlan | None] | None = None,
    ) -> list[jax.Array]:
        """List-of-arrays variant of :meth:`execute_batch_stacked`."""
        stacked = self.execute_batch_stacked(plans, seed=seed, execs=execs)
        return [stacked[i] for i in range(len(plans))]
