"""FlashDevice: the vectorized multi-plane batch-execution engine.

A :class:`repro.core.engine.FlashArray` executes one plan at a time with a
Python loop over commands.  ``FlashDevice`` extends it for query serving:

* the page store is packed ``(planes, pages, words_per_plane)`` (see
  :class:`repro.core.store.PackedStore`) — a logical bit vector is striped
  across ``num_planes`` planes exactly like the paper's SSD stripes a
  800M-user bitmap, and because planes are word-axis shards, ONE fused
  ``mws_reduce`` dispatch senses a command on every plane at once;
* a :class:`CommandPlan` compiles to an :class:`ExecPlan`: per MWS command
  a static ``(blocks, wordlines)`` slot-index array (ragged wordline sets
  padded with the store's all-ones identity slot) plus the static ISCM
  flags.  Executing is then pure array code — gather, fused reduce, latch
  algebra — with **no Python-level per-page work**;
* plans with identical *signatures* (same command structure and shapes,
  different slot indices) execute as one batch under ``jax.vmap``: the
  whole batch becomes a handful of kernel dispatches regardless of batch
  size.  Runners are jitted and cached per signature.

Plans that spill (ESP-program scratch pages mid-plan) mutate the store and
fall back to the eager :meth:`FlashArray.execute` path, which since the
packed-store refactor also senses via gather + fused reduce.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.commands import (
    CommandPlan,
    ESPCommand,
    MWSCommand,
    SpillCommand,
    TransferCommand,
    XORCommand,
)
from repro.core.engine import FlashArray, fused_block_reduce
from repro.core.store import IDENTITY_SLOT, PackedStore


@dataclass(frozen=True)
class _Step:
    """Static (trace-time) part of one executable command."""

    kind: str  # "mws" | "xor" | "xfer"
    inverse: bool = False
    init_s: bool = True
    init_c: bool = True
    move: bool = False
    source: str = "C"
    invert: bool = False
    shape: tuple[int, int] = (0, 0)  # (blocks, padded wordlines) for "mws"


@dataclass(frozen=True)
class ExecPlan:
    """A CommandPlan lowered to gather indices + static step descriptors."""

    steps: tuple[_Step, ...]
    idxs: tuple[np.ndarray, ...]  # one (blocks, wordlines) array per MWS

    @property
    def signature(self) -> tuple[_Step, ...]:
        """Batch key: two plans with equal signatures vmap together."""
        return self.steps


@dataclass
class FlashDevice(FlashArray):
    """Multi-plane Flash-Cosmos device with batched plan execution."""

    num_planes: int = 4
    _runners: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self.store.planes != self.num_planes:
            if len(self.store):
                raise ValueError(
                    "cannot re-stripe a non-empty store; construct the "
                    "device with store=PackedStore(planes=num_planes)"
                )
            self.store = PackedStore(planes=self.num_planes)

    # -- plan lowering -----------------------------------------------------
    def build_exec(self, plan: CommandPlan) -> ExecPlan | None:
        """Lower to an ExecPlan, or None if the plan spills (not batchable)."""
        if plan.num_spills:
            return None
        steps: list[_Step] = []
        idxs: list[np.ndarray] = []
        for cmd in plan.commands:
            if isinstance(cmd, MWSCommand):
                n_max = max(len(t.wordlines) for t in cmd.targets)
                idx = np.full(
                    (len(cmd.targets), n_max), IDENTITY_SLOT, dtype=np.int32
                )
                for bi, t in enumerate(cmd.targets):
                    for wi, wl in enumerate(t.wordlines):
                        name = self.layout.page_at(t.block, wl)
                        idx[bi, wi] = self.store.slot(name)
                steps.append(
                    _Step(
                        "mws",
                        inverse=cmd.iscm.inverse_read,
                        init_s=cmd.iscm.init_s_latch,
                        init_c=cmd.iscm.init_c_latch,
                        move=cmd.iscm.move_s_to_c,
                        shape=(len(cmd.targets), n_max),
                    )
                )
                idxs.append(idx)
            elif isinstance(cmd, XORCommand):
                steps.append(_Step("xor"))
            elif isinstance(cmd, TransferCommand):
                steps.append(
                    _Step("xfer", source=cmd.source, invert=cmd.invert)
                )
            elif isinstance(cmd, (SpillCommand, ESPCommand)):
                raise AssertionError("spill-free plan expected")
        return ExecPlan(tuple(steps), tuple(idxs))

    # -- batched execution -------------------------------------------------
    def _runner(self, signature: tuple[_Step, ...]):
        fn = self._runners.get(signature)
        if fn is not None:
            return fn
        interpret = self.interpret

        def run_one(data: jax.Array, *idxs: jax.Array) -> jax.Array:
            s = c = out = None
            it = iter(idxs)
            for st in signature:
                if st.kind == "mws":
                    cube = data[next(it)]  # (blocks, wordlines, words)
                    raw = fused_block_reduce(
                        cube, st.inverse, interpret=interpret
                    )
                    s = raw if (st.init_s or s is None) else s & raw
                    if st.init_c:
                        c = None
                    if st.move:
                        c = s if c is None else c | s
                elif st.kind == "xor":
                    c = s ^ c
                else:
                    val = s if st.source == "S" else c
                    out = ~val if st.invert else val
            assert out is not None, "plan missing TransferCommand"
            return out

        n_mws = sum(1 for st in signature if st.kind == "mws")
        fn = jax.jit(
            jax.vmap(run_one, in_axes=(None,) + (0,) * n_mws)
        )
        self._runners[signature] = fn
        return fn

    def execute_batch(
        self,
        plans: list[CommandPlan],
        seed: int = 0,
        execs: list[ExecPlan | None] | None = None,
    ) -> list[jax.Array]:
        """Execute independent plans, vectorizing structurally-equal ones.

        Returns per-plan logical result words, in input order.  The batch
        path never injects read errors, so every page a batched plan senses
        must be ESP-programmed (`fc_write` default) — unrelated non-ESP
        pages are fine; spilling plans run eagerly one by one.  Pass
        ``execs`` (from :meth:`build_exec`) to skip re-lowering.
        """
        if execs is None:
            execs = [self.build_exec(p) for p in plans]
        noisy_slots = {
            self.store.slot(n) for n in self._non_esp if n in self.store
        }
        if noisy_slots:
            for e in execs:
                if e is not None and any(
                    bool(np.isin(idx, list(noisy_slots)).any())
                    for idx in e.idxs
                ):
                    raise ValueError(
                        "batched execution senses a non-ESP page; "
                        "reprogram it with esp=True or execute eagerly"
                    )
        groups: dict[tuple, list[int]] = {}
        for i, e in enumerate(execs):
            if e is not None:
                groups.setdefault(e.signature, []).append(i)

        results: list[jax.Array | None] = [None] * len(plans)
        w = self.store.num_words
        if groups:
            data = self.store.snapshot()
            for signature, members in groups.items():
                stacked = [
                    jnp.asarray(
                        np.stack([execs[i].idxs[s] for i in members])
                    )
                    for s in range(len(execs[members[0]].idxs))
                ]
                out = self._runner(signature)(data, *stacked)  # (B, Wp)
                for row, i in enumerate(members):
                    results[i] = out[row, :w]
        for i, e in enumerate(execs):
            if e is None:  # spilling plan: eager fallback
                results[i] = self.execute(plans[i], seed=seed + i)
        return results  # type: ignore[return-value]
