"""FlashQL predicate AST and aggregate specs.

A deliberately small relational-predicate language over one columnar table:
leaf predicates select rows by column value (``Eq``, ``In``, ``Range``) and
compose with ``And`` / ``Or`` / ``Not``; a :class:`Query` pairs a predicate
with an *aggregate spec* describing what to compute over the selected rows:

* ``Count()`` / ``Mask()`` — the BMI bit-count / the raw result bitmap;
* ``Sum(col)`` / ``Avg(col)`` / ``Min(col)`` / ``Max(col)`` — bit-sliced
  arithmetic over the column's BSI slices (weighted popcounts);
* ``TopK(col, k)`` — the k most frequent values of ``col`` among selected
  rows (per-value popcounts over the equality bitmaps);
* ``GroupBy(key, value)`` — per-group aggregation (``Count``/``Sum``/
  ``Avg``) keyed on a low-cardinality column's equality bitmaps.

The legacy ``Agg.COUNT`` / ``Agg.MASK`` enum members keep working and
normalize to ``Count()`` / ``Mask()`` (see :func:`normalize_agg`); the
execution semantics of every spec live in :mod:`repro.query.aggregate`.
Predicates support ``&``, ``|``, ``~`` like the core expression IR.

Every node is frozen and hashable: the structural identity of a predicate
(and of its aggregate spec) is its plan-cache key (``repro.query.compile``).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field


class _PredOps:
    def __and__(self, other: "Pred") -> "And":
        return And(_flatten(And, (self, other)))

    def __or__(self, other: "Pred") -> "Or":
        return Or(_flatten(Or, (self, other)))

    def __invert__(self) -> "Pred":
        if isinstance(self, Not):
            return self.child
        return Not(self)


@dataclass(frozen=True)
class Eq(_PredOps):
    """Rows where ``column == value``."""

    column: str
    value: int


@dataclass(frozen=True)
class In(_PredOps):
    """Rows where ``column`` is any of ``values``."""

    column: str
    values: tuple[int, ...]

    def __init__(self, column: str, values) -> None:
        object.__setattr__(self, "column", column)
        object.__setattr__(self, "values", tuple(sorted(set(values))))


@dataclass(frozen=True)
class Range(_PredOps):
    """Rows where ``lo <= column <= hi`` (either bound may be None)."""

    column: str
    lo: int | None = None
    hi: int | None = None

    def __post_init__(self):
        if self.lo is None and self.hi is None:
            raise ValueError("Range needs at least one bound")


@dataclass(frozen=True)
class And(_PredOps):
    children: tuple["Pred", ...]

    def __init__(self, children) -> None:
        object.__setattr__(self, "children", _flatten(And, children))


@dataclass(frozen=True)
class Or(_PredOps):
    children: tuple["Pred", ...]

    def __init__(self, children) -> None:
        object.__setattr__(self, "children", _flatten(Or, children))


@dataclass(frozen=True)
class Not(_PredOps):
    child: "Pred"


Pred = Eq | In | Range | And | Or | Not


def _flatten(cls, items) -> tuple["Pred", ...]:
    out: list[Pred] = []
    for it in items:
        if isinstance(it, cls):
            out.extend(it.children)
        else:
            out.append(it)
    return tuple(out)


def columns_of(pred: Pred):
    """Yield every column name a predicate references (with repeats)."""
    if isinstance(pred, (Eq, In, Range)):
        yield pred.column
    elif isinstance(pred, Not):
        yield from columns_of(pred.child)
    elif isinstance(pred, (And, Or)):
        for c in pred.children:
            yield from columns_of(c)
    else:
        raise TypeError(f"not a FlashQL predicate: {pred!r}")


class Agg(enum.Enum):
    """Legacy aggregation enum; normalizes to ``Count()`` / ``Mask()``."""

    COUNT = "count"
    MASK = "mask"


@dataclass(frozen=True)
class Count:
    """Number of selected rows (the BMI bit-count)."""


@dataclass(frozen=True)
class Mask:
    """The selected-row bitmap itself, as a :class:`BitVector`."""


@dataclass(frozen=True)
class Sum:
    """Exact integer ``sum(column)`` over selected rows, computed as the
    weighted popcount Σ_b 2^b · popcount(mask ∧ slice_b) over BSI slices."""

    column: str


@dataclass(frozen=True)
class Avg:
    """``sum(column) / count`` over selected rows (None if none selected);
    the numerator is the exact-integer :class:`Sum`."""

    column: str


@dataclass(frozen=True)
class Min:
    """Minimum ``column`` value among selected rows (None if empty); walks
    the BSI slices MSB→LSB narrowing a candidate mask."""

    column: str


@dataclass(frozen=True)
class Max:
    """Maximum ``column`` value among selected rows (None if empty)."""

    column: str


@dataclass(frozen=True)
class TopK:
    """The ``k`` most frequent values of ``column`` among selected rows as
    ``((value, count), ...)`` sorted by (-count, value); ties break toward
    the smaller value, deterministically across shard merges."""

    column: str
    k: int


@dataclass(frozen=True)
class GroupBy:
    """Per-group aggregation over the groups of a low-cardinality ``key``
    column: ``{value: aggregate}`` for every group with at least one
    selected row.  ``value`` may be ``Count()``, ``Sum(col)``, or
    ``Avg(col)``."""

    key: str
    value: "Count | Sum | Avg" = Count()


AggSpec = Count | Mask | Sum | Avg | Min | Max | TopK | GroupBy


def normalize_agg(agg: "Agg | AggSpec") -> AggSpec:
    """Map the legacy ``Agg`` enum onto spec instances; pass specs through."""
    if agg is Agg.COUNT:
        return Count()
    if agg is Agg.MASK:
        return Mask()
    if isinstance(agg, AggSpec):
        return agg
    raise TypeError(f"not an aggregate spec: {agg!r}")


@dataclass(frozen=True)
class Query:
    where: Pred
    agg: "Agg | AggSpec" = Agg.COUNT
    tag: str = field(default="", compare=False)  # free-form client label


def and_(*preds: Pred) -> And:
    return And(preds)


def or_(*preds: Pred) -> Or:
    return Or(preds)
