"""FlashQL predicate AST.

A deliberately small relational-predicate language over one columnar table:
leaf predicates select rows by column value (``Eq``, ``In``, ``Range``) and
compose with ``And`` / ``Or`` / ``Not``; a :class:`Query` pairs a predicate
with an aggregation — ``COUNT`` (the BMI bit-count) or ``MASK`` (the raw
result bitmap).  Predicates support ``&``, ``|``, ``~`` like the core
expression IR.

Every node is frozen and hashable: the structural identity of a predicate
is its plan-cache key (``repro.query.compile``).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field


class _PredOps:
    def __and__(self, other: "Pred") -> "And":
        return And(_flatten(And, (self, other)))

    def __or__(self, other: "Pred") -> "Or":
        return Or(_flatten(Or, (self, other)))

    def __invert__(self) -> "Pred":
        if isinstance(self, Not):
            return self.child
        return Not(self)


@dataclass(frozen=True)
class Eq(_PredOps):
    """Rows where ``column == value``."""

    column: str
    value: int


@dataclass(frozen=True)
class In(_PredOps):
    """Rows where ``column`` is any of ``values``."""

    column: str
    values: tuple[int, ...]

    def __init__(self, column: str, values) -> None:
        object.__setattr__(self, "column", column)
        object.__setattr__(self, "values", tuple(sorted(set(values))))


@dataclass(frozen=True)
class Range(_PredOps):
    """Rows where ``lo <= column <= hi`` (either bound may be None)."""

    column: str
    lo: int | None = None
    hi: int | None = None

    def __post_init__(self):
        if self.lo is None and self.hi is None:
            raise ValueError("Range needs at least one bound")


@dataclass(frozen=True)
class And(_PredOps):
    children: tuple["Pred", ...]

    def __init__(self, children) -> None:
        object.__setattr__(self, "children", _flatten(And, children))


@dataclass(frozen=True)
class Or(_PredOps):
    children: tuple["Pred", ...]

    def __init__(self, children) -> None:
        object.__setattr__(self, "children", _flatten(Or, children))


@dataclass(frozen=True)
class Not(_PredOps):
    child: "Pred"


Pred = Eq | In | Range | And | Or | Not


def _flatten(cls, items) -> tuple["Pred", ...]:
    out: list[Pred] = []
    for it in items:
        if isinstance(it, cls):
            out.extend(it.children)
        else:
            out.append(it)
    return tuple(out)


class Agg(enum.Enum):
    """Result aggregation: a row count or the selected-row bitmap itself."""

    COUNT = "count"
    MASK = "mask"


@dataclass(frozen=True)
class Query:
    where: Pred
    agg: Agg = Agg.COUNT
    tag: str = field(default="", compare=False)  # free-form client label


def and_(*preds: Pred) -> And:
    return And(preds)


def or_(*preds: Pred) -> Or:
    return Or(preds)
