"""FlashQL predicate AST and aggregate specs.

A deliberately small relational-predicate language over one columnar table:
leaf predicates select rows by column value (``Eq``, ``In``, ``Range``) and
compose with ``And`` / ``Or`` / ``Not``; a :class:`Query` pairs a predicate
with an *aggregate spec* describing what to compute over the selected rows:

* ``Count()`` / ``Mask()`` — the BMI bit-count / the raw result bitmap;
* ``Sum(col)`` / ``Avg(col)`` / ``Min(col)`` / ``Max(col)`` — bit-sliced
  arithmetic over the column's BSI slices (weighted popcounts);
* ``TopK(col, k)`` — the k most frequent values of ``col`` among selected
  rows (per-value popcounts over the equality bitmaps);
* ``GroupBy(key, value)`` — per-group aggregation (``Count``/``Sum``/
  ``Avg``) keyed on a low-cardinality column's equality bitmaps.

The legacy ``Agg.COUNT`` / ``Agg.MASK`` enum members keep working and
normalize to ``Count()`` / ``Mask()`` (see :func:`normalize_agg`); the
execution semantics of every spec live in :mod:`repro.query.aggregate`.
Predicates support ``&``, ``|``, ``~`` like the core expression IR.

Every node is frozen and hashable: the structural identity of a predicate
(and of its aggregate spec) is its plan-cache key (``repro.query.compile``).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field


class _PredOps:
    def __and__(self, other: "Pred") -> "And":
        return And(_flatten(And, (self, other)))

    def __or__(self, other: "Pred") -> "Or":
        return Or(_flatten(Or, (self, other)))

    def __invert__(self) -> "Pred":
        if isinstance(self, Not):
            return self.child
        return Not(self)


@dataclass(frozen=True)
class Eq(_PredOps):
    """Rows where ``column == value``."""

    column: str
    value: int


@dataclass(frozen=True)
class In(_PredOps):
    """Rows where ``column`` is any of ``values``."""

    column: str
    values: tuple[int, ...]

    def __init__(self, column: str, values) -> None:
        object.__setattr__(self, "column", column)
        object.__setattr__(self, "values", tuple(sorted(set(values))))


@dataclass(frozen=True)
class Range(_PredOps):
    """Rows where ``lo <= column <= hi`` (either bound may be None)."""

    column: str
    lo: int | None = None
    hi: int | None = None

    def __post_init__(self):
        if self.lo is None and self.hi is None:
            raise ValueError("Range needs at least one bound")


@dataclass(frozen=True)
class And(_PredOps):
    children: tuple["Pred", ...]

    def __init__(self, children) -> None:
        object.__setattr__(self, "children", _flatten(And, children))


@dataclass(frozen=True)
class Or(_PredOps):
    children: tuple["Pred", ...]

    def __init__(self, children) -> None:
        object.__setattr__(self, "children", _flatten(Or, children))


@dataclass(frozen=True)
class Not(_PredOps):
    child: "Pred"


@dataclass(frozen=True)
class AtLeast(_PredOps):
    """Fuzzy predicate: rows matching at least ``k`` of the ``children``.

    ``k == len(children)`` is And and ``k == 1`` is Or — canonicalization
    rewrites those onto the existing nodes so they share plan-cache and
    CSE entries with equivalent And/Or queries.  The strict interior
    lowers to ONE threshold sensing when every child is a single
    co-located wordline group, and to an Or-of-And-combinations chain
    otherwise (the cost model picks whichever is cheaper).

    Unlike And/Or, children do NOT dedupe: a duplicated child
    legitimately counts twice toward ``k``.
    """

    k: int
    children: tuple["Pred", ...]

    def __init__(self, k: int, children) -> None:
        # validation lives here, not __post_init__: defining __init__ on a
        # dataclass means the generated one (and its __post_init__ hook)
        # never runs
        from repro.core.commands import THRESHOLD_MAX_BLOCKS

        k = int(k)
        children = tuple(children)
        n = len(children)
        if not 1 <= k <= n:
            raise ValueError(
                f"AtLeast(k={k}) needs 1 <= k <= {n} children"
            )
        if n > THRESHOLD_MAX_BLOCKS:
            raise ValueError(
                f"AtLeast supports at most {THRESHOLD_MAX_BLOCKS} children "
                "(dynamic-sensing power envelope)"
            )
        object.__setattr__(self, "k", k)
        object.__setattr__(self, "children", children)


def Majority(children) -> AtLeast:
    """Strict-majority sugar: ``AtLeast(len(children)//2 + 1, children)``."""
    children = tuple(children)
    return AtLeast(len(children) // 2 + 1, children)


Pred = Eq | In | Range | And | Or | Not | AtLeast


def _flatten(cls, items) -> tuple["Pred", ...]:
    out: list[Pred] = []
    for it in items:
        if isinstance(it, cls):
            out.extend(it.children)
        else:
            out.append(it)
    return tuple(out)


def pred_key(pred: Pred) -> tuple:
    """Total-order-comparable structural key of a predicate.

    Two predicates are structurally equal iff their keys are equal, and
    keys of sibling predicates always compare (same-kind keys share a
    tuple shape; cross-kind comparison resolves on the leading tag), so
    :func:`canonicalize` can sort And/Or children deterministically.
    """
    if isinstance(pred, Eq):
        return ("eq", pred.column, int(pred.value))
    if isinstance(pred, In):
        return ("in", pred.column) + tuple(int(v) for v in pred.values)
    if isinstance(pred, Range):
        return (
            "range",
            pred.column,
            (1, int(pred.lo)) if pred.lo is not None else (0, 0),
            (1, int(pred.hi)) if pred.hi is not None else (0, 0),
        )
    if isinstance(pred, Not):
        return ("not", pred_key(pred.child))
    if isinstance(pred, AtLeast):
        return ("atleast", pred.k) + tuple(
            pred_key(c) for c in pred.children
        )
    if isinstance(pred, (And, Or)):
        tag = "and" if isinstance(pred, And) else "or"
        return (tag,) + tuple(pred_key(c) for c in pred.children)
    raise TypeError(f"not a FlashQL predicate: {pred!r}")


def pred_size(pred: Pred) -> int:
    """Approximate lowered size of a predicate (CSE candidate ordering).

    ``Range``/``In`` leaves weigh more than ``Eq``: they lower to
    multi-page expressions (BSI comparison networks / member-page ORs),
    so a shared ``Range`` is worth more than its single AST node suggests.
    """
    if isinstance(pred, Not):
        return 1 + pred_size(pred.child)
    if isinstance(pred, (And, Or, AtLeast)):
        return 1 + sum(pred_size(c) for c in pred.children)
    if isinstance(pred, Range):
        return 3
    if isinstance(pred, In):
        return 2
    return 1


def iter_subtrees(pred: Pred):
    """Yield ``pred`` and every nested predicate subtree (pre-order)."""
    yield pred
    if isinstance(pred, Not):
        yield from iter_subtrees(pred.child)
    elif isinstance(pred, (And, Or, AtLeast)):
        for c in pred.children:
            yield from iter_subtrees(c)


def canonicalize(pred: Pred) -> Pred:
    """Canonical form: structurally equal-modulo-commutativity predicates
    become *identical* (equal ``pred_key``, equal hash).

    Bit-exact rewrites only — And/Or are commutative, associative, and
    idempotent over row sets, and every rule below is one of those:

    * And/Or chains flatten (constructors already do) and their children
      sort by :func:`pred_key`;
    * duplicate children dedupe (``a & a`` -> ``a``);
    * double negation collapses (``~~a`` -> ``a``);
    * single-child And/Or unwrap to the child;
    * sibling ``Eq``/``In`` literals on one column inside an ``Or`` merge
      into one ``In`` (plain member-page OR either way), and a one-value
      ``In`` is an ``Eq``.

    The compiler keys its plan cache on the canonicalized predicate, so
    ``Eq(a) & Eq(b)`` and ``Eq(b) & Eq(a)`` share one cache entry — and
    one sensing when they meet in a flush.
    """
    if isinstance(pred, Eq):
        return pred
    if isinstance(pred, In):
        if len(pred.values) == 1:
            return Eq(pred.column, pred.values[0])
        return pred
    if isinstance(pred, Range):
        return pred
    if isinstance(pred, Not):
        c = canonicalize(pred.child)
        if isinstance(c, Not):
            return c.child
        return Not(c)
    if isinstance(pred, AtLeast):
        # degenerate thresholds ARE the existing nodes — rewriting here
        # means they share plan-cache entries and CSE with equivalent
        # And/Or queries (satellite of the threshold-sensing work)
        if pred.k == len(pred.children):
            return canonicalize(And(pred.children))
        if pred.k == 1:
            return canonicalize(Or(pred.children))
        # children sort for commutativity but NEVER dedupe: unlike
        # And/Or, a duplicated child counts twice toward k
        kids = sorted(
            (canonicalize(c) for c in pred.children), key=pred_key
        )
        return AtLeast(pred.k, kids)
    if not isinstance(pred, (And, Or)):
        raise TypeError(f"not a FlashQL predicate: {pred!r}")
    cls = type(pred)
    kids: list[Pred] = []
    for ch in pred.children:
        cc = canonicalize(ch)
        if isinstance(cc, cls):
            kids.extend(cc.children)  # Not-collapse can surface same-class
        else:
            kids.append(cc)
    if cls is Or:
        # merge per-column value literals: Eq(c,1) | Eq(c,2) == In(c,(1,2))
        by_col: dict[str, set[int]] = {}
        rest: list[Pred] = []
        for k in kids:
            if isinstance(k, Eq):
                by_col.setdefault(k.column, set()).add(k.value)
            elif isinstance(k, In):
                by_col.setdefault(k.column, set()).update(k.values)
            else:
                rest.append(k)
        for col, vals in by_col.items():
            rest.append(
                Eq(col, next(iter(vals)))
                if len(vals) == 1
                else In(col, vals)
            )
        kids = rest
    seen: dict[tuple, Pred] = {}
    for k in kids:
        seen.setdefault(pred_key(k), k)
    ordered = [seen[key] for key in sorted(seen)]
    if not ordered:
        return pred
    if len(ordered) == 1:
        return ordered[0]
    return cls(tuple(ordered))


def columns_of(pred: Pred):
    """Yield every column name a predicate references (with repeats)."""
    if isinstance(pred, (Eq, In, Range)):
        yield pred.column
    elif isinstance(pred, Not):
        yield from columns_of(pred.child)
    elif isinstance(pred, (And, Or, AtLeast)):
        for c in pred.children:
            yield from columns_of(c)
    else:
        raise TypeError(f"not a FlashQL predicate: {pred!r}")


class Agg(enum.Enum):
    """Legacy aggregation enum; normalizes to ``Count()`` / ``Mask()``."""

    COUNT = "count"
    MASK = "mask"


@dataclass(frozen=True)
class Count:
    """Number of selected rows (the BMI bit-count)."""


@dataclass(frozen=True)
class Mask:
    """The selected-row bitmap itself, as a :class:`BitVector`."""


@dataclass(frozen=True)
class Sum:
    """Exact integer ``sum(column)`` over selected rows, computed as the
    weighted popcount Σ_b 2^b · popcount(mask ∧ slice_b) over BSI slices."""

    column: str


@dataclass(frozen=True)
class Avg:
    """``sum(column) / count`` over selected rows (None if none selected);
    the numerator is the exact-integer :class:`Sum`."""

    column: str


@dataclass(frozen=True)
class Min:
    """Minimum ``column`` value among selected rows (None if empty); walks
    the BSI slices MSB→LSB narrowing a candidate mask."""

    column: str


@dataclass(frozen=True)
class Max:
    """Maximum ``column`` value among selected rows (None if empty)."""

    column: str


@dataclass(frozen=True)
class TopK:
    """The ``k`` most frequent values of ``column`` among selected rows as
    ``((value, count), ...)`` sorted by (-count, value); ties break toward
    the smaller value, deterministically across shard merges."""

    column: str
    k: int


@dataclass(frozen=True)
class GroupBy:
    """Per-group aggregation over the groups of a low-cardinality ``key``
    column: ``{value: aggregate}`` for every group with at least one
    selected row.  ``value`` may be ``Count()``, ``Sum(col)``, or
    ``Avg(col)``."""

    key: str
    value: "Count | Sum | Avg" = Count()


AggSpec = Count | Mask | Sum | Avg | Min | Max | TopK | GroupBy


def normalize_agg(agg: "Agg | AggSpec") -> AggSpec:
    """Map the legacy ``Agg`` enum onto spec instances; pass specs through."""
    if agg is Agg.COUNT:
        return Count()
    if agg is Agg.MASK:
        return Mask()
    if isinstance(agg, AggSpec):
        return agg
    raise TypeError(f"not an aggregate spec: {agg!r}")


@dataclass(frozen=True)
class Query:
    where: Pred
    agg: "Agg | AggSpec" = Agg.COUNT
    tag: str = field(default="", compare=False)  # free-form client label


def and_(*preds: Pred) -> And:
    return And(preds)


def or_(*preds: Pred) -> Or:
    return Or(preds)
