"""FlashQL: a batched bitmap-index query-serving subsystem.

The paper's headline use case (§7) is BMI — bitmap-index analytics over
hundreds of millions of users — but the seed repo only exposed raw bitwise
expressions executed one plan at a time.  FlashQL closes the gap to a real
query layer (cf. Perach et al., *Understanding Bulk-Bitwise PIM Through
Database Analytics*):

* :mod:`repro.query.ast` — a small predicate AST (``Eq``/``In``/``Range``
  composed with ``And``/``Or``/``Not``) plus aggregate specs
  (``Count``/``Mask``/``Sum``/``Avg``/``Min``/``Max``/``TopK``/``GroupBy``);
* :mod:`repro.query.aggregate` — the pluggable aggregation pipeline: each
  spec maps to an ``Aggregator`` declaring its extra sensed planes (BSI
  slices / equality bitmaps), a batched jit'd weighted-popcount reduce,
  and a shard-merge rule;
* :mod:`repro.query.bitmap` — ``BitmapStore``: ingests columnar tables into
  equality bitmaps and bit-sliced range indexes, ESP-programs them with the
  paper's §6.3 placement rules;
* :mod:`repro.query.compile` — lowers predicates to ``core.expr`` trees and
  caches command plans by expression structure + leaf placement, so repeated
  query shapes skip the Planner entirely; ``compile_flush`` goes further
  and compiles a whole flush (every signature group + every aggregate
  reduce) into ONE jitted device program returning a single payload;
* :mod:`repro.query.device` — ``FlashDevice``: the vectorized multi-plane
  engine; executes batches of structurally-identical plans with one
  ``jax.vmap``-ed gather + fused-MWS program;
* :mod:`repro.query.scheduler` — ``BatchScheduler``: admits concurrent
  queries, groups them by plan shape, reports throughput/latency, and feeds
  executed command shapes into :mod:`repro.flashsim` for full-scale time and
  energy projection;
* :mod:`repro.query.shard` — ``ShardedBitmapStore`` / ``ShardedFlashQL``:
  rows striped over a fleet of devices (optionally sorted by a
  ``stripe_key`` so range queries route to few shards), queries scattered
  to per-shard plan caches, shard batches fused under one ``jit(vmap)``
  per signature group, partial results gathered through each aggregate's
  shard-merge rule with a multi-chip time/energy projection;
* :mod:`repro.query.telemetry` — ``Telemetry``: the unified metrics
  registry (counters/gauges/histograms), flush-lifecycle trace spans
  exportable as Chrome trace-event JSON, per-query sensing attribution,
  and the slow-query log shared by both schedulers.
"""

from repro.query.aggregate import (
    Aggregator,
    get_aggregator,
    validate_query,
)
from repro.query.ast import (
    Agg,
    And,
    AtLeast,
    Avg,
    Count,
    Eq,
    GroupBy,
    In,
    Majority,
    Mask,
    Max,
    Min,
    Not,
    Or,
    Query,
    Range,
    Sum,
    TopK,
)
from repro.query.bitmap import (
    VALID_PAGE,
    AppendDelta,
    BitmapStore,
    PageDelta,
)
from repro.query.compile import (
    CompiledQuery,
    FlushProgram,
    QueryCompiler,
    compile_flush,
    lower,
)
from repro.query.device import FlashDevice
from repro.query.scheduler import BatchScheduler, QueryResult
from repro.query.shard import (
    ShardedBitmapStore,
    ShardedFlashQL,
    build_sharded_flashql,
)
from repro.query.telemetry import (
    Histogram,
    Telemetry,
    percentile,
    validate_trace,
)

__all__ = [
    "Agg",
    "Aggregator",
    "And",
    "AtLeast",
    "Majority",
    "Avg",
    "Count",
    "Eq",
    "GroupBy",
    "In",
    "Mask",
    "Max",
    "Min",
    "Not",
    "Or",
    "Query",
    "Range",
    "Sum",
    "TopK",
    "get_aggregator",
    "validate_query",
    "AppendDelta",
    "BitmapStore",
    "PageDelta",
    "VALID_PAGE",
    "CompiledQuery",
    "FlushProgram",
    "QueryCompiler",
    "compile_flush",
    "lower",
    "FlashDevice",
    "BatchScheduler",
    "QueryResult",
    "ShardedBitmapStore",
    "ShardedFlashQL",
    "build_sharded_flashql",
    "Histogram",
    "Telemetry",
    "percentile",
    "validate_trace",
]
