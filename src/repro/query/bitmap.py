"""Bitmap indexes over columnar tables, ESP-programmed into a flash array.

Two index kinds per column (classic BMI organization, cf. O'Neil/Quass):

* **equality bitmaps** — one page ``col=v`` per distinct value ``v``; bit
  ``j`` is set iff row ``j`` has that value.  ``Eq`` is one page; ``In`` is
  an OR over the member pages.
* **bit-sliced index (BSI)** — one page ``col#b`` per bit position ``b`` of
  the column's values; ``Range`` predicates evaluate with the bit-sliced
  comparison network (``repro.query.compile``), needing only ``ceil(log2
  max)`` pages regardless of cardinality.

Placement follows the paper's §6.3 rules: pages first appearing in a warmup
query are placed by :func:`repro.core.placement.auto_layout` (OR-context
leaves stored inverted + co-located for De-Morgan single-sensing; AND/XOR
context plain + co-located); remaining equality bitmaps are stored
**inverted and co-located per column** — ``In`` over one column then
resolves in a single inverse-read MWS, and cross-column ``And`` of inverse
units De-Morgan-merges into one inter-block command — while BSI slices are
stored plain + co-located.  Everything is ESP-programmed (`fc_write(...,
esp=True)`), so query serving is error-free per the paper's reliability
result.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import num_words, pack_bits, valid_mask
from repro.query.ast import Query

TRUE_PAGE = "__all"
FALSE_PAGE = "__none"


def eq_page(column: str, value: int) -> str:
    return f"{column}={value}"


def bsi_page(column: str, bit: int) -> str:
    return f"{column}#{bit}"


def bsi_pages(store: "BitmapStore", column: str) -> tuple[str, ...]:
    """Every BSI slice page of ``column``, LSB first (slice b = bit b)."""
    ci = store.columns[column]
    return tuple(bsi_page(column, b) for b in range(ci.bits))


def eq_pages(store: "BitmapStore", column: str) -> tuple[str, ...]:
    """Every equality-bitmap page of ``column``, in sorted value order."""
    ci = store.columns[column]
    return tuple(eq_page(column, v) for v in ci.values)


def fetch_pages(store: "BitmapStore", names: tuple[str, ...]) -> jax.Array:
    """Stack logical pages into one ``(len(names), words)`` device array.

    This is how aggregators read their extra sensed planes (BSI slices /
    equality bitmaps): the logical pages, like everything ESP-programmed
    into the array, are error-free per the paper's reliability result.
    """
    return jnp.stack([store.logical[n] for n in names])


@dataclass(frozen=True)
class ColumnIndex:
    """Per-column metadata the compiler lowers predicates against."""

    name: str
    values: tuple[int, ...]  # distinct values present, sorted
    bits: int  # BSI slice count = bit length of max value

    @property
    def max_value(self) -> int:
        return self.values[-1] if self.values else 0


@dataclass
class BitmapStore:
    """Ingests a columnar table; owns the logical bitmap pages."""

    num_rows: int = 0
    columns: dict[str, ColumnIndex] = field(default_factory=dict)
    logical: dict[str, jax.Array] = field(default_factory=dict)  # packed
    epoch: int = 0  # bumped per ingest; part of the plan-cache key
    # Sharded stores pad every page to a fleet-wide word count so shard
    # snapshots stack under one vmap; padding bits are zero and masked out
    # of every aggregation (see valid_words_mask).
    min_words: int = 0

    @property
    def words(self) -> int:
        return max(num_words(self.num_rows), self.min_words)

    def valid_words_mask(self) -> np.ndarray:
        """Per-word mask of real rows: zeros in the last word's slack bits
        AND in any whole padding word beyond ``num_rows``."""
        mask = np.zeros((self.words,), dtype=np.uint32)
        mask[: num_words(self.num_rows)] = valid_mask(self.num_rows)
        return mask

    # -- ingest -------------------------------------------------------------
    def ingest(
        self,
        table: dict[str, np.ndarray],
        schema: dict[str, tuple[int, ...]] | None = None,
    ) -> None:
        """Build equality + BSI bitmaps for every column of ``table``.

        Columns are 1-D arrays of non-negative integers, all equal length.

        ``schema`` optionally forces each column's distinct-value set (a
        superset of the values actually present).  A sharded store ingests
        every shard with the *global* schema: values absent from a shard
        still get (all-zero) equality pages and the BSI width matches the
        global maximum, so predicate lowering, placement, and hence plan
        signatures are identical on every shard.
        """
        lengths = {len(v) for v in table.values()}
        if len(lengths) != 1:
            raise ValueError(f"ragged table: row counts {sorted(lengths)}")
        (n,) = lengths
        if self.num_rows and n != self.num_rows:
            raise ValueError("all ingests must share one row count")
        self.num_rows = n
        self.epoch += 1

        ones = np.zeros((self.words,), dtype=np.uint32)
        ones[: num_words(n)] = valid_mask(n)
        self.logical.setdefault(TRUE_PAGE, jnp.asarray(ones))
        self.logical.setdefault(
            FALSE_PAGE, jnp.zeros((self.words,), jnp.uint32)
        )

        for col, raw in table.items():
            vals = np.asarray(raw)
            if n and vals.min() < 0:
                raise ValueError(f"column {col!r} has negative values")
            if schema is not None:
                distinct = np.asarray(schema[col])
                missing = np.setdiff1d(vals, distinct)
                if missing.size:
                    raise ValueError(
                        f"column {col!r} has values {missing[:5]} outside "
                        "the forced schema"
                    )
            else:
                distinct = np.unique(vals)
            bits = (
                max(int(distinct[-1]).bit_length(), 1)
                if distinct.size
                else 1
            )
            self.columns[col] = ColumnIndex(
                col, tuple(int(v) for v in distinct), bits
            )
            for v in distinct:
                bitsarr = (vals == v).astype(np.uint8)
                self.logical[eq_page(col, int(v))] = self._pack(bitsarr)
            for b in range(bits):
                slice_bits = ((vals >> b) & 1).astype(np.uint8)
                self.logical[bsi_page(col, b)] = self._pack(slice_bits)

    def _pack(self, bits: np.ndarray) -> jax.Array:
        """Pack a row-bit array, zero-padding words up to ``self.words``."""
        packed = pack_bits(jnp.asarray(bits))
        pad = self.words - packed.shape[-1]
        if pad:
            packed = jnp.concatenate(
                [packed, jnp.zeros((pad,), jnp.uint32)]
            )
        return packed

    # -- program ------------------------------------------------------------
    def place_into(self, layout, warmup: Iterable[Query] = ()) -> None:
        """Compute §6.3 placements for every bitmap page into ``layout``.

        ``warmup`` queries steer placement: their lowered expressions run
        through :func:`auto_layout` first, so hot query shapes get the
        paper's context-sensitive inverted/plain co-location.  Pages no
        warmup query touches fall back to the per-column defaults described
        in the module docstring.  Pages already placed are left alone, so a
        sharded deployment can compute one canonical layout and fork it per
        device (``Layout.fork``).
        """
        from repro.core.placement import auto_layout
        from repro.query.compile import lower

        for q in warmup:
            auto_layout(lower(q.where, self), layout)

        for col, ci in self.columns.items():
            eq_new = [
                eq_page(col, v)
                for v in ci.values
                if eq_page(col, v) not in layout
            ]
            if eq_new:
                layout.place_colocated(eq_new, inverted=True)
            bsi_new = [
                bsi_page(col, b)
                for b in range(ci.bits)
                if bsi_page(col, b) not in layout
            ]
            if bsi_new:
                layout.place_colocated(bsi_new, inverted=False)
        for const in (TRUE_PAGE, FALSE_PAGE):
            if const in self.logical and const not in layout:
                layout.place_colocated([const], inverted=False)

    def program(self, array, warmup: Iterable[Query] = ()) -> None:
        """ESP-program every bitmap page into ``array`` (§6.3 placement)."""
        self.place_into(array.layout, warmup=warmup)
        for name, words in self.logical.items():
            array.fc_write(name, words, esp=True)
