"""Bitmap indexes over columnar tables, ESP-programmed into a flash array.

Two index kinds per column (classic BMI organization, cf. O'Neil/Quass):

* **equality bitmaps** — one page ``col=v`` per distinct value ``v``; bit
  ``j`` is set iff row ``j`` has that value.  ``Eq`` is one page; ``In`` is
  an OR over the member pages.
* **bit-sliced index (BSI)** — one page ``col#b`` per bit position ``b`` of
  the column's values; ``Range`` predicates evaluate with the bit-sliced
  comparison network (``repro.query.compile``), needing only ``ceil(log2
  max)`` pages regardless of cardinality.

Placement follows the paper's §6.3 rules: pages first appearing in a warmup
query are placed by :func:`repro.core.placement.auto_layout` (OR-context
leaves stored inverted + co-located for De-Morgan single-sensing; AND/XOR
context plain + co-located); remaining equality bitmaps are stored
**inverted and co-located per column** — ``In`` over one column then
resolves in a single inverse-read MWS, and cross-column ``And`` of inverse
units De-Morgan-merges into one inter-block command — while BSI slices are
stored plain + co-located.  Everything is ESP-programmed (`fc_write(...,
esp=True)`), so query serving is error-free per the paper's reliability
result.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import num_words, pack_bits
from repro.query.ast import Query

TRUE_PAGE = "__all"
FALSE_PAGE = "__none"


def eq_page(column: str, value: int) -> str:
    return f"{column}={value}"


def bsi_page(column: str, bit: int) -> str:
    return f"{column}#{bit}"


@dataclass(frozen=True)
class ColumnIndex:
    """Per-column metadata the compiler lowers predicates against."""

    name: str
    values: tuple[int, ...]  # distinct values present, sorted
    bits: int  # BSI slice count = bit length of max value

    @property
    def max_value(self) -> int:
        return self.values[-1] if self.values else 0


@dataclass
class BitmapStore:
    """Ingests a columnar table; owns the logical bitmap pages."""

    num_rows: int = 0
    columns: dict[str, ColumnIndex] = field(default_factory=dict)
    logical: dict[str, jax.Array] = field(default_factory=dict)  # packed
    epoch: int = 0  # bumped per ingest; part of the plan-cache key

    @property
    def words(self) -> int:
        return num_words(self.num_rows)

    # -- ingest -------------------------------------------------------------
    def ingest(self, table: dict[str, np.ndarray]) -> None:
        """Build equality + BSI bitmaps for every column of ``table``.

        Columns are 1-D arrays of non-negative integers, all equal length.
        """
        lengths = {len(v) for v in table.values()}
        if len(lengths) != 1:
            raise ValueError(f"ragged table: row counts {sorted(lengths)}")
        (n,) = lengths
        if self.num_rows and n != self.num_rows:
            raise ValueError("all ingests must share one row count")
        self.num_rows = n
        self.epoch += 1

        ones = jnp.asarray(
            np.full((self.words,), 0xFFFFFFFF, dtype=np.uint32)
        )
        self.logical.setdefault(TRUE_PAGE, ones)
        self.logical.setdefault(
            FALSE_PAGE, jnp.zeros((self.words,), jnp.uint32)
        )

        for col, raw in table.items():
            vals = np.asarray(raw)
            if vals.min() < 0:
                raise ValueError(f"column {col!r} has negative values")
            distinct = np.unique(vals)
            bits = max(int(distinct[-1]).bit_length(), 1)
            self.columns[col] = ColumnIndex(
                col, tuple(int(v) for v in distinct), bits
            )
            for v in distinct:
                bitsarr = (vals == v).astype(np.uint8)
                self.logical[eq_page(col, int(v))] = pack_bits(
                    jnp.asarray(bitsarr)
                )
            for b in range(bits):
                slice_bits = ((vals >> b) & 1).astype(np.uint8)
                self.logical[bsi_page(col, b)] = pack_bits(
                    jnp.asarray(slice_bits)
                )

    # -- program ------------------------------------------------------------
    def program(self, array, warmup: Iterable[Query] = ()) -> None:
        """ESP-program every bitmap page into ``array`` (§6.3 placement).

        ``warmup`` queries steer placement: their lowered expressions run
        through :func:`auto_layout` first, so hot query shapes get the
        paper's context-sensitive inverted/plain co-location.  Pages no
        warmup query touches fall back to the per-column defaults described
        in the module docstring.
        """
        from repro.core.placement import auto_layout
        from repro.query.compile import lower

        layout = array.layout
        for q in warmup:
            auto_layout(lower(q.where, self), layout)

        for col, ci in self.columns.items():
            eq_new = [
                eq_page(col, v)
                for v in ci.values
                if eq_page(col, v) not in layout
            ]
            if eq_new:
                layout.place_colocated(eq_new, inverted=True)
            bsi_new = [
                bsi_page(col, b)
                for b in range(ci.bits)
                if bsi_page(col, b) not in layout
            ]
            if bsi_new:
                layout.place_colocated(bsi_new, inverted=False)
        for const in (TRUE_PAGE, FALSE_PAGE):
            if const in self.logical and const not in layout:
                layout.place_colocated([const], inverted=False)

        for name, words in self.logical.items():
            array.fc_write(name, words, esp=True)
