"""Bitmap indexes over columnar tables, ESP-programmed into a flash array.

Two index kinds per column (classic BMI organization, cf. O'Neil/Quass):

* **equality bitmaps** — one page ``col=v`` per distinct value ``v``; bit
  ``j`` is set iff row ``j`` has that value.  ``Eq`` is one page; ``In`` is
  an OR over the member pages.
* **bit-sliced index (BSI)** — one page ``col#b`` per bit position ``b`` of
  the column's values; ``Range`` predicates evaluate with the bit-sliced
  comparison network (``repro.query.compile``), needing only ``ceil(log2
  max)`` pages regardless of cardinality.

Placement follows the paper's §6.3 rules: pages first appearing in a warmup
query are placed by :func:`repro.core.placement.auto_layout` (OR-context
leaves stored inverted + co-located for De-Morgan single-sensing; AND/XOR
context plain + co-located); remaining equality bitmaps are stored
**inverted and co-located per column** — ``In`` over one column then
resolves in a single inverse-read MWS, and cross-column ``And`` of inverse
units De-Morgan-merges into one inter-block command — while BSI slices are
stored plain + co-located.  Everything is ESP-programmed (`fc_write(...,
esp=True)`), so query serving is error-free per the paper's reliability
result.

The index is *mutable*: :meth:`BitmapStore.append` extends a live index
with new rows, reprogramming only the delta pages (tail words of pages
the new rows set bits in, plus fresh pages for first-seen values and
grown BSI widths — placed into the column's reserved layout region).
Page word capacity is fixed at ingest (``reserve_rows``), so in-capacity
appends only ever program erased tail words — the delta-page model that
makes ESP, the paper's reliability-critical expensive step, an O(batch)
cost per update instead of O(table).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import WORD_BITS, num_words, valid_mask
from repro.query.ast import Query

TRUE_PAGE = "__all"
FALSE_PAGE = "__none"
# Per-stripe tombstone page: bit j is set while row j is live.  The
# compiler ANDs it into every plan's sensing set (one extra wordline per
# MWS — nearly free), so COUNT/MASK/aggregates only ever see live rows.
# Stored NON-inverted: a delete clears logical bits, which is a physical
# 1->0 transition — exactly the program NAND supports without an erase,
# so tombstoning is a single delta-page ESP program however many rows die.
VALID_PAGE = "__valid"


def eq_region(column: str) -> str:
    """Layout region holding a column's equality bitmaps (inverted)."""
    return f"eq:{column}"


def bsi_region(column: str) -> str:
    """Layout region holding a column's BSI slices (plain)."""
    return f"bsi:{column}"


def eq_page(column: str, value: int) -> str:
    return f"{column}={value}"


def bsi_page(column: str, bit: int) -> str:
    return f"{column}#{bit}"


def bsi_pages(store: "BitmapStore", column: str) -> tuple[str, ...]:
    """Every BSI slice page of ``column``, LSB first (slice b = bit b)."""
    ci = store.columns[column]
    return tuple(bsi_page(column, b) for b in range(ci.bits))


def eq_pages(store: "BitmapStore", column: str) -> tuple[str, ...]:
    """Every equality-bitmap page of ``column``, in sorted value order."""
    ci = store.columns[column]
    return tuple(eq_page(column, v) for v in ci.values)


def fetch_pages(store: "BitmapStore", names: tuple[str, ...]) -> jax.Array:
    """Stack logical pages into one ``(len(names), words)`` device array.

    This is how aggregators read their extra sensed planes (BSI slices /
    equality bitmaps): the logical pages, like everything ESP-programmed
    into the array, are error-free per the paper's reliability result.
    """
    return jnp.stack([store.logical[n] for n in names])


@dataclass(frozen=True)
class ColumnIndex:
    """Per-column metadata the compiler lowers predicates against."""

    name: str
    values: tuple[int, ...]  # distinct values present, sorted
    bits: int  # BSI slice count = bit length of max value

    @property
    def max_value(self) -> int:
        return self.values[-1] if self.values else 0


def validate_batch(columns, rows: dict[str, np.ndarray]) -> int:
    """Schema-level append-batch validation; returns the batch length.

    Shared by :meth:`BitmapStore.check_append` (against the store's
    columns) and :meth:`repro.query.shard.ShardedBitmapStore.append`
    (against the fleet's global schema): the batch's column set must
    EXACTLY match ``columns`` (missing and unknown both reject), all
    columns must be equal length, and values must be non-negative.
    """
    missing = sorted(set(columns) - set(rows))
    unknown = sorted(set(rows) - set(columns))
    if missing or unknown:
        raise ValueError(
            "append batch columns do not match the ingest schema: "
            f"missing {missing}, unknown {unknown}"
        )
    lengths = {len(v) for v in rows.values()}
    if len(lengths) != 1:
        raise ValueError(
            f"ragged append batch: row counts {sorted(lengths)}"
        )
    (b,) = lengths
    for col, vals in rows.items():
        arr = np.asarray(vals)
        if b and arr.min() < 0:
            raise ValueError(f"column {col!r} has negative values")
    return b


@dataclass(frozen=True)
class PageDelta:
    """One page's contribution to an append (delta-page programming).

    ``new`` pages (equality bitmap of a first-seen value, or a BSI slice
    for a grown bit width) carry their full words and a placement region;
    existing pages carry only the tail words from ``start`` on — the words
    an append actually changes — so programming cost scales with the
    appended rows, not the rows already resident.
    """

    name: str
    start: int  # first programmed word (0 for new pages)
    words: np.ndarray  # programmed words (the full page when new)
    new: bool = False
    region: str | None = None  # layout region for new pages
    inverted: bool = False  # placement inversion for new pages


@dataclass(frozen=True)
class AppendDelta:
    """Everything :meth:`BitmapStore.append` changed, ready to program."""

    rows: int  # appended row count
    start_row: int  # first appended global row index
    pages: tuple[PageDelta, ...]

    @property
    def num_programs(self) -> int:
        """ESP page programs this delta costs (one per touched page)."""
        return len(self.pages)


@dataclass
class BitmapStore:
    """Ingests a columnar table; owns the logical bitmap pages."""

    num_rows: int = 0
    columns: dict[str, ColumnIndex] = field(default_factory=dict)
    # packed logical pages, HOST-resident (numpy): appends mutate only the
    # delta words in place, O(delta) per touched page; consumers convert
    # to device arrays lazily (jnp.stack / fc_write / snapshot)
    logical: dict[str, np.ndarray] = field(default_factory=dict)
    # content version: bumped per ingest AND per append — snapshot-level
    # caches (valid-row masks, stacked aggregate extras) key on it
    epoch: int = 0
    # per-column *metadata* epochs: bumped only when a column's lowering-
    # relevant index metadata (distinct values / BSI bit width) changes.
    # Plan caches key on the epochs of the columns a plan's leaves touch,
    # so an append that only extends existing pages leaves every plan
    # warm, and one that introduces a new value in column A invalidates
    # only plans sensing column A.
    column_epochs: dict[str, int] = field(default_factory=dict)
    # row capacity reserved for appends: pages are sized for this many
    # rows, so in-capacity appends only ever program erased tail words
    # (the word count — and hence the programmed page geometry — is fixed
    # at ingest; appends past capacity are rejected with a clear error)
    capacity_rows: int = 0
    # Sharded stores pad every page to a fleet-wide word count so shard
    # snapshots stack under one vmap; padding bits are zero and masked out
    # of every aggregation (see valid_words_mask).
    min_words: int = 0
    # rows tombstoned since ingest/rebuild: their VALID_PAGE bits are
    # cleared, every plan masks them out, and compaction reclaims their
    # capacity.  ``num_rows`` keeps counting them (row ids are stable
    # between compactions); ``live_rows`` is the serving row count.
    deleted_rows: int = 0

    @property
    def words(self) -> int:
        return max(
            num_words(max(self.num_rows, self.capacity_rows)),
            self.min_words,
        )

    def valid_words_mask(self) -> np.ndarray:
        """Per-word mask of real rows: zeros in the last word's slack bits
        AND in any whole padding word beyond ``num_rows``."""
        mask = np.zeros((self.words,), dtype=np.uint32)
        mask[: num_words(self.num_rows)] = valid_mask(self.num_rows)
        return mask

    # -- ingest -------------------------------------------------------------
    def ingest(
        self,
        table: dict[str, np.ndarray],
        schema: dict[str, tuple[int, ...]] | None = None,
        reserve_rows: int = 0,
    ) -> None:
        """Build equality + BSI bitmaps for every column of ``table``.

        Columns are 1-D arrays of non-negative integers, all equal length.

        ``schema`` optionally forces each column's distinct-value set (a
        superset of the values actually present).  A sharded store ingests
        every shard with the *global* schema: values absent from a shard
        still get (all-zero) equality pages and the BSI width matches the
        global maximum, so predicate lowering, placement, and hence plan
        signatures are identical on every shard.

        ``reserve_rows`` sizes every page for that many future
        :meth:`append` rows beyond the ingested table — the page word
        count is fixed here, so appends beyond the reserve are rejected.
        """
        lengths = {len(v) for v in table.values()}
        if len(lengths) != 1:
            raise ValueError(f"ragged table: row counts {sorted(lengths)}")
        (n,) = lengths
        if self.num_rows and n != self.num_rows:
            raise ValueError("all ingests must share one row count")
        self.num_rows = n
        self.capacity_rows = max(self.capacity_rows, n + reserve_rows)
        self.epoch += 1

        ones = np.zeros((self.words,), dtype=np.uint32)
        ones[: num_words(n)] = valid_mask(n)
        self.logical.setdefault(TRUE_PAGE, ones)
        self.logical.setdefault(
            FALSE_PAGE, np.zeros((self.words,), np.uint32)
        )
        # the tombstone page starts as a copy of the all-rows page: every
        # ingested row is live, every reserved tail row is 0 — which is
        # also what masks rows >= num_rows out of NOT/MASK plans (the
        # compiler splices this page into every plan's sensing set)
        self.logical.setdefault(VALID_PAGE, ones.copy())

        for col, raw in table.items():
            vals = np.asarray(raw)
            if n and vals.min() < 0:
                raise ValueError(f"column {col!r} has negative values")
            if schema is not None:
                distinct = np.asarray(schema[col])
                missing = np.setdiff1d(vals, distinct)
                if missing.size:
                    raise ValueError(
                        f"column {col!r} has values {missing[:5]} outside "
                        "the forced schema"
                    )
            else:
                distinct = np.unique(vals)
            bits = (
                max(int(distinct[-1]).bit_length(), 1)
                if distinct.size
                else 1
            )
            self.columns[col] = ColumnIndex(
                col, tuple(int(v) for v in distinct), bits
            )
            self.column_epochs[col] = self.column_epochs.get(col, 0) + 1
            for v in distinct:
                bitsarr = (vals == v).astype(np.uint8)
                self.logical[eq_page(col, int(v))] = self._pack(bitsarr)
            for b in range(bits):
                slice_bits = ((vals >> b) & 1).astype(np.uint8)
                self.logical[bsi_page(col, b)] = self._pack(slice_bits)

    def _pack(self, bits: np.ndarray) -> np.ndarray:
        """Pack a row-bit array into a host page of ``self.words`` words
        (LSB-first per word, little word order — same convention as
        :func:`repro.core.bitops.pack_bits`)."""
        span = np.zeros((self.words * WORD_BITS,), np.uint8)
        span[: bits.shape[0]] = bits
        return np.packbits(span, bitorder="little").view(np.uint32).copy()

    # -- incremental ingest --------------------------------------------------
    def check_append(self, rows: dict[str, np.ndarray]) -> int:
        """Validate an append batch WITHOUT mutating anything.

        Returns the batch length.  Raises — at the call site, before any
        page state or shard queue can be touched — on: an un-ingested
        store, a column set that does not match the ingest schema (missing
        *or* unknown columns), ragged column lengths, negative values, and
        word-capacity overflow.  Both schedulers validate through this up
        front, so a bad batch can never poison a half-applied append.
        """
        if not self.columns:
            raise ValueError("append() needs an ingested store")
        b = validate_batch(self.columns, rows)
        if num_words(self.num_rows + b) > self.words:
            raise ValueError(
                f"appending {b} rows to {self.num_rows} overflows the "
                f"store's {self.words}-word page capacity "
                f"({self.capacity_rows} rows); ingest with a larger "
                "reserve_rows to leave append headroom"
            )
        return b

    def _tail_words(
        self, name: str, new_bits: np.ndarray, n0: int, b: int
    ) -> tuple[int, np.ndarray]:
        """Delta words of a page whose rows ``n0..n0+b-1`` become
        ``new_bits``: only the words an append touches, with the partial
        first word preserving the resident rows' bits."""
        sw = n0 // WORD_BITS
        ew = num_words(n0 + b)
        span = np.zeros(((ew - sw) * WORD_BITS,), np.uint8)
        off = n0 - sw * WORD_BITS
        span[off : off + b] = new_bits
        words = np.packbits(span, bitorder="little").view(np.uint32).copy()
        if off and name in self.logical:
            old = int(self.logical[name][sw])
            words[0] |= np.uint32(old & ((1 << off) - 1))
        return sw, words

    def _apply_words(self, name: str, start: int, words: np.ndarray) -> None:
        """Mutate only the delta words of a host page — O(delta), never a
        full-page copy, however wide the store's pages are."""
        page = self.logical.get(name)
        if page is None:
            page = np.zeros((self.words,), np.uint32)
            self.logical[name] = page
        page[start : start + words.shape[0]] = words

    def append(
        self,
        rows: dict[str, np.ndarray],
        schema_update: dict[str, tuple[int, ...]] | None = None,
    ) -> AppendDelta:
        """Append ``rows`` to the live index; returns the page deltas.

        Only pages an append actually changes appear in the delta:

        * the all-rows page and every page with a set bit among the new
          rows get their *tail words* reprogrammed in place;
        * first-seen values get fresh equality pages in the column's
          reserved (inverted, co-located) layout region, and values wider
          than the column's BSI index grow fresh slice pages in the BSI
          region — zero for all resident rows, so no old page is touched.

        Pages with an all-zero delta (values absent from the batch) keep
        their erased tails and cost nothing.  ``schema_update`` forces the
        post-append distinct-value set per column (a superset of old ∪
        batch): a sharded fleet passes the global union so every shard
        grows the same pages and stays merge-aligned.  Column metadata
        epochs bump only for columns whose value set / bit width actually
        changed — plans over untouched columns stay warm.
        """
        b = self.check_append(rows)
        n0 = self.num_rows
        deltas: list[PageDelta] = []

        if b:
            for const in (TRUE_PAGE, VALID_PAGE):
                # appended rows are live: the tombstone page's tail extends
                # exactly like the all-rows page's (one delta program each)
                sw, words = self._tail_words(
                    const, np.ones((b,), np.uint8), n0, b
                )
                self._apply_words(const, sw, words)
                deltas.append(PageDelta(const, sw, words))

        for col, ci in self.columns.items():
            vals = np.asarray(rows[col])
            forced = (
                schema_update.get(col, ()) if schema_update is not None else ()
            )
            new_values = sorted(
                ({int(v) for v in vals} | {int(v) for v in forced})
                - set(ci.values)
            )
            all_values = tuple(sorted(set(ci.values) | set(new_values)))
            bits = max(
                ci.bits,
                max((int(v).bit_length() for v in all_values), default=1),
            )
            # equality bitmaps: tails of existing pages with hits, fresh
            # pages (zero for resident rows) for first-seen values
            if b:
                for v in sorted({int(v) for v in vals} & set(ci.values)):
                    hit = (vals == v).astype(np.uint8)
                    sw, words = self._tail_words(eq_page(col, v), hit, n0, b)
                    self._apply_words(eq_page(col, v), sw, words)
                    deltas.append(PageDelta(eq_page(col, v), sw, words))
            for v in new_values:
                eq_bits = np.zeros((n0 + b,), np.uint8)
                if b:
                    eq_bits[n0:] = (vals == v).astype(np.uint8)
                full = self._pack(eq_bits)
                self.logical[eq_page(col, v)] = full
                deltas.append(
                    PageDelta(
                        eq_page(col, v),
                        0,
                        full,
                        new=True,
                        region=eq_region(col),
                        inverted=True,
                    )
                )
            # BSI slices: tails of existing slices with set bits, fresh
            # slices for a grown bit width (resident rows are all zero
            # there by construction: every old value < 2^old_bits)
            if b:
                for bit in range(ci.bits):
                    sl = ((vals >> bit) & 1).astype(np.uint8)
                    if not sl.any():
                        continue
                    sw, words = self._tail_words(
                        bsi_page(col, bit), sl, n0, b
                    )
                    self._apply_words(bsi_page(col, bit), sw, words)
                    deltas.append(PageDelta(bsi_page(col, bit), sw, words))
            for new_bit in range(ci.bits, bits):
                slice_bits = np.zeros((n0 + b,), np.uint8)
                if b:
                    slice_bits[n0:] = ((vals >> new_bit) & 1).astype(
                        np.uint8
                    )
                full = self._pack(slice_bits)
                self.logical[bsi_page(col, new_bit)] = full
                deltas.append(
                    PageDelta(
                        bsi_page(col, new_bit),
                        0,
                        full,
                        new=True,
                        region=bsi_region(col),
                        inverted=False,
                    )
                )
            if new_values or bits != ci.bits:
                self.columns[col] = ColumnIndex(col, all_values, bits)
                self.column_epochs[col] = self.column_epochs.get(col, 0) + 1

        self.num_rows = n0 + b
        if b or deltas:
            self.epoch += 1
        return AppendDelta(rows=b, start_row=n0, pages=tuple(deltas))

    def program_delta(
        self, array, delta: AppendDelta, telemetry=None
    ) -> tuple[int, int]:
        """ESP-program an append's page deltas into ``array``.

        New pages are placed into their column's reserved layout region
        (keeping the §6.3 inverted/plain co-location invariants) and
        programmed whole; existing pages get a single delta-page program
        covering only their tail words (``fc_append``).

        Returns ``(programs, words)`` — the PHYSICAL page programs issued
        and the physical words they covered.  Under multi-level packing
        (``array.layout.levels > 1``) the logical pages co-resident in one
        physical page program together in ONE ISPP pass: the group's lead
        delta charges the wear/ESP counters, the other levels ride along
        (``charge=False``), and the group's word cost is the union span of
        its members' programmed words.  At ``levels == 1`` every group is
        a singleton and the accounting is bit-identical to SLC.

        ``telemetry`` (a :class:`repro.query.telemetry.Telemetry`, attached
        by the owning scheduler) records the programming pass as a trace
        span + page-program histogram when enabled.
        """
        timed = telemetry is not None and telemetry.enabled
        t0 = time.perf_counter() if timed else 0.0
        layout = array.layout
        for pd in delta.pages:
            if pd.new and pd.name not in layout:
                layout.place_colocated(
                    [pd.name], inverted=pd.inverted, region=pd.region
                )
        levels = layout.levels
        groups: dict[tuple[int, int], list[PageDelta]] = {}
        for pd in delta.pages:
            p = layout[pd.name]
            groups.setdefault(
                (p.block, p.wordline // levels), []
            ).append(pd)
        programs = words = 0
        for group in groups.values():
            lo = min(pd.start for pd in group)
            hi = max(pd.start + int(pd.words.shape[0]) for pd in group)
            charge = True
            for pd in group:
                if pd.new:
                    array.fc_write(
                        pd.name, pd.words, esp=True, charge=charge
                    )
                else:
                    array.fc_append(
                        pd.name, pd.words, start=pd.start, charge=charge
                    )
                charge = False
            programs += 1
            words += hi - lo
        if timed:
            t1 = time.perf_counter()
            telemetry.span(
                "program_delta",
                "ingest",
                t0,
                t1,
                tid="ingest",
                args={"pages": delta.num_programs, "rows": delta.rows},
            )
            telemetry.observe("append_pages_programmed", delta.num_programs)
            telemetry.observe("append_program_s", t1 - t0)
        return programs, words

    # -- deletes / tombstones ------------------------------------------------
    @property
    def live_rows(self) -> int:
        """Rows a query can still match (``num_rows`` minus tombstones)."""
        return self.num_rows - self.deleted_rows

    @property
    def tombstone_density(self) -> float:
        """Fraction of resident rows that are tombstoned (compaction
        trigger: garbage the stripe carries through every sensing)."""
        return self.deleted_rows / self.num_rows if self.num_rows else 0.0

    def live_bits(self) -> np.ndarray:
        """Boolean live-row mask over ``num_rows`` (the VALID_PAGE bits)."""
        page = self.logical[VALID_PAGE]
        bits = np.unpackbits(
            page.view(np.uint8), bitorder="little", count=self.num_rows
        )
        return bits.astype(bool)

    def check_delete(self, row_ids) -> np.ndarray:
        """Validate a delete batch WITHOUT mutating; returns the unique ids.

        Raises — before any page state can be touched — on ids outside
        ``[0, num_rows)``, duplicate ids within the batch, and ids already
        tombstoned (a double delete is a client bug worth surfacing, and
        silently accepting it would skew ``deleted_rows`` accounting).
        """
        if VALID_PAGE not in self.logical:
            raise ValueError("delete() needs an ingested store")
        raw = np.asarray(row_ids)
        if raw.size and raw.dtype.kind not in "iu":
            raise ValueError(
                f"delete ids must be integers, got dtype {raw.dtype} "
                "(a float id would silently truncate to a neighbour row)"
            )
        ids = np.unique(raw.astype(np.int64, copy=False))
        if ids.size != len(np.asarray(row_ids).ravel()):
            raise ValueError("delete batch has duplicate row ids")
        if ids.size and (ids[0] < 0 or ids[-1] >= self.num_rows):
            raise ValueError(
                f"delete ids outside [0, {self.num_rows}): "
                f"{ids[(ids < 0) | (ids >= self.num_rows)][:5]}"
            )
        if ids.size:
            page = self.logical[VALID_PAGE]
            dead = (page[ids // WORD_BITS] >> (ids % WORD_BITS)) & 1 == 0
            if dead.any():
                raise ValueError(
                    f"rows already deleted: {ids[dead][:5]}"
                )
        return ids

    def delete(self, row_ids) -> AppendDelta:
        """Tombstone ``row_ids``; returns the (one-page) delta to program.

        Clears the rows' VALID_PAGE bits — a physical 1->0 transition on
        the non-inverted tombstone page, so however many rows die the cost
        is ONE delta-page ESP program spanning the touched words.  No
        other page changes: row ids stay stable, plans stay warm (the
        content epoch bumps so snapshot-level caches refresh, but no
        column or region epoch moves), and every plan's spliced valid
        wordline masks the rows out of all subsequent sensings.
        """
        ids = self.check_delete(row_ids)
        if not ids.size:
            return AppendDelta(rows=0, start_row=self.num_rows, pages=())
        page = self.logical[VALID_PAGE]
        dead = np.zeros_like(page)
        np.bitwise_or.at(
            dead,
            ids // WORD_BITS,
            (np.uint32(1) << (ids % WORD_BITS)).astype(np.uint32),
        )
        page &= ~dead
        sw = int(ids[0] // WORD_BITS)
        ew = int(ids[-1] // WORD_BITS) + 1
        self.deleted_rows += int(ids.size)
        self.epoch += 1
        return AppendDelta(
            rows=0,
            start_row=self.num_rows,
            pages=(
                PageDelta(VALID_PAGE, sw, page[sw:ew].copy()),
            ),
        )

    def to_table(self) -> dict[str, np.ndarray]:
        """Reconstruct the resident rows' column values from the BSI pages.

        Every column carries a full bit-sliced index (``bits`` slices cover
        its maximum value), so ``value[row] = sum_b slice_b[row] << b`` is
        exact — this is what compaction rebuilds a stripe from, instead of
        requiring callers to retain their source tables.
        """
        n = self.num_rows
        out: dict[str, np.ndarray] = {}
        for col, ci in self.columns.items():
            vals = np.zeros((n,), dtype=np.int64)
            for b in range(ci.bits):
                bits = np.unpackbits(
                    self.logical[bsi_page(col, b)].view(np.uint8),
                    bitorder="little",
                    count=n,
                )
                vals |= bits.astype(np.int64) << b
            out[col] = vals
        return out

    def rebuild(
        self,
        table: dict[str, np.ndarray],
        *,
        reserve_rows: int = 0,
        schema: dict[str, tuple[int, ...]] | None = None,
        min_words: int | None = None,
    ) -> None:
        """Reset and re-ingest in place — the host half of compaction.

        Keeps the object identity (schedulers, compilers, and aggregators
        hold references) and the epoch counters: the content ``epoch``
        keeps rising and every column's metadata epoch bumps through
        ``ingest``, so no cache key minted against the old index can ever
        match the rebuilt one.  ``schema`` (normally the pre-compaction
        value sets) keeps pages for values the surviving rows no longer
        contain, so a sharded fleet stays merge-aligned after a partial
        rebuild; ``reserve_rows`` re-opens append headroom in the freshly
        erased pages; ``min_words`` re-applies fleet-wide padding.
        """
        self.logical.clear()
        self.columns.clear()
        self.num_rows = 0
        self.capacity_rows = 0
        self.deleted_rows = 0
        if min_words is not None:
            self.min_words = min_words
        self.ingest(table, schema=schema, reserve_rows=reserve_rows)

    # -- program ------------------------------------------------------------
    def place_into(self, layout, warmup: Iterable[Query] = ()) -> None:
        """Compute §6.3 placements for every bitmap page into ``layout``.

        ``warmup`` queries steer placement: their lowered expressions run
        through :func:`auto_layout` first, so hot query shapes get the
        paper's context-sensitive inverted/plain co-location.  Pages no
        warmup query touches fall back to the per-column defaults described
        in the module docstring.  Pages already placed are left alone, so a
        sharded deployment can compute one canonical layout and fork it per
        device (``Layout.fork``).
        """
        from repro.core.placement import auto_layout
        from repro.query.compile import lower

        for q in warmup:
            auto_layout(lower(q.where, self), layout)

        for col, ci in self.columns.items():
            eq_new = [
                eq_page(col, v)
                for v in ci.values
                if eq_page(col, v) not in layout
            ]
            if eq_new:
                layout.place_colocated(
                    eq_new, inverted=True, region=eq_region(col)
                )
            bsi_new = [
                bsi_page(col, b)
                for b in range(ci.bits)
                if bsi_page(col, b) not in layout
            ]
            if bsi_new:
                layout.place_colocated(
                    bsi_new, inverted=False, region=bsi_region(col)
                )
        for const in (TRUE_PAGE, FALSE_PAGE, VALID_PAGE):
            # VALID_PAGE placement must stay non-inverted: deletes clear
            # logical bits in place, which is only the erase-free 1->0
            # program NAND supports if physical == logical
            if const in self.logical and const not in layout:
                layout.place_colocated([const], inverted=False)

    def program(
        self, array, warmup: Iterable[Query] = ()
    ) -> tuple[int, int]:
        """ESP-program every bitmap page into ``array`` (§6.3 placement).

        Returns ``(programs, words)`` physical-program stats: logical pages
        packed into the same physical page (``layout.levels > 1``) program
        in one ISPP pass, with the lead page charging wear/ESP counters and
        the group costing ``max`` of its members' word counts.  Bit-identical
        to per-page accounting at ``levels == 1``.
        """
        self.place_into(array.layout, warmup=warmup)
        levels = array.layout.levels
        groups: dict[tuple[int, int], list[tuple[str, np.ndarray]]] = {}
        for name, words in self.logical.items():
            p = array.layout[name]
            groups.setdefault(
                (p.block, p.wordline // levels), []
            ).append((name, words))
        programs = total = 0
        for group in groups.values():
            charge = True
            for name, words in group:
                array.fc_write(name, words, esp=True, charge=charge)
                charge = False
            programs += 1
            total += max(int(w.shape[0]) for _, w in group)
        return programs, total
