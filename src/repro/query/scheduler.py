"""BatchScheduler: admission, shape-grouped batching, and stats/projection.

The serving loop of FlashQL: clients ``submit`` queries (tickets), and
``flush`` compiles the pending set through the plan cache and executes it
as ONE fused device program per flush signature
(:func:`repro.query.compile.compile_flush`): every predicate signature
group senses under ``jax.vmap``, the results feed every aggregate's
(weighted-)popcount reduce device-side, and the whole flush returns as a
single flat payload — one kernel dispatch and one host transfer per
flush, whatever mix of aggregate kinds it holds (counted in
``host_transfers`` / ``fused_dispatches`` and asserted in tests).
Devices holding non-ESP pages (whose reads inject modelled bit errors)
fall back to the per-group legacy path: vmap batches via
:class:`FlashDevice.execute_batch`, then one reduce dispatch + one
transfer per reduce signature (:func:`repro.query.aggregate.reduce_flush`).

Every stat the scheduler keeps lives in one
:class:`repro.query.telemetry.Telemetry` registry: the legacy counter
attributes (``host_transfers``, ``wordlines_sensed``, …) are read-only
views over it, ``stats()`` is reimplemented on top (bit-compatible,
asserted in tests), and — when telemetry is enabled — every flush records
its lifecycle (compile -> dispatch -> transfer -> reduce) as trace spans,
every result carries a sensing + latency attribution, and tickets past a
latency/sensing threshold land in the slow-query log.

The scheduler also records every executed MWS command's shape
(:class:`repro.flashsim.workloads.MWSCommandShape`), so ``projection()``
can replay the served traffic through the paper's full-scale SSD model and
report projected wall-clock time and energy on real NAND-flash hardware
(Table-1 geometry), next to the OSP baseline.
"""

from __future__ import annotations

import time
from collections import Counter
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.commands import MWSCommand, ThresholdCommand
from repro.flashsim.geometry import DEFAULT_SSD, SSDConfig
from repro.flashsim.platforms import Platform, run_workload
from repro.flashsim.timing import level_program_factor, level_read_factor
from repro.flashsim.workloads import BulkBitwiseWorkload, MWSCommandShape
from repro.query.aggregate import (
    get_aggregator,
    reduce_flush,
    validate_query,
)
from repro.query.ast import Count, Mask, Query, normalize_agg
from repro.query.bitmap import BitmapStore
from repro.query.compile import QueryCompiler, compile_flush
from repro.query.device import FlashDevice, age_spill_blocks
from repro.query.optimize import cse_flush
from repro.query.telemetry import (
    TID_FLUSH,
    TID_TICKETS,
    Telemetry,
)

# one extra sensed plane (a BSI slice / equality bitmap read for an
# aggregate) = one single-wordline sensing in the SSD projection
AGG_READ_SHAPE = MWSCommandShape(n_blocks=1, max_wls_per_block=1)


def merge_appends(batches: list[dict]) -> dict:
    """Concatenate queued append batches into one combined batch."""
    return {
        col: np.concatenate([b[col] for b in batches])
        for col in batches[0]
    }


def queue_append(store, buf: list[dict], rows: dict) -> None:
    """Validate + queue one append batch for coalesced programming.

    Shared by both schedulers' ``coalesce_appends`` paths so the subtle
    ordering stays in one place: THIS batch's column set is validated
    first (the merge below is built from the first queued batch's
    columns, so an unknown or missing column would slip through it), then
    the cumulative concatenation must fit the schema and capacity BEFORE
    the batch is accepted — ``apply_appends`` can never fail halfway.
    Empty batches validate but queue nothing (an empty ndarray defaults
    to float64 and would poison the merged integer dtype).

    The cumulative check re-merges the queue, O(queued rows) per append:
    exactness is the point — stripe-key routing is data-dependent, so a
    cheaper running row count could admit a stream that overflows one
    stripe at apply time.  Flush boundaries bound the queue length.
    """
    arrays = {c: np.asarray(v) for c, v in rows.items()}
    store.check_append(arrays)
    store.check_append(merge_appends(buf + [arrays]))
    if len(next(iter(arrays.values()))):
        buf.append(arrays)


def plan_traffic(plan) -> tuple[tuple, int]:
    """A plan's projected-traffic contribution, memoized on the plan.

    Walking every MWS command's wordline bitmaps per flush dominated warm
    serving (it was ~2/3 of a steady-state sharded flush in profiles);
    plans are cached and immutable, so their ``(shape counts, wordlines)``
    is computed once and pinned on the instance.
    """
    memo = getattr(plan, "_traffic_memo", None)
    if memo is None:
        shapes: Counter = Counter()
        wls = 0
        for cmd in plan.commands:
            if isinstance(cmd, MWSCommand):
                shapes[
                    MWSCommandShape(
                        n_blocks=cmd.num_blocks,
                        max_wls_per_block=max(
                            len(t.wordlines) for t in cmd.targets
                        ),
                        threshold_k=cmd.k
                        if isinstance(cmd, ThresholdCommand)
                        else 0,
                    )
                ] += 1
                wls += cmd.num_wordlines
        memo = (tuple(shapes.items()), wls)
        plan._traffic_memo = memo
    return memo


def record_plan_traffic(counter: Counter, plan) -> int:
    """Fold a plan's MWS commands into a shape counter; returns wordlines.

    Shape counts keep long-running traffic O(distinct shapes); wordlines are
    tracked exactly because ragged commands pad to ``max_wls_per_block`` and
    must not inflate operand counts in the projection.
    """
    shapes, wls = plan_traffic(plan)
    for shape, cnt in shapes:
        counter[shape] += cnt
    return wls


def plan_sensings(plan) -> int:
    """MWS sensing operations a plan performs (memoized via plan_traffic)."""
    shapes, _ = plan_traffic(plan)
    return sum(cnt for _, cnt in shapes)


def plan_thresholds(plan) -> int:
    """k-of-N threshold sensings in a plan (memoized via plan_traffic)."""
    shapes, _ = plan_traffic(plan)
    return sum(cnt for shape, cnt in shapes if shape.threshold_k)


def attribute_result(
    tele: Telemetry,
    ticket: int,
    query: Query,
    attr: dict | None,
    t_submit: float,
    t_end: float,
) -> None:
    """Shared per-result telemetry: ticket trace span, latency histogram,
    and the slow-query log.  Called only when telemetry is enabled."""
    latency = t_end - t_submit
    tele.observe("query_latency_s", latency)
    sensings = attr["sensings"] if attr else 0
    tele.span(
        "ticket",
        "query",
        t_submit,
        t_end,
        tid=TID_TICKETS,
        args={"ticket": ticket, "sensings": sensings},
    )
    tele.slow(
        {
            "ticket": ticket,
            "predicate": repr(query.where),
            "agg": repr(query.agg),
            "latency_s": latency,
            "attribution": attr,
        },
        latency,
        sensings,
    )


def project_traffic(
    command_shape_counts: Counter,
    *,
    wordlines_sensed: int,
    num_rows: int,
    num_queries: int,
    host_postprocess: bool,
    esp_programs: int = 0,
    block_erases: int = 0,
    levels: int = 1,
    ssd: SSDConfig = DEFAULT_SSD,
    name: str = "flashql",
) -> dict:
    """Project served MWS traffic onto the paper's SSD timing/energy model.

    One call models one device (chip); a sharded fleet projects each
    device's traffic separately and aggregates — time as the max over
    concurrently-serving devices, energy as the sum (see
    ``repro.query.shard``).

    ``esp_programs`` counts the *delta* page programs incremental appends
    issued — only the pages an update actually touched, never a full
    reprogram of the index.  They are charged at ``t_esp_us`` on the
    Flash-Cosmos side (ESP reliability costs ~2x a plain SLC program) and
    at ``t_prog_slc_us`` for the OSP baseline, which rewrites the same
    pages through the ordinary program path.

    ``block_erases`` counts whole-block erases (compaction rebuilds): both
    platforms pay ``t_bers_ms`` per block — garbage collection is the same
    erase-before-program dance wherever the data is computed on.

    ``levels`` is the multi-level packing factor (``Layout.levels``): both
    platforms sense L-level pages through a longer reference staircase
    (``level_read_factor``) and program them with finer ISPP verify steps
    (``level_program_factor``).  What makes packing a *win* is that the
    traffic counts themselves shrink — fewer physical programs/erases for
    the same logical pages — which the callers already fold in before
    projecting.
    """
    if not command_shape_counts and not esp_programs and not block_erases:
        raise ValueError("no traffic served yet")
    if levels > 1:
        ssd = replace(ssd, t_r_us=ssd.t_r_us * level_read_factor(levels))
    wl = BulkBitwiseWorkload(
        name=name,
        num_operands=wordlines_sensed,
        operand_bits=num_rows,
        # a program-only projection (appends landed on a stripe that never
        # sensed) streams no result bitmaps out
        result_bits=num_rows * (num_queries if command_shape_counts else 0),
        num_queries=1,  # shape counts already cover ALL served queries
        host_postprocess=host_postprocess,
        fc_command_counts=tuple(command_shape_counts.items()),
        fc_sensing_ops=sum(command_shape_counts.values()),
    )
    fc = run_workload(wl, Platform.FC, ssd)
    osp = run_workload(wl, Platform.OSP, ssd)
    prog_scale = level_program_factor(levels)
    t_esp = esp_programs * ssd.t_esp_us * prog_scale * 1e-6
    t_prog_osp = esp_programs * ssd.t_prog_slc_us * prog_scale * 1e-6
    t_erase = block_erases * ssd.t_bers_ms * 1e-3
    fc_time = fc.time_s + t_esp + t_erase
    osp_time = osp.time_s + t_prog_osp + t_erase
    fc_energy = fc.energy_j + (t_esp + t_erase) * ssd.p_prog_w
    osp_energy = osp.energy_j + (t_prog_osp + t_erase) * ssd.p_prog_w
    return {
        "workload": wl.name,
        "fc_time_s": fc_time,
        "fc_energy_j": fc_energy,
        "osp_time_s": osp_time,
        "osp_energy_j": osp_energy,
        "esp_programs": esp_programs,
        "block_erases": block_erases,
        "speedup_vs_osp": osp_time / fc_time,
        "energy_ratio_vs_osp": osp_energy / fc_energy,
    }


@dataclass(frozen=True)
class QueryResult:
    ticket: int
    query: Query
    value: object  # the aggregate's final value (int, float, BitVector, …)
    latency_s: float
    cache_hit: bool
    # per-query sensing + latency attribution (None when telemetry is
    # disabled): sensings / wordlines / spill_steps / agg_plane_reads are
    # exact per query; the *_s phase durations are the enclosing flush's
    # lifecycle (shared flush work — a batch amortizes it)
    attribution: dict | None = None

    # legacy accessors: COUNT/MASK callers predate the aggregate pipeline
    @property
    def count(self) -> int | None:
        spec = normalize_agg(self.query.agg)
        return self.value if isinstance(spec, Count) else None

    @property
    def mask(self):
        spec = normalize_agg(self.query.agg)
        return self.value if isinstance(spec, Mask) else None


# legacy counter attributes of the schedulers, reimplemented as read-only
# views over the telemetry registry (one source of truth; stats() stays
# bit-compatible — asserted in tests/test_query_telemetry.py)
def registry_counters(cls, names: tuple[str, ...]):
    for name in names:
        setattr(
            cls,
            name,
            property(lambda self, _n=name: self.telemetry.value(_n)),
        )
    return cls


@dataclass
class BatchScheduler:
    device: FlashDevice
    store: BitmapStore
    max_batch: int = 256
    compiler: QueryCompiler = None  # type: ignore[assignment]
    # one fused device program + ONE host transfer per flush (the default
    # serving path); False keeps the per-reduce-group legacy path — the
    # oracle the differential harness compares against
    fuse_flush: bool = True
    # queue small append() batches and program them as one coalesced delta
    # per touched page on the next flush (or apply_appends())
    coalesce_appends: bool = False
    # -- the cost-based multi-query optimizer (repro.query.optimize) --------
    # canonicalize predicates, pick chain orderings by the flashsim cost
    # model, dedup + CSE-share plans within each fused flush, and
    # materialize hot predicates; False serves exactly as before (the
    # optimizer-off baseline the Zipfian benchmark compares against)
    optimize: bool = True
    # compiles of one canonical predicate before its result bitmap is
    # ESP-programmed as a cached page (see QueryCompiler.materialize);
    # None disables materialization while keeping the other stages
    materialize_after: int | None = 32
    # -- background-compaction policy (see compact()) -----------------------
    # auto-compact when the stripe's tombstone density crosses this (None
    # disables the policy; compact() stays available explicitly).  Checked
    # at mutation boundaries — after delete()/update()/apply_appends() —
    # never mid-flush, so no ticket ever spans a rebuild.
    compact_density: float | None = None
    # on append overflow, rebuild into wider pages (capacity growth folded
    # into the compaction path) instead of rejecting the batch
    grow_on_overflow: bool = False
    # the unified metrics registry + trace recorder; pass
    # Telemetry(enabled=False) to strip every per-event recorder off the
    # hot path (counters keep counting — stats()/projection read them)
    telemetry: Telemetry = None  # type: ignore[assignment]

    _pending: list[tuple[int, Query, float]] = field(default_factory=list)
    _next_ticket: int = 0
    # executed traffic, aggregated per command shape (bounded memory even
    # for a long-running service); wordlines tracked exactly because ragged
    # commands pad to max_wls_per_block and must not inflate operand counts
    command_shape_counts: Counter = field(default_factory=Counter)
    _host_postprocess: bool = False
    # stacked extra sensed planes (BSI slices / equality bitmaps) per
    # (store epoch, page tuple) — see repro.query.aggregate.reduce_flush
    _extras_cache: dict = field(default_factory=dict, repr=False)
    # device-resident valid-row word mask, memoized per ingest epoch
    _mask_cache: tuple | None = field(default=None, repr=False)
    # fused flush programs per (batch composition, store epochs) and their
    # jitted runners per flush signature — see compile_flush
    _flush_programs: dict = field(default_factory=dict, repr=False)
    _runner_cache: dict = field(default_factory=dict, repr=False)
    # flush-level CSE rewrites per (batch composition, store epochs) —
    # see repro.query.optimize.cse_flush
    _cse_cache: dict = field(default_factory=dict, repr=False)
    # queued (validated) append batches awaiting coalesced programming
    _append_buf: list = field(default_factory=list, repr=False)

    def __post_init__(self):
        if self.telemetry is None:
            self.telemetry = Telemetry()
        if self.compiler is None:
            self.compiler = QueryCompiler(self.store, self.device)
        self.compiler.telemetry = self.telemetry
        self.compiler.optimize = self.optimize
        self.compiler.materialize_after = (
            self.materialize_after if self.optimize else None
        )
        self.device.telemetry = self.telemetry
        self.telemetry.name_tid(TID_FLUSH, "flush")
        self.telemetry.name_tid(TID_TICKETS, "tickets")
        self.telemetry.providers.setdefault("plan_cache", self._plan_cache)
        self.telemetry.providers.setdefault("projection", self.projection)
        self.telemetry.providers.setdefault(
            "optimizer", self._optimizer_stats
        )

    def _plan_cache(self) -> dict:
        return {
            "hits": self.compiler.hits,
            "misses": self.compiler.misses,
            "size": self.compiler.cache_size,
        }

    def _optimizer_stats(self) -> dict:
        tele = self.telemetry
        served = int(self.queries_served)
        mws = sum(self.command_shape_counts.values())
        return {
            "enabled": self.optimize,
            "sensings_per_query": (mws / served) if served else None,
            "cse_plan_hits": int(tele.value("cse_plan_hits")),
            "cse_shared_senses": int(tele.value("cse_shared_senses")),
            "cse_rewritten_members": int(
                tele.value("cse_rewritten_members")
            ),
            "materializations": int(tele.value("materializations")),
            "materialization_hits": int(
                tele.value("materialization_hits")
            ),
            "materialization_invalidations": int(
                tele.value("materialization_invalidations")
            ),
        }

    def _materialize_hot(self) -> None:
        """Materialization policy: at each flush boundary, ESP-program the
        result bitmaps of predicates past the compiler's heat threshold.
        The build's one sensing pass + page program are charged to traffic
        (the payoff is every later compile lowering to ``mat AND valid``)."""
        if not self.optimize:
            return
        for key, canon in self.compiler.hot_preds():
            plan = self.compiler.materialize(key, canon)
            if plan is not None:
                self.telemetry.count(
                    "wordlines_sensed",
                    record_plan_traffic(self.command_shape_counts, plan),
                )
                thr = plan_thresholds(plan)
                if thr:
                    self.telemetry.count("threshold_senses", thr)
                self.telemetry.count("materialization_programs")

    # -- incremental ingest --------------------------------------------------
    def append(self, rows: dict[str, object]) -> int:
        """Append rows to the live index; returns pages ESP-programmed.

        The whole batch is validated against the ingest schema (exact
        column set, equal lengths, non-negative values, word capacity)
        *before* any page state mutates, and appends are rejected while
        queries are pending — a half-applied batch could otherwise serve a
        flush from a torn index.  Only delta pages are programmed: the
        tail words of pages the new rows actually set, plus fresh pages
        for first-seen values / grown BSI widths.  Plans over columns
        whose index metadata did not change stay warm in the plan cache.

        With ``coalesce_appends`` the (still fully validated, cumulative
        capacity included) batch is queued instead and returns 0; the next
        ``flush()`` — or an explicit :meth:`apply_appends` — programs all
        queued batches as ONE delta per touched page, so N small appends
        between flushes cost the page programs of one combined append.
        """
        if self._pending:
            raise RuntimeError(
                f"append() with {len(self._pending)} queries pending; "
                "flush() first so no ticket spans the mutation"
            )
        try:
            return self._admit_append(rows)
        except ValueError as err:
            if not (self.grow_on_overflow and "overflows" in str(err)):
                raise
            # capacity growth rides the compaction rebuild: re-stripe into
            # wider pages (the failed attempt validated before mutating, so
            # nothing is half-applied), leaving the batch plus the original
            # headroom — or twice the batch, whichever is larger — free
            b = len(next(iter(rows.values())))
            self.compact(
                reserve_rows=b
                + max(2 * b, self.store.capacity_rows - self.store.live_rows)
            )
            return self._admit_append(rows)

    def _admit_append(self, rows: dict) -> int:
        if self.coalesce_appends:
            queue_append(self.store, self._append_buf, rows)
            return 0
        return self._program_append(rows)

    def _program_append(self, rows: dict) -> int:
        delta = self.store.append(rows)  # validates before mutating
        programs, words = self.store.program_delta(
            self.device, delta, telemetry=self.telemetry
        )
        self.telemetry.count("rows_appended", delta.rows)
        self.telemetry.count("esp_delta_programs", programs)
        self._count_programmed_words(delta, physical=words, logical=True)
        return programs

    def _count_programmed_words(
        self, delta, *, physical: int, logical: bool
    ) -> None:
        """Write-amplification accounting for one programmed delta.

        ``words_programmed`` counts the words physically ESP-programmed —
        ``physical`` comes from :meth:`BitmapStore.program_delta`, which
        under multi-level packing merges co-resident logical pages into one
        physical program (this is where the MLC density win shows up).
        ``words_written`` counts the words a client mutation had to change
        (``logical=True``) — always the per-logical-page sum, independent
        of the packing factor.  Compaction reprograms surviving data the
        client never touched, so it adds to the physical side only — the
        ratio is the index's write amplification
        (``stats()["write_amplification"]``, also in snapshots).
        """
        self.telemetry.count("words_programmed", physical)
        if logical:
            words = sum(int(pd.words.shape[0]) for pd in delta.pages)
            self.telemetry.count("words_written", words)

    @property
    def appends_queued(self) -> int:
        return len(self._append_buf)

    def apply_appends(self) -> int:
        """Program every queued append batch as one coalesced delta.

        A page touched by many queued batches programs ONCE (its combined
        tail words); returns the pages programmed.  Ran automatically at
        the top of ``flush()``, so queries submitted after an append always
        see its rows — identical semantics to immediate appends, minus the
        per-batch page programs.
        """
        if not self._append_buf:
            return 0
        rows = merge_appends(self._append_buf)
        self.telemetry.count(
            "append_batches_coalesced", len(self._append_buf)
        )
        self._append_buf.clear()
        return self._program_append(rows)

    # -- deletes / updates / compaction --------------------------------------
    def delete(self, row_ids) -> int:
        """Tombstone rows; returns pages ESP-programmed (always 1).

        Queued appends apply first so ``row_ids`` address the fully
        up-to-date table; like appends, deletes are refused while tickets
        are in flight.  The whole batch costs one delta-page program of
        the stripe's tombstone page — no other page changes, no region
        epoch moves, every cached plan stays warm (its spliced valid
        wordline reads the new tombstones on the next sensing).  May
        trigger the auto-compaction policy (``compact_density``).
        """
        if self._pending:
            raise RuntimeError(
                f"delete() with {len(self._pending)} queries pending; "
                "flush() first so no ticket spans the mutation"
            )
        self.apply_appends()
        delta = self.store.delete(row_ids)
        programs, words = self.store.program_delta(
            self.device, delta, telemetry=self.telemetry
        )
        self.telemetry.count("rows_deleted", len(np.asarray(row_ids)))
        self.telemetry.count("esp_delta_programs", programs)
        self._count_programmed_words(delta, physical=words, logical=True)
        self.telemetry.gauge(
            "tombstone_density", self.store.tombstone_density
        )
        self._maybe_compact()
        return programs

    def update(self, row_ids, rows: dict[str, object]) -> int:
        """Update = delete + append: tombstone ``row_ids``, append ``rows``
        (which get fresh row ids at the tail); returns pages programmed.

        Both halves validate BEFORE either mutates, so a bad update can
        never leave the rows deleted but not re-appended.  Reuses delta-
        page programming + region epochs end to end: a value-stable update
        (no first-seen value, no grown BSI width) invalidates no plan.
        """
        if self._pending:
            raise RuntimeError(
                f"update() with {len(self._pending)} queries pending; "
                "flush() first so no ticket spans the mutation"
            )
        self.apply_appends()
        ids = self.store.check_delete(row_ids)
        arrays = {c: np.asarray(v) for c, v in rows.items()}
        b = self.store.check_append(arrays)
        if b != ids.size:
            raise ValueError(
                f"update() got {ids.size} row ids but {b} replacement rows"
            )
        n = self.delete(ids)
        n += self.append(arrays)
        self.telemetry.count("rows_updated", ids.size)
        return n

    def _maybe_compact(self) -> bool:
        """The background-compaction policy: rebuild once tombstone density
        crosses ``compact_density`` (checked only at mutation boundaries,
        with no tickets in flight by construction)."""
        if (
            self.compact_density is None
            or self.store.tombstone_density < self.compact_density
        ):
            return False
        self.compact()
        return True

    def compact(self, reserve_rows: int | None = None) -> dict:
        """Rewrite the stripe without its tombstoned rows; returns stats.

        The erase-unit-aware rebuild a real device must do: NAND programs
        only 1->0, so reclaiming tombstoned capacity means erasing every
        block the stripe occupies (charged per block at ``t_bers_ms`` in
        the SSD projection, one P/E cycle each) and ESP-reprogramming the
        surviving rows.  Surviving rows are renumbered densely (row ``k``
        = the k-th live row in old id order); ``reserve_rows`` sets the
        fresh append headroom and defaults to restoring the stripe's full
        pre-compaction capacity — this same path grows capacity when
        ``grow_on_overflow`` re-stripes into wider pages.  The reprogram
        counts toward physical (but not logical) programmed words: the
        write-amplification cost of garbage collection.
        """
        if self._pending:
            raise RuntimeError(
                f"compact() with {len(self._pending)} queries pending; "
                "flush() first so no ticket spans the rebuild"
            )
        self.apply_appends()
        store, tele = self.store, self.telemetry
        t0 = time.perf_counter()
        dropped = store.deleted_rows
        live = store.live_bits()
        table = {c: v[live] for c, v in store.to_table().items()}
        if reserve_rows is None:
            reserve_rows = store.capacity_rows - store.live_rows
        schema = {c: ci.values for c, ci in store.columns.items()}
        erased = self.device.erase_rebuild()
        store.rebuild(table, reserve_rows=reserve_rows, schema=schema)
        _, words = store.program(self.device)
        self.device.reset_after_rebuild()
        self._flush_programs.clear()
        self._extras_cache.clear()
        self._cse_cache.clear()
        self._mask_cache = None
        tele.count("compactions")
        tele.count("block_erases", erased)
        tele.count("words_programmed", words)
        tele.count("compaction_rows_dropped", dropped)
        tele.gauge("tombstone_density", 0.0)
        self._record_wear()
        t1 = time.perf_counter()
        tele.span("compact", "flush", t0, t1, args={"erased": erased})
        tele.observe("compact_s", t1 - t0)
        return {
            "rows_dropped": dropped,
            "live_rows": store.num_rows,
            "capacity_rows": store.capacity_rows,
            "blocks_erased": erased,
            "words_reprogrammed": words,
            "seconds": t1 - t0,
        }

    def _record_wear(self) -> None:
        """Per-block wear gauges (P/E cycles) after erase-heavy operations."""
        pec = self.device.pec
        if pec:
            self.telemetry.gauge("max_pec", max(pec.values()))
            self.telemetry.gauge(
                "mean_pec", sum(pec.values()) / len(pec)
            )

    # -- admission ----------------------------------------------------------
    def submit(self, query: Query) -> int:
        """Admit a query; returns its ticket.  Queries execute on the next
        ``flush()`` (or ``serve()``), ``max_batch`` at a time.

        Validation (predicate columns + the aggregate's target columns)
        happens here, so a bad query raises immediately instead of
        poisoning a later flush.
        """
        validate_query(query, self.store.columns)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, query, time.perf_counter()))
        return ticket

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- serving -------------------------------------------------------------
    def flush(self) -> dict[int, QueryResult]:
        """Compile, batch-execute, and aggregate all pending queries."""
        self.apply_appends()
        if not self._pending:
            return {}
        self._materialize_hot()
        tele = self.telemetry
        batch, self._pending = (
            self._pending[: self.max_batch],
            self._pending[self.max_batch :],
        )
        tele.gauge("pending_after_pop", len(self._pending))
        t0 = time.perf_counter()
        compiled = [self.compiler.compile(q) for _, q, _ in batch]
        execs = [self.compiler.exec_for(cq) for cq in compiled]
        t_comp = time.perf_counter()
        if self._mask_cache is None or self._mask_cache[0] != self.store.epoch:
            self._mask_cache = (
                self.store.epoch,
                jnp.asarray(self.store.valid_words_mask()),
            )
        mask_words = self._mask_cache[1]
        queries = [q for _, q, _ in batch]
        aggs = [get_aggregator(q.agg) for q in queries]

        cse = None
        if self.fuse_flush and not self.device._non_esp:
            # the fused path: ONE jitted program senses every signature
            # group and reduces every aggregate kind device-side; ONE
            # payload transfer brings back the whole flush.  Epochs inside
            # the plan keys + the content epochs make stale hits impossible.
            # Plan keys cover only the predicate side, so the members'
            # aggregate specs join the key explicitly — the same predicates
            # under different aggregates are different programs.
            if self.optimize:
                ckey = (
                    tuple(cq.key for cq in compiled),
                    self.store.epoch,
                    self.device.store.epoch,
                )
                cse = self._cse_cache.get(ckey)
                if cse is None:
                    if len(self._cse_cache) >= 64:
                        self._cse_cache.clear()
                    cse = cse_flush(compiled, self.compiler, self.device)
                    self._cse_cache[ckey] = cse
            key = (
                tuple(cq.key for cq in compiled),
                tuple(a.spec for a in aggs),
                self.store.epoch,
                self.device.store.epoch,
            )
            program = self._flush_programs.get(key)
            if program is None:
                if len(self._flush_programs) >= 64:
                    self._flush_programs.clear()
                program = compile_flush(
                    execs if cse is None else list(cse.member_execs),
                    [q.agg for q in queries],
                    [self.store] * len(queries),
                    [self.store.epoch] * len(queries),
                    words=self.store.words,
                    interpret=self.device.interpret,
                    runner_cache=self._runner_cache,
                    extras_cache=self._extras_cache,
                    pad=self.device.pad_signatures,
                    dedup_keys=(
                        None if cse is None else list(cse.dedup_keys)
                    ),
                    shared_execs=() if cse is None else cse.shared_execs,
                )
                self._flush_programs[key] = program
            payload = program.run(self.device.store.snapshot(), mask_words)
            if cse is None:
                age_spill_blocks(self.device.pec, execs)
            else:
                # wear: one run of each UNIQUE member plan + each shared
                # plan, plus one scratch program per shared result
                age_spill_blocks(
                    self.device.pec,
                    [cse.member_execs[i] for i in cse.uix]
                    + list(cse.shared_execs),
                )
                for b in cse.shared_blocks:
                    self.device.pec[b] = self.device.pec.get(b, 0) + 1
            tele.count("fused_dispatches")
            self.device.last_signature_groups = program.n_sense_groups
            t_disp = time.perf_counter()
            # the single device->host copy of the flush (also the barrier
            # that keeps qps/latency from measuring only Python dispatch)
            host = jax.device_get(payload)
            tele.count("host_transfers")
            t_xfer = time.perf_counter()
            partials = program.unpack(host, aggs)
            extra_counts = list(program.extra_counts)
            tele.span("dispatch", "flush", t_comp, t_disp)
            tele.span("transfer", "flush", t_disp, t_xfer)
        else:
            # legacy path (devices with non-ESP pages, and the oracle for
            # the differential harness): vmap batches + one reduce dispatch
            # and one transfer per reduce signature
            plans = [c.plan for c in compiled]
            stacked = (
                self.device.execute_batch_stacked(
                    plans,
                    execs=execs,
                    # epochs inside cq.key make the memoized grouping
                    # impossible to hit stale
                    batch_key=tuple(cq.key for cq in compiled),
                )
                & mask_words
            )  # (B, W), padding zeroed
            t_disp = time.perf_counter()
            partials, extra_counts, n_groups = reduce_flush(
                stacked,
                [q.agg for q in queries],
                [self.store] * len(queries),
                [self.store.epoch] * len(queries),
                interpret=self.device.interpret,
                extras_cache=self._extras_cache,
            )
            tele.count("host_transfers", n_groups)
            tele.count("eager_plans", self.device.last_eager_plans)
            t_xfer = time.perf_counter()
            # force device work before timestamping, or qps/latency would
            # only measure the Python-side dispatch
            jax.block_until_ready(stacked)
            tele.span("dispatch", "flush", t_comp, t_disp)
            tele.span("reduce+transfer", "flush", t_disp, t_xfer)
        t1 = time.perf_counter()
        if cse is not None:
            # physical traffic after CSE: each UNIQUE member plan runs once
            # (duplicates ride the member gather) plus each shared subplan
            wls = thr = 0
            for p in list(cse.member_plans) + list(cse.shared_plans):
                wls += record_plan_traffic(self.command_shape_counts, p)
                thr += plan_thresholds(p)
            tele.count("wordlines_sensed", wls)
            if thr:
                tele.count("threshold_senses", thr)
            tele.count("cse_plan_hits", cse.n_dedup_hits)
            tele.count("cse_shared_senses", len(cse.shared_plans))
            tele.count("cse_rewritten_members", cse.n_rewritten)
            tele.count("cse_spill_programs", len(cse.shared_plans))
        results: dict[int, QueryResult] = {}
        for i, ((ticket, q, t_submit), cq) in enumerate(zip(batch, compiled)):
            agg = aggs[i]
            self._host_postprocess |= agg.host_postprocess
            if cse is None:
                self.telemetry.count(
                    "wordlines_sensed",
                    record_plan_traffic(self.command_shape_counts, cq.plan),
                )
                thr = plan_thresholds(cq.plan)
                if thr:
                    self.telemetry.count("threshold_senses", thr)
            # each extra plane the aggregate sensed (a BSI slice or an
            # equality bitmap) is one single-wordline read in the
            # projected traffic
            if extra_counts[i]:
                self.command_shape_counts[AGG_READ_SHAPE] += extra_counts[i]
                tele.count("wordlines_sensed", extra_counts[i])
            attr = None
            if tele.enabled:
                attr = {
                    "sensings": plan_sensings(cq.plan) + extra_counts[i],
                    "wordlines": plan_traffic(cq.plan)[1] + extra_counts[i],
                    "spill_steps": execs[i].spills if execs[i] else 0,
                    "agg_plane_reads": extra_counts[i],
                    "queue_s": t0 - t_submit,
                    "compile_s": t_comp - t0,
                    "device_s": t_disp - t_comp,
                    "transfer_s": t_xfer - t_disp,
                    "reduce_s": t1 - t_xfer,
                }
                attribute_result(tele, ticket, q, attr, t_submit, t1)
            results[ticket] = QueryResult(
                ticket,
                q,
                agg.finalize(partials[i], self.store),
                t1 - t_submit,
                cq.cache_hit,
                attribution=attr,
            )
            tele.count("total_latency_s", t1 - t_submit)

        tele.count("queries_served", len(batch))
        tele.count("flushes")
        tele.count("vmap_batches", self.device.last_signature_groups)
        tele.count("serve_time_s", t1 - t0)
        tele.span("compile", "flush", t0, t_comp)
        tele.span("reduce", "flush", t_xfer, t1)
        tele.span(
            "flush",
            "flush",
            t0,
            t1,
            args={"flush": int(self.flushes), "batch": len(batch)},
        )
        tele.observe("flush_latency_s", t1 - t0)
        return results

    def serve(self, queries: list[Query]) -> list[QueryResult]:
        """Submit + flush until drained; results in submission order."""
        tickets = [self.submit(q) for q in queries]
        results: dict[int, QueryResult] = {}
        while self._pending:
            results.update(self.flush())
        return [results[t] for t in tickets]

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict:
        served = max(self.queries_served, 1)
        return {
            "queries_served": self.queries_served,
            "flushes": self.flushes,
            "vmap_batches": self.vmap_batches,
            "eager_plans": self.eager_plans,
            "plan_cache_hits": self.compiler.hits,
            "plan_cache_misses": self.compiler.misses,
            "plan_cache_size": self.compiler.cache_size,
            "queries_per_sec": (
                self.queries_served / self.serve_time_s
                if self.serve_time_s
                else float("inf")
            ),
            "mean_latency_s": self.total_latency_s / served,
            "mws_commands": sum(self.command_shape_counts.values()),
            "sensings_per_query": (
                sum(self.command_shape_counts.values()) / served
            ),
            "threshold_senses": self.threshold_senses,
            "cse_plan_hits": self.cse_plan_hits,
            "cse_shared_senses": self.cse_shared_senses,
            "materializations": self.materializations,
            "materialization_hits": self.materialization_hits,
            "fused_dispatches": self.fused_dispatches,
            "host_transfers": self.host_transfers,
            "rows_appended": self.rows_appended,
            "esp_delta_programs": self.esp_delta_programs,
            "append_batches_coalesced": self.append_batches_coalesced,
            "rows_deleted": self.rows_deleted,
            "rows_updated": self.rows_updated,
            "compactions": self.compactions,
            "block_erases": self.block_erases,
            "live_rows": self.store.live_rows,
            "tombstone_density": self.store.tombstone_density,
            "write_amplification": (
                self.words_programmed / self.words_written
                if self.words_written
                else 1.0
            ),
        }

    def projection(self, ssd: SSDConfig = DEFAULT_SSD) -> dict:
        """Full-scale SSD time/energy projection of the served traffic.

        Replays every executed MWS command shape through the paper's timing
        and energy model at Table-1 geometry, with the result bitmaps of all
        served queries streamed out — reported next to the outside-storage
        (OSP) baseline that would sense and ship every operand page.
        """
        return project_traffic(
            self.command_shape_counts,
            wordlines_sensed=int(self.wordlines_sensed),
            num_rows=self.store.num_rows,
            num_queries=int(self.queries_served),
            host_postprocess=self._host_postprocess,
            # appends' delta programs + CSE scratch-page programs + hot-
            # predicate materialization programs all ride the ESP path
            esp_programs=int(
                self.esp_delta_programs
                + self.cse_spill_programs
                + self.materialization_programs
            ),
            block_erases=int(self.block_erases),
            levels=self.device.layout.levels,
            ssd=ssd,
            name=f"flashql({int(self.queries_served)}q)",
        )


registry_counters(
    BatchScheduler,
    (
        "queries_served",
        "flushes",
        "vmap_batches",
        "eager_plans",
        "serve_time_s",
        "total_latency_s",
        "fused_dispatches",
        "host_transfers",
        "rows_appended",
        "esp_delta_programs",
        "append_batches_coalesced",
        "wordlines_sensed",
        "threshold_senses",
        "rows_deleted",
        "rows_updated",
        "compactions",
        "block_erases",
        "words_programmed",
        "words_written",
        "compaction_rows_dropped",
        "cse_plan_hits",
        "cse_shared_senses",
        "cse_rewritten_members",
        "cse_spill_programs",
        "materializations",
        "materialization_hits",
        "materialization_invalidations",
        "materialization_programs",
    ),
)
