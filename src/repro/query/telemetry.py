"""FlashQL telemetry: metrics registry, trace spans, sensing attribution.

One zero-dependency (stdlib-only) observability layer for the whole query
stack.  Both schedulers (:class:`repro.query.scheduler.BatchScheduler`,
:class:`repro.query.shard.ShardedFlashQL`) carry a :class:`Telemetry`
instance and route every stat they keep through it:

* **counters** — monotonic accounting (``host_transfers``,
  ``fused_dispatches``, ``wordlines_sensed``, per-shard mirrors, …).
  Counters are *always on*: they are functional inputs — ``stats()`` and
  the SSD time/energy projection are computed from them — and an
  increment is one dict update per event, so there is nothing to save by
  gating them.  The schedulers' legacy counter attributes are thin
  properties over this registry (asserted bit-compatible in
  ``tests/test_query_telemetry.py``).
* **gauges** — last-value samples (per-shard queue depth, routed drain
  budgets).
* **histograms** — bounded rings of observations with nearest-rank
  p50/p95/p99 (flush latency, per-query latency, plan-compile time).
  :func:`percentile` is the repo's ONE quantile codepath —
  ``benchmarks/_harness.py`` delegates here.
* **trace spans** — the flush lifecycle (admission -> plan compile ->
  fused dispatch -> device execute -> host transfer -> reduce -> shard
  merge) recorded into a bounded ring and exportable as a Chrome
  trace-event JSON (:meth:`Telemetry.export_trace`) — load it in
  ``chrome://tracing`` / Perfetto and a pipelined 4-shard flush reads as
  overlapping per-shard rows.
* **slow-query log** — tickets whose latency or sensing count crosses a
  configurable threshold land in a bounded ring with their predicate
  repr and full attribution.

Everything except counters is **off when** ``enabled=False``: ``span`` /
``observe`` / ``gauge`` / ``slow`` return after one attribute check, the
schedulers skip building per-ticket attribution entirely, and no query
result changes either way (differential-tested).  The overhead of the
enabled path is gated in ``benchmarks/flashql_telemetry.py`` (within 10%
of disabled serving).

Every buffer here is bounded (ring buffers via ``deque(maxlen=...)``), so
a long-running service's telemetry memory is O(capacity), never O(tickets
served).
"""

from __future__ import annotations

import json
import math
import time
from collections import deque
from typing import Callable

# trace rows (Chrome trace "tid"s) shared by both schedulers: shard rows
# occupy 0..num_shards-1, then these synthetic rows follow
TID_FLUSH = "flush"
TID_MERGE = "merge"
TID_TICKETS = "tickets"


def percentile(samples, q: float) -> float | None:
    """The ``q``-th percentile (nearest-rank) of a sample set, or ``None``
    when the set is empty.

    The single quantile implementation in the repo: histogram summaries
    and the benchmark harness (``benchmarks/_harness.py``) both call this.
    A fresh (or fully-drained) ring has no distribution to summarize —
    that is an answerable question, not an error, so callers get ``None``
    and omit the quantile instead of unwinding a snapshot mid-build.
    """
    s = sorted(samples)
    if not s:
        return None
    rank = min(max(1, math.ceil(q / 100 * len(s))), len(s))  # 1-based
    return s[rank - 1]


class Histogram:
    """Bounded ring of observations with nearest-rank quantile summary.

    ``count``/``total`` (and hence ``mean``) cover every observation ever
    made; quantiles cover the retained ring (the most recent ``capacity``
    samples) — a long-running service keeps O(capacity) memory and its
    tail percentiles track the *recent* distribution, which is what a
    latency gate wants.
    """

    __slots__ = ("samples", "count", "total")

    def __init__(self, capacity: int = 2048):
        self.samples: deque = deque(maxlen=capacity)
        self.count = 0
        self.total = 0.0

    def observe(self, value: float) -> None:
        self.samples.append(value)
        self.count += 1
        self.total += value

    def summary(self) -> dict:
        """Count/mean plus ring quantiles; quantile keys are OMITTED (not
        ``None``-valued, not raised over) when the ring holds no samples —
        ``Telemetry.snapshot()`` must stay total on a fresh registry."""
        out: dict = {"count": self.count}
        if self.count:
            out["mean"] = self.total / self.count
        if self.samples:
            out.update(
                p50=percentile(self.samples, 50),
                p95=percentile(self.samples, 95),
                p99=percentile(self.samples, 99),
                max=max(self.samples),
            )
        return out


class Telemetry:
    """The unified registry + trace recorder (see module docstring).

    ``enabled=False`` freezes every per-event recorder (spans, gauges,
    histograms, slow log) behind a single attribute check; counters keep
    counting because ``stats()`` and the SSD projection are built on them.
    """

    def __init__(
        self,
        enabled: bool = True,
        *,
        trace_capacity: int = 4096,
        hist_capacity: int = 2048,
        slow_capacity: int = 256,
        slow_latency_s: float | None = None,
        slow_sensings: int | None = None,
    ):
        self.enabled = enabled
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.hists: dict[str, Histogram] = {}
        self.hist_capacity = hist_capacity
        self.trace: deque = deque(maxlen=trace_capacity)
        self.slow_queries: deque = deque(maxlen=slow_capacity)
        self.slow_latency_s = slow_latency_s
        self.slow_sensings = slow_sensings
        # snapshot sections computed lazily at snapshot() time (plan-cache
        # counters live on the compilers, the projection on the scheduler)
        self.providers: dict[str, Callable[[], object]] = {}
        self.tid_names: dict[object, str] = {}
        self._t0 = time.perf_counter()

    # -- counters (always on: stats()/projection inputs) ---------------------
    def count(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def value(self, name: str, default: float = 0) -> float:
        return self.counters.get(name, default)

    # -- per-event recorders (no-ops when disabled) --------------------------
    def gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram(self.hist_capacity)
        h.observe(value)

    def span(
        self,
        name: str,
        cat: str,
        t_start: float,
        t_end: float,
        tid: object = TID_FLUSH,
        args: dict | None = None,
    ) -> None:
        """Record one complete trace span from already-taken perf_counter
        stamps — the hot path never takes extra timestamps for tracing."""
        if not self.enabled:
            return
        self.trace.append((name, cat, tid, t_start, t_end, args))

    def name_tid(self, tid: object, name: str) -> None:
        """Label a trace row (emitted as thread_name metadata on export)."""
        self.tid_names[tid] = name

    def slow(self, entry: dict, latency_s: float, sensings: int) -> None:
        """Log ``entry`` if it crosses the latency OR sensing threshold."""
        if not self.enabled:
            return
        if (
            self.slow_latency_s is not None
            and latency_s >= self.slow_latency_s
        ) or (
            self.slow_sensings is not None and sensings >= self.slow_sensings
        ):
            self.slow_queries.append(entry)

    # -- reporting -----------------------------------------------------------
    def snapshot(self) -> dict:
        """Everything the registry knows, as one plain dict.

        Counters and gauges verbatim, histogram summaries, the slow-query
        log, plus every registered provider section — the schedulers
        register ``plan_cache`` (hits/misses/size off the live compilers)
        and ``projection`` (the SSD time/energy model over the served
        traffic; ``None`` until traffic exists), so observed host metrics
        and projected device metrics read out together.
        """
        out: dict = {
            "enabled": self.enabled,
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.summary() for k, h in self.hists.items()},
            "slow_queries": list(self.slow_queries),
            "trace_events": len(self.trace),
        }
        for key, fn in self.providers.items():
            try:
                out[key] = fn()
            except ValueError:  # e.g. projection before any traffic
                out[key] = None
        return out

    def export_trace(self, path: str | None = None) -> dict:
        """The recorded spans as a Chrome trace-event JSON object.

        Complete ("ph": "X") events with microsecond timestamps relative
        to this Telemetry's construction, one trace row per tid (labelled
        via thread_name metadata).  Written to ``path`` when given; load
        the file in ``chrome://tracing`` or https://ui.perfetto.dev.
        """
        events: list[dict] = []
        tids = {}
        for tid in self.tid_names:
            tids.setdefault(tid, len(tids))
        for name, cat, tid, t_start, t_end, args in self.trace:
            row = tids.setdefault(tid, len(tids))
            ts = (t_start - self._t0) * 1e6
            dur = max(t_end - t_start, 0.0) * 1e6
            if tid == TID_TICKETS:
                # tickets legitimately overlap (one can straddle flushes),
                # so they export as nestable async pairs, not "X" slices —
                # each renders as its own sub-track keyed on its id
                base = {
                    "name": name,
                    "cat": cat,
                    "pid": 0,
                    "tid": row,
                    "id": (args or {}).get("ticket", 0),
                }
                events.append(
                    {**base, "ph": "b", "ts": ts, "args": args or {}}
                )
                events.append({**base, "ph": "e", "ts": ts + dur})
                continue
            ev = {
                "name": name,
                "cat": cat,
                "ph": "X",
                "pid": 0,
                "tid": row,
                "ts": ts,
                "dur": dur,
            }
            if args:
                ev["args"] = args
            events.append(ev)
        for tid, row in tids.items():
            events.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 0,
                    "tid": row,
                    "args": {"name": self.tid_names.get(tid, str(tid))},
                }
            )
        trace = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(trace, f)
        return trace


def validate_trace(trace: dict) -> int:
    """Validate an exported Chrome trace: well-formed events and properly
    nested spans; returns the number of duration events checked.

    Nesting is checked per trace row (tid): sorted by start time, every
    span must either be fully contained in the enclosing open span or
    start after it ends — partial overlap within a row means the recorded
    lifecycle stamps are inconsistent.  (Different rows — shards, the
    merge row, the ticket row — legitimately overlap; that overlap IS the
    pipelining the trace exists to show.)
    """
    if "traceEvents" not in trace:
        raise ValueError("missing traceEvents")
    rows: dict[object, list[tuple[float, float, str]]] = {}
    n = 0
    open_async: dict[tuple, float] = {}
    for ev in trace["traceEvents"]:
        # async ticket pairs ("b"/"e") overlap by design; only check that
        # every begin closes with a non-negative duration
        if ev.get("ph") == "b":
            open_async[(ev.get("id"), ev.get("name"))] = ev["ts"]
            continue
        if ev.get("ph") == "e":
            key = (ev.get("id"), ev.get("name"))
            if key not in open_async:
                raise ValueError(f"async end without begin: {ev!r}")
            if ev["ts"] < open_async.pop(key):
                raise ValueError(f"async event ends before it begins: {ev!r}")
            n += 1
            continue
        if ev.get("ph") != "X":
            continue
        if ev["dur"] < 0 or not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"malformed event {ev!r}")
        rows.setdefault((ev.get("pid"), ev.get("tid")), []).append(
            (ev["ts"], ev["ts"] + ev["dur"], ev["name"])
        )
        n += 1
    eps = 1.0  # μs: perf_counter stamps taken back-to-back may tie
    for row in rows.values():
        row.sort(key=lambda e: (e[0], -(e[1] - e[0])))
        stack: list[tuple[float, float, str]] = []
        for start, end, name in row:
            while stack and start >= stack[-1][1] - eps:
                stack.pop()
            if stack and end > stack[-1][1] + eps:
                raise ValueError(
                    f"span {name!r} [{start:.1f}, {end:.1f}] overlaps "
                    f"{stack[-1][2]!r} ending {stack[-1][1]:.1f} "
                    "without nesting"
                )
            stack.append((start, end, name))
    if open_async:
        raise ValueError(f"unclosed async events: {sorted(open_async)}")
    return n
