"""Cost-based multi-query optimizer: sense once, answer many.

Flash-Cosmos makes a single multi-wordline sensing evaluate a many-operand
bitwise op, so the dominant serving cost is *how many sensings a flush
needs* — not how many queries it answers.  This module holds the three
optimizer stages the compiler and schedulers compose:

* **Canonicalization** (:func:`repro.query.ast.canonicalize`, applied by
  ``QueryCompiler``): structurally-equal-modulo-commutativity predicates
  become identical, so they share one plan-cache entry and one sensing
  when they meet in a flush.

* **Cost-based reordering** (:func:`best_plan`): the flashsim timing model
  prices a plan — :func:`repro.flashsim.timing.mws_latency_us` per MWS
  command, ``t_esp_us`` per spill (an ESP program is ~18x one sensing, so
  avoiding a spill dominates everything else) — and the compiler keeps the
  cheapest of a small set of candidate And/Or chain orderings.

* **Cross-query CSE** (:func:`cse_flush`): within one flush, queries are
  first deduplicated by whole-plan cache key (two queries with one
  predicate sense it once — the fused program's member gather fans the row
  out), then predicate *subtrees* shared by two or more distinct plans are
  extracted: the subtree is sensed once as a shared plan, its latch result
  is ESP-programmed to a scratch page (priced as one ``t_esp_us``, worn as
  one P/E cycle — exactly a planner spill), and every member plan that
  references it senses the scratch wordline instead of recomputing the
  subtree.  Inside the fused :class:`repro.query.compile.FlushProgram` the
  scratch round-trip collapses to a static splice
  (:attr:`repro.query.device._Step.shared`), so the rewrite stays a pure
  array program.  The whole rewrite is accepted only when the timing model
  says the flush got cheaper; otherwise the flush falls back to plain
  whole-plan dedup.

Hot-predicate materialization (the fourth stage) lives on
``QueryCompiler`` itself — see ``QueryCompiler.materialize`` — because its
cache is per-device state with epoch-guarded invalidation.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from itertools import combinations

import numpy as np

from repro.core.bitops import BitOp
from repro.core.commands import (
    CommandPlan,
    MWSCommand,
    SpillCommand,
    ThresholdCommand,
)
from repro.core.expr import Expr, Node, Page, Threshold, and_, leaves, or_
from repro.core.planner import Planner
from repro.flashsim.geometry import DEFAULT_SSD, SSDConfig
from repro.flashsim.timing import mws_latency_us, threshold_latency_us
from repro.query.ast import Eq, Pred, iter_subtrees, pred_key, pred_size


def plan_cost_us(plan: CommandPlan, ssd: SSDConfig = DEFAULT_SSD) -> float:
    """Price a plan with the flashsim timing model (microseconds).

    Each MWS command costs the characterized multi-wordline sensing
    latency for its (blocks, max wordlines-per-block) shape; each spill
    costs one ESP program (``t_esp_us`` — the paper's zero-error program
    mode at tESP/tPROG = 2), which at the default config is ~18x a
    sensing: the cost function therefore prefers any reordering that
    trades spills for extra sensings.
    """
    cost = 0.0
    for cmd in plan.commands:
        if isinstance(cmd, ThresholdCommand):
            # dynamic-sensing staircase: slower than one wired-OR MWS,
            # far cheaper than the C(N, k) chain it replaces at large N
            max_wls = max(len(t.wordlines) for t in cmd.targets)
            cost += threshold_latency_us(
                ssd.t_r_us, len(cmd.targets), max_wls
            )
        elif isinstance(cmd, MWSCommand):
            max_wls = max(len(t.wordlines) for t in cmd.targets)
            cost += mws_latency_us(ssd.t_r_us, len(cmd.targets), max_wls)
        elif isinstance(cmd, SpillCommand):
            cost += ssd.t_esp_us
    return cost


def _primary_block(e: Expr, layout) -> int:
    for p in leaves(e):
        if p.name in layout:
            return layout[p.name].block
    return -1


def reorder_expr(e: Expr, layout) -> Expr:
    """Round-robin And/Or children across their primary leaf blocks.

    The planner buckets a chain's operands into inter-block MWS commands
    greedily, so runs of same-block operands fragment the packing;
    interleaving the blocks ([1,1,2,2] -> [1,2,1,2]) lets consecutive
    operands land in one command's block slots.  This is only a candidate
    generator — :func:`best_plan` keeps it solely when the timing model
    agrees.
    """
    if isinstance(e, Page):
        return e
    if isinstance(e, Threshold):
        # child order is sensing-irrelevant for a threshold (every child
        # gets its own block slot); recurse only
        return Threshold(
            e.k, tuple(reorder_expr(c, layout) for c in e.children)
        )
    kids = tuple(reorder_expr(c, layout) for c in e.children)
    if e.op in (BitOp.AND, BitOp.OR) and len(kids) >= 3:
        groups: dict[int, list[Expr]] = {}
        for k in kids:
            groups.setdefault(_primary_block(k, layout), []).append(k)
        if len(groups) > 1:
            buckets = sorted(groups.values(), key=len, reverse=True)
            out: list[Expr] = []
            i = 0
            while len(out) < len(kids):
                for b in buckets:
                    if i < len(b):
                        out.append(b[i])
                i += 1
            kids = tuple(out)
    return Node(e.op, kids)


_EXPAND_CAP = 20  # largest C(N, k) worth trial-compiling as a chain


def _has_threshold(e: Expr) -> bool:
    if isinstance(e, Page):
        return False
    if isinstance(e, Threshold):
        return True
    return any(_has_threshold(c) for c in e.children)


def _expand_thresholds(e: Expr) -> Expr | None:
    """Boolean-chain form of a threshold expression (the And/Or dual).

    ``Threshold(k, kids)`` is equivalent to ``OR over all C(N, k)``
    ``k``-subsets of ``AND(subset)`` — the form a device without dynamic
    sensing thresholds must execute.  An AND node with one Threshold child
    distributes its other factors INTO the expanded OR (the planner then
    inlines each AND-combination into the C-latch chain instead of
    spilling the big OR), which is exactly how ``pred AND valid-page``
    roots lower.  Returns None when any expansion exceeds
    :data:`_EXPAND_CAP` combinations — at that size the chain can never
    beat one threshold sensing, so the candidate is not worth compiling.
    """
    if isinstance(e, Page):
        return e
    if isinstance(e, Node) and e.op is BitOp.AND:
        thr = [c for c in e.children if isinstance(c, Threshold)]
        if len(thr) == 1:
            others = [
                _expand_thresholds(c)
                for c in e.children
                if not isinstance(c, Threshold)
            ]
            if any(o is None for o in others):
                return None
            t = thr[0]
            tkids = [_expand_thresholds(c) for c in t.children]
            if any(x is None for x in tkids):
                return None
            if math.comb(len(tkids), t.k) > _EXPAND_CAP:
                return None
            return or_(
                *(
                    and_(*combo, *others)
                    for combo in combinations(tkids, t.k)
                )
            )
    kids = []
    for c in e.children:
        x = _expand_thresholds(c)
        if x is None:
            return None
        kids.append(x)
    if isinstance(e, Threshold):
        if math.comb(len(kids), e.k) > _EXPAND_CAP:
            return None
        return or_(*(and_(*combo) for combo in combinations(kids, e.k)))
    return Node(e.op, tuple(kids))


def best_plan(
    expr: Expr, layout, ssd: SSDConfig = DEFAULT_SSD
) -> tuple[CommandPlan, Expr, float]:
    """Compile candidate orderings of ``expr``; keep the cheapest plan.

    Returns ``(plan, expr_of_plan, cost_us)``.  Trial compiles run under
    layout snapshots, so spill-scratch allocations of losing candidates
    never leak; the layout is left in the winning candidate's state.

    Threshold expressions compile BOTH forms — the native k-of-N sensing
    and the equivalent And/Or combination chain — and keep whichever the
    timing model prices lower: for small C(N, k) a couple of ordinary
    sensings undercut the staircase threshold sense, while for wide fuzzy
    matches the single threshold sensing wins by an order of magnitude.
    """
    cands = [expr]
    alt = reorder_expr(expr, layout)
    if alt != expr:
        cands.append(alt)
    if _has_threshold(expr):
        chain = _expand_thresholds(expr)
        if chain is not None and chain != expr:
            cands.append(chain)
    base = layout.snapshot()
    best = None
    for cand in cands:
        plan = Planner(layout).compile(cand)
        cost = plan_cost_us(plan, ssd)
        state = layout.snapshot()
        layout.restore(base)
        if best is None or cost < best[2]:
            best = (plan, cand, cost, state)
    plan, cand, cost, state = best
    layout.restore(state)
    return plan, cand, cost


# -- cross-query common-subexpression elimination ----------------------------


@dataclass(frozen=True)
class CseResult:
    """One flush's CSE rewrite: deduplicated members + shared subplans.

    ``member_execs[i]`` is member *i*'s exec — duplicates point at their
    representative's object, and :func:`repro.query.compile.compile_flush`
    (given ``dedup_keys``) senses each distinct plan once, fanning the row
    out through the member gather.  ``member_plans`` / ``uix`` describe
    the unique members (for traffic accounting: the physical work is one
    plan per *unique* member plus the shared plans, not one per query).
    """

    member_execs: tuple
    member_plans: tuple  # per UNIQUE member, in uix order
    dedup_keys: tuple  # per member: whole-plan dedup key (plan-cache key)
    uix: tuple  # unique member indices into the flush
    shared_execs: tuple = ()
    shared_plans: tuple = ()
    shared_blocks: tuple = ()  # scratch blocks worn per flush execution
    n_rewritten: int = 0

    @property
    def n_members(self) -> int:
        return len(self.dedup_keys)

    @property
    def n_unique(self) -> int:
        return len(self.uix)

    @property
    def n_dedup_hits(self) -> int:
        return self.n_members - self.n_unique


def cse_flush(
    compiled: list,
    compiler,
    device,
    *,
    ssd: SSDConfig = DEFAULT_SSD,
    subexpr: bool = True,
    max_shared: int = 8,
) -> CseResult:
    """Plan one flush's cross-query sharing.

    ``compiled`` are the flush members' :class:`CompiledQuery` objects (in
    member order), ``compiler`` the owning ``QueryCompiler`` and
    ``device`` its ``FlashDevice``.  Whole-plan deduplication always
    applies; with ``subexpr``, predicate subtrees shared by >= 2 distinct
    member plans additionally become shared plans — sensed once, spilled
    to a scratch page, spliced into each referencing member — when the
    timing model prices the rewritten flush below the original.
    """
    from repro.query.compile import _lower, lower_shared

    keys = [cq.key for cq in compiled]
    pos: dict = {}
    uix: list[int] = []
    urep: list[int] = []
    for i, k in enumerate(keys):
        j = pos.get(k)
        if j is None:
            j = pos[k] = len(uix)
            uix.append(i)
        urep.append(j)

    def plain() -> CseResult:
        uexecs = [compiler.exec_for(compiled[i]) for i in uix]
        return CseResult(
            member_execs=tuple(uexecs[j] for j in urep),
            member_plans=tuple(compiled[i].plan for i in uix),
            dedup_keys=tuple(keys),
            uix=tuple(uix),
        )

    if not subexpr or len(uix) < 2:
        return plain()

    # candidate shared subtrees: composite predicates appearing in >= 2
    # DISTINCT unique members (identical whole predicates already dedupe,
    # and a bare Eq is one wordline — nothing to share)
    occurs: dict[tuple, set[int]] = {}
    trees: dict[tuple, Pred] = {}
    for u, i in enumerate(uix):
        canon = getattr(compiled[i], "canon", None)
        if canon is None:
            continue
        for sub in iter_subtrees(canon):
            if isinstance(sub, Eq):
                continue
            k = pred_key(sub)
            occurs.setdefault(k, set()).add(u)
            trees.setdefault(k, sub)
    cands = [k for k, s in occurs.items() if len(s) >= 2]
    if not cands:
        return plain()
    # larger subtrees first: the top-down rewrite then subsumes any nested
    # candidate inside a member that shares the outer one
    cands.sort(key=lambda k: (-pred_size(trees[k]), k))

    store = compiler.store
    layout = device.layout
    accepted: dict[tuple, str] = {}
    shared_ord: dict[str, int] = {}
    shared_plans: list[CommandPlan] = []
    shared_blocks: list[int] = []
    for k in cands:
        if len(accepted) >= max_shared:
            break
        expr_s = _lower(trees[k], store)
        if isinstance(expr_s, Page):
            continue  # constant-folded / single page: nothing to share
        snap = layout.snapshot()
        plan_s = Planner(layout).compile(expr_s)
        if plan_s.num_sensing_ops < 2 and plan_s.num_spills == 0:
            layout.restore(snap)  # one sensing already: sharing can't win
            continue
        # the shared result is ESP-programmed to a real scratch page the
        # members re-sense (the fused program splices the latch value, but
        # the cost/wear model charges the physical round-trip)
        name, block, wl = layout.alloc_scratch()
        layout.place(name, block, wl, inverted=False)
        shared_ord[name] = len(accepted)
        accepted[k] = name
        shared_plans.append(plan_s)
        shared_blocks.append(block)
    if not accepted:
        return plain()

    uexecs: list = []
    uplans: list[CommandPlan] = []
    before = 0.0
    after = len(shared_plans) * ssd.t_esp_us  # one scratch program each
    n_rewritten = 0
    for u, i in enumerate(uix):
        cq = compiled[i]
        before += plan_cost_us(cq.plan, ssd)
        canon = getattr(cq, "canon", None)
        used: set[str] = set()
        if canon is not None:
            expr_r = lower_shared(canon, store, accepted, used)
        if not used:
            uplans.append(cq.plan)
            uexecs.append(compiler.exec_for(cq))
            after += plan_cost_us(cq.plan, ssd)
            continue
        plan_r, _, cost_r = best_plan(expr_r, layout, ssd)
        uplans.append(plan_r)
        uexecs.append(device.build_exec(plan_r, shared=shared_ord))
        after += cost_r
        n_rewritten += 1
    after += sum(plan_cost_us(p, ssd) for p in shared_plans)
    if n_rewritten == 0 or after >= before:
        return plain()
    shared_execs = tuple(device.build_exec(p) for p in shared_plans)
    return CseResult(
        member_execs=tuple(uexecs[j] for j in urep),
        member_plans=tuple(uplans),
        dedup_keys=tuple(keys),
        uix=tuple(uix),
        shared_execs=shared_execs,
        shared_plans=tuple(shared_plans),
        shared_blocks=tuple(shared_blocks),
        n_rewritten=n_rewritten,
    )
