"""Dense decoder-only transformer (starcoder2 / yi / granite / command-r).

Layers are parameter-stacked and driven by ``lax.scan`` (fast compiles for
60+ layer configs) with optional per-layer remat.  The same block is reused
by the VLM backbone (patch embeddings prepended) and — with window masks —
by the hybrid model's attention layers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, stacked
from repro.models.config import ArchConfig
from repro.models.layers import (
    FSDP,
    TP,
    attention_fwd,
    embed_fwd,
    init_attention,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    layernorm_fwd,
    init_layernorm,
    mlp_fwd,
    rmsnorm_fwd,
    unembed_fwd,
)


def _init_norm(cfg, d, dtype):
    return (
        init_rmsnorm(d, dtype)
        if cfg.norm == "rmsnorm"
        else init_layernorm(d, dtype)
    )


def _norm_fwd(cfg, p, x):
    return rmsnorm_fwd(p, x) if cfg.norm == "rmsnorm" else layernorm_fwd(p, x)


def init_layer(cfg: ArchConfig, key):
    k1, k2 = jax.random.split(key)
    attn_p, attn_s = init_attention(
        k1,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.resolved_head_dim,
        cfg.pdtype,
        bias=cfg.attn_bias,
    )
    mlp_p, mlp_s = init_mlp(
        k2,
        cfg.d_model,
        cfg.d_ff,
        cfg.pdtype,
        gated=(cfg.activation == "silu"),
        bias=cfg.mlp_bias,
    )
    n1_p, n1_s = _init_norm(cfg, cfg.d_model, cfg.pdtype)
    n2_p, n2_s = _init_norm(cfg, cfg.d_model, cfg.pdtype)
    return (
        {"attn": attn_p, "mlp": mlp_p, "norm1": n1_p, "norm2": n2_p},
        {"attn": attn_s, "mlp": mlp_s, "norm1": n1_s, "norm2": n2_s},
    )


def layer_fwd(
    cfg: ArchConfig, lp, x, *, kv_cache=None, cache_offset=None, window=None
):
    h = _norm_fwd(cfg, lp["norm1"], x)
    h = constrain(h, "data", None, None)
    attn_out, new_cache = attention_fwd(
        lp["attn"],
        h,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        rope_theta=cfg.rope_theta,
        use_rope=cfg.use_rope,
        window=window,
        kv_cache=kv_cache,
        cache_offset=cache_offset,
        impl=cfg.attention_impl,
    )
    x = x + attn_out
    h = _norm_fwd(cfg, lp["norm2"], x)
    x = x + mlp_fwd(lp["mlp"], h, cfg.activation)
    x = constrain(x, "data", None, None)
    return x, new_cache


def init_params(cfg: ArchConfig, key):
    keys = jax.random.split(key, cfg.n_layers + 1)
    emb_p, emb_s = init_embedding(keys[0], cfg.vocab, cfg.d_model, cfg.pdtype)
    layer_keys = jnp.stack(list(keys[1:]))
    stacked_layers = jax.vmap(lambda k: init_layer(cfg, k)[0])(layer_keys)
    _, layer_spec = init_layer(cfg, keys[1])
    fn_p, fn_s = _init_norm(cfg, cfg.d_model, cfg.pdtype)
    params = {"embed": emb_p, "layers": stacked_layers, "final_norm": fn_p}
    specs = {
        "embed": emb_s,
        "layers": stacked(layer_spec),
        "final_norm": fn_s,
    }
    return params, specs


def _scan_layers(cfg: ArchConfig, step_fn, x, stacked_params, *extra_xs):
    if cfg.remat:
        step_fn = jax.checkpoint(
            step_fn, policy=jax.checkpoint_policies.nothing_saveable
        )
    if cfg.scan_layers:
        return jax.lax.scan(step_fn, x, (stacked_params, *extra_xs))
    carry, ys = x, []
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], stacked_params)
        ex = tuple(jax.tree.map(lambda a: a[i], e) for e in extra_xs)
        carry, y = step_fn(carry, (sl, *ex))
        ys.append(y)
    ys = (
        None
        if all(y is None for y in ys)
        else jax.tree.map(lambda *a: jnp.stack(a), *ys)
    )
    return carry, ys


def forward(cfg: ArchConfig, params, tokens, patch_embeds=None):
    """Training/prefill forward: tokens (B, S) -> logits (B, S', vocab).

    ``patch_embeds`` (VLM stub): (B, N_patch, d) embeddings prepended to the
    token embeddings; logits returned only for the token positions.
    """
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)
    n_patch = 0
    if patch_embeds is not None:
        n_patch = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(cfg.cdtype), x], axis=1)
    x = constrain(x, "data", None, None)

    def step(h, xs):
        (lp,) = xs
        h, _ = layer_fwd(cfg, lp, h)
        return h, None

    x, _ = _scan_layers(cfg, step, x, params["layers"])
    x = _norm_fwd(cfg, params["final_norm"], x)
    logits = unembed_fwd(params["embed"], x)
    if n_patch:
        logits = logits[:, n_patch:]
    return constrain(logits, "data", None, "model")


# ---------------------------------------------------------------------------
# Serving: KV cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    cache = {
        "k": jnp.zeros(shape, cfg.cdtype),
        "v": jnp.zeros(shape, cfg.cdtype),
    }
    spec = {
        "k": P(None, "data", None, "model", None),
        "v": P(None, "data", None, "model", None),
    }
    return cache, spec


def decode_step(cfg: ArchConfig, params, cache, tokens, offset):
    """One decode step: tokens (B, 1) + cache at ``offset`` -> logits, cache."""
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)

    def step(h, xs):
        lp, ck, cv = xs
        h, new_kv = layer_fwd(
            cfg, lp, h, kv_cache=(ck, cv), cache_offset=offset
        )
        return h, new_kv

    x, new_kv = _scan_layers(
        cfg, step, x, params["layers"], cache["k"], cache["v"]
    )
    new_cache = {"k": new_kv[0], "v": new_kv[1]}
    x = _norm_fwd(cfg, params["final_norm"], x)
    logits = unembed_fwd(params["embed"], x)
    return constrain(logits, "data", None, "model"), new_cache


def prefill(cfg: ArchConfig, params, tokens, max_len, patch_embeds=None):
    """Prefill: run the full prompt, building the cache; returns logits of
    the last position + filled cache.  VLM: patch embeddings occupy the
    first ``num_patch_tokens`` cache slots — decode offsets are absolute
    cache positions (n_patch + tokens seen)."""
    B, S = tokens.shape
    cache, _ = init_kv_cache(cfg, B, max_len)
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(cfg.cdtype), x], axis=1)

    def step(h, xs):
        lp, ck, cv = xs
        h, new_kv = layer_fwd(cfg, lp, h, kv_cache=(ck, cv), cache_offset=0)
        return h, new_kv

    x, new_kv = _scan_layers(
        cfg, step, x, params["layers"], cache["k"], cache["v"]
    )
    x = _norm_fwd(cfg, params["final_norm"], x[:, -1:, :])
    logits = unembed_fwd(params["embed"], x)
    return logits, {"k": new_kv[0], "v": new_kv[1]}
