"""xLSTM (sLSTM + mLSTM) blocks — the [ssm] architecture (xlstm-350m).

Layers alternate mLSTM / sLSTM blocks (scanned as pairs).  d_ff = 0 per the
assigned table: blocks carry only their internal projections, no extra MLP.

* mLSTM: matrix memory C ∈ R^{hd×hd} per head with exponential input gating
  and a log-space stabilizer.  Training/prefill run the **chunkwise-parallel
  form** (MXU-friendly: intra-chunk attention-like einsums + inter-chunk
  recurrent state), decode runs the O(1) recurrent step.  The step-recurrent
  form is the test oracle for the chunkwise math.
* sLSTM: scalar memory with head-block-diagonal recurrent mixing — inherently
  sequential, implemented with ``lax.scan`` over time; O(1) decode step.

`long_500k` runs on this family: decode state is O(1) in sequence length.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, stacked
from repro.models.config import ArchConfig
from repro.models.layers import (
    FSDP,
    TP,
    _init_dense,
    embed_fwd,
    init_embedding,
    init_rmsnorm,
    rmsnorm_fwd,
    unembed_fwd,
)

CHUNK = 128  # chunkwise-parallel block length


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------


def init_mlstm_block(key, cfg: ArchConfig):
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 8)
    p = {
        "w_up": _init_dense(ks[0], d, 2 * d, cfg.pdtype),
        "w_q": _init_dense(ks[1], d, d, cfg.pdtype),
        "w_k": _init_dense(ks[2], d, d, cfg.pdtype),
        "w_v": _init_dense(ks[3], d, d, cfg.pdtype),
        "w_i": _init_dense(ks[4], d, H, cfg.pdtype, scale=0.01),
        "w_f": _init_dense(ks[5], d, H, cfg.pdtype, scale=0.01),
        "b_i": jnp.zeros((H,), cfg.pdtype),
        "b_f": jnp.full((H,), 3.0, cfg.pdtype),  # open forget gates at init
        "w_down": _init_dense(ks[6], d, d, cfg.pdtype),
        "norm": jnp.ones((d,), cfg.pdtype),
    }
    s = {
        "w_up": P(FSDP, TP),
        "w_q": P(FSDP, TP),
        "w_k": P(FSDP, TP),
        "w_v": P(FSDP, TP),
        "w_i": P(FSDP, None),
        "w_f": P(FSDP, None),
        "b_i": P(None),
        "b_f": P(None),
        "w_down": P(TP, FSDP),
        "norm": P(None),
    }
    return p, s


def _mlstm_gates(p, xin, H):
    """log input gate (pre-stabilizer) and log forget gate, (B,S,H) f32."""
    i_pre = jnp.einsum("bsd,dh->bsh", xin, p["w_i"].astype(xin.dtype)) + p[
        "b_i"
    ].astype(xin.dtype)
    f_pre = jnp.einsum("bsd,dh->bsh", xin, p["w_f"].astype(xin.dtype)) + p[
        "b_f"
    ].astype(xin.dtype)
    log_i = i_pre.astype(jnp.float32)  # exponential input gate: log i = ĩ
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    return log_i, log_f


def _mlstm_qkv(p, xin, H):
    B, S, d = xin.shape
    hd = d // H
    q = jnp.einsum("bsd,de->bse", xin, p["w_q"].astype(xin.dtype))
    k = jnp.einsum("bsd,de->bse", xin, p["w_k"].astype(xin.dtype))
    v = jnp.einsum("bsd,de->bse", xin, p["w_v"].astype(xin.dtype))
    shp = (B, S, H, hd)
    return (
        q.reshape(shp).astype(jnp.float32),
        k.reshape(shp).astype(jnp.float32) / math.sqrt(hd),
        v.reshape(shp).astype(jnp.float32),
    )


def mlstm_recurrent_step(q, k, v, log_i, log_f, state):
    """One-token recurrent update. q/k/v: (B,H,hd); gates (B,H); state
    (C,n,m) with C:(B,H,hd,hd), n:(B,H,hd), m:(B,H). Returns (h, state)."""
    C, n, m = state
    m_new = jnp.maximum(log_f + m, log_i)
    a = jnp.exp(log_f + m - m_new)[..., None]
    b = jnp.exp(log_i - m_new)[..., None]
    C = a[..., None] * C + (b * k)[..., None] * v[..., None, :]
    n = a * n + b * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new)
    )[..., None]
    return num / den, (C, n, m_new)


def mlstm_recurrent(q, k, v, log_i, log_f, state):
    """Oracle: scan the one-step recurrence over time. q: (B,S,H,hd)."""

    def step(st, xs):
        qt, kt, vt, lit, lft = xs
        h, st = mlstm_recurrent_step(qt, kt, vt, lit, lft, st)
        return st, h

    xs = jax.tree.map(
        lambda a: jnp.moveaxis(a, 1, 0), (q, k, v, log_i, log_f)
    )
    state, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1), state


def mlstm_chunkwise(q, k, v, log_i, log_f, state):
    """Chunkwise-parallel mLSTM (training/prefill path).

    Splits S into chunks of ``CHUNK``; scan carries (C, n, m) across chunks;
    intra-chunk work is parallel einsums.  Matches ``mlstm_recurrent``.
    """
    B, S, H, hd = q.shape
    L = CHUNK
    assert S % L == 0, (S, L)
    nc = S // L

    def chunk(a):
        return jnp.moveaxis(
            a.reshape(B, nc, L, *a.shape[2:]), 1, 0
        )  # (nc, B, L, ...)

    qc, kc, vc, lic, lfc = map(chunk, (q, k, v, log_i, log_f))

    def step(st, xs):
        C, n, m = st
        qt, kt, vt, li, lf = xs  # (B, L, H, ...)
        b = jnp.cumsum(lf, axis=1)  # (B,L,H) cumulative log-forget
        # running stabilizer: m_t = max(m_prev + b_t, max_{s<=t}(b_t - b_s + li_s))
        m_t = jnp.maximum(m[:, None] + b, b + jax.lax.cummax(li - b, axis=1))
        # inter-chunk term
        inter_scale = jnp.exp(m[:, None] + b - m_t)  # (B,L,H)
        num_inter = jnp.einsum("blhd,bhde->blhe", qt, C) * inter_scale[..., None]
        den_inter = jnp.einsum("blhd,bhd->blh", qt, n) * inter_scale
        # intra-chunk term: weight(t,s) = exp(b_t - b_s + li_s - m_t), s<=t
        w = b[:, :, None, :] - b[:, None, :, :] + li[:, None, :, :] - m_t[
            :, :, None, :
        ]  # (B,L,L,H)
        causal = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(causal[None, :, :, None], jnp.exp(w), 0.0)
        scores = jnp.einsum("blhd,bshd->blsh", qt, kt) * w
        num_intra = jnp.einsum("blsh,bshe->blhe", scores, vt)
        den_intra = jnp.sum(scores, axis=2)  # (B,L,H)
        den = jnp.maximum(
            jnp.abs(den_inter + den_intra), jnp.exp(-m_t)
        )
        h = (num_inter + num_intra) / den[..., None]
        # state to next chunk
        bL = b[:, -1]  # (B,H)
        m_new = jnp.maximum(m + bL, jnp.max(li - b + bL[:, None], axis=1))
        carry_scale = jnp.exp(m + bL - m_new)  # (B,H)
        kv_w = jnp.exp(bL[:, None] - b + li - m_new[:, None])  # (B,L,H)
        C_new = carry_scale[..., None, None] * C + jnp.einsum(
            "blhd,blhe,blh->bhde", kt, vt, kv_w
        )
        n_new = carry_scale[..., None] * n + jnp.einsum(
            "blhd,blh->bhd", kt, kv_w
        )
        return (C_new, n_new, m_new), h

    state, hs = jax.lax.scan(step, state, (qc, kc, vc, lic, lfc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)
    return h, state


def init_mlstm_state(cfg, batch):
    H = cfg.n_heads
    hd = cfg.d_model // H
    return (
        jnp.zeros((batch, H, hd, hd), jnp.float32),
        jnp.zeros((batch, H, hd), jnp.float32),
        jnp.full((batch, H), -1e30, jnp.float32),
    )


def mlstm_block_fwd(p, x, cfg, state, *, step_mode=False):
    cdt = x.dtype
    h = rmsnorm_fwd({"scale": p["norm"]}, x)
    up = jnp.einsum("bsd,de->bse", h, p["w_up"].astype(cdt))
    xin, gate = jnp.split(up, 2, axis=-1)
    H = cfg.n_heads
    q, k, v = _mlstm_qkv(p, xin, H)
    log_i, log_f = _mlstm_gates(p, xin, H)
    if step_mode:
        out, state = mlstm_recurrent_step(
            q[:, 0], k[:, 0], v[:, 0], log_i[:, 0], log_f[:, 0], state
        )
        out = out[:, None]
    else:
        out, state = mlstm_chunkwise(q, k, v, log_i, log_f, state)
    B, S = x.shape[:2]
    out = out.reshape(B, S, -1).astype(cdt) * jax.nn.silu(gate)
    out = jnp.einsum("bse,ed->bsd", out, p["w_down"].astype(cdt))
    return x + out, state


# ---------------------------------------------------------------------------
# sLSTM cell
# ---------------------------------------------------------------------------


def init_slstm_block(key, cfg: ArchConfig):
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 6)
    p = {
        "w_x": _init_dense(ks[0], d, 4 * d, cfg.pdtype),  # i,f,z,o pre-acts
        "r": (jax.random.normal(ks[1], (H, hd, 4 * hd)) / math.sqrt(hd)).astype(
            cfg.pdtype
        ),  # block-diagonal recurrent mixing
        "b": jnp.concatenate(
            [
                jnp.zeros((d,)),
                jnp.full((d,), 3.0),
                jnp.zeros((2 * d,)),
            ]
        ).astype(cfg.pdtype),
        "w_down": _init_dense(ks[2], d, d, cfg.pdtype),
        "norm": jnp.ones((d,), cfg.pdtype),
    }
    s = {
        "w_x": P(FSDP, TP),
        "r": P(TP, None, None),
        "b": P(None),
        "w_down": P(TP, FSDP),
        "norm": P(None),
    }
    return p, s


def init_slstm_state(cfg, batch):
    H = cfg.n_heads
    hd = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return (z(), z(), jnp.full((batch, H, hd), -1e30, jnp.float32), z())


def slstm_step(pre_x, r, b, state, H, hd):
    """pre_x: (B, 4d) token pre-activations; state (c,n,m,h)."""
    c, n, m, h = state
    B = pre_x.shape[0]
    rec = jnp.einsum("bhd,hde->bhe", h, r.astype(jnp.float32))  # (B,H,4hd)
    pre = pre_x.reshape(B, 4, H, hd).astype(jnp.float32) + jnp.moveaxis(
        rec.reshape(B, H, 4, hd), 2, 1
    ) + b.reshape(4, H, hd)[None]
    i_p, f_p, z_p, o_p = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    log_i = i_p
    log_f = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(log_f + m, log_i)
    a = jnp.exp(log_f + m - m_new)
    bb = jnp.exp(log_i - m_new)
    c = a * c + bb * jnp.tanh(z_p)
    n = a * n + bb
    h_new = jax.nn.sigmoid(o_p) * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h_new)


def slstm_block_fwd(p, x, cfg, state, *, step_mode=False):
    cdt = x.dtype
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    hn = rmsnorm_fwd({"scale": p["norm"]}, x)
    pre = jnp.einsum("bsd,de->bse", hn, p["w_x"].astype(cdt))  # (B,S,4d)

    if step_mode:
        state = slstm_step(pre[:, 0], p["r"], p["b"], state, H, hd)
        hs = state[3][:, None]  # (B,1,H,hd)
    else:

        def step(st, pre_t):
            st = slstm_step(pre_t, p["r"], p["b"], st, H, hd)
            return st, st[3]

        state, hs = jax.lax.scan(step, state, jnp.moveaxis(pre, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1)  # (B,S,H,hd)

    out = hs.reshape(B, -1, d).astype(cdt)
    out = jnp.einsum("bse,ed->bsd", out, p["w_down"].astype(cdt))
    return x + out, state


# ---------------------------------------------------------------------------
# Full model: alternating (mLSTM, sLSTM) pairs, scanned
# ---------------------------------------------------------------------------


def init_pair(cfg, key):
    k1, k2 = jax.random.split(key)
    mp, ms = init_mlstm_block(k1, cfg)
    sp, ss = init_slstm_block(k2, cfg)
    return {"m": mp, "s": sp}, {"m": ms, "s": ss}


def init_params(cfg: ArchConfig, key):
    assert cfg.n_layers % 2 == 0, "xLSTM config uses (mLSTM, sLSTM) pairs"
    n_pairs = cfg.n_layers // 2
    keys = jax.random.split(key, n_pairs + 1)
    emb_p, emb_s = init_embedding(keys[0], cfg.vocab, cfg.d_model, cfg.pdtype)
    pairs = jax.vmap(lambda k: init_pair(cfg, k)[0])(keys[1:])
    _, pair_spec = init_pair(cfg, keys[1])
    fn_p, fn_s = init_rmsnorm(cfg.d_model, cfg.pdtype)
    return (
        {"embed": emb_p, "pairs": pairs, "final_norm": fn_p},
        {"embed": emb_s, "pairs": stacked(pair_spec), "final_norm": fn_s},
    )


def init_state(cfg: ArchConfig, batch: int):
    n_pairs = cfg.n_layers // 2
    rep = lambda t: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_pairs, *a.shape)), t
    )
    state = {
        "m": rep(init_mlstm_state(cfg, batch)),
        "s": rep(init_slstm_state(cfg, batch)),
    }
    spec = jax.tree.map(lambda _: P(None, "data"), state)
    return state, spec


def _run(cfg, params, x, state, step_mode):
    def pair_step(h, xs):
        lp, mst, sst = xs
        h, mst = mlstm_block_fwd(lp["m"], h, cfg, mst, step_mode=step_mode)
        h, sst = slstm_block_fwd(lp["s"], h, cfg, sst, step_mode=step_mode)
        return h, (mst, sst)

    if cfg.remat and not step_mode:
        pair_step = jax.checkpoint(
            pair_step, policy=jax.checkpoint_policies.nothing_saveable
        )
    if cfg.scan_layers:
        x, (mst, sst) = jax.lax.scan(
            pair_step, x, (params["pairs"], state["m"], state["s"])
        )
    else:
        n = jax.tree.leaves(params["pairs"])[0].shape[0]
        msts, ssts = [], []
        for i in range(n):
            sl = jax.tree.map(lambda a: a[i], params["pairs"])
            mst_i = jax.tree.map(lambda a: a[i], state["m"])
            sst_i = jax.tree.map(lambda a: a[i], state["s"])
            x, (mst_i, sst_i) = pair_step(x, (sl, mst_i, sst_i))
            msts.append(mst_i)
            ssts.append(sst_i)
        mst = jax.tree.map(lambda *a: jnp.stack(a), *msts)
        sst = jax.tree.map(lambda *a: jnp.stack(a), *ssts)
    return x, {"m": mst, "s": sst}


def forward(cfg: ArchConfig, params, tokens):
    B, S = tokens.shape
    pad = (-S) % CHUNK
    if pad:
        tokens = jnp.pad(tokens, ((0, 0), (0, pad)))
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)
    x = constrain(x, "data", None, None)
    state, _ = init_state(cfg, B)
    x, _ = _run(cfg, params, x, state, step_mode=False)
    x = rmsnorm_fwd(params["final_norm"], x[:, :S])
    return constrain(unembed_fwd(params["embed"], x), "data", None, "model")


def prefill(cfg: ArchConfig, params, tokens, max_len=None):
    B, S = tokens.shape
    pad = (-S) % CHUNK
    ptoks = jnp.pad(tokens, ((0, 0), (0, pad))) if pad else tokens
    x = embed_fwd(params["embed"], ptoks, cfg.cdtype)
    state, _ = init_state(cfg, B)
    if pad:
        # run the aligned prefix chunkwise, the ragged tail step-by-step
        # (exactness beats elegance here; pad tokens would corrupt state)
        aligned = S - (S % CHUNK)
        if aligned:
            xa, state = _run(
                cfg, params, x[:, :aligned], state, step_mode=False
            )
        outs = [xa[:, -1:]] if aligned else []
        for t in range(aligned, S):
            xt, state = _run(cfg, params, x[:, t : t + 1], state, True)
            outs.append(xt)
        x_last = outs[-1]
    else:
        xf, state = _run(cfg, params, x, state, step_mode=False)
        x_last = xf[:, -1:]
    x_last = rmsnorm_fwd(params["final_norm"], x_last)
    logits = unembed_fwd(params["embed"], x_last)
    return logits, state


def decode_step(cfg: ArchConfig, params, state, tokens, offset=None):
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)
    x, state = _run(cfg, params, x, state, step_mode=True)
    x = rmsnorm_fwd(params["final_norm"], x)
    return unembed_fwd(params["embed"], x), state


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Recurrent state plays the role of the KV cache (O(1) in max_len)."""
    return init_state(cfg, batch)
