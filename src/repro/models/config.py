"""Architecture configuration schema for all assigned model families."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_ff_expert: int = 0  # per-expert FFN hidden
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # DeepSeek: first layer(s) dense
    d_ff_dense: int = 0  # hidden of those dense layers


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style block pattern."""

    pattern: tuple[str, ...] = ("recurrent", "recurrent", "attention")
    window: int = 2048
    conv_width: int = 4
    lru_dim: int = 0  # defaults to d_model


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int | None = None
    rope_theta: float = 10_000.0
    attn_bias: bool = False
    mlp_bias: bool = False
    activation: str = "silu"
    norm: str = "rmsnorm"  # or "layernorm"
    use_rope: bool = True
    tie_embeddings: bool = True

    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    hybrid: HybridConfig | None = None

    # audio/vlm stub frontends
    encoder_layers: int = 0  # whisper: separate encoder stack
    num_patch_tokens: int = 0  # internvl: prepended image-patch embeddings

    # attention implementation: "naive" materializes (Sq,Skv) scores (the
    # recorded baseline); "blockwise" = online-softmax over KV blocks (§Perf
    # optimization); "auto" picks blockwise for kv_len >= 4096.
    attention_impl: str = "naive"

    # MoE dispatch: "scatter" = f32 scatter-add into the (B,E,C,d) buffer
    # (baseline; GSPMD all-reduces the full buffer across the EP axis);
    # "gather" = int32 slot-index scatter + local token gather (§Perf fix).
    moe_dispatch: str = "scatter"

    # numerics / memory policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"  # adam moments (+master when f32)
    remat: bool = True
    scan_layers: bool = True

    # serving
    supports_decode: bool = True
    subquadratic: bool = False  # may run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 2 if not self.hybrid else 3),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16 if self.head_dim else None,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            scan_layers=self.scan_layers,
            encoder_layers=2 if self.encoder_layers else 0,
            num_patch_tokens=4 if self.num_patch_tokens else 0,
        )
        if self.moe:
            kw["moe"] = MoEConfig(
                num_experts=4,
                top_k=2,
                num_shared=min(self.moe.num_shared, 1),
                d_ff_expert=32,
                capacity_factor=2.0,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                d_ff_dense=64,
            )
        if self.mla:
            kw["mla"] = MLAConfig(
                kv_lora_rank=32, rope_head_dim=8, nope_head_dim=16, v_head_dim=16
            )
        if self.hybrid:
            kw["hybrid"] = HybridConfig(
                pattern=self.hybrid.pattern, window=16, conv_width=4
            )
        return self.with_(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
