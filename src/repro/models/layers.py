"""Shared model layers: norms, embeddings, RoPE, GQA attention, MLPs.

Functional style: ``init_*`` builds a param pytree (+ a parallel PartitionSpec
pytree via ``repro.distributed.sharding`` rules), ``*_fwd`` applies it.  All
matmul-bearing layers take a ``compute_dtype`` so big configs run bf16 on the
MXU while tests run f32.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Params = dict[str, Any]

# Logical sharding axes; resolved against the active mesh by
# repro.distributed.sharding.  "fsdp" = ("pod","data") when present.
FSDP = "fsdp"
TP = "model"


def _init_dense(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": P(None)}


def rmsnorm_fwd(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d, dtype=jnp.float32):
    return (
        {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        {"scale": P(None), "bias": P(None)},
    )


def layernorm_fwd(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (
        x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    ).astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding  (vocab sharded on TP axis)
# ---------------------------------------------------------------------------


def init_embedding(key, vocab, d, dtype=jnp.float32):
    emb = (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)
    return {"embedding": emb}, {"embedding": P(TP, FSDP)}


def embed_fwd(p, tokens, compute_dtype):
    return jnp.take(p["embedding"], tokens, axis=0).astype(compute_dtype)


def unembed_fwd(p, x):
    # logits in f32 for loss stability
    return jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32), p["embedding"].astype(jnp.float32)
    )


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10_000.0):
    return 1.0 / (
        theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim)
    )


def apply_rope(x, positions, theta=10_000.0):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    inv = jnp.asarray(rope_frequencies(hd, theta))  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate(
        [
            x1 * cos - x2 * sin,
            x2 * cos + x1 * sin,
        ],
        axis=-1,
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (full / causal / sliding-window; train + cached decode)
# ---------------------------------------------------------------------------


def init_attention(
    key,
    d_model,
    n_heads,
    n_kv_heads,
    head_dim=None,
    dtype=jnp.float32,
    bias=False,
):
    head_dim = head_dim or d_model // n_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _init_dense(k1, d_model, n_heads * head_dim, dtype),
        "wk": _init_dense(k2, d_model, n_kv_heads * head_dim, dtype),
        "wv": _init_dense(k3, d_model, n_kv_heads * head_dim, dtype),
        "wo": _init_dense(k4, n_heads * head_dim, d_model, dtype),
    }
    s = {
        "wq": P(FSDP, TP),
        "wk": P(FSDP, TP),
        "wv": P(FSDP, TP),
        "wo": P(TP, FSDP),
    }
    if bias:
        p |= {
            "bq": jnp.zeros((n_heads * head_dim,), dtype),
            "bk": jnp.zeros((n_kv_heads * head_dim,), dtype),
            "bv": jnp.zeros((n_kv_heads * head_dim,), dtype),
            "bo": jnp.zeros((d_model,), dtype),
        }
        s |= {"bq": P(TP), "bk": P(TP), "bv": P(TP), "bo": P(None)}
    return p, s


def _mask_bias(q_len, kv_len, offset, window, dtype):
    """Causal (+ optional sliding-window) additive mask bias."""
    q_pos = jnp.arange(q_len)[:, None] + offset
    kv_pos = jnp.arange(kv_len)[None, :]
    ok = kv_pos <= q_pos
    if window is not None:
        ok &= kv_pos > q_pos - window
    return jnp.where(ok, 0.0, jnp.finfo(dtype).min).astype(dtype)


BLOCKWISE_KV_BLOCK = 1024
BLOCKWISE_MIN_KV = 4096  # use the online-softmax path above this kv length


def _blockwise_attention(qg, k, v, *, offset, window, causal, scale):
    """Flash-attention-style online softmax over KV blocks.

    Never materializes the (S_q, S_kv) score matrix — the §Perf fix for the
    memory-roofline blowup of long-context prefill (hypothesis H1 in
    EXPERIMENTS.md).  qg: (B, Sq, n_kv, group, hd); k/v: (B, Skv, n_kv, hd).
    Runs in f32 accumulation with a lax.scan over KV blocks.
    """
    B, Sq, NKV, G, hd = qg.shape
    Skv = k.shape[1]
    blk = min(BLOCKWISE_KV_BLOCK, Skv)
    pad = (-Skv) % blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = k.shape[1] // blk
    kb = jnp.moveaxis(k.reshape(B, nblk, blk, NKV, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, blk, NKV, hd), 1, 0)

    q32 = qg.astype(jnp.float32) * scale
    q_pos = jnp.arange(Sq) + offset  # absolute positions of queries

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, start = xs
        s = jnp.einsum(
            "bsngh,btnh->bngst", q32, kblk.astype(jnp.float32)
        )  # (B, NKV, G, Sq, blk)
        kv_pos = start + jnp.arange(blk)
        ok = jnp.ones((Sq, blk), bool)
        if causal:
            ok &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(ok[None, None, None], s, -jnp.inf)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        # guard fully-masked rows (m_new = -inf)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(ok[None, None, None], p, 0.0)
        corr = jnp.where(
            jnp.isfinite(m), jnp.exp(m - safe_m), 0.0
        )
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bngst,btnh->bngsh", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, NKV, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, NKV, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, NKV, G, Sq, hd), jnp.float32)
    starts = jnp.arange(nblk) * blk
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,NKV,G,Sq,hd)
    return jnp.moveaxis(out, 3, 1).reshape(B, Sq, NKV * G * hd)


def attention_fwd(
    p,
    x,
    *,
    n_heads,
    n_kv_heads,
    positions=None,
    rope_theta=10_000.0,
    use_rope=True,
    window=None,
    causal=True,
    kv_cache=None,  # (k, v) each (B, S_max, n_kv, hd) + write offset
    cache_offset=None,
    kv_x=None,  # cross-attention source (enc-dec)
    impl="auto",  # "naive" | "blockwise" | "auto"
):
    """Returns (out, new_kv) — new_kv is None unless kv_cache is provided."""
    B, S, D = x.shape
    hd = p["wq"].shape[1] // n_heads
    cdt = x.dtype

    def proj(w, b, src, nh):
        y = jnp.einsum("bsd,dh->bsh", src, w.astype(cdt))
        if b is not None:
            y = y + b.astype(cdt)
        return y.reshape(src.shape[0], src.shape[1], nh, hd)

    src_kv = x if kv_x is None else kv_x
    q = proj(p["wq"], p.get("bq"), x, n_heads)
    k = proj(p["wk"], p.get("bk"), src_kv, n_kv_heads)
    v = proj(p["wv"], p.get("bv"), src_kv, n_kv_heads)

    if positions is None:
        positions = jnp.arange(S)[None, :] + (
            0 if cache_offset is None else cache_offset
        )
        positions = jnp.broadcast_to(positions, (B, S))
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        if kv_x is None:
            k = apply_rope(k, positions, rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_offset, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_offset, 0, 0))
        k, v = ck.astype(cdt), cv.astype(cdt)
        new_cache = (ck, cv)

    group = n_heads // n_kv_heads
    kv_len = k.shape[1]
    qg = q.reshape(B, S, n_kv_heads, group, hd)
    offset = cache_offset if kv_cache is not None else 0
    is_causal = causal or kv_cache is not None

    use_blockwise = impl == "blockwise" or (
        impl == "auto" and kv_len >= BLOCKWISE_MIN_KV
    )
    if use_blockwise:
        out = _blockwise_attention(
            qg,
            k,
            v,
            offset=offset,
            window=window,
            causal=is_causal,
            scale=1.0 / math.sqrt(hd),
        ).astype(cdt)
    else:
        logits = jnp.einsum("bsngh,btnh->bngst", qg, k) / math.sqrt(hd)
        logits = logits.astype(jnp.float32)
        if is_causal:
            bias = _mask_bias(S, kv_len, offset, window, jnp.float32)
            logits = logits + bias[None, None, None, :, :]
        attn = jax.nn.softmax(logits, axis=-1).astype(cdt)
        out = jnp.einsum("bngst,btnh->bsngh", attn, v).reshape(B, S, -1)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
    if p.get("bo") is not None:
        out = out + p["bo"].astype(cdt)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, dtype=jnp.float32, gated=True, bias=False):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": _init_dense(k1, d_model, d_ff, dtype),
        "down": _init_dense(k2, d_ff, d_model, dtype),
    }
    s = {"up": P(FSDP, TP), "down": P(TP, FSDP)}
    if gated:
        p["gate"] = _init_dense(k3, d_model, d_ff, dtype)
        s["gate"] = P(FSDP, TP)
    if bias:
        p |= {"b_up": jnp.zeros((d_ff,), dtype), "b_down": jnp.zeros((d_model,), dtype)}
        s |= {"b_up": P(TP), "b_down": P(None)}
    return p, s


def mlp_fwd(p, x, activation="silu"):
    cdt = x.dtype
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[
        activation
    ]
    h = jnp.einsum("bsd,df->bsf", x, p["up"].astype(cdt))
    if p.get("b_up") is not None:
        h = h + p["b_up"].astype(cdt)
    if "gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["gate"].astype(cdt))
        h = act(g) * h
    else:
        h = act(h)
    out = jnp.einsum("bsf,fd->bsd", h, p["down"].astype(cdt))
    if p.get("b_down") is not None:
        out = out + p["b_down"].astype(cdt)
    return out
