"""Whisper-style encoder–decoder backbone (whisper-medium, [audio]).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d).  Encoder = bidirectional
self-attention stack; decoder = causal self-attention + cross-attention.
Absolute position embeddings (sinusoidal enc / learned dec), LayerNorm, GELU
MLP, MHA (kv = heads).  Decode caches decoder self-KV + precomputed cross-KV.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, stacked
from repro.models.config import ArchConfig
from repro.models.layers import (
    FSDP,
    TP,
    _init_dense,
    attention_fwd,
    embed_fwd,
    init_attention,
    init_embedding,
    init_layernorm,
    init_mlp,
    layernorm_fwd,
    mlp_fwd,
    unembed_fwd,
)

MAX_DEC_POS = 33024  # learned decoder position table — covers prefill_32k
# (+ margin for decode offsets; real whisper uses 448, the assigned 32k
# shapes exercise the backbone beyond that — noted in DESIGN.md)


def _sinusoid(max_len, d):
    pos = np.arange(max_len)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * dim / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32
    )


def init_enc_layer(cfg, key):
    k1, k2 = jax.random.split(key)
    ap, as_ = init_attention(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, None, cfg.pdtype, bias=True
    )
    mp, ms = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.pdtype, gated=False, bias=True)
    n1p, n1s = init_layernorm(cfg.d_model, cfg.pdtype)
    n2p, n2s = init_layernorm(cfg.d_model, cfg.pdtype)
    return (
        {"attn": ap, "mlp": mp, "norm1": n1p, "norm2": n2p},
        {"attn": as_, "mlp": ms, "norm1": n1s, "norm2": n2s},
    )


def init_dec_layer(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    sp, ss = init_attention(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, None, cfg.pdtype, bias=True
    )
    xp, xs = init_attention(
        k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, None, cfg.pdtype, bias=True
    )
    mp, ms = init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.pdtype, gated=False, bias=True)
    norms = [init_layernorm(cfg.d_model, cfg.pdtype) for _ in range(3)]
    return (
        {
            "self": sp,
            "cross": xp,
            "mlp": mp,
            "norm1": norms[0][0],
            "norm2": norms[1][0],
            "norm3": norms[2][0],
        },
        {
            "self": ss,
            "cross": xs,
            "mlp": ms,
            "norm1": norms[0][1],
            "norm2": norms[1][1],
            "norm3": norms[2][1],
        },
    )


def init_params(cfg: ArchConfig, key):
    n_enc = cfg.encoder_layers
    keys = jax.random.split(key, 4)
    emb_p, emb_s = init_embedding(keys[0], cfg.vocab, cfg.d_model, cfg.pdtype)
    enc_keys = jax.random.split(keys[1], n_enc)
    dec_keys = jax.random.split(keys[2], cfg.n_layers)
    enc = jax.vmap(lambda k: init_enc_layer(cfg, k)[0])(enc_keys)
    dec = jax.vmap(lambda k: init_dec_layer(cfg, k)[0])(dec_keys)
    _, enc_spec = init_enc_layer(cfg, enc_keys[0])
    _, dec_spec = init_dec_layer(cfg, dec_keys[0])
    dec_pos = (
        jax.random.normal(keys[3], (MAX_DEC_POS, cfg.d_model)) * 0.01
    ).astype(cfg.pdtype)
    params = {
        "embed": emb_p,
        "enc_layers": enc,
        "dec_layers": dec,
        "dec_pos": dec_pos,
        "enc_norm": init_layernorm(cfg.d_model, cfg.pdtype)[0],
        "dec_norm": init_layernorm(cfg.d_model, cfg.pdtype)[0],
    }
    specs = {
        "embed": emb_s,
        "enc_layers": stacked(enc_spec),
        "dec_layers": stacked(dec_spec),
        "dec_pos": P(None, FSDP),
        "enc_norm": init_layernorm(cfg.d_model)[1],
        "dec_norm": init_layernorm(cfg.d_model)[1],
    }
    return params, specs


def _scan(cfg, fn, x, stacked_params, *extra):
    if cfg.remat:
        fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        return jax.lax.scan(fn, x, (stacked_params, *extra))
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], stacked_params)
        ex = tuple(jax.tree.map(lambda a: a[i], e) for e in extra)
        x, y = fn(x, (sl, *ex))
        ys.append(y)
    ys = (
        None
        if all(y is None for y in ys)
        else jax.tree.map(lambda *a: jnp.stack(a), *ys)
    )
    return x, ys


def encode(cfg: ArchConfig, params, frames):
    """frames: (B, S_enc, d) precomputed embeddings (frontend stub)."""
    x = frames.astype(cfg.cdtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model)[None].astype(cfg.cdtype)
    x = constrain(x, "data", None, None)

    def step(h, xs):
        (lp,) = xs
        a = layernorm_fwd(lp["norm1"], h)
        a, _ = attention_fwd(
            lp["attn"],
            a,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            use_rope=False,
            causal=False,
        )
        h = h + a
        m = layernorm_fwd(lp["norm2"], h)
        h = h + mlp_fwd(lp["mlp"], m, "gelu")
        return constrain(h, "data", None, None), None

    x, _ = _scan(cfg, step, x, params["enc_layers"])
    return layernorm_fwd(params["enc_norm"], x)


def _dec_layer(cfg, lp, x, enc_out, kv_cache=None, cache_offset=None):
    a = layernorm_fwd(lp["norm1"], x)
    a, new_kv = attention_fwd(
        lp["self"],
        a,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        use_rope=False,
        kv_cache=kv_cache,
        cache_offset=cache_offset,
    )
    x = x + a
    c = layernorm_fwd(lp["norm2"], x)
    c, _ = attention_fwd(
        lp["cross"],
        c,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        use_rope=False,
        causal=False,
        kv_x=enc_out,
    )
    x = x + c
    m = layernorm_fwd(lp["norm3"], x)
    x = x + mlp_fwd(lp["mlp"], m, "gelu")
    return constrain(x, "data", None, None), new_kv


def decode_stack(cfg, params, tokens, enc_out, cache=None, offset=0):
    B, S = tokens.shape
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)
    pos = jax.lax.dynamic_slice_in_dim(params["dec_pos"], offset, S, 0)
    x = x + pos[None].astype(cfg.cdtype)
    x = constrain(x, "data", None, None)

    if cache is None:

        def step(h, xs):
            (lp,) = xs
            h, _ = _dec_layer(cfg, lp, h, enc_out)
            return h, None

        x, _ = _scan(cfg, step, x, params["dec_layers"])
        new_cache = None
    else:

        def step(h, xs):
            lp, ck, cv = xs
            h, kv = _dec_layer(
                cfg, lp, h, enc_out, kv_cache=(ck, cv), cache_offset=offset
            )
            return h, kv

        x, kv = _scan(cfg, step, x, params["dec_layers"], cache["k"], cache["v"])
        new_cache = {"k": kv[0], "v": kv[1], "enc_out": enc_out}
    x = layernorm_fwd(params["dec_norm"], x)
    return constrain(unembed_fwd(params["embed"], x), "data", None, "model"), new_cache


def forward(cfg: ArchConfig, params, tokens, frames):
    enc_out = encode(cfg, params, frames)
    logits, _ = decode_stack(cfg, params, tokens, enc_out)
    return logits


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int = 0):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    cache = {
        "k": jnp.zeros(shape, cfg.cdtype),
        "v": jnp.zeros(shape, cfg.cdtype),
        "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), cfg.cdtype),
    }
    spec = {
        "k": P(None, "data", None, "model", None),
        "v": P(None, "data", None, "model", None),
        "enc_out": P("data", None, None),
    }
    return cache, spec


def prefill(cfg: ArchConfig, params, tokens, frames, max_len):
    enc_out = encode(cfg, params, frames)
    cache, _ = init_kv_cache(
        cfg, tokens.shape[0], max_len, enc_len=frames.shape[1]
    )
    logits, cache = decode_stack(cfg, params, tokens, enc_out, cache, offset=0)
    return logits[:, -1:], cache


def decode_step(cfg: ArchConfig, params, cache, tokens, offset):
    logits, cache = decode_stack(
        cfg, params, tokens, cache["enc_out"].astype(cfg.cdtype), cache, offset
    )
    return logits, cache
