"""Mixture-of-Experts decoder (deepseek-v2-lite, kimi-k2) with optional MLA.

* Routing: top-k softmax gating with per-group capacity (GShard-style drops),
  computed with a sort-free rank: position-in-expert comes from a cumulative
  one-hot count per group — groups are sequences, so the dispatch scatter is
  group-local and shards cleanly over the data axis while experts shard over
  the model axis (EP).
* Expert compute: batched einsum over the (E, C) dispatch buffer — dense
  matmul FLOPs ∝ tokens × top_k × capacity_factor.
* Shared experts: a dense SwiGLU MLP applied to every token (DeepSeek).
* MLA (DeepSeek): low-rank compressed KV (kv_lora_rank) + decoupled RoPE
  head; the decode cache stores the compressed c_kv + k_pe only.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, stacked
from repro.models.config import ArchConfig
from repro.models.layers import (
    FSDP,
    TP,
    _init_dense,
    apply_rope,
    attention_fwd,
    embed_fwd,
    init_attention,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    mlp_fwd,
    rmsnorm_fwd,
    unembed_fwd,
)

# ---------------------------------------------------------------------------
# MoE FFN
# ---------------------------------------------------------------------------


def init_moe_ffn(key, cfg: ArchConfig):
    m = cfg.moe
    d, E, f = cfg.d_model, m.num_experts, m.d_ff_expert
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": _init_dense(k1, d, E, jnp.float32),
        "w_gate": (jax.random.normal(k2, (E, d, f)) / math.sqrt(d)).astype(
            cfg.pdtype
        ),
        "w_up": (jax.random.normal(k3, (E, d, f)) / math.sqrt(d)).astype(
            cfg.pdtype
        ),
        "w_down": (jax.random.normal(k4, (E, f, d)) / math.sqrt(f)).astype(
            cfg.pdtype
        ),
    }
    s = {
        "router": P(FSDP, None),
        "w_gate": P(TP, FSDP, None),
        "w_up": P(TP, FSDP, None),
        "w_down": P(TP, None, FSDP),
    }
    if m.num_shared:
        sp, ss = init_mlp(k5, d, f * m.num_shared, cfg.pdtype, gated=True)
        p["shared"] = sp
        s["shared"] = ss
    return p, s


def moe_ffn_fwd(p, x, cfg: ArchConfig):
    """x: (B, S, d). Groups = sequences; capacity per group."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    C = max(1, int(math.ceil(m.capacity_factor * S * k / E)))
    cdt = x.dtype

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"]
    )  # (B,S,E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, k)  # (B,S,k)
    gate_vals = gate_vals / (
        jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9
    )

    # position-in-expert within each group: cumulative count over (S*k)
    # assignments in order.  one_hot (B, S*k, E) int32 — S*k*E ints/group.
    flat_e = eidx.reshape(B, S * k)
    one_hot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (B, S*k, E)
    pos = jnp.cumsum(one_hot, axis=1) - 1  # count before + self
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]
    keep = pos < C  # capacity drop (B, S*k)

    b_ix = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S * k))
    pos_c = jnp.where(keep, pos, C)  # dropped -> scratch slot C

    if cfg.moe_dispatch == "gather":
        # §Perf fix: scatter only int32 slot->assignment indices (tiny),
        # then gather tokens locally — x is replicated across the EP axis,
        # so the (B,E,C,d) buffer materializes WITHOUT the full-buffer
        # all-reduce the f32 scatter-add provokes under GSPMD.
        a_ix = jnp.broadcast_to(
            jnp.arange(S * k, dtype=jnp.int32)[None], (B, S * k)
        )
        slot_src = jnp.full((B, E, C + 1), S * k, jnp.int32)
        slot_src = slot_src.at[b_ix, flat_e, pos_c].set(a_ix)
        slot_src = slot_src[:, :, :C]
        valid = slot_src < S * k
        tok_src = jnp.minimum(slot_src // k, S - 1)
        x_g = x[jnp.arange(B)[:, None, None], tok_src]  # (B,E,C,d)
        buf = jnp.where(valid[..., None], x_g, jnp.zeros((), cdt))
    else:
        # baseline (recorded): f32 scatter-add of token vectors
        xk = jnp.repeat(x, k, axis=1)  # (B, S*k, d) token per assignment
        buf = jnp.zeros((B, E, C + 1, d), cdt)
        buf = buf.at[b_ix, flat_e, pos_c].add(xk)
        buf = buf[:, :, :C, :]
    buf = constrain(buf, "data", "model", None, None)

    # expert compute (EP over the model axis)
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(cdt))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(cdt))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(cdt))
    out_buf = constrain(out_buf, "data", "model", None, None)

    # combine: gather per assignment, weight, sum over k
    gathered = out_buf[b_ix, flat_e, jnp.minimum(pos, C - 1)]  # (B,S*k,d)
    w = (gate_vals.reshape(B, S * k) * keep.astype(jnp.float32)).astype(cdt)
    out = jnp.sum(
        (gathered * w[..., None]).reshape(B, S, k, d), axis=2
    )

    if "shared" in p:
        out = out + mlp_fwd(p["shared"], x, "silu")
    return out


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig):
    a = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    r, rd, nd, vd = a.kv_lora_rank, a.rope_head_dim, a.nope_head_dim, a.v_head_dim
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "w_dkv": _init_dense(k1, d, r + rd, cfg.pdtype),
        "w_uk": _init_dense(k2, r, H * nd, cfg.pdtype),
        "w_uv": _init_dense(k3, r, H * vd, cfg.pdtype),
        "w_q": _init_dense(k4, d, H * (nd + rd), cfg.pdtype),
        "w_o": _init_dense(k5, H * vd, d, cfg.pdtype),
        "kv_norm": jnp.ones((r,), cfg.pdtype),
    }
    s = {
        "w_dkv": P(FSDP, None),
        "w_uk": P(None, TP),
        "w_uv": P(None, TP),
        "w_q": P(FSDP, TP),
        "w_o": P(TP, FSDP),
        "kv_norm": P(None),
    }
    return p, s


def mla_fwd(p, x, cfg: ArchConfig, kv_cache=None, cache_offset=None):
    """MLA attention; cache stores (c_kv normed, k_pe) of shape (B,S,r+rd)."""
    a = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    r, rd, nd, vd = a.kv_lora_rank, a.rope_head_dim, a.nope_head_dim, a.v_head_dim
    cdt = x.dtype
    offset = 0 if cache_offset is None else cache_offset
    positions = jnp.broadcast_to(jnp.arange(S)[None, :] + offset, (B, S))

    ckv_pe = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(cdt))
    c_kv, k_pe = ckv_pe[..., :r], ckv_pe[..., r:]
    c_kv = rmsnorm_fwd({"scale": p["kv_norm"]}, c_kv)
    k_pe = apply_rope(
        k_pe[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]

    new_cache = None
    if kv_cache is not None:
        cc, cp = kv_cache  # (B, Smax, r), (B, Smax, rd)
        cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), (0, offset, 0))
        cp = jax.lax.dynamic_update_slice(cp, k_pe.astype(cp.dtype), (0, offset, 0))
        c_kv, k_pe = cc.astype(cdt), cp.astype(cdt)
        new_cache = (cc, cp)

    q = jnp.einsum("bsd,dh->bsh", x, p["w_q"].astype(cdt)).reshape(
        B, S, H, nd + rd
    )
    q_nope, q_pe = q[..., :nd], q[..., nd:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    k_nope = jnp.einsum("btr,rh->bth", c_kv, p["w_uk"].astype(cdt)).reshape(
        B, -1, H, nd
    )
    v = jnp.einsum("btr,rh->bth", c_kv, p["w_uv"].astype(cdt)).reshape(
        B, -1, H, vd
    )
    kv_len = k_nope.shape[1]

    scale = 1.0 / math.sqrt(nd + rd)
    logits = (
        jnp.einsum("bshn,bthn->bhst", q_nope, k_nope)
        + jnp.einsum("bshr,btr->bhst", q_pe, k_pe)
    ) * scale
    logits = logits.astype(jnp.float32)

    from repro.models.layers import _mask_bias

    bias = _mask_bias(S, kv_len, offset, None, jnp.float32)
    logits = logits + bias[None, None, :, :]
    attn = jax.nn.softmax(logits, axis=-1).astype(cdt)
    out = jnp.einsum("bhst,bthv->bshv", attn, v).reshape(B, S, H * vd)
    out = jnp.einsum("bsh,hd->bsd", out, p["w_o"].astype(cdt))
    return out, new_cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def _init_attn(cfg, key):
    if cfg.mla is not None:
        return init_mla(key, cfg)
    return init_attention(
        key,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.resolved_head_dim,
        cfg.pdtype,
        bias=cfg.attn_bias,
    )


def _attn_fwd(cfg, p, x, kv_cache=None, cache_offset=None):
    if cfg.mla is not None:
        return mla_fwd(p, x, cfg, kv_cache, cache_offset)
    return attention_fwd(
        p,
        x,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        rope_theta=cfg.rope_theta,
        use_rope=cfg.use_rope,
        kv_cache=kv_cache,
        cache_offset=cache_offset,
    )


def init_moe_layer(cfg: ArchConfig, key, dense: bool):
    k1, k2 = jax.random.split(key)
    attn_p, attn_s = _init_attn(cfg, k1)
    if dense:
        ffn_p, ffn_s = init_mlp(
            k2, cfg.d_model, cfg.moe.d_ff_dense or cfg.d_ff, cfg.pdtype, True
        )
    else:
        ffn_p, ffn_s = init_moe_ffn(k2, cfg)
    n1_p, n1_s = init_rmsnorm(cfg.d_model, cfg.pdtype)
    n2_p, n2_s = init_rmsnorm(cfg.d_model, cfg.pdtype)
    return (
        {"attn": attn_p, "ffn": ffn_p, "norm1": n1_p, "norm2": n2_p},
        {"attn": attn_s, "ffn": ffn_s, "norm1": n1_s, "norm2": n2_s},
    )


def moe_layer_fwd(cfg, lp, x, dense: bool, kv_cache=None, cache_offset=None):
    h = rmsnorm_fwd(lp["norm1"], x)
    attn_out, new_cache = _attn_fwd(cfg, lp["attn"], h, kv_cache, cache_offset)
    x = x + attn_out
    h = rmsnorm_fwd(lp["norm2"], x)
    if dense:
        x = x + mlp_fwd(lp["ffn"], h, cfg.activation)
    else:
        x = x + moe_ffn_fwd(lp["ffn"], h, cfg)
    return constrain(x, "data", None, None), new_cache


def init_params(cfg: ArchConfig, key):
    nd = cfg.moe.first_dense_layers
    n_moe = cfg.n_layers - nd
    keys = jax.random.split(key, cfg.n_layers + 1)
    emb_p, emb_s = init_embedding(keys[0], cfg.vocab, cfg.d_model, cfg.pdtype)
    params = {"embed": emb_p}
    specs = {"embed": emb_s}
    if nd:
        dense_layers = [
            init_moe_layer(cfg, keys[1 + i], dense=True)[0] for i in range(nd)
        ]
        params["dense_layers"] = jax.tree.map(
            lambda *a: jnp.stack(a), *dense_layers
        ) if nd > 1 else jax.tree.map(lambda a: a[None], dense_layers[0])
        _, dl_spec = init_moe_layer(cfg, keys[1], dense=True)
        specs["dense_layers"] = stacked(dl_spec)
    moe_keys = keys[1 + nd :]
    params["layers"] = jax.vmap(
        lambda k: init_moe_layer(cfg, k, dense=False)[0]
    )(jnp.stack(list(moe_keys)))
    _, ml_spec = init_moe_layer(cfg, moe_keys[0], dense=False)
    specs["layers"] = stacked(ml_spec)
    fn_p, fn_s = init_rmsnorm(cfg.d_model, cfg.pdtype)
    params["final_norm"] = fn_p
    specs["final_norm"] = fn_s
    return params, specs


def _run_stack(cfg, step_fn, x, stacked_params, *extra):
    if cfg.remat:
        step_fn = jax.checkpoint(
            step_fn, policy=jax.checkpoint_policies.nothing_saveable
        )
    if cfg.scan_layers:
        return jax.lax.scan(step_fn, x, (stacked_params, *extra))
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], stacked_params)
        ex = tuple(jax.tree.map(lambda a: a[i], e) for e in extra)
        x, y = step_fn(x, (sl, *ex))
        ys.append(y)
    ys = (
        None
        if all(y is None for y in ys)
        else jax.tree.map(lambda *a: jnp.stack(a), *ys)
    )
    return x, ys


def forward(cfg: ArchConfig, params, tokens):
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)
    x = constrain(x, "data", None, None)

    if "dense_layers" in params:

        def dstep(h, xs):
            (lp,) = xs
            h, _ = moe_layer_fwd(cfg, lp, h, dense=True)
            return h, None

        x, _ = _run_stack(cfg, dstep, x, params["dense_layers"])

    def step(h, xs):
        (lp,) = xs
        h, _ = moe_layer_fwd(cfg, lp, h, dense=False)
        return h, None

    x, _ = _run_stack(cfg, step, x, params["layers"])
    x = rmsnorm_fwd(params["final_norm"], x)
    return constrain(unembed_fwd(params["embed"], x), "data", None, "model")


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.mla is not None:
        a = cfg.mla
        mk = lambda d_: jnp.zeros((cfg.n_layers, batch, max_len, d_), cfg.cdtype)
        cache = {"c_kv": mk(a.kv_lora_rank), "k_pe": mk(a.rope_head_dim)}
        spec = {
            "c_kv": P(None, "data", None, None),
            "k_pe": P(None, "data", None, None),
        }
    else:
        hd = cfg.resolved_head_dim
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
        cache = {
            "k": jnp.zeros(shape, cfg.cdtype),
            "v": jnp.zeros(shape, cfg.cdtype),
        }
        spec = {
            "k": P(None, "data", None, "model", None),
            "v": P(None, "data", None, "model", None),
        }
    return cache, spec


def _cache_slices(cfg, cache):
    if cfg.mla is not None:
        return cache["c_kv"], cache["k_pe"]
    return cache["k"], cache["v"]


def _cache_pack(cfg, a, b):
    if cfg.mla is not None:
        return {"c_kv": a, "k_pe": b}
    return {"k": a, "v": b}


def _cached_forward(cfg: ArchConfig, params, tokens, cache, offset):
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)
    nd = cfg.moe.first_dense_layers
    ca, cb = _cache_slices(cfg, cache)

    def dstep(h, xs):
        lp, a, b = xs
        h, new_kv = moe_layer_fwd(
            cfg, lp, h, dense=True, kv_cache=(a, b), cache_offset=offset
        )
        return h, new_kv

    def step(h, xs):
        lp, a, b = xs
        h, new_kv = moe_layer_fwd(
            cfg, lp, h, dense=False, kv_cache=(a, b), cache_offset=offset
        )
        return h, new_kv

    new_a, new_b = [], []
    if "dense_layers" in params:
        x, kv = _run_stack(
            cfg, dstep, x, params["dense_layers"], ca[:nd], cb[:nd]
        )
        new_a.append(kv[0])
        new_b.append(kv[1])
    x, kv = _run_stack(cfg, step, x, params["layers"], ca[nd:], cb[nd:])
    new_a.append(kv[0])
    new_b.append(kv[1])
    a = jnp.concatenate(new_a) if len(new_a) > 1 else new_a[0]
    b = jnp.concatenate(new_b) if len(new_b) > 1 else new_b[0]
    x = rmsnorm_fwd(params["final_norm"], x)
    logits = constrain(unembed_fwd(params["embed"], x), "data", None, "model")
    return logits, _cache_pack(cfg, a, b)


def decode_step(cfg: ArchConfig, params, cache, tokens, offset):
    return _cached_forward(cfg, params, tokens, cache, offset)


def prefill(cfg: ArchConfig, params, tokens, max_len):
    cache, _ = init_kv_cache(cfg, tokens.shape[0], max_len)
    logits, cache = _cached_forward(cfg, params, tokens, cache, 0)
    return logits[:, -1:], cache
