"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local (MQA)
attention, 2:1 pattern, gated MLP after every temporal block.

* RG-LRU: gated linear recurrence  h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t⊙x_t)
  with a_t = exp(−c·softplus(Λ)·r_t) — parallelized over time with
  ``jax.lax.associative_scan`` (TPU-friendly log-depth scan), O(1) decode.
* Local attention: sliding-window MQA (kv=1) with a **ring-buffer** decode
  cache of window size — `long_500k` decode state is O(window), so this
  family runs the long-context shape.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, stacked
from repro.models.config import ArchConfig
from repro.models.layers import (
    FSDP,
    TP,
    _init_dense,
    apply_rope,
    embed_fwd,
    init_attention,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    mlp_fwd,
    rmsnorm_fwd,
    unembed_fwd,
)

LRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU recurrent block
# ---------------------------------------------------------------------------


def init_recurrent_block(key, cfg: ArchConfig):
    d = cfg.d_model
    dr = cfg.hybrid.lru_dim or d
    cw = cfg.hybrid.conv_width
    ks = jax.random.split(key, 7)
    # Λ init: a ≈ uniform(0.9, 0.999) as in Griffin
    u = jax.random.uniform(ks[0], (dr,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / LRU_C))  # softplus^-1(-ln u / c)
    p = {
        "w_gate": _init_dense(ks[1], d, dr, cfg.pdtype),
        "w_rec": _init_dense(ks[2], d, dr, cfg.pdtype),
        "conv": (jax.random.normal(ks[3], (cw, dr)) / math.sqrt(cw)).astype(
            cfg.pdtype
        ),
        "w_a": _init_dense(ks[4], dr, dr, cfg.pdtype, scale=0.01),
        "w_x": _init_dense(ks[5], dr, dr, cfg.pdtype, scale=0.01),
        "lam": lam.astype(jnp.float32),
        "w_down": _init_dense(ks[6], dr, d, cfg.pdtype),
        "norm": jnp.ones((d,), cfg.pdtype),
    }
    s = {
        "w_gate": P(FSDP, TP),
        "w_rec": P(FSDP, TP),
        "conv": P(None, TP),
        "w_a": P(FSDP, TP),
        "w_x": P(FSDP, TP),
        "lam": P(None),
        "w_down": P(TP, FSDP),
        "norm": P(None),
    }
    return p, s


RGLRU_CHUNK = 4096  # chunk long sequences: outer lax.scan carries the state,
# inner associative_scan stays log-depth-bounded (compile + VMEM friendly)


def _rglru(p, u, h0):
    """u: (B,S,dr) f32 inputs; h0: (B,dr) carry. Returns (y, h_last)."""
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", u, p["w_a"].astype(u.dtype)).astype(
            jnp.float32
        )
    )
    i = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", u, p["w_x"].astype(u.dtype)).astype(
            jnp.float32
        )
    )
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r  # (B,S,dr), ≤ 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * u.astype(jnp.float32)
    )
    B, S, dr = a.shape
    if S <= RGLRU_CHUNK:
        return _rglru_scan(a, gated, h0)
    nc = -(-S // RGLRU_CHUNK)
    pad = nc * RGLRU_CHUNK - S
    if pad:  # pad with identity steps (a=1, b=0) — state passes through
        a = jnp.concatenate([a, jnp.ones((B, pad, dr), a.dtype)], axis=1)
        gated = jnp.concatenate(
            [gated, jnp.zeros((B, pad, dr), gated.dtype)], axis=1
        )
    ac = jnp.moveaxis(a.reshape(B, nc, RGLRU_CHUNK, dr), 1, 0)
    bc = jnp.moveaxis(gated.reshape(B, nc, RGLRU_CHUNK, dr), 1, 0)

    def step(h, xs):
        a_i, b_i = xs
        y, h_new = _rglru_scan(a_i, b_i, h)
        return h_new, y

    h_last, ys = jax.lax.scan(step, h0, (ac, bc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc * RGLRU_CHUNK, dr)[:, :S]
    return y, h_last


def _rglru_scan(a, gated, h0):
    """Parallel linear-recurrence solve within one chunk."""
    # prepend carry as step 0: h_t = a_t h_{t-1} + b_t
    a_ext = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
    b_ext = jnp.concatenate([h0[:, None], gated], axis=1)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a_ext, b_ext), axis=1)
    return h[:, 1:], h[:, -1]


def recurrent_block_fwd(p, x, cfg, h0, conv_state=None):
    """Returns (out, (h_last, new_conv_state)).  conv_state: (B, cw-1, dr)."""
    cdt = x.dtype
    cw = cfg.hybrid.conv_width
    xn = rmsnorm_fwd({"scale": p["norm"]}, x)
    gate = jax.nn.gelu(
        jnp.einsum("bsd,de->bse", xn, p["w_gate"].astype(cdt))
    )
    u = jnp.einsum("bsd,de->bse", xn, p["w_rec"].astype(cdt))

    # causal depthwise conv (width cw); carry the last cw-1 inputs in decode
    if conv_state is None:
        upad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        upad = jnp.concatenate([conv_state.astype(cdt), u], axis=1)
    new_conv_state = upad[:, -(cw - 1) :, :] if cw > 1 else None
    conv = sum(
        upad[:, i : i + u.shape[1], :] * p["conv"][i].astype(cdt)
        for i in range(cw)
    )

    y, h_last = _rglru(p, conv.astype(jnp.float32), h0)
    out = (y.astype(cdt) * gate)
    out = jnp.einsum("bse,ed->bsd", out, p["w_down"].astype(cdt))
    return x + out, (h_last, new_conv_state)


# ---------------------------------------------------------------------------
# Local attention block (MQA, sliding window, ring-buffer decode cache)
# ---------------------------------------------------------------------------


def init_attention_block(key, cfg: ArchConfig):
    ap, as_ = init_attention(
        key,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.resolved_head_dim,
        cfg.pdtype,
    )
    np_, ns = init_rmsnorm(cfg.d_model, cfg.pdtype)
    return {"attn": ap, "norm": np_}, {"attn": as_, "norm": ns}


def _ring_attention_step(p, x, cfg, cache, offset):
    """Decode step against a ring-buffer window cache.

    cache: {k,v: (B, W, kv, hd), pos: (B, W) int32 (absolute, -1 = empty)}.
    """
    B, S, d = x.shape
    assert S == 1
    cdt = x.dtype
    H, KV = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    W = cache["k"].shape[1]
    positions = jnp.broadcast_to(offset[None, None], (B, 1))

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(cdt)).reshape(B, 1, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(cdt)).reshape(B, 1, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(cdt)).reshape(B, 1, KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    slot = jnp.mod(offset, W)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)
    )
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)
    )
    cpos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.broadcast_to(offset[None, None], (B, 1)), (0, slot)
    )

    group = H // KV
    qg = q.reshape(B, 1, KV, group, hd)
    logits = jnp.einsum(
        "bsngh,btnh->bngst", qg, ck.astype(cdt)
    ) / math.sqrt(hd)
    logits = logits.astype(jnp.float32)
    valid = (cpos >= 0) & (cpos <= offset) & (cpos > offset - W)
    logits = jnp.where(
        valid[:, None, None, None, :], logits, jnp.finfo(jnp.float32).min
    )
    attn = jax.nn.softmax(logits, axis=-1).astype(cdt)
    out = jnp.einsum("bngst,btnh->bsngh", attn, cv.astype(cdt)).reshape(
        B, 1, -1
    )
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
    return out, {"k": ck, "v": cv, "pos": cpos}


def _fill_ring_cache(p, h_norm, cfg, cache):
    """Populate the ring cache from a parallel pass's last `window` tokens.

    h_norm: (B, S, d) the attention block's normed input; cache slots for
    absolute positions S-W..S-1 are written (slot = pos % W).
    """
    cdt = h_norm.dtype
    B, S, d = h_norm.shape
    KV = cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    W = cache["k"].shape[1]
    Wt = min(W, S)
    hh = h_norm[:, S - Wt :]
    positions = jnp.broadcast_to(
        jnp.arange(S - Wt, S)[None, :], (B, Wt)
    )
    k = jnp.einsum("bsd,dh->bsh", hh, p["wk"].astype(cdt)).reshape(
        B, Wt, KV, hd
    )
    v = jnp.einsum("bsd,dh->bsh", hh, p["wv"].astype(cdt)).reshape(
        B, Wt, KV, hd
    )
    k = apply_rope(k, positions, cfg.rope_theta)
    slots = jnp.mod(jnp.arange(S - Wt, S), W)
    ck = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
    cpos = cache["pos"].at[:, slots].set(positions)
    return {"k": ck, "v": cv, "pos": cpos}


def attention_block_fwd(p, x, cfg, cache=None, offset=None, build_cache=False):
    h = rmsnorm_fwd(p["norm"], x)
    if cache is None or build_cache:
        from repro.models.layers import attention_fwd

        out, _ = attention_fwd(
            p["attn"],
            h,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            rope_theta=cfg.rope_theta,
            window=cfg.hybrid.window,
            impl=cfg.attention_impl,
        )
        new_cache = (
            _fill_ring_cache(p["attn"], h, cfg, cache) if build_cache else None
        )
        return x + out, new_cache
    out, new_cache = _ring_attention_step(p["attn"], h, cfg, cache, offset)
    return x + out, new_cache


# ---------------------------------------------------------------------------
# Full model: scanned (rec+mlp, rec+mlp, attn+mlp) triples + remainder
# ---------------------------------------------------------------------------


def init_triple(cfg, key):
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    r1, r1s = init_recurrent_block(k1, cfg)
    r2, r2s = init_recurrent_block(k2, cfg)
    at, ats = init_attention_block(k3, cfg)
    mls = [init_mlp(k, cfg.d_model, cfg.d_ff, cfg.pdtype) for k in (k4, k5, k6)]
    nrm = [init_rmsnorm(cfg.d_model, cfg.pdtype) for _ in range(3)]
    p = {
        "rec1": r1,
        "rec2": r2,
        "attn": at,
        "mlp1": mls[0][0],
        "mlp2": mls[1][0],
        "mlp3": mls[2][0],
        "mnorm1": nrm[0][0],
        "mnorm2": nrm[1][0],
        "mnorm3": nrm[2][0],
    }
    s = {
        "rec1": r1s,
        "rec2": r2s,
        "attn": ats,
        "mlp1": mls[0][1],
        "mlp2": mls[1][1],
        "mlp3": mls[2][1],
        "mnorm1": nrm[0][1],
        "mnorm2": nrm[1][1],
        "mnorm3": nrm[2][1],
    }
    return p, s


def _n_triples(cfg):
    return cfg.n_layers // len(cfg.hybrid.pattern)


def init_params(cfg: ArchConfig, key):
    nt = _n_triples(cfg)
    rem = cfg.n_layers - nt * len(cfg.hybrid.pattern)
    keys = jax.random.split(key, nt + rem + 1)
    emb_p, emb_s = init_embedding(keys[0], cfg.vocab, cfg.d_model, cfg.pdtype)
    triples = jax.vmap(lambda k: init_triple(cfg, k)[0])(keys[1 : nt + 1])
    _, t_spec = init_triple(cfg, keys[1])
    params = {"embed": emb_p, "triples": triples}
    specs = {"embed": emb_s, "triples": stacked(t_spec)}
    # remainder layers are recurrent blocks (+ MLP), unrolled
    for i in range(rem):
        kk = keys[nt + 1 + i]
        k1, k2 = jax.random.split(kk)
        rp, rs = init_recurrent_block(k1, cfg)
        mp, ms = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.pdtype)
        nrm_p, nrm_s = init_rmsnorm(cfg.d_model, cfg.pdtype)
        params[f"rem{i}"] = {"rec": rp, "mlp": mp, "mnorm": nrm_p}
        specs[f"rem{i}"] = {"rec": rs, "mlp": ms, "mnorm": nrm_s}
    fn_p, fn_s = init_rmsnorm(cfg.d_model, cfg.pdtype)
    params["final_norm"] = fn_p
    specs["final_norm"] = fn_s
    return params, specs


def _mlp_res(cfg, norm_p, mlp_p, x):
    return x + mlp_fwd(mlp_p, rmsnorm_fwd(norm_p, x), "gelu")


def _triple_fwd(cfg, tp, x, state, decode=False, offset=None, build_cache=False):
    h1, c1, h2, c2, attn_cache = state
    x, (h1, c1) = recurrent_block_fwd(tp["rec1"], x, cfg, h1, c1 if decode else None)
    x = _mlp_res(cfg, tp["mnorm1"], tp["mlp1"], x)
    x, (h2, c2) = recurrent_block_fwd(tp["rec2"], x, cfg, h2, c2 if decode else None)
    x = _mlp_res(cfg, tp["mnorm2"], tp["mlp2"], x)
    x, new_cache = attention_block_fwd(
        tp["attn"],
        x,
        cfg,
        attn_cache if (decode or build_cache) else None,
        offset,
        build_cache=build_cache,
    )
    if new_cache is not None:
        attn_cache = new_cache
    x = _mlp_res(cfg, tp["mnorm3"], tp["mlp3"], x)
    return x, (h1, c1, h2, c2, attn_cache)


def init_state(cfg: ArchConfig, batch: int, max_len: int):
    nt = _n_triples(cfg)
    rem = cfg.n_layers - nt * len(cfg.hybrid.pattern)
    dr = cfg.hybrid.lru_dim or cfg.d_model
    cw = cfg.hybrid.conv_width
    W = min(max_len, cfg.hybrid.window)
    hd = cfg.resolved_head_dim

    def rec_state():
        return (
            jnp.zeros((nt, batch, dr), jnp.float32),
            jnp.zeros((nt, batch, cw - 1, dr), cfg.cdtype),
        )

    h1, c1 = rec_state()
    h2, c2 = rec_state()
    attn = {
        "k": jnp.zeros((nt, batch, W, cfg.n_kv_heads, hd), cfg.cdtype),
        "v": jnp.zeros((nt, batch, W, cfg.n_kv_heads, hd), cfg.cdtype),
        "pos": jnp.full((nt, batch, W), -1, jnp.int32),
    }
    rem_state = [
        (
            jnp.zeros((batch, dr), jnp.float32),
            jnp.zeros((batch, cw - 1, dr), cfg.cdtype),
        )
        for _ in range(rem)
    ]
    state = {"triples": (h1, c1, h2, c2, attn), "rem": rem_state}
    spec = jax.tree.map(lambda a: P(None, "data"), state)
    return state, spec


def _run(cfg, params, x, state, decode, offset, build_cache=False):
    h1, c1, h2, c2, attn = state["triples"]

    def step(carry, xs):
        h, off = carry
        tp, st = xs
        h, st = _triple_fwd(cfg, tp, h, st, decode, off, build_cache)
        return (h, off), st

    step_fn = step
    if cfg.remat and not decode:
        step_fn = jax.checkpoint(
            step, policy=jax.checkpoint_policies.nothing_saveable
        )
    (x, _), new_triple_state = jax.lax.scan(
        step_fn, (x, offset), (params["triples"], (h1, c1, h2, c2, attn))
    )
    new_rem = []
    i = 0
    while f"rem{i}" in params:
        rp = params[f"rem{i}"]
        h0, cs = state["rem"][i]
        x, (h0, cs) = recurrent_block_fwd(
            rp["rec"], x, cfg, h0, cs if decode else None
        )
        x = _mlp_res(cfg, rp["mnorm"], rp["mlp"], x)
        new_rem.append((h0, cs))
        i += 1
    return x, {"triples": new_triple_state, "rem": new_rem}


def forward(cfg: ArchConfig, params, tokens):
    B, S = tokens.shape
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)
    x = constrain(x, "data", None, None)
    state, _ = init_state(cfg, B, max_len=1)
    x, _ = _run(cfg, params, x, state, decode=False, offset=jnp.int32(0))
    x = rmsnorm_fwd(params["final_norm"], x)
    return constrain(unembed_fwd(params["embed"], x), "data", None, "model")


def prefill(cfg: ArchConfig, params, tokens, max_len):
    """Parallel prefill: one full forward pass that also materializes the
    decode state — recurrent carries + conv tails fall out of the parallel
    blocks, and the attention ring caches are filled from the last
    ``window`` positions (everything older is out-of-window by
    construction)."""
    B, S = tokens.shape
    state, _ = init_state(cfg, B, max_len)
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)
    x, state = _run(
        cfg,
        params,
        x,
        state,
        decode=False,
        offset=jnp.int32(0),
        build_cache=True,
    )
    logits = rmsnorm_fwd(params["final_norm"], x[:, -1:])
    return unembed_fwd(params["embed"], logits), state


def decode_step(cfg: ArchConfig, params, state, tokens, offset):
    x = embed_fwd(params["embed"], tokens, cfg.cdtype)
    offset = jnp.asarray(offset, jnp.int32)
    x, state = _run(cfg, params, x, state, decode=True, offset=offset)
    x = rmsnorm_fwd(params["final_norm"], x)
    return unembed_fwd(params["embed"], x), state


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int):
    return init_state(cfg, batch, max_len)
