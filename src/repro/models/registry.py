"""arch-family -> model implementation dispatch."""

from __future__ import annotations

import importlib
from types import SimpleNamespace

from repro.models.config import ArchConfig

_FAMILY_MODULES = {
    "dense": "repro.models.transformer",
    "vlm": "repro.models.transformer",  # + patch-embedding stub inputs
    "moe": "repro.models.moe",
    "ssm": "repro.models.xlstm",
    "hybrid": "repro.models.rglru",
    "audio": "repro.models.encdec",
}


def get_model(cfg: ArchConfig) -> SimpleNamespace:
    mod = importlib.import_module(_FAMILY_MODULES[cfg.family])
    return SimpleNamespace(
        init_params=mod.init_params,
        forward=mod.forward,
        prefill=mod.prefill,
        decode_step=mod.decode_step,
        init_kv_cache=getattr(mod, "init_kv_cache", None),
    )
