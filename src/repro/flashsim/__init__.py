from repro.flashsim.geometry import SSDConfig, DEFAULT_SSD
from repro.flashsim.timing import (
    inter_block_tmws_ratio,
    intra_block_tmws_ratio,
    mws_power_ratio,
)
from repro.flashsim.platforms import (
    Platform,
    PlatformResult,
    run_workload,
)
from repro.flashsim.workloads import (
    BulkBitwiseWorkload,
    bmi_workload,
    ims_workload,
    kcs_workload,
)

__all__ = [
    "SSDConfig",
    "DEFAULT_SSD",
    "inter_block_tmws_ratio",
    "intra_block_tmws_ratio",
    "mws_power_ratio",
    "Platform",
    "PlatformResult",
    "run_workload",
    "BulkBitwiseWorkload",
    "bmi_workload",
    "ims_workload",
    "kcs_workload",
]
