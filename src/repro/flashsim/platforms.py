"""End-to-end performance/energy model of the four platforms (paper §7).

OSP  — outside-storage processing: stream every operand to the host CPU;
       external PCIe link is the bottleneck, host busy the whole time.
ISP  — in-storage processing: per-channel accelerator; SSD-internal channel
       bandwidth is the bottleneck.
PB   — ParaBit IFP: one sensing per operand per page position; sensing is
       the bottleneck for many-operand ops.
FC   — Flash-Cosmos: one MWS per planner command (≈ one per 48 operands);
       result transfer dominates when operands are few but large.

Modeling follows the paper's two-stage throughput formulation: SSD-side
(sense + internal DMA) and host-side stages pipeline, so the end-to-end time
is the max of the stage times plus un-overlappable result handling.  Energy
integrates active/idle host power over time plus per-operation flash/DMA/link
energies — with host idle power included, which is what makes the paper's
energy ratios (e.g. 1839× for BMI m=36) reproducible.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field

from repro.flashsim.geometry import DEFAULT_SSD, SSDConfig
from repro.flashsim.timing import (
    mws_energy_j,
    mws_latency_us,
    threshold_latency_us,
)
from repro.flashsim.workloads import BulkBitwiseWorkload, MWSCommandShape


def _shape_latency_us(ssd: SSDConfig, s: MWSCommandShape) -> float:
    """One command's sensing latency: threshold shapes pay the staircase
    reference sweep on top of the MWS wordline-select setup."""
    if getattr(s, "threshold_k", 0):
        return threshold_latency_us(ssd.t_r_us, s.n_blocks, s.max_wls_per_block)
    return mws_latency_us(ssd.t_r_us, s.n_blocks, s.max_wls_per_block)


class Platform(enum.Enum):
    OSP = "osp"
    ISP = "isp"
    PB = "parabit"
    FC = "flash-cosmos"


@dataclass(frozen=True)
class PlatformResult:
    platform: Platform
    time_s: float
    energy_j: float
    breakdown: dict = field(default_factory=dict)

    @property
    def bits_per_joule(self) -> float:
        return self.breakdown.get("useful_bits", 0.0) / self.energy_j


def _sense_time_s(ssd: SSDConfig, senses_per_plane: int) -> float:
    return senses_per_plane * ssd.t_r_us * 1e-6


def _common(ssd: SSDConfig, wl: BulkBitwiseWorkload):
    positions = ssd.pages_per_plane(wl.operand_bits)
    operand_bytes = wl.num_operands * wl.operand_bits / 8 * wl.num_queries
    result_bytes = wl.result_bits / 8 * wl.num_queries
    total_sense_pages = positions * ssd.num_planes  # per operand vector
    useful_bits = wl.num_operands * wl.operand_bits * wl.num_queries
    return positions, operand_bytes, result_bytes, total_sense_pages, useful_bits


def run_workload(
    wl: BulkBitwiseWorkload,
    platform: Platform,
    ssd: SSDConfig = DEFAULT_SSD,
) -> PlatformResult:
    positions, operand_bytes, result_bytes, sense_pages, useful_bits = _common(
        ssd, wl
    )
    Q = wl.num_queries

    if platform is Platform.OSP:
        t_sense = _sense_time_s(ssd, wl.num_operands * positions * Q)
        t_int = operand_bytes / ssd.internal_bw
        t_ext = operand_bytes / ssd.ext_bw
        # host compute fully hidden behind operand streaming (§8.1)
        t = max(t_sense, t_int, t_ext)
        e = (
            ssd.p_host_active_w * t
            + wl.num_operands * Q * sense_pages * ssd.e_sense_page
            + operand_bytes * 8 * (ssd.e_dma_per_bit + ssd.e_ext_per_bit)
            + ssd.p_ssd_idle_w * t
        )
        return PlatformResult(
            platform,
            t,
            e,
            {
                "t_sense": t_sense,
                "t_internal": t_int,
                "t_external": t_ext,
                "bottleneck": "external-io",
                "useful_bits": useful_bits,
            },
        )

    if platform is Platform.ISP:
        t_sense = _sense_time_s(ssd, wl.num_operands * positions * Q)
        t_int = operand_bytes / ssd.internal_bw
        t_result = result_bytes / ssd.ext_bw
        # the accelerator streams results out while operands stream in
        t = max(t_sense, t_int, t_result)
        t_host = result_bytes / ssd.host_compute_bw
        e = (
            ssd.p_host_active_w * t_host
            + ssd.p_host_idle_w * max(0.0, t - t_host)
            + wl.num_operands * Q * sense_pages * ssd.e_sense_page
            + operand_bytes * 8 * ssd.e_dma_per_bit
            + (operand_bytes / 64) * ssd.e_accel_per_64b
            + result_bytes * 8 * ssd.e_ext_per_bit
            + ssd.p_ssd_idle_w * t
        )
        return PlatformResult(
            platform,
            t,
            e,
            {
                "t_sense": t_sense,
                "t_internal": t_int,
                "t_result": t_result,
                "bottleneck": "internal-io" if t_int >= t_sense else "sense",
                "useful_bits": useful_bits,
            },
        )

    if platform is Platform.PB:
        # one sensing per operand per position; result moves overlap sensing
        t_sense = _sense_time_s(ssd, wl.num_operands * positions * Q)
        t_res_int = result_bytes / ssd.internal_bw
        t_res_ext = result_bytes / ssd.ext_bw
        t = max(t_sense, t_res_int, t_res_ext)
        t_host = (
            result_bytes / ssd.host_compute_bw if wl.host_postprocess else 0.0
        )
        e = (
            ssd.p_host_active_w * t_host
            + ssd.p_host_idle_w * max(0.0, t - t_host)
            + wl.num_operands * Q * sense_pages * ssd.e_sense_page
            + result_bytes * 8 * (ssd.e_dma_per_bit + ssd.e_ext_per_bit)
            + ssd.p_ssd_idle_w * t
        )
        return PlatformResult(
            platform,
            t,
            e,
            {
                "t_sense": t_sense,
                "t_result_ext": t_res_ext,
                "bottleneck": "sense" if t_sense >= t_res_ext else "external-io",
                "useful_bits": useful_bits,
            },
        )

    assert platform is Platform.FC
    cmd_pairs = wl.fc_command_pairs
    t_cmd_us = sum(
        _shape_latency_us(ssd, s) * cnt for s, cnt in cmd_pairs
    )
    t_sense = t_cmd_us * 1e-6 * positions * Q
    t_res_int = result_bytes / ssd.internal_bw
    t_res_ext = result_bytes / ssd.ext_bw
    t = max(t_sense, t_res_int, t_res_ext)
    t_host = result_bytes / ssd.host_compute_bw if wl.host_postprocess else 0.0
    e_mws = (
        sum(
            # threshold sensings hold the read circuitry active for their
            # longer staircase sweep: energy scales with the same latency
            mws_energy_j(
                ssd.t_r_us, ssd.p_read_w, s.n_blocks, s.max_wls_per_block
            )
            * (
                _shape_latency_us(ssd, s)
                / mws_latency_us(ssd.t_r_us, s.n_blocks, s.max_wls_per_block)
            )
            * cnt
            for s, cnt in cmd_pairs
        )
        * positions
        * ssd.num_planes
        * Q
    )
    e = (
        ssd.p_host_active_w * t_host
        + ssd.p_host_idle_w * max(0.0, t - t_host)
        + e_mws
        + result_bytes * 8 * (ssd.e_dma_per_bit + ssd.e_ext_per_bit)
        + ssd.p_ssd_idle_w * t
    )
    return PlatformResult(
        platform,
        t,
        e,
        {
            "t_sense": t_sense,
            "t_result_ext": t_res_ext,
            "mws_commands": sum(cnt for _, cnt in cmd_pairs),
            "bottleneck": "sense" if t_sense >= t_res_ext else "external-io",
            "useful_bits": useful_bits,
        },
    )


def fig7_timeline(ssd: SSDConfig) -> dict:
    """Per-channel segment durations for the Fig. 7 walk-through (3 × 1 MiB
    OR): returns the per-die tR/tDMA/tEXT figures and each platform's
    channel-level bottleneck time for one batch of 32 KiB per die."""
    batch_bytes = ssd.planes_per_die * ssd.page_bytes  # 32 KiB per die
    t_dma = batch_bytes / ssd.channel_bw
    t_ext = batch_bytes / ssd.ext_bw
    dies = ssd.dies_per_channel
    return {
        "tR_us": ssd.t_r_us,
        "tDMA_us": t_dma * 1e6,
        "tEXT_us": t_ext * 1e6,
        # one sensing round across the channel's dies:
        "osp_round_us": max(ssd.t_r_us, dies * t_dma * 1e6)
        + dies * t_ext * 1e6 * ssd.channels,  # ext shared by 8 channels
        "isp_round_us": max(ssd.t_r_us, dies * t_dma * 1e6),
        "ifp_round_us": ssd.t_r_us,
    }
