"""MWS latency and power models calibrated to the paper's measurements.

Anchor points (all stated in §5.2):

* intra-block (Fig. 12): single-WL read without randomization needs no extra
  latency; ≤ 8 WLs < +1%; all 48 WLs +3.3%.
* inter-block (Fig. 13): WL-precharge hidden by BL-precharge up to ~8 blocks;
  4 blocks +3.3%; 32 blocks +36.3% (≪ 32× for serial reads).
* power (Fig. 14): 1→2 blocks +34%; 4 blocks ≈ +80% (< erase power);
  4-block MWS saves ~53% energy vs 4 serial reads.

Between anchors we interpolate piecewise-linearly — the paper publishes only
these points, and every consumer in this repo (benchmarks, platform model)
asserts against the anchors, not the interpolation.
"""

from __future__ import annotations

import numpy as np

# (n_wls, tMWS/tR - 1) anchors for intra-block MWS (Fig. 12)
_INTRA_ANCHORS = [(1, 0.0), (8, 0.008), (48, 0.033)]
# (n_blocks, tMWS/tR - 1) anchors for inter-block MWS (Fig. 13)
_INTER_ANCHORS = [(1, 0.0), (4, 0.033), (8, 0.049), (32, 0.363)]
# (n_blocks, P/P_read) anchors for inter-block MWS power (Fig. 14)
_POWER_ANCHORS = [(1, 1.0), (2, 1.34), (4, 1.80), (32, 8.24)]

ERASE_POWER_RATIO = 1.9  # erase power ceiling: 4-block MWS stays below it


def _interp(anchors, x: float) -> float:
    xs = np.array([a[0] for a in anchors], dtype=float)
    ys = np.array([a[1] for a in anchors], dtype=float)
    if x >= xs[-1]:  # extrapolate with the final slope
        slope = (ys[-1] - ys[-2]) / (xs[-1] - xs[-2])
        return float(ys[-1] + slope * (x - xs[-1]))
    return float(np.interp(x, xs, ys))


def intra_block_tmws_ratio(n_wls: int) -> float:
    """tMWS / tR for an intra-block MWS over ``n_wls`` wordlines."""
    return 1.0 + _interp(_INTRA_ANCHORS, n_wls)


def inter_block_tmws_ratio(n_blocks: int) -> float:
    """tMWS / tR for an inter-block MWS over ``n_blocks`` blocks."""
    return 1.0 + _interp(_INTER_ANCHORS, n_blocks)


def mws_power_ratio(n_blocks: int, n_wls_intra: int = 1) -> float:
    """MWS power / regular-read power.

    Inter-block activation dominates (more WLs precharged); intra-block MWS
    is slightly *cheaper* than a read (extra target WLs get V_REF instead of
    the much higher V_PASS, §4.1).
    """
    p = _interp(_POWER_ANCHORS, n_blocks)
    p -= 0.002 * max(0, n_wls_intra - 1)  # small intra-block discount
    return max(p, 0.5)


def mws_latency_us(
    t_r_us: float, n_blocks: int, max_wls_per_block: int
) -> float:
    """Latency of one MWS command (the slower of the two effects governs)."""
    ratio = max(
        inter_block_tmws_ratio(n_blocks),
        intra_block_tmws_ratio(max_wls_per_block),
    )
    return t_r_us * ratio


def mws_energy_j(
    t_r_us: float, p_read_w: float, n_blocks: int, max_wls_per_block: int
) -> float:
    """Energy of one MWS command on one plane."""
    t = mws_latency_us(t_r_us, n_blocks, max_wls_per_block) * 1e-6
    return t * p_read_w * mws_power_ratio(n_blocks, max_wls_per_block)


# ---------------------------------------------------------------------------
# Threshold sensing (MCFlash dynamic sensing thresholds)
# ---------------------------------------------------------------------------
#
# A k-of-N sense replaces the wired-OR cross-block combine with a
# programmable current comparison: the sense amplifier must settle a
# reference ladder and resolve the summed block current, so one threshold
# sense costs several plain-read times of setup plus a small per-block
# current-resolution term.  Still FAR cheaper than the C(N, k) And/Or
# chain it replaces once N grows — the cost model prices both and keeps
# the cheaper form.
THRESH_SETUP_RATIO = 6.0  # reference-ladder settle, in units of tR
THRESH_PER_BLOCK_RATIO = 0.15  # per-block current resolution, units of tR


def threshold_latency_us(
    t_r_us: float, n_blocks: int, max_wls_per_block: int
) -> float:
    """Latency of one k-of-N threshold sensing command."""
    return mws_latency_us(t_r_us, n_blocks, max_wls_per_block) + t_r_us * (
        THRESH_SETUP_RATIO - 1.0 + THRESH_PER_BLOCK_RATIO * n_blocks
    )


# ---------------------------------------------------------------------------
# Multi-level (MLC/TLC) packing factors
# ---------------------------------------------------------------------------


def level_read_factor(levels: int) -> float:
    """Sense-time scale for an L-level page, per logical page sensed.

    Resolving L bits per cell needs a (2^L - 1)-step reference staircase
    that yields L logical pages: (2^L - 1) / L reads' worth of staircase
    per page — 1.0 (SLC), 1.5 (MLC), ~2.33 (TLC).
    """
    return (2.0**levels - 1.0) / levels


def level_program_factor(levels: int) -> float:
    """Program-time scale for an L-level page, per physical program.

    ISPP needs finer verify steps as the per-level margin shrinks; the
    paper's Table 1 tPROG SLC:MLC:TLC = 200:500:700 is roughly linear in
    the level count — modelled as (1 + L) / 2: 1.0, 1.5, 2.0.
    """
    return (1.0 + levels) / 2.0
