"""The paper's three real-world workloads (§7): BMI, IMS, KCS.

Each workload compiles its bulk bitwise expression with the *actual*
Flash-Cosmos planner (``repro.core.planner``) against the paper's placement
policy, so the simulated FC command counts come from the same code path that
executes on the TPU engine — not from a hand-derived formula.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.commands import MWSCommand, ThresholdCommand
from repro.core.expr import Page, and_, or_
from repro.core.placement import Layout
from repro.core.planner import Planner


@dataclass(frozen=True)
class MWSCommandShape:
    """What the timing model needs to know about one MWS command.

    ``threshold_k > 0`` marks a k-of-N threshold sensing (§ESP-style
    one-shot vote across blocks): same wordline-select setup as MWS, but
    the timing model prices the staircase sense-amp reference sweep via
    :func:`repro.flashsim.timing.threshold_latency_us` instead of the
    plain inter-block read.  ``0`` (the default) is an ordinary MWS read.
    """

    n_blocks: int
    max_wls_per_block: int
    threshold_k: int = 0


@dataclass(frozen=True)
class BulkBitwiseWorkload:
    name: str
    num_operands: int  # operand vectors sensed per query (PB/OSP/ISP path)
    operand_bits: int  # bits per operand vector
    result_bits: int  # result bits transferred to host, per query
    num_queries: int = 1
    host_postprocess: bool = False  # e.g. BMI bit-count on the host
    fc_commands: tuple[MWSCommandShape, ...] = field(default_factory=tuple)
    # weighted alternative to fc_commands for long traces: (shape, count)
    # pairs keep the workload O(distinct shapes) instead of O(commands)
    fc_command_counts: tuple[tuple[MWSCommandShape, int], ...] = field(
        default_factory=tuple
    )
    # sanity metadata
    fc_sensing_ops: int = 0

    @property
    def fc_command_pairs(self) -> tuple[tuple[MWSCommandShape, int], ...]:
        if self.fc_command_counts:
            return self.fc_command_counts
        return tuple((s, 1) for s in self.fc_commands)


def _shapes_from_plan(plan) -> tuple[MWSCommandShape, ...]:
    shapes = []
    for c in plan.commands:
        if isinstance(c, MWSCommand):
            shapes.append(
                MWSCommandShape(
                    n_blocks=c.num_blocks,
                    max_wls_per_block=max(
                        len(t.wordlines) for t in c.targets
                    ),
                    threshold_k=getattr(c, "k", 0)
                    if isinstance(c, ThresholdCommand)
                    else 0,
                )
            )
    return tuple(shapes)


def bmi_workload(months: int, users: int = 800_000_000) -> BulkBitwiseWorkload:
    """Bitmap Index: AND over d daily activity vectors + host bit-count.

    d = days in the past ``months`` months (paper: 30 … 1095 operands for
    m = 1 … 36); vectors of one bit per user.
    """
    d = round(30.4166 * months)
    layout = Layout()
    names = [f"day{i}" for i in range(d)]
    layout.place_colocated(names, inverted=False)  # §6.3 placement rule
    expr = and_(*map(Page, names))
    plan = Planner(layout).compile(expr)
    return BulkBitwiseWorkload(
        name=f"BMI(m={months})",
        num_operands=d,
        operand_bits=users,
        result_bits=users,
        num_queries=1,
        host_postprocess=True,  # bit-count overlapped with result transfer
        fc_commands=_shapes_from_plan(plan),
        fc_sensing_ops=plan.num_sensing_ops,
    )


def ims_workload(images: int) -> BulkBitwiseWorkload:
    """Image Segmentation: Y·U·V bitwise AND over three bit vectors of
    images × 800 × 600 pixels × 4 colors bits each."""
    bits = images * 800 * 600 * 4
    layout = Layout()
    names = ["Y", "U", "V"]
    layout.place_colocated(names, inverted=False)
    plan = Planner(layout).compile(and_(*map(Page, names)))
    return BulkBitwiseWorkload(
        name=f"IMS(I={images})",
        num_operands=3,
        operand_bits=bits,
        result_bits=bits,
        num_queries=1,
        host_postprocess=False,
        fc_commands=_shapes_from_plan(plan),
        fc_sensing_ops=plan.num_sensing_ops,
    )


def kcs_workload(
    k: int, vertices: int = 32_000_000, cliques: int = 1024
) -> BulkBitwiseWorkload:
    """K-Clique Star listing: per clique, AND of the k members' adjacency
    vectors OR'd with the clique's own vector — both ops in one inter-block
    MWS when the clique vector lives in a different block (paper §7)."""
    layout = Layout()
    adj = [f"adj{i}" for i in range(k)]
    layout.place_colocated(adj, inverted=False)
    layout.place_spread(["clique"])
    expr = or_(and_(*map(Page, adj)), Page("clique"))
    plan = Planner(layout).compile(expr)
    return BulkBitwiseWorkload(
        name=f"KCS(k={k})",
        num_operands=k + 1,
        operand_bits=vertices,
        result_bits=vertices,
        num_queries=cliques,
        host_postprocess=False,
        fc_commands=_shapes_from_plan(plan),
        fc_sensing_ops=plan.num_sensing_ops,
    )
