"""SSD / NAND geometry and timing constants (paper Table 1)."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class SSDConfig:
    # -- organization (Table 1: 48-WL-layer 3D TLC NAND SSD, 2 TB) ---------
    channels: int = 8
    dies_per_channel: int = 8
    planes_per_die: int = 2
    blocks_per_plane: int = 2048
    wls_per_block: int = 48  # sub-block = compute granularity (paper §2.1)
    subblocks_per_block: int = 4  # 196 = 4 × 48 WLs per (full) block
    page_bytes: int = 16 * 1024

    # -- latencies (Table 1) -------------------------------------------------
    t_r_us: float = 22.5  # SLC-mode page read
    t_mws_us: float = 25.0  # MWS with the ≤4-block inter-block limit
    t_prog_slc_us: float = 200.0
    t_prog_mlc_us: float = 500.0
    t_prog_tlc_us: float = 700.0
    t_esp_us: float = 400.0
    t_bers_ms: float = 4.0  # block erase (3–5 ms, §2.1)

    # -- bandwidths (Table 1) -----------------------------------------------
    channel_bw: float = 1.2e9  # B/s per channel
    ext_bw: float = 8.0e9  # B/s host link (4-lane PCIe Gen4)

    # -- limits ----------------------------------------------------------------
    max_inter_blocks: int = 4  # power budget (§5.2 / Fig. 14)

    # -- power/energy constants (documented estimates; §Energy in DESIGN) --
    p_read_w: float = 0.0825  # per-plane active sense power (≈25 mA @ 3.3 V)
    p_prog_w: float = 0.165  # per-plane program power (~2x read: ISPP pulses)
    e_dma_per_bit: float = 8e-12  # ONFI channel I/O
    e_ext_per_bit: float = 15e-12  # PCIe + SSD controller
    e_accel_per_64b: float = 93e-12  # ISP accelerator (Table 1)
    p_host_active_w: float = 100.0  # i7-11700K package+DRAM under load
    p_host_idle_w: float = 15.0
    p_ssd_idle_w: float = 2.0
    host_compute_bw: float = 20e9  # host bulk-bitwise/bit-count B/s (DRAM-bw)

    # -- derived ----------------------------------------------------------
    @property
    def num_planes(self) -> int:
        return self.channels * self.dies_per_channel * self.planes_per_die

    @property
    def internal_bw(self) -> float:
        return self.channels * self.channel_bw  # 9.6 GB/s (Table 1)

    @property
    def page_bits(self) -> int:
        return self.page_bytes * 8

    @property
    def e_sense_page(self) -> float:
        """Energy of one SLC page sense (J)."""
        return self.p_read_w * self.t_r_us * 1e-6

    def pages_per_plane(self, vector_bits: int) -> int:
        """Page positions per plane for a bit vector striped over all planes."""
        total_pages = -(-vector_bits // self.page_bits)
        return -(-total_pages // self.num_planes)


DEFAULT_SSD = SSDConfig()


# The Fig. 7 walk-through example uses a smaller SSD (4 dies/channel = 64
# planes) with tR = 60 µs; kept separate so the timeline benchmark can
# reproduce the figure's numbers exactly (tDMA = 27 µs, tEXT = 4 µs).
FIG7_SSD = SSDConfig(dies_per_channel=4, t_r_us=60.0)
