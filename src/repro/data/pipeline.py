"""Training data pipeline with a Flash-Cosmos bitmap index.

The corpus is synthetic (deterministic hash-generated token streams — no
external data), but the *selection* layer is the paper's BMI workload made
into a real substrate: every sample carries metadata predicate bit-planes
(language, quality tier, length bucket, dedup flag, …) stored packed; batch
construction ANDs the enabled predicates with one fused MWS reduction and
gathers the selected sample indices.

This is how the paper's technique becomes a first-class training feature:
on a Flash-Cosmos SSD the filter runs in-flash and only matching samples
move to the host; here the same expression executes on the TPU engine.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import BitOp, pack_bits, unpack_bits
from repro.kernels.mws import mws_reduce
from repro.kernels.popcount import popcount

PREDICATES = (
    "lang_en",
    "quality_high",
    "len_ok",
    "dedup_ok",
    "license_ok",
    "not_toxic",
)


@dataclass
class BitmapIndex:
    """Packed per-sample predicate planes: (num_predicates, W) uint32."""

    planes: jax.Array
    num_samples: int
    names: tuple[str, ...] = PREDICATES

    @classmethod
    def synthesize(cls, num_samples: int, seed: int = 0, density=0.8):
        rng = np.random.default_rng(seed)
        bits = (
            rng.random((len(PREDICATES), num_samples)) < density
        ).astype(np.uint8)
        planes = jnp.stack([pack_bits(jnp.asarray(b)) for b in bits])
        return cls(planes=planes, num_samples=num_samples)

    def select(self, predicates: list[str]) -> jax.Array:
        """Fused multi-operand AND over the enabled predicate planes (the
        BMI query); returns the packed eligibility plane."""
        idx = [self.names.index(p) for p in predicates]
        return mws_reduce(self.planes[jnp.array(idx)], BitOp.AND)

    def count(self, predicates: list[str]) -> int:
        return int(popcount(self.select(predicates)))

    def eligible_indices(self, predicates: list[str]) -> np.ndarray:
        mask = unpack_bits(self.select(predicates), self.num_samples)
        return np.nonzero(np.asarray(mask))[0]


@dataclass
class SyntheticCorpus:
    """Deterministic token stream per sample id (splitmix-style hashing)."""

    vocab: int
    seq_len: int
    num_samples: int = 65536
    index: BitmapIndex = field(default=None)

    def __post_init__(self):
        if self.index is None:
            self.index = BitmapIndex.synthesize(self.num_samples)

    def sample_tokens(self, sample_id: int) -> np.ndarray:
        rng = np.random.default_rng(np.uint64(0x9E3779B9) * np.uint64(sample_id + 1))
        return rng.integers(
            0, self.vocab, self.seq_len + 1, dtype=np.int64
        )

    def batches(self, batch_size: int, predicates=("lang_en", "quality_high")):
        """Yield filtered next-token batches forever."""
        eligible = self.index.eligible_indices(list(predicates))
        assert eligible.size >= batch_size, "filter too strict"
        cursor = 0
        while True:
            ids = eligible[
                (cursor + np.arange(batch_size)) % eligible.size
            ]
            cursor += batch_size
            toks = np.stack([self.sample_tokens(int(i)) for i in ids])
            yield {
                "inputs": {"tokens": jnp.asarray(toks[:, :-1], jnp.int32)},
                "labels": jnp.asarray(toks[:, 1:], jnp.int32),
            }
