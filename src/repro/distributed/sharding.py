"""Logical-axis sharding rules resolved against the active mesh.

Model code annotates params/activations with *logical* axes ("fsdp",
"model", "data"); this module rewrites them to the physical mesh axes:

* ``fsdp``  -> ("pod", "data") on the multi-pod mesh, ("data",) on a single
  pod, dropped on meshes without a data axis (CPU smoke tests).
* ``model`` -> "model" when present, else dropped.
* ``data``  -> ("pod", "data") / ("data",) for activation batch dims.

Dropping an axis = replication along it, so the same model code runs on a
1-device CPU and a 512-chip two-pod mesh.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

LOGICAL_FSDP = "fsdp"
LOGICAL_TP = "model"
LOGICAL_DP = "data"

_ACTIVE_MESH: list[Mesh] = []


@contextlib.contextmanager
def active_mesh(mesh: Mesh):
    """Enter a mesh for both legacy (``with mesh:``) resolution and the
    logical-axis ``constrain`` helper."""
    _ACTIVE_MESH.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _ACTIVE_MESH.pop()


def current_mesh() -> Mesh | None:
    return _ACTIVE_MESH[-1] if _ACTIVE_MESH else None


def _physical(entry, mesh_axes: tuple[str, ...]):
    if entry is None:
        return None
    entries = entry if isinstance(entry, tuple) else (entry,)
    out: list[str] = []
    for e in entries:
        if e in (LOGICAL_FSDP, LOGICAL_DP):
            if "pod" in mesh_axes and "data" in mesh_axes:
                out.extend(["pod", "data"])
            elif "data" in mesh_axes:
                out.append("data")
        elif e == LOGICAL_TP:
            if "model" in mesh_axes:
                out.append("model")
        elif e in mesh_axes:
            out.append(e)
    if not out:
        return None
    return tuple(out) if len(out) > 1 else out[0]


def resolve_spec(spec: P, mesh: Mesh) -> P:
    axes = tuple(mesh.axis_names)
    return P(*[_physical(e, axes) for e in spec])


def resolve_tree(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: resolve_spec(s, mesh),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def shardings_tree(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, mesh)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def constrain(x: jax.Array, *entries) -> jax.Array:
    """with_sharding_constraint with logical axes; no-op outside a mesh."""
    mesh = current_mesh()
    if mesh is None or len(mesh.devices.flatten()) == 1:
        return x
    spec = resolve_spec(P(*entries), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def stacked(spec_tree: Any) -> Any:
    """Prepend an unsharded leading (layer-stack) dim to every spec."""
    return jax.tree.map(
        lambda s: P(None, *s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
