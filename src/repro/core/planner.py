"""Expression -> Flash-Cosmos command-plan compiler (paper §6.1–6.2, Fig. 16).

Compilation model:

* A **unit** is a subexpression computable by ONE MWS command given the
  layout: a page read; an intra-block AND (plain pages, one block); a
  De-Morgan OR (inverted pages, one block, inverse read); an inter-block
  OR-of-string-ANDs (≤ 4 blocks, Eq. 1).
* Outer **AND** chains units in the S-latch (first command inits S, the rest
  accumulate — ParaBit-AND semantics).  Only the FIRST command of an S-chain
  may use inverse read (§6.2 ordering rule); additional inverse units are
  *spilled*: computed by their own chain and ESP-programmed into a scratch
  page, then re-sensed as a plain operand.
* Outer **OR** runs one command per unit, accumulating in the C-latch via
  the move-S-to-C path (ParaBit-OR semantics); every command re-inits S, so
  any number of inverse-read units is fine.  Plain intra-AND units in
  distinct blocks are merged ≤ 4-per-command into inter-block MWS (Eq. 1).
* Outer **XOR** senses one unit at a time and folds with the inter-latch
  XOR command (§6.1).
* NAND/NOR/XNOR: single-unit cases use inverse read directly; multi-command
  chains apply the final complement during DMA (controller-side inverter —
  no extra flash-array operation).

Deeper nesting spills subexpression results to scratch pages.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.bitops import BitOp
from repro.core.commands import (
    MAX_INTER_BLOCKS,
    ISCM,
    BlockPBM,
    CommandPlan,
    MWSCommand,
    SpillCommand,
    ThresholdCommand,
    TransferCommand,
    XORCommand,
)
from repro.core.expr import Expr, Node, Page, Threshold
from repro.core.placement import Layout


@dataclass(frozen=True)
class Unit:
    """One-MWS-command realization of a subexpression."""

    targets: tuple[BlockPBM, ...]
    inverse: bool


def _merge_pbms(pbms: list[BlockPBM]) -> tuple[BlockPBM, ...]:
    by_block: dict[int, int] = {}
    for t in pbms:
        by_block[t.block] = by_block.get(t.block, 0) | t.pbm
    return tuple(BlockPBM(b, m) for b, m in sorted(by_block.items()))


def _as_unit(e: Expr, layout: Layout) -> Unit | None:
    """Try to realize ``e`` as a single MWS command; None if impossible."""
    if isinstance(e, Page):
        p = layout[e.name]
        return Unit((BlockPBM(p.block, 1 << p.wordline),), p.inverted)

    if isinstance(e, Threshold):
        return None  # always its own ThresholdCommand, never a plain MWS

    assert isinstance(e, Node)
    kids = e.children
    if len(kids) == 1 and e.op in (BitOp.NAND, BitOp.NOR):  # NOT
        inner = _as_unit(kids[0], layout)
        if inner is None:
            return None
        return Unit(inner.targets, not inner.inverse)

    if not all(isinstance(k, Page) for k in kids):
        # OR over intra-block AND groups (Eq. 1) — each child AND-unit must
        # own a distinct block.
        if e.op.base is BitOp.OR and all(
            isinstance(k, (Node, Page)) for k in kids
        ):
            units = []
            for k in kids:
                u = _as_unit(k, layout)
                if (
                    u is None
                    or u.inverse
                    or len(u.targets) != 1
                ):
                    return None
                units.append(u)
            blocks = [u.targets[0].block for u in units]
            if len(set(blocks)) != len(blocks):
                return None
            if len(blocks) > MAX_INTER_BLOCKS:
                return None
            return Unit(
                _merge_pbms([u.targets[0] for u in units]),
                e.op is BitOp.NOR,
            )
        return None

    placements = [layout[k.name] for k in kids]
    base = e.op.base

    if base is BitOp.AND:
        if any(p.inverted for p in placements):
            return None  # AND wants plain storage
        blocks = {p.block for p in placements}
        if len(blocks) != 1:
            return None  # AND across blocks needs an S-chain
        pbm = 0
        for p in placements:
            pbm |= 1 << p.wordline
        return Unit(
            (BlockPBM(placements[0].block, pbm),), e.op is BitOp.NAND
        )

    if base is BitOp.OR:
        if all(p.inverted for p in placements):
            blocks = {p.block for p in placements}
            if len(blocks) == 1:  # De Morgan: inverse read of AND of A̅_i
                pbm = 0
                for p in placements:
                    pbm |= 1 << p.wordline
                return Unit(
                    (BlockPBM(placements[0].block, pbm),),
                    e.op is BitOp.OR,  # inverse => OR; plain sense => NOR
                )
            return None
        if all(not p.inverted for p in placements):
            blocks = [p.block for p in placements]
            if len(set(blocks)) == len(blocks) and len(blocks) <= MAX_INTER_BLOCKS:
                return Unit(
                    _merge_pbms(
                        [BlockPBM(p.block, 1 << p.wordline) for p in placements]
                    ),
                    e.op is BitOp.NOR,
                )
        return None

    return None  # XOR is never a single sensing


class Planner:
    def __init__(self, layout: Layout):
        self.layout = layout

    # -- public -----------------------------------------------------------
    def compile(self, e: Expr) -> CommandPlan:
        plan = CommandPlan()
        self._compile_into(e, plan, top=True)
        plan.commands.append(
            TransferCommand(plan.result_source, plan.result_invert)
        )
        return plan

    # -- internals ----------------------------------------------------------
    def _spill(self, e: Expr, plan: CommandPlan) -> Page:
        """Compute a subexpression with its own chain and ESP-program the
        result into a scratch page; returns the scratch leaf."""
        sub = CommandPlan()
        self._compile_into(e, sub, top=False)
        plan.commands.extend(sub.commands)
        name, block, wl = self.layout.alloc_scratch()
        self.layout.place(name, block, wl, inverted=sub.result_invert)
        plan.commands.append(
            SpillCommand(block, wl, name, source=sub.result_source)
        )
        return Page(name)

    def _units_or_spill(
        self, kids: tuple[Expr, ...], plan: CommandPlan
    ) -> list[Unit]:
        units = []
        for k in kids:
            u = _as_unit(k, self.layout)
            if u is None:
                leaf = self._spill(k, plan)
                u = _as_unit(leaf, self.layout)
                assert u is not None
            units.append(u)
        return units

    def _plain_single_unit(
        self, child: Expr, plan: CommandPlan, force: bool = False
    ) -> Unit:
        """A PLAIN single-block unit for ``child``, spilling if needed.

        Threshold sensing counts conducting blocks, so every operand must
        occupy its own block and conduct exactly when its value is 1 —
        inverted storage, multi-block units, and (with ``force``) block
        collisions are resolved by ESP-spilling to a fresh scratch block.
        """
        if not force:
            u = _as_unit(child, self.layout)
            if u is not None and not u.inverse and len(u.targets) == 1:
                return u
        leaf = self._spill(child, plan)
        u = _as_unit(leaf, self.layout)
        if u.inverse:  # NAND/NOR/XNOR-rooted spill: re-sense + re-spill plain
            leaf = self._spill(Node(BitOp.AND, (leaf,)), plan)
            u = _as_unit(leaf, self.layout)
        assert u is not None and not u.inverse and len(u.targets) == 1
        return u

    def _threshold_parts(
        self, e: Threshold, plan: CommandPlan
    ) -> tuple[tuple[BlockPBM, ...], int, bool]:
        """Resolve a Threshold's children to ThresholdCommand parameters.

        Fast path: when EVERY child is an inverted single-block unit in a
        distinct block, fold the polarity into the threshold instead of
        spilling — a block then conducts iff its child is 0, and

            #set >= k  <=>  N - #conducting >= k
                       <=>  NOT (#conducting >= N - k + 1)

        so the command uses k' = N-k+1 with inverse read (complement after
        the comparison).  Otherwise children are normalized to plain units
        in distinct blocks via :meth:`_plain_single_unit`.
        """
        units = [_as_unit(c, self.layout) for c in e.children]
        if all(
            u is not None and u.inverse and len(u.targets) == 1
            for u in units
        ):
            blocks = [u.targets[0].block for u in units]
            if len(set(blocks)) == len(blocks):
                return (
                    tuple(u.targets[0] for u in units),
                    len(units) - e.k + 1,
                    True,
                )
        out: list[Unit] = []
        seen: set[int] = set()
        for child, u in zip(e.children, units):
            force = u is None or u.inverse or len(u.targets) != 1
            u = self._plain_single_unit(child, plan, force=force)
            if u.targets[0].block in seen:
                u = self._plain_single_unit(child, plan, force=True)
            seen.add(u.targets[0].block)
            out.append(u)
        return tuple(u.targets[0] for u in out), e.k, False

    def _compile_threshold(self, e: Threshold, plan: CommandPlan) -> None:
        targets, k, inverse = self._threshold_parts(e, plan)
        plan.commands.append(
            ThresholdCommand(ISCM(inverse_read=inverse), targets, k=k)
        )
        plan.result_source = "S"
        plan.result_invert = False

    def _compile_into(self, e: Expr, plan: CommandPlan, top: bool) -> None:
        if isinstance(e, Page):
            e = Node(BitOp.AND, (e,))
        if isinstance(e, Threshold):
            self._compile_threshold(e, plan)
            return
        u = _as_unit(e, self.layout)
        if u is not None:
            plan.commands.append(
                MWSCommand(ISCM(inverse_read=u.inverse), u.targets)
            )
            plan.result_source = "S"
            plan.result_invert = False
            return

        base = e.op.base
        if base is BitOp.AND:
            self._compile_and_chain(e, plan)
        elif base is BitOp.OR:
            self._compile_or_chain(e, plan)
        else:
            self._compile_xor_chain(e, plan)

    def _compile_and_chain(self, e: Node, plan: CommandPlan) -> None:
        kids = list(e.children)
        # A threshold sense resolves in the S-latch exactly like a plain
        # MWS, so ONE Threshold child may head the S-chain directly (no
        # scratch round-trip); further thresholds spill like any other
        # non-unit subexpression.
        thr_kids = [k for k in kids if isinstance(k, Threshold)]
        kids = [k for k in kids if not isinstance(k, Threshold)]
        head_cmd: ThresholdCommand | None = None
        if thr_kids:
            kids.extend(self._spill(t, plan) for t in thr_kids[1:])
            targets, tk, tinv = self._threshold_parts(thr_kids[0], plan)
            head_cmd = ThresholdCommand(
                ISCM(inverse_read=tinv, init_c_latch=False), targets, k=tk
            )
        # AND of plain same-... pages spread across blocks: group by block.
        grouped: list[Expr] = []
        by_block: dict[int, list[Page]] = {}
        for k in kids:
            if isinstance(k, Page) and not self.layout[k.name].inverted:
                by_block.setdefault(self.layout[k.name].block, []).append(k)
            else:
                grouped.append(k)
        for block_pages in by_block.values():
            grouped.append(
                block_pages[0]
                if len(block_pages) == 1
                else Node(BitOp.AND, tuple(block_pages))
            )
        units = self._units_or_spill(tuple(grouped), plan)
        inverse_units = [u for u in units if u.inverse]
        plain_units = [u for u in units if not u.inverse]
        # De Morgan merge (the Fig. 16 command-① pattern): AND of inverse
        # units == ONE inverse-read inter-block MWS over the union of their
        # targets — valid while blocks stay distinct and within the ≤4-block
        # power budget; otherwise start a new chunk.
        inv_cmds: list[tuple[BlockPBM, ...]] = []
        bucket: list[BlockPBM] = []
        blocks: set[int] = set()
        for u in inverse_units:
            tblocks = {t.block for t in u.targets}
            if blocks & tblocks or len(blocks | tblocks) > MAX_INTER_BLOCKS:
                inv_cmds.append(_merge_pbms(bucket))
                bucket, blocks = [], set()
            bucket.extend(u.targets)
            blocks |= tblocks
        if bucket:
            inv_cmds.append(_merge_pbms(bucket))
        # §6.2 ordering: the (single) inverse-read command must head the
        # S-chain; further inverse chunks are spilled and re-sensed plain.
        # When a ThresholdCommand heads the chain instead, EVERY inverse
        # chunk spills (the head slot is taken).
        if head_cmd is not None:
            ordered = list(plain_units)
            spill_chunks = inv_cmds
        else:
            ordered = (
                [Unit(inv_cmds[0], True)] if inv_cmds else []
            ) + plain_units
            spill_chunks = inv_cmds[1:]
        for extra in spill_chunks:
            # init_c_latch must stay False: when this AND chain is inlined
            # into an OR chain, a C-init here would wipe the partial OR.
            plan.commands.append(
                MWSCommand(
                    ISCM(inverse_read=True, init_c_latch=False), extra
                )
            )
            name, block, wl = self.layout.alloc_scratch()
            self.layout.place(name, block, wl)
            plan.commands.append(SpillCommand(block, wl, name, source="S"))
            ordered.append(_as_unit(Page(name), self.layout))
        if head_cmd is not None:
            plan.commands.append(head_cmd)
        for i, u in enumerate(ordered):
            plan.commands.append(
                MWSCommand(
                    ISCM(
                        inverse_read=u.inverse,
                        init_s_latch=(i == 0 and head_cmd is None),
                        init_c_latch=False,  # C-latch untouched by AND chains
                    ),
                    u.targets,
                )
            )
        plan.result_source = "S"
        plan.result_invert = e.op is BitOp.NAND

    def _compile_or_chain(self, e: Node, plan: CommandPlan) -> None:
        # Non-unit AND children can be inlined: run their S-chain and pulse
        # move-S-to-C only on the LAST command (intermediate partial ANDs
        # must not leak into the C-latch OR).  Chains whose own sub-plan
        # needs the C-latch (spilled OR/XOR subexpressions) CANNOT be
        # inlined — they would clobber the accumulating OR — and go through
        # the unit/spill path like everything else.
        unit_kids: list[Expr] = []
        inline_chains: list[tuple[Node, CommandPlan]] = []
        thr_parts: list[tuple[tuple[BlockPBM, ...], int, bool]] = []
        for k in e.children:
            if isinstance(k, Threshold):
                # a threshold sense lands in S like a plain MWS; OR it into
                # the C-latch directly (every OR command re-inits S, so any
                # number of thresholds is fine).  Child spills emitted here
                # run before the C accumulation starts.
                thr_parts.append(self._threshold_parts(k, plan))
                continue
            if (
                isinstance(k, Node)
                and k.op is BitOp.AND
                and _as_unit(k, self.layout) is None
            ):
                # Trial-compile against a layout snapshot: a rejected chain
                # must not leak its scratch placements (they would pile up
                # in a long-running service) nor advance the scratch
                # counter for pages that are recompiled via _spill below.
                snap = self.layout.snapshot()
                sub = CommandPlan()
                self._compile_and_chain(k, sub)
                if not any(
                    isinstance(c, XORCommand)
                    or (
                        isinstance(c, MWSCommand)
                        and (c.iscm.init_c_latch or c.iscm.move_s_to_c)
                    )
                    for c in sub.commands
                ):
                    inline_chains.append((k, sub))
                    continue
                self.layout.restore(snap)
            unit_kids.append(k)
        units = self._units_or_spill(tuple(unit_kids), plan)
        # Merge plain single-block units into inter-block commands (Eq. 1).
        plain = [u for u in units if not u.inverse and len(u.targets) == 1]
        others = [u for u in units if u.inverse or len(u.targets) > 1]
        merged: list[Unit] = []
        bucket: list[BlockPBM] = []
        seen_blocks: set[int] = set()
        for u in plain:
            t = u.targets[0]
            if t.block in seen_blocks or len(bucket) == MAX_INTER_BLOCKS:
                merged.append(Unit(_merge_pbms(bucket), False))
                bucket, seen_blocks = [], set()
            bucket.append(t)
            seen_blocks.add(t.block)
        if bucket:
            merged.append(Unit(_merge_pbms(bucket), False))
        all_units = merged + others
        first_c = True
        for u in all_units:
            plan.commands.append(
                MWSCommand(
                    ISCM(
                        inverse_read=u.inverse,
                        init_s_latch=True,
                        init_c_latch=first_c,
                        move_s_to_c=True,
                    ),
                    u.targets,
                )
            )
            first_c = False
        for targets, tk, tinv in thr_parts:
            plan.commands.append(
                ThresholdCommand(
                    ISCM(
                        inverse_read=tinv,
                        init_s_latch=True,
                        init_c_latch=first_c,
                        move_s_to_c=True,
                    ),
                    targets,
                    k=tk,
                )
            )
            first_c = False
        for _chain, sub in inline_chains:
            assert not sub.result_invert  # op is AND (not NAND) by filter
            cmds = [c for c in sub.commands if isinstance(c, MWSCommand)]
            last = cmds[-1]
            for c in sub.commands:
                if c is last:
                    iscm = ISCM(
                        inverse_read=last.iscm.inverse_read,
                        init_s_latch=last.iscm.init_s_latch,
                        init_c_latch=first_c,
                        move_s_to_c=True,
                    )
                    if isinstance(last, ThresholdCommand):
                        plan.commands.append(
                            ThresholdCommand(iscm, last.targets, k=last.k)
                        )
                    else:
                        plan.commands.append(MWSCommand(iscm, last.targets))
                else:
                    plan.commands.append(c)
            first_c = False
        plan.result_source = "C"
        plan.result_invert = e.op is BitOp.NOR

    def _compile_xor_chain(self, e: Node, plan: CommandPlan) -> None:
        units = self._units_or_spill(e.children, plan)
        for i, u in enumerate(units):
            plan.commands.append(
                MWSCommand(
                    ISCM(
                        inverse_read=u.inverse,
                        init_s_latch=True,
                        init_c_latch=(i == 0),
                        move_s_to_c=(i == 0),
                    ),
                    u.targets,
                )
            )
            if i > 0:
                plan.commands.append(XORCommand())
        plan.result_source = "C" if len(units) > 1 else "S"
        plan.result_invert = e.op is BitOp.XNOR
