"""Flash-Cosmos NAND command set (paper §6.2, Fig. 15).

Three new commands: ``MWS`` (multi-wordline sensing with ISCM flags and
per-block page bitmaps), ``ESP`` (enhanced SLC-mode program), ``XOR``
(inter-latch XOR).  The encodings below follow Fig. 15: an MWS command
carries an ISCM flag slot, then up to :data:`MAX_INTER_BLOCKS` (block
address, page-bitmap) slots chained with CONT and closed with CONF.
"""

from __future__ import annotations

from dataclasses import dataclass, field

MAX_INTER_BLOCKS = 4  # power-budget limit measured in §5.2 (Fig. 14)
WLS_PER_BLOCK = 48  # NAND-string length of the characterized chips
# Threshold sensing (MCFlash-style dynamic sensing) compares the summed
# bitline current of the activated blocks against a programmable reference
# instead of a fixed conduct/no-conduct cut, so its power envelope is the
# slower staircase sense, not the parallel-OR one — the characterized
# dynamic-sensing chips resolve up to 8 block currents in one shot.
THRESHOLD_MAX_BLOCKS = 8


@dataclass(frozen=True)
class ISCM:
    """The four ISCM feature flags of an MWS command (Fig. 15a)."""

    inverse_read: bool = False  # I: sense in inverse-read mode
    init_s_latch: bool = True  # S: initialize sensing latch before evaluate
    init_c_latch: bool = True  # C: initialize cache latch
    move_s_to_c: bool = False  # M: pulse M3 (S-latch -> C-latch transfer)

    def __post_init__(self):
        # §6.2: an inverse read requires S-latch initialization, which
        # prevents accumulation into the S-latch by an inverse-read command.
        if self.inverse_read and not self.init_s_latch:
            raise ValueError(
                "inverse read requires S-latch initialization (paper §6.2)"
            )


@dataclass(frozen=True)
class BlockPBM:
    """One address slot: block index + page bitmap of wordlines to sense."""

    block: int
    pbm: int  # bit i set => apply V_REF to wordline i (V_PASS otherwise)

    def __post_init__(self):
        if self.pbm <= 0 or self.pbm >= (1 << WLS_PER_BLOCK):
            raise ValueError(f"PBM out of range for {WLS_PER_BLOCK}-WL block")

    @property
    def wordlines(self) -> tuple[int, ...]:
        return tuple(i for i in range(WLS_PER_BLOCK) if (self.pbm >> i) & 1)


@dataclass(frozen=True)
class MWSCommand:
    iscm: ISCM
    targets: tuple[BlockPBM, ...]

    def __post_init__(self):
        if not 1 <= len(self.targets) <= MAX_INTER_BLOCKS:
            raise ValueError(
                f"MWS activates 1..{MAX_INTER_BLOCKS} blocks, got "
                f"{len(self.targets)} (power budget, §5.2)"
            )
        blocks = [t.block for t in self.targets]
        if len(set(blocks)) != len(blocks):
            raise ValueError("duplicate block address slots")

    @property
    def num_blocks(self) -> int:
        return len(self.targets)

    @property
    def num_wordlines(self) -> int:
        return sum(len(t.wordlines) for t in self.targets)


@dataclass(frozen=True)
class ThresholdCommand(MWSCommand):
    """k-of-N threshold sensing (MCFlash dynamic sensing thresholds).

    Bit ``j`` of the raw result is 1 iff at least ``k`` of the activated
    blocks conduct at position ``j`` — each block conducts iff ALL of its
    selected wordlines conduct, exactly as in a plain MWS, but the
    cross-block combine is a programmable current threshold instead of
    the fixed wired-OR (``k == 1`` degenerates to the MWS OR).
    ``iscm.inverse_read`` complements the result AFTER the comparison.
    """

    k: int = 1

    def __post_init__(self):
        if not 1 <= len(self.targets) <= THRESHOLD_MAX_BLOCKS:
            raise ValueError(
                f"threshold sensing activates 1..{THRESHOLD_MAX_BLOCKS} "
                f"blocks, got {len(self.targets)} (dynamic-sensing power "
                "envelope)"
            )
        blocks = [t.block for t in self.targets]
        if len(set(blocks)) != len(blocks):
            raise ValueError("duplicate block address slots")
        if not 1 <= self.k <= len(self.targets):
            raise ValueError(
                f"threshold k={self.k} outside 1..{len(self.targets)} blocks"
            )


@dataclass(frozen=True)
class XORCommand:
    """C-latch := S-latch XOR C-latch (existing on-chip XOR logic, §6.1)."""


@dataclass(frozen=True)
class ESPCommand:
    """Program one wordline with enhanced SLC-mode programming (§4.2)."""

    block: int
    wordline: int
    page_name: str
    tesp_ratio: float = 2.0  # tESP/tPROG; >= 1.9 guarantees zero errors


@dataclass(frozen=True)
class TransferCommand:
    """DMA the result latch to the controller; optionally invert in flight.

    The controller-side inversion is how the engine realizes a final NOT when
    the inverse-read slot is already used (free: the bus inverter costs no
    flash-array operation)."""

    source: str = "C"  # "S" or "C"
    invert: bool = False


@dataclass(frozen=True)
class SpillCommand:
    """Program the current result latch into a scratch page (ESP mode) so a
    later command chain can re-sense it — used when an expression needs more
    inverse-read groups than one S-latch chain allows."""

    block: int
    wordline: int
    page_name: str
    source: str = "S"


Command = (
    MWSCommand
    | ThresholdCommand
    | XORCommand
    | ESPCommand
    | TransferCommand
    | SpillCommand
)


@dataclass
class CommandPlan:
    commands: list[Command] = field(default_factory=list)
    result_source: str = "S"  # latch holding the final result
    result_invert: bool = False  # controller-side inversion on transfer

    @property
    def num_sensing_ops(self) -> int:
        return sum(1 for c in self.commands if isinstance(c, MWSCommand))

    @property
    def num_spills(self) -> int:
        return sum(1 for c in self.commands if isinstance(c, SpillCommand))
