"""Flash-Cosmos execution engine.

Executes a :class:`CommandPlan` with bit-exact latch semantics (paper
Figs. 3/4/6 and §6.2):

* MWS sensing: per target block, the NAND string conducts only if **all**
  selected cells conduct ⇒ AND of the block's selected wordlines; blocks
  share bitlines ⇒ OR across blocks; inverse read complements.
* S-latch: ``S = raw`` when initialized, else ``S & raw`` (ParaBit-AND).
* move-S-to-C: ``C = S`` when C initialized, else ``C | S`` (ParaBit-OR).
* XOR command: ``C = S ^ C``.
* Spill: ESP-program a latch into a scratch page.
* Transfer: DMA out, optional controller-side inversion.

The engine stores *logical* page data; physical cell data is complemented
for pages placed ``inverted`` (De Morgan storage).  Reads of non-ESP pages
can inject modelled bit errors (``repro.core.reliability``); ESP pages are
error-free — the paper's headline reliability result.

Page data lives in a :class:`repro.core.store.PackedStore` — one contiguous
``(slots, words)`` array — so sensing is a *gather* of the command's
wordline rows plus at most two fused MWS kernel dispatches (AND within
blocks, OR across blocks), never a Python loop over pages.  The ragged
per-block wordline sets are padded to a rectangle with the store's all-ones
identity row, letting one kernel call cover every target block.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import BitOp
from repro.core.commands import (
    CommandPlan,
    ESPCommand,
    MWSCommand,
    SpillCommand,
    ThresholdCommand,
    TransferCommand,
    XORCommand,
)
from repro.core.expr import Expr, Node, Page, Threshold
from repro.core.placement import Layout
from repro.core.planner import Planner
from repro.core.reliability import (
    CellMode,
    ProgramConfig,
    inject_bit_errors,
    rber,
)
from repro.core.store import IDENTITY_SLOT, PackedStore
from repro.kernels.mws import mws_reduce
from repro.kernels.threshold import bitslice_threshold, threshold_reduce


def _stable_seed(name: str) -> int:
    """Deterministic per-page seed component.

    ``hash(str)`` varies with ``PYTHONHASHSEED``, which made reliability
    simulations irreproducible across interpreter runs; CRC32 is stable.
    """
    return zlib.crc32(name.encode("utf-8")) & 0xFFFF


def fused_block_reduce(
    cube: jax.Array, inverse: bool, *, interpret: bool = True
) -> jax.Array:
    """MWS semantics on a gathered ``(blocks, wordlines, words)`` cube.

    AND over the wordline axis of every block in ONE fused ``mws_reduce``
    dispatch (blocks ride along the word axis, so all planes and blocks are
    covered at once), then OR across blocks in a second dispatch;
    ``inverse`` complements the result (inverse-read mode).  Rows padded
    with the all-ones identity are AND-neutral.

    With ``interpret=True`` (no TPU) the Pallas interpreter's ~ms/call
    overhead would dominate query serving, so emulation folds with plain
    XLA ops instead — bit-identical to the kernel (the kernel tests assert
    exactly that) and efficient under ``jax.vmap``; on real hardware
    (``interpret=False``) the fused Pallas kernel is dispatched.
    """
    k, n, w = cube.shape
    if interpret:
        anded = cube[:, 0]
        for i in range(1, n):
            anded = anded & cube[:, i]
        raw = anded[0]
        for b in range(1, k):
            raw = raw | anded[b]
    else:
        flat = cube.swapaxes(0, 1).reshape(n, k * w)
        raw = mws_reduce(flat, BitOp.AND, interpret=False).reshape(k, w)
        raw = (
            mws_reduce(raw, BitOp.OR, interpret=False) if k > 1 else raw[0]
        )
    return ~raw if inverse else raw


def threshold_block_reduce(
    cube: jax.Array, k: int, inverse: bool, *, interpret: bool = True
) -> jax.Array:
    """k-of-N threshold sensing on a gathered ``(blocks, wls, words)`` cube.

    Stage one is identical to a plain MWS: each block's NAND strings AND
    its selected wordlines (identity-padded rows are AND-neutral).  The
    cross-block combine then sets bit j iff at least ``k`` blocks conduct
    at j (dynamic sensing threshold); ``k == 1`` reproduces the wired-OR
    exactly.  Blocks padded with the all-zeros row never conduct, so
    family/vmap shape padding can never count toward the threshold.
    ``inverse`` complements AFTER the comparison.

    Like :func:`fused_block_reduce`, emulation (``interpret=True``) folds
    with plain XLA ops — the same bit-sliced ripple-carry counter the
    Pallas kernel runs, so both paths are bit-identical by construction —
    while ``interpret=False`` dispatches the fused kernels.  Explicit
    folds throughout (no ``jnp.bitwise_*.reduce``).
    """
    kb, n, w = cube.shape
    if interpret:
        anded = cube[:, 0]
        for i in range(1, n):
            anded = anded & cube[:, i]
        raw = bitslice_threshold(anded, k, kb)[0]
    else:
        flat = cube.swapaxes(0, 1).reshape(n, kb * w)
        anded = mws_reduce(flat, BitOp.AND, interpret=False).reshape(kb, w)
        raw = threshold_reduce(anded, k, interpret=False)
    return ~raw if inverse else raw


@dataclass
class FlashArray:
    """A (single-plane) Flash-Cosmos array: layout + packed page store."""

    layout: Layout = field(default_factory=Layout)
    store: PackedStore = field(default_factory=PackedStore)
    program_configs: dict[str, ProgramConfig] = field(default_factory=dict)
    pec: dict[int, int] = field(default_factory=dict)  # block -> P/E cycles
    interpret: bool = True
    # names of non-ESP pages, maintained incrementally so hot paths never
    # scan program_configs (one entry per (column, value) bitmap adds up)
    _non_esp: set = field(default_factory=set, repr=False)
    # host-initiated ESP page programs (fc_write(esp=True) + fc_append):
    # incremental ingest is gated on this — appending B rows must program
    # O(B) pages, not O(num_rows) (delta-page programming)
    esp_programs: int = 0
    # whole-block erases issued by erase_rebuild (NAND programs only 1->0,
    # so reclaiming tombstoned rows means erasing every block a stripe
    # occupies and reprogramming the live data — compaction charges these
    # in the SSD projection at t_bers_ms)
    block_erases: int = 0

    # -- host API (fc_write / fc_read, §6.3) -------------------------------
    def fc_write(
        self,
        name: str,
        words: jax.Array,
        *,
        inverted: bool | None = None,
        block: int | None = None,
        wordline: int | None = None,
        esp: bool = True,
        charge: bool = True,
    ) -> None:
        """Program a page. ESP mode (default) guarantees error-free reads.

        Under multi-level packing (``layout.levels > 1``) the ESP margin
        stretches to ``tESP = (1 + levels) x tPROG`` — the per-level
        margin shrinks by 1/levels, so holding the paper's zero-error
        result needs the proportionally longer program (still zero-error
        per the reliability model at every supported level count).

        ``charge=False`` records the page content without bumping the
        wear/ESP counters: the MLC program path groups the co-resident
        logical pages of one physical page into ONE counted program (the
        group lead charges; the other levels ride the same ISPP pass).
        """
        if name in self.layout:
            p = self.layout[name]
            inverted = p.inverted if inverted is None else inverted
        else:
            inverted = bool(inverted)
            if block is None:
                (p,) = self.layout.place_colocated([name], inverted)
            else:
                p = self.layout.place(name, block, wordline or 0, inverted)
        levels = self.layout.levels
        cfg = (
            ProgramConfig(
                CellMode.SLC,
                randomized=False,
                tesp_ratio=1.0 + float(levels),
                levels=levels,
            )
            if esp
            else ProgramConfig(
                CellMode.SLC,
                randomized=False,
                tesp_ratio=1.0,
                levels=levels,
            )
        )
        self.program_configs[name] = cfg
        if esp:
            self._non_esp.discard(name)
        else:
            self._non_esp.add(name)
        physical = ~words if inverted else words
        self.store[name] = physical
        if charge:
            self.pec[p.block] = self.pec.get(p.block, 0) + 1
            if esp:
                self.esp_programs += 1

    def fc_append(
        self, name: str, words, *, start: int, charge: bool = True
    ) -> None:
        """Delta-page ESP program: extend an already-placed page's tail.

        Only ``words`` (logical, at word offset ``start``) are programmed —
        ONE page program's worth of traffic however many earlier words the
        page holds, which is what makes appending B rows to an N-row index
        cost O(B) instead of O(N).  The page keeps its placement, inversion,
        and program config; the store treats the write as a tail extension
        (compiled plans stay valid, see ``PackedStore.append_words``).
        ``charge=False`` as in :meth:`fc_write` (MLC physical-page groups).
        """
        p = self.layout[name]
        w = np.asarray(words, dtype=np.uint32)
        physical = ~w if p.inverted else w
        self.store.append_words(name, physical, start)
        if charge:
            self.pec[p.block] = self.pec.get(p.block, 0) + 1
            self.esp_programs += 1

    def fc_read(self, e: Expr) -> jax.Array:
        """Plan + execute a bulk bitwise expression; returns logical words."""
        plan = Planner(self.layout).compile(e)
        return self.execute(plan)

    def erase_rebuild(self) -> int:
        """Erase every programmed block and reset for a full reprogram.

        NAND programs cells 1->0 only; clearing a tombstone-riddled stripe
        back to fresh capacity requires erasing whole blocks (the erase
        unit) and reprogramming the surviving data — this is the device
        half of compaction.  Every block the layout occupies takes one P/E
        cycle (``pec``) and counts toward ``block_erases``; the page store
        and layout come back empty, but the store's content and region
        epochs are seeded ABOVE their old values, so every plan-cache /
        snapshot-cache key minted against the old data is permanently
        stale (a rebuild must never collide with a cached artifact of the
        pre-compaction page contents).  Returns the blocks erased.
        """
        blocks = {p.block for p in self.layout.placements.values()}
        for b in blocks:
            self.pec[b] = self.pec.get(b, 0) + 1
        self.block_erases += len(blocks)
        old = self.store
        self.store = PackedStore(planes=old.planes)
        self.store.epoch = old.epoch + 1
        self.store.region_epochs = {
            r: e + 1 for r, e in old.region_epochs.items()
        }
        self.layout = Layout(
            wls_per_block=self.layout.wls_per_block,
            levels=self.layout.levels,
        )
        self.program_configs.clear()
        self._non_esp.clear()
        return len(blocks)

    # -- sensing ------------------------------------------------------------
    def _gather_cube(
        self,
        cmd: MWSCommand,
        seed: int,
        scratch: dict[str, jax.Array] | None = None,
    ) -> jax.Array:
        """Gather the command's wordline rows into a padded (k, n, W) cube.

        Non-ESP pages get modelled bit errors injected on their gathered
        rows; ESP pages (the common case) come straight from the packed
        snapshot, so the gather is one fancy-index over the device array.
        ``scratch`` holds the device-resident values of pages spilled
        earlier in the executing plan — they live only in latch scratch
        (never the packed store), so their rows are substituted after the
        gather.  Spilled values are ESP-quality by construction (the spill
        IS an ESP program), so they never take injected errors.
        """
        snap = self.store.snapshot()
        n_max = max(len(t.wordlines) for t in cmd.targets)
        idx = []
        noisy: list[tuple[int, int, str]] = []
        subs: list[tuple[int, int, str]] = []
        for bi, t in enumerate(cmd.targets):
            row = []
            for wl in t.wordlines:
                name = self.layout.page_at(t.block, wl)
                if scratch is not None and name in scratch:
                    subs.append((bi, len(row), name))
                    row.append(IDENTITY_SLOT)  # placeholder, overwritten
                    continue
                row.append(self.store.slot(name))
                if name in self._non_esp:
                    noisy.append((bi, len(row) - 1, name))
            row.extend([IDENTITY_SLOT] * (n_max - len(row)))
            idx.append(row)
        cube = snap[jnp.asarray(idx)]
        for bi, wi, name in subs:
            cube = cube.at[bi, wi].set(scratch[name])
        for bi, wi, name in noisy:
            p = self.layout[name]
            r = rber(
                self.program_configs[name], pec=self.pec.get(p.block, 0)
            )
            cube = cube.at[bi, wi].set(
                inject_bit_errors(
                    cube[bi, wi], r, seed=seed ^ _stable_seed(name)
                )
            )
        return cube

    def _sense(
        self,
        cmd: MWSCommand,
        seed: int,
        scratch: dict[str, jax.Array] | None = None,
    ) -> jax.Array:
        cube = self._gather_cube(cmd, seed, scratch)
        if isinstance(cmd, ThresholdCommand):
            return threshold_block_reduce(
                cube, cmd.k, cmd.iscm.inverse_read, interpret=self.interpret
            )
        return fused_block_reduce(
            cube, cmd.iscm.inverse_read, interpret=self.interpret
        )

    # -- plan execution -------------------------------------------------------
    def execute(self, plan: CommandPlan, seed: int = 0) -> jax.Array:
        # Spilled sub-results stay device-resident for the plan's lifetime:
        # the SpillCommand's ESP program targets latch scratch, not the
        # packed store, so repeated executions of a cached spilling plan
        # never invalidate the store snapshot (the pre-pipeline engine
        # rewrote a store page per spill and re-uploaded the whole packed
        # buffer on the next sense).
        scratch: dict[str, jax.Array] = {}
        s = c = None
        out = None
        w = self.store.num_words
        for i, cmd in enumerate(plan.commands):
            if isinstance(cmd, MWSCommand):
                raw = self._sense(cmd, seed + i, scratch)
                s = raw if cmd.iscm.init_s_latch or s is None else s & raw
                if cmd.iscm.init_c_latch:
                    c = None  # M4 pulse wipes the cache latch (Fig. 6a)
                if cmd.iscm.move_s_to_c:
                    c = s if c is None else c | s
            elif isinstance(cmd, XORCommand):
                c = s ^ c
            elif isinstance(cmd, SpillCommand):
                # Keep the latch value as-is; when the sub-plan's logical
                # result is the complement of the latch, the planner
                # recorded that in the scratch page's layout.inverted flag
                # (spilled data is physical, like every stored page).
                scratch[cmd.page_name] = s if cmd.source == "S" else c
                self.pec[cmd.block] = self.pec.get(cmd.block, 0) + 1
            elif isinstance(cmd, TransferCommand):
                value = s if cmd.source == "S" else c
                out = ~value if cmd.invert else value
            elif isinstance(cmd, ESPCommand):
                pass  # data writes flow through fc_write in this model
        assert out is not None, "plan missing TransferCommand"
        return out[:w]


def eval_expr(e: Expr, logical: dict[str, jax.Array]) -> jax.Array:
    """Direct (oracle) evaluation of an expression on logical page data."""
    if isinstance(e, Page):
        return logical[e.name]
    if isinstance(e, Threshold):
        vals = jnp.stack([eval_expr(c, logical) for c in e.children])
        return bitslice_threshold(vals, e.k, vals.shape[0])[0]
    assert isinstance(e, Node)
    vals = jnp.stack([eval_expr(c, logical) for c in e.children])
    from repro.core.bitops import reduce_words

    return reduce_words(vals, e.op)
