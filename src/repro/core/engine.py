"""Flash-Cosmos execution engine.

Executes a :class:`CommandPlan` with bit-exact latch semantics (paper
Figs. 3/4/6 and §6.2):

* MWS sensing: per target block, the NAND string conducts only if **all**
  selected cells conduct ⇒ AND of the block's selected wordlines; blocks
  share bitlines ⇒ OR across blocks; inverse read complements.
* S-latch: ``S = raw`` when initialized, else ``S & raw`` (ParaBit-AND).
* move-S-to-C: ``C = S`` when C initialized, else ``C | S`` (ParaBit-OR).
* XOR command: ``C = S ^ C``.
* Spill: ESP-program a latch into a scratch page.
* Transfer: DMA out, optional controller-side inversion.

The engine stores *logical* page data; physical cell data is complemented
for pages placed ``inverted`` (De Morgan storage).  Reads of non-ESP pages
can inject modelled bit errors (``repro.core.reliability``); ESP pages are
error-free — the paper's headline reliability result.

On TPU, plans whose sensing ops reduce the same operand stack collapse into
the fused MWS kernel (``repro.kernels.mws``); `execute` uses it for every
sensing command.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core.bitops import BitOp
from repro.core.commands import (
    CommandPlan,
    ESPCommand,
    MWSCommand,
    SpillCommand,
    TransferCommand,
    XORCommand,
)
from repro.core.expr import Expr, Node, Page
from repro.core.placement import Layout
from repro.core.planner import Planner
from repro.core.reliability import (
    CellMode,
    ProgramConfig,
    inject_bit_errors,
    rber,
)
from repro.kernels.mws import mws_reduce


@dataclass
class FlashArray:
    """A (single-plane) Flash-Cosmos array: layout + page store + planner."""

    layout: Layout = field(default_factory=Layout)
    store: dict[str, jax.Array] = field(default_factory=dict)  # physical
    program_configs: dict[str, ProgramConfig] = field(default_factory=dict)
    pec: dict[int, int] = field(default_factory=dict)  # block -> P/E cycles
    interpret: bool = True

    # -- host API (fc_write / fc_read, §6.3) -------------------------------
    def fc_write(
        self,
        name: str,
        words: jax.Array,
        *,
        inverted: bool | None = None,
        block: int | None = None,
        wordline: int | None = None,
        esp: bool = True,
    ) -> None:
        """Program a page. ESP mode (default) guarantees error-free reads."""
        if name in self.layout:
            p = self.layout[name]
            inverted = p.inverted if inverted is None else inverted
        else:
            inverted = bool(inverted)
            if block is None:
                (p,) = self.layout.place_colocated([name], inverted)
            else:
                p = self.layout.place(name, block, wordline or 0, inverted)
        cfg = (
            ProgramConfig(CellMode.SLC, randomized=False, tesp_ratio=2.0)
            if esp
            else ProgramConfig(CellMode.SLC, randomized=False, tesp_ratio=1.0)
        )
        self.program_configs[name] = cfg
        physical = ~words if inverted else words
        self.store[name] = physical
        self.pec[p.block] = self.pec.get(p.block, 0) + 1

    def fc_read(self, e: Expr) -> jax.Array:
        """Plan + execute a bulk bitwise expression; returns logical words."""
        plan = Planner(self.layout).compile(e)
        return self.execute(plan)

    # -- sensing ------------------------------------------------------------
    def _page_by_location(self, block: int, wordline: int) -> str:
        for name, p in self.layout.placements.items():
            if p.block == block and p.wordline == wordline:
                return name
        raise KeyError(f"no page at block {block} wl {wordline}")

    def _sense(self, cmd: MWSCommand, seed: int) -> jax.Array:
        per_block = []
        for t in cmd.targets:
            names = [self._page_by_location(t.block, wl) for wl in t.wordlines]
            stack = jnp.stack([self._physical_read(n, seed) for n in names])
            per_block.append(
                mws_reduce(stack, BitOp.AND, interpret=self.interpret)
            )
        raw = (
            per_block[0]
            if len(per_block) == 1
            else mws_reduce(
                jnp.stack(per_block), BitOp.OR, interpret=self.interpret
            )
        )
        return ~raw if cmd.iscm.inverse_read else raw

    def _physical_read(self, name: str, seed: int) -> jax.Array:
        words = self.store[name]
        cfg = self.program_configs.get(name)
        if cfg is None or cfg.is_esp:
            return words
        p = self.layout[name]
        r = rber(cfg, pec=self.pec.get(p.block, 0))
        return inject_bit_errors(words, r, seed=seed ^ hash(name) & 0xFFFF)

    # -- plan execution -------------------------------------------------------
    def execute(self, plan: CommandPlan, seed: int = 0) -> jax.Array:
        s = c = None
        out = None
        for i, cmd in enumerate(plan.commands):
            if isinstance(cmd, MWSCommand):
                raw = self._sense(cmd, seed + i)
                s = raw if cmd.iscm.init_s_latch or s is None else s & raw
                if cmd.iscm.init_c_latch:
                    c = None  # M4 pulse wipes the cache latch (Fig. 6a)
                if cmd.iscm.move_s_to_c:
                    c = s if c is None else c | s
            elif isinstance(cmd, XORCommand):
                c = s ^ c
            elif isinstance(cmd, SpillCommand):
                # ESP-program the latch value as-is; when the sub-plan's
                # logical result is the complement of the latch, the planner
                # recorded that in the scratch page's layout.inverted flag.
                value = s if cmd.source == "S" else c
                self.store[cmd.page_name] = value
                self.program_configs[cmd.page_name] = ProgramConfig(
                    CellMode.SLC, randomized=False, tesp_ratio=2.0
                )
                self.pec[cmd.block] = self.pec.get(cmd.block, 0) + 1
            elif isinstance(cmd, TransferCommand):
                value = s if cmd.source == "S" else c
                out = ~value if cmd.invert else value
            elif isinstance(cmd, ESPCommand):
                pass  # data writes flow through fc_write in this model
        assert out is not None, "plan missing TransferCommand"
        return out


def eval_expr(e: Expr, logical: dict[str, jax.Array]) -> jax.Array:
    """Direct (oracle) evaluation of an expression on logical page data."""
    if isinstance(e, Page):
        return logical[e.name]
    assert isinstance(e, Node)
    vals = jnp.stack([eval_expr(c, logical) for c in e.children])
    from repro.core.bitops import reduce_words

    return reduce_words(vals, e.op)
