"""Bitwise-expression IR for bulk operations on stored pages.

Users (and the BMI/IMS/KCS workloads) build expressions over *named pages*;
the planner (``repro.core.planner``) compiles them into MWS/XOR command
sequences against a physical layout (``repro.core.placement``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Union

from repro.core.bitops import BitOp


@dataclass(frozen=True)
class Page:
    """A stored operand page (one wordline's worth of packed bits)."""

    name: str

    def __and__(self, other):
        return and_(self, other)

    def __or__(self, other):
        return or_(self, other)

    def __xor__(self, other):
        return xor_(self, other)

    def __invert__(self):
        return not_(self)


@dataclass(frozen=True)
class Node:
    op: BitOp
    children: tuple["Expr", ...] = field(default_factory=tuple)

    __and__ = Page.__and__
    __or__ = Page.__or__
    __xor__ = Page.__xor__
    __invert__ = Page.__invert__


@dataclass(frozen=True)
class Threshold:
    """k-of-N threshold node: bit j is set iff >= k children are set at j.

    ``k == 1`` is OR and ``k == len(children)`` is AND — callers should
    build those as plain Nodes (the query layer canonicalizes degenerate
    thresholds away); this node exists for the strict-majority interior,
    which the planner lowers to one ThresholdCommand sensing.
    """

    k: int
    children: tuple["Expr", ...]

    __and__ = Page.__and__
    __or__ = Page.__or__
    __xor__ = Page.__xor__
    __invert__ = Page.__invert__


Expr = Union[Page, Node, Threshold]


def _flatten(op: BitOp, items) -> tuple[Expr, ...]:
    out = []
    for it in items:
        if isinstance(it, Node) and it.op is op:
            out.extend(it.children)
        else:
            out.append(it)
    return tuple(out)


def and_(*items: Expr) -> Node:
    return Node(BitOp.AND, _flatten(BitOp.AND, items))


def or_(*items: Expr) -> Node:
    return Node(BitOp.OR, _flatten(BitOp.OR, items))


def xor_(*items: Expr) -> Node:
    return Node(BitOp.XOR, _flatten(BitOp.XOR, items))


def not_(item: Expr) -> Node:
    # NOT == single-operand NAND (inverse read of one wordline).
    return Node(BitOp.NAND, (item,))


def nand_(*items: Expr) -> Node:
    return Node(BitOp.NAND, _flatten(BitOp.AND, items))


def nor_(*items: Expr) -> Node:
    return Node(BitOp.NOR, _flatten(BitOp.OR, items))


def xnor_(*items: Expr) -> Node:
    return Node(BitOp.XNOR, _flatten(BitOp.XOR, items))


def leaves(e: Expr) -> list[Page]:
    if isinstance(e, Page):
        return [e]
    out: list[Page] = []
    for c in e.children:
        out.extend(leaves(c))
    return out
