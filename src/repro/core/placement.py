"""Physical placement of operand pages (paper §6.3 "Requirements").

The paper's placement rules, encoded:

* operands of an AND group should be **co-located in one block** (one
  intra-block MWS covers all of them);
* operands of an OR-heavy group should be stored **inverted and co-located**
  (inverse-read intra-block MWS + De Morgan gives OR in one command);
* OR across plain operands needs them in **different blocks** (inter-block
  MWS, ≤ 4 blocks per command for the power budget).

``Layout`` tracks name -> (block, wordline, inverted) and hands out scratch
pages for planner spills.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.commands import WLS_PER_BLOCK
from repro.core.expr import Expr, Node, Page, Threshold
from repro.core.bitops import BitOp


@dataclass(frozen=True)
class PagePlacement:
    block: int
    wordline: int
    inverted: bool = False  # stored as complement (for De Morgan OR)
    level: int = 0  # voltage level within the physical page (MLC/TLC)


@dataclass
class Layout:
    wls_per_block: int = WLS_PER_BLOCK
    # Multi-level packing: ``levels`` consecutive logical wordlines of a
    # block co-reside in ONE physical page at distinct voltage levels
    # (1 = SLC baseline, 2/3 = MLC/TLC-style packing).  Logical wordline
    # addressing — and hence every MWS/planner coordinate — is untouched:
    # packing changes the physical footprint, program grouping, timing,
    # and reliability accounting only (the device senses all levels of a
    # physical page in one staircase read, so a logical wordline is
    # always individually addressable).  Immutable after construction.
    levels: int = 1
    placements: dict[str, PagePlacement] = field(default_factory=dict)
    _block_fill: dict[int, int] = field(default_factory=dict)
    _next_block: int = 0
    _scratch_count: int = 0
    # reverse index (block, wordline) -> name, maintained by place(); the
    # engine resolves every sensed wordline through it, so lookup must not
    # scan all placements.
    _by_location: dict[tuple[int, int], str] = field(default_factory=dict)
    # appendable page regions: region name -> the block the region is
    # currently filling.  place_colocated(..., region=...) records it, so a
    # later call with the same region continues packing the same block —
    # incremental ingest drops a column's new equality/BSI pages into the
    # column's reserved region instead of scattering one page per block.
    # Forks copy region state, keeping shard layouts appending in lockstep.
    _regions: dict[str, int] = field(default_factory=dict)

    def __post_init__(self):
        if not 1 <= self.levels <= 3:
            raise ValueError(
                f"levels must be 1 (SLC) .. 3 (TLC), got {self.levels}"
            )

    # -- explicit placement ------------------------------------------------
    def place(
        self, name: str, block: int, wordline: int, inverted: bool = False
    ) -> PagePlacement:
        if name in self.placements:
            raise ValueError(f"page {name!r} already placed")
        if not 0 <= wordline < self.wls_per_block:
            raise ValueError("wordline out of range")
        if (block, wordline) in self._by_location:
            raise ValueError(
                f"block {block} wl {wordline} already holds "
                f"{self._by_location[(block, wordline)]!r}"
            )
        # logical wordline w lives at level (w % levels) of physical page
        # (w // levels) — consecutive co-located pages share one cell
        p = PagePlacement(block, wordline, inverted, wordline % self.levels)
        self.placements[name] = p
        self._by_location[(block, wordline)] = name
        self._block_fill[block] = max(
            self._block_fill.get(block, 0), wordline + 1
        )
        self._next_block = max(self._next_block, block + 1)
        return p

    def page_at(self, block: int, wordline: int) -> str:
        """O(1) reverse lookup of the page programmed at a physical location."""
        try:
            return self._by_location[(block, wordline)]
        except KeyError:
            raise KeyError(f"no page at block {block} wl {wordline}") from None

    def physical_wordlines(self) -> int:
        """Physical pages the layout occupies (the index-footprint metric).

        Each block's fill packs ``levels`` logical wordlines per physical
        page, so the footprint is sum(ceil(fill / levels)) — at TLC
        packing a 48-page column costs 16 physical pages.
        """
        return sum(
            -(-fill // self.levels)
            for fill in self._block_fill.values()
            if fill
        )

    # -- snapshot / rollback (planner trial compiles) ----------------------
    def snapshot(self) -> tuple:
        """Capture all mutable state; pair with :meth:`restore`.

        Lives on Layout (not its callers) so that growing the class with a
        new index or counter keeps rollback correct in one place.
        """
        return (
            dict(self.placements),
            dict(self._block_fill),
            self._next_block,
            self._scratch_count,
            dict(self._by_location),
            dict(self._regions),
        )

    def fork(self) -> "Layout":
        """Independent copy with identical placements (shard-local layouts).

        Sharded serving programs every shard device from one canonical
        layout: forks start bit-identical, so per-shard compiled plans share
        gather shapes (and hence vmap signatures) across the fleet, while
        later spill allocations stay local to each shard.
        """
        other = Layout(wls_per_block=self.wls_per_block, levels=self.levels)
        other.restore(self.snapshot())
        return other

    def restore(self, snap: tuple) -> None:
        (
            self.placements,
            self._block_fill,
            self._next_block,
            self._scratch_count,
            self._by_location,
            self._regions,
        ) = (
            dict(snap[0]),
            dict(snap[1]),
            snap[2],
            snap[3],
            dict(snap[4]),
            dict(snap[5]),
        )

    # -- allocation helpers --------------------------------------------
    def alloc_block(self) -> int:
        b = self._next_block
        self._next_block += 1
        self._block_fill[b] = 0
        return b

    def place_colocated(
        self,
        names: list[str],
        inverted: bool = False,
        region: str | None = None,
    ) -> list[PagePlacement]:
        """Pack names into as few blocks as possible (AND / De-Morgan-OR).

        With ``region``, the packing state persists: a later call naming
        the same region continues filling the region's current block, so
        incrementally-ingested pages stay co-located with the column they
        extend (a fresh block is allocated only when the region fills up).
        """
        out = []
        block = self._regions.get(region) if region is not None else None
        if block is None:
            block = self.alloc_block()
        for name in names:
            wl = self._block_fill[block]
            if wl >= self.wls_per_block:
                block = self.alloc_block()
                wl = 0
            out.append(self.place(name, block, wl, inverted))
        if region is not None:
            self._regions[region] = block
        return out

    def place_spread(self, names: list[str]) -> list[PagePlacement]:
        """One block per name (plain OR via inter-block MWS)."""
        return [self.place(n, self.alloc_block(), 0, False) for n in names]

    def alloc_scratch(self) -> tuple[str, int, int]:
        """Scratch page for planner spills (ESP-programmed intermediates)."""
        name = f"__scratch{self._scratch_count}"
        self._scratch_count += 1
        block = self.alloc_block()
        self._block_fill[block] = 1
        return name, block, 0

    def __getitem__(self, name: str) -> PagePlacement:
        return self.placements[name]

    def __contains__(self, name: str) -> bool:
        return name in self.placements


def auto_layout(expr: Expr, layout: Layout | None = None) -> Layout:
    """Derive a placement from an expression per the paper's §6.3 rules.

    AND/NAND/XOR groups of leaves -> co-located plain; OR/NOR groups of
    leaves -> co-located inverted; nested nodes recurse.  Pages already
    placed (shared between subexpressions) are left where they are.
    """
    layout = layout if layout is not None else Layout()

    def walk(e: Expr, ctx: BitOp) -> None:
        if isinstance(e, Page):
            if e.name not in layout:
                if ctx.base is BitOp.OR:
                    layout.place_colocated([e.name], inverted=True)
                else:
                    layout.place_colocated([e.name], inverted=False)
            return
        if isinstance(e, Threshold):
            # a threshold sense counts CONDUCTING blocks, and an inverted
            # page conducts when the logical bit is clear — children are
            # placed plain and spread like an inter-block OR's operands
            # (k == 1 IS the OR), one child group per block slot
            leaf_children = [c for c in e.children if isinstance(c, Page)]
            new = [c.name for c in leaf_children if c.name not in layout]
            for name in new:
                layout.place_colocated([name], inverted=False)
            for c in e.children:
                if not isinstance(c, Page):
                    walk(c, BitOp.AND)
            return
        assert isinstance(e, Node)
        leaf_children = [c for c in e.children if isinstance(c, Page)]
        new = [c.name for c in leaf_children if c.name not in layout]
        if e.op.base is BitOp.OR:
            layout.place_colocated(new, inverted=True)
        else:
            layout.place_colocated(new, inverted=False)
        for c in e.children:
            if not isinstance(c, Page):
                walk(c, e.op)

    if isinstance(expr, Threshold):
        walk(expr, BitOp.AND)
    else:
        walk(expr, expr.op if isinstance(expr, Node) else BitOp.AND)
    return layout
