"""Packed page store: the contiguous array behind the Flash-Cosmos engine.

The seed engine kept page data in a ``dict[str, Array]`` and sensed with a
Python loop over pages.  :class:`PackedStore` packs every programmed page
into one contiguous ``(planes, slots, words_per_plane)`` buffer — the layout
analogue of a multi-plane NAND die where a logical bit vector is striped
across planes and every plane holds the same (block, wordline) grid.  An
MWS command then becomes a *gather* of slot rows plus one fused kernel
dispatch over the whole word axis (= all planes at once), instead of one
Python-level reduce per page.

Slot 0 is reserved for an all-ones row: the AND identity used to pad the
ragged per-block wordline sets of an inter-block MWS to a rectangle, so a
whole command batch reduces in a single Pallas call.  Slot 1 is the dual
all-zeros row: a block whose first wordline gathers slot 1 ANDs to zero and
is therefore OR-neutral across blocks — plan-aware batching uses it to pad
a plan with fewer target blocks into a wider signature's shape.

Writes append to a host-side ``numpy`` buffer (amortized doubling); the
device-side ``jax`` snapshot is materialized lazily and invalidated on
write, so steady-state query serving gathers from one cached array.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

IDENTITY_SLOT = 0  # all-ones row (AND identity / pad row), always present
ZERO_SLOT = 1  # all-zeros row (OR identity: pads whole blocks), always present

_ONES = np.uint32(0xFFFFFFFF)
_SCRATCH_PREFIX = "__scratch"


def page_region(name: str) -> str | None:
    """Epoch region of a page name, or ``None`` for planner scratch pages.

    The naming convention shared with :mod:`repro.query.bitmap`: equality
    bitmaps are ``column=value`` and BSI slices ``column#bit``, so the
    prefix before the first ``=`` / ``#`` groups every page of one column
    into one region; constant pages (``__all`` / ``__none``) are their own
    single-page regions.  Plan caches invalidate per region, so
    reprogramming one column's pages leaves plans over other columns warm.
    """
    if name.startswith(_SCRATCH_PREFIX):
        return None
    return name.split("=", 1)[0].split("#", 1)[0]


@dataclass
class PackedStore:
    """Name-addressed packed page store striped over ``planes`` planes.

    All pages share one word count ``W`` (fixed by the first write); each
    page occupies one *slot* of ``planes * words_per_plane`` words, where
    ``words_per_plane = ceil(W / planes)`` (tail padding is sliced off on
    read).
    """

    planes: int = 1
    _slots: dict[str, int] = field(default_factory=dict)
    _buf: np.ndarray | None = None  # (capacity, planes * wpp) uint32
    _n: int = 0
    _words: int | None = None  # logical words per page (pre-padding)
    _snapshot: jax.Array | None = None
    # Content version: bumped whenever page *content* changes (new page,
    # reprogram, or delta append), except planner scratch pages — those are
    # plan-internal temporaries rewritten on every execution of a spilling
    # plan.  Snapshot-level caches (stacked fleet arrays, aggregate extras)
    # key on this.
    epoch: int = 0
    # Region-granular mutation epochs (see :func:`page_region`): bumped on
    # a full (re)program of a page in the region, but NOT by
    # :meth:`append_words` — an append extends a page's erased tail, so
    # compiled plans (which gather by slot) remain valid.  Plan caches key
    # on the regions their leaves touch, so reprogramming column A's pages
    # recompiles only plans that sense column A.
    region_epochs: dict[str, int] = field(default_factory=dict)
    # Device-upload instrumentation: how many times the packed buffer was
    # re-materialized as a device array.  Steady-state serving must hold
    # this flat — in particular, spilling plans keep their scratch values
    # device-resident (latch scratch, never store writes), so a flush full
    # of deep-range queries re-uploads nothing (asserted in tests).
    snapshot_uploads: int = 0

    # -- geometry ----------------------------------------------------------
    @property
    def num_words(self) -> int | None:
        """Logical words per page (None until the first write)."""
        return self._words

    @property
    def padded_words(self) -> int:
        assert self._words is not None
        return -(-self._words // self.planes) * self.planes

    @property
    def words_per_plane(self) -> int:
        return self.padded_words // self.planes

    @property
    def num_slots(self) -> int:
        return self._n

    def __len__(self) -> int:
        return len(self._slots)

    def __contains__(self, name: str) -> bool:
        return name in self._slots

    # -- writes ------------------------------------------------------------
    def _ensure_buf(self, words: int) -> None:
        if self._buf is not None:
            return
        self._words = words
        wp = self.padded_words
        self._buf = np.empty((16, wp), dtype=np.uint32)
        self._buf[IDENTITY_SLOT] = _ONES  # AND identity row
        self._buf[ZERO_SLOT] = 0  # OR identity row (block padding)
        self._n = 2

    def __setitem__(self, name: str, words) -> None:
        w = np.asarray(words, dtype=np.uint32).reshape(-1)
        self._ensure_buf(w.shape[0])
        if w.shape[0] != self._words:
            raise ValueError(
                f"page {name!r} has {w.shape[0]} words, store fixed at "
                f"{self._words}"
            )
        row = np.zeros((self.padded_words,), dtype=np.uint32)
        row[: self._words] = w
        slot = self._slots.get(name)
        if slot is None:
            if self._n == self._buf.shape[0]:
                grown = np.empty(
                    (2 * self._buf.shape[0], self._buf.shape[1]),
                    dtype=np.uint32,
                )
                grown[: self._n] = self._buf[: self._n]
                self._buf = grown
            slot = self._n
            self._n += 1
            self._slots[name] = slot
        self._buf[slot] = row
        self._snapshot = None
        region = page_region(name)
        if region is not None:
            self.epoch += 1
            self.region_epochs[region] = self.region_epochs.get(region, 0) + 1

    def append_words(self, name: str, words, start: int) -> None:
        """Delta-page programming: overwrite only ``words`` at ``start``.

        The incremental-ingest write path.  The caller guarantees the
        written range covers only the page's tail beyond previously-valid
        rows (an *append*), so compiled plans — which gather by slot —
        remain valid: the page's region epoch is left alone and only the
        content ``epoch`` is bumped (snapshot-level caches must refresh).
        """
        w = np.asarray(words, dtype=np.uint32).reshape(-1)
        slot = self._slots[name]
        assert self._words is not None
        if start < 0 or start + w.shape[0] > self._words:
            raise ValueError(
                f"delta [{start}, {start + w.shape[0]}) out of range for "
                f"page {name!r} with {self._words} words"
            )
        self._buf[slot, start : start + w.shape[0]] = w
        self._snapshot = None
        if page_region(name) is not None:
            self.epoch += 1

    # -- reads -------------------------------------------------------------
    def slot(self, name: str) -> int:
        return self._slots[name]

    def __getitem__(self, name: str) -> jax.Array:
        slot = self._slots[name]
        return jnp.asarray(self._buf[slot, : self._words])

    def snapshot(self) -> jax.Array:
        """Device-side ``(slots, planes * words_per_plane)`` packed array.

        Cached until the next write; a multi-plane gather + reduce over this
        array covers every plane in one kernel dispatch because planes are
        word-axis shards of each slot row.
        """
        if self._snapshot is None:
            assert self._buf is not None, "empty store has no snapshot"
            self._snapshot = jnp.asarray(self._buf[: self._n])
            self.snapshot_uploads += 1
        return self._snapshot

    def plane_view(self) -> jax.Array:
        """The same data as ``(planes, slots, words_per_plane)``."""
        snap = self.snapshot()
        return snap.reshape(self._n, self.planes, self.words_per_plane).swapaxes(
            0, 1
        )
