"""Packed bit-plane arrays and the bulk bitwise op algebra.

This is the data model of the TPU adaptation of Flash-Cosmos: a "page" (one
NAND wordline's worth of data in the paper) becomes one packed ``uint32``
bit-plane row.  A stack of operands is a ``(num_operands, num_words)`` array,
the layout analogue of co-locating operands in one NAND block so that a single
MWS sensing covers all of them (paper §6.3: placement matters; here it means
the operand axis is contiguous and a single BlockSpec block covers all rows).

Bit ``i`` of the logical vector lives at word ``i // 32``, bit ``i % 32``
(LSB-first), matching ``numpy.packbits(..., bitorder='little')`` on a uint32
view.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
WORD_DTYPE = jnp.uint32
_FULL = np.uint32(0xFFFFFFFF)


class BitOp(enum.Enum):
    """Bulk bitwise ops supported by Flash-Cosmos (paper §4.1, §6.1)."""

    AND = "and"
    OR = "or"
    XOR = "xor"
    NAND = "nand"
    NOR = "nor"
    XNOR = "xnor"

    @property
    def base(self) -> "BitOp":
        """The non-inverted reduction this op is built on (inverse read)."""
        return {
            BitOp.AND: BitOp.AND,
            BitOp.NAND: BitOp.AND,
            BitOp.OR: BitOp.OR,
            BitOp.NOR: BitOp.OR,
            BitOp.XOR: BitOp.XOR,
            BitOp.XNOR: BitOp.XOR,
        }[self]

    @property
    def inverted(self) -> bool:
        """Whether the result is complemented (paper: inverse-read mode)."""
        return self in (BitOp.NAND, BitOp.NOR, BitOp.XNOR)

    @property
    def identity_word(self) -> np.uint32:
        """Reduction identity for the *base* op, as a packed word."""
        return _FULL if self.base is BitOp.AND else np.uint32(0)


def num_words(num_bits: int) -> int:
    return -(-num_bits // WORD_BITS)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a {0,1} array of shape (..., L) into (..., ceil(L/32)) uint32.

    Padding bits (when L % 32 != 0) are packed as 0; callers that reduce with
    AND must mask with :func:`valid_mask` (the engine does this).
    """
    L = bits.shape[-1]
    W = num_words(L)
    pad = W * WORD_BITS - L
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1
        )
    b = bits.astype(WORD_DTYPE).reshape(bits.shape[:-1] + (W, WORD_BITS))
    shifts = jnp.arange(WORD_BITS, dtype=WORD_DTYPE)
    return jnp.sum(b << shifts, axis=-1, dtype=WORD_DTYPE)


def unpack_bits(words: jax.Array, num_bits: int) -> jax.Array:
    """Inverse of :func:`pack_bits`: (..., W) uint32 -> (..., num_bits) uint8."""
    shifts = jnp.arange(WORD_BITS, dtype=WORD_DTYPE)
    bits = (words[..., None] >> shifts) & WORD_DTYPE(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD_BITS,))
    return bits[..., :num_bits].astype(jnp.uint8)


def valid_mask(num_bits: int) -> np.ndarray:
    """Per-word mask with 1s at valid bit positions for a length-num_bits vector."""
    W = num_words(num_bits)
    mask = np.full((W,), _FULL, dtype=np.uint32)
    rem = num_bits % WORD_BITS
    if rem:
        mask[-1] = np.uint32((1 << rem) - 1)
    return mask


@dataclass(frozen=True)
class BitVector:
    """A logical bit vector backed by packed words.

    ``words``: (..., W) uint32; ``length``: number of valid bits.
    """

    words: jax.Array
    length: int

    @classmethod
    def from_bits(cls, bits: jax.Array) -> "BitVector":
        return cls(pack_bits(bits), bits.shape[-1])

    def to_bits(self) -> jax.Array:
        return unpack_bits(self.words, self.length)

    @property
    def num_words(self) -> int:
        return self.words.shape[-1]

    def masked(self) -> "BitVector":
        """Zero the padding bits (needed before popcount / after NOT-like ops)."""
        mask = jnp.asarray(valid_mask(self.length))
        return BitVector(self.words & mask, self.length)


def reduce_words(stack: jax.Array, op: BitOp) -> jax.Array:
    """Pure-jnp word-level reduction over the operand axis (axis 0).

    This is the *semantic* definition of an MWS operation; the Pallas kernel in
    ``repro.kernels.mws`` must match it bit-exactly (see tests).
    """
    # NOTE: jnp.bitwise_and.reduce is unusable on uint32 under numpy>=2.0
    # (its -1 init value overflows), so fold explicitly.
    fn = {
        BitOp.AND: jnp.bitwise_and,
        BitOp.OR: jnp.bitwise_or,
        BitOp.XOR: jnp.bitwise_xor,
    }[op.base]
    out = stack[0]
    for i in range(1, stack.shape[0]):
        out = fn(out, stack[i])
    if op.inverted:
        out = ~out
    return out
