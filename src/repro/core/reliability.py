"""RBER / ESP reliability model (paper §2.2, §5, Figs. 8 & 11).

There is no threshold voltage on a TPU, so ESP becomes (i) this calibrated
analytical RBER model, consumed by :mod:`repro.flashsim` to reproduce the
paper's reliability figures, and (ii) the *verified storage mode* of the TPU
engine (no error injection + parity check) — the software analogue of
"zero bit errors in computation results".

Calibration anchors (all stated in the paper text; interior points of Fig. 8
are interpolated, which we document rather than pretend to measure):

* disabling randomization multiplies RBER by **1.91×** (SLC) / **4.92×** (MLC);
* MLC-mode RBER is up to **4×** SLC-mode RBER;
* the MLC plots span **8.6e-4 … 1.6e-2** across (PEC, retention, rand);
* SLC+randomization is "~12 orders of magnitude above" the 1e-15…1e-16 UBER
  target at the worst tested condition (10K PEC, 1-year retention);
* ESP (Fig. 11): at tESP ≥ **1.9×tPROG**, zero errors across 4.83e11 bits
  (statistical RBER < **2.07e-12** → modelled as 0); the *median* block gains
  one order of magnitude at tESP = 1.6×tPROG.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from enum import Enum

import jax
import jax.numpy as jnp
import numpy as np

UBER_TARGET = 1e-15  # JEDEC-ish requirement quoted in the paper
ESP_ZERO_THRESHOLD = 2.07e-12  # below this the paper observed zero errors
ESP_ZERO_TESP = 1.9  # tESP/tPROG ratio where all tested blocks hit zero

# Reference worst-case condition used throughout the paper's §5 methodology.
REF_PEC = 10_000
REF_RETENTION_DAYS = 365

# Anchors (see module docstring).
_RAND_OFF_SLC = 1.91
_RAND_OFF_MLC = 4.92
_MLC_OVER_SLC = 4.0
_MLC_NORAND_MAX = 1.6e-2  # @ (10K PEC, 1 yr, no randomization)
_MLC_RAND_MIN = 8.6e-4  # @ (1K PEC, 1 day, randomized)

# Derived reference points.
_MLC_RAND_REF = _MLC_NORAND_MAX / _RAND_OFF_MLC  # 3.25e-3
_SLC_RAND_REF = _MLC_RAND_REF / _MLC_OVER_SLC  # 8.1e-4 (~12 orders over UBER)

# Stress exponents: chosen so MLC+rand at the mildest tested condition
# (1K PEC, 1 day) lands on the paper's 8.6e-4 minimum.
#   total dynamic range needed: 3.25e-3 / 8.6e-4 = 3.78×
_PEC_EXP = 0.447  # (1K -> 10K) contributes 10**0.447 = 2.80×
_RET_EXP = math.log(3.78 / 2.80) / math.log(365.0)  # 1 d -> 365 d: 1.35×

# ESP log-drop curve  drop(Δ) = α·Δ + β·Δ^γ  (orders of magnitude),
# fitted to: median block −1 order at Δ=0.6; ≥10.3 orders at Δ=0.9 so that
# even the worst tested block (quality ≈ 22×) lands below the zero threshold
# — Fig. 11 reports zero errors in ALL tested pages at tESP ≥ 1.9×tPROG.
_ESP_ALPHA = 0.725
_ESP_BETA = 20.2
_ESP_GAMMA = 7.0


class CellMode(Enum):
    SLC = "slc"
    MLC = "mlc"
    TLC = "tlc"  # storage-only in this work (paper characterizes TLC chips
    # but computes on SLC-mode pages)


@dataclass(frozen=True)
class ProgramConfig:
    """How a page was programmed (paper: mode + randomization + tESP).

    ``levels`` is the multi-level packing count (1 = one bitmap page per
    physical page, the SLC-parity baseline; 2/3 = MLC/TLC-style packing
    of 2/3 bitmap pages at distinct voltage levels).  Packing L pages
    divides the per-level voltage margin by L, so the raw error rate
    scales as L^2 (RBER ~ margin^-2 in the charge-noise regime — the
    L=2 factor reproduces the paper's 4x MLC-over-SLC anchor), and the
    ESP margin gain of a given tESP stretch shrinks by the same 1/L.
    """

    mode: CellMode = CellMode.SLC
    randomized: bool = True
    tesp_ratio: float = 1.0  # tESP / tPROG; 1.0 == regular programming
    levels: int = 1  # bitmap pages packed per physical page (1..3)

    def __post_init__(self):
        if not 1 <= self.levels <= 3:
            raise ValueError(f"levels must be 1..3, got {self.levels}")

    @property
    def is_esp(self) -> bool:
        # zero-error needs the FULL 0.9x margin stretch at the per-level
        # scale: Delta >= (ESP_ZERO_TESP - 1) * levels
        zero_at = 1.0 + (ESP_ZERO_TESP - 1.0) * self.levels
        return self.tesp_ratio >= zero_at and not self.randomized


def _mode_base(mode: CellMode) -> float:
    if mode is CellMode.SLC:
        return _SLC_RAND_REF
    if mode is CellMode.MLC:
        return _MLC_RAND_REF
    # TLC ~ 2× MLC (paper: more bits/cell => smaller margins; §2.2)
    return 2.0 * _MLC_RAND_REF


def _rand_off_factor(mode: CellMode) -> float:
    return _RAND_OFF_SLC if mode is CellMode.SLC else _RAND_OFF_MLC


def esp_log_drop(tesp_ratio: float, levels: int = 1) -> float:
    """Orders of magnitude of RBER reduction vs regular programming.

    Packing ``levels`` pages per cell shrinks the margin an extra tESP
    stretch buys by 1/levels, so the same ratio drops fewer orders — the
    zero-error point moves out to ``1 + 0.9 * levels``.
    """
    delta = max(0.0, tesp_ratio - 1.0) / levels
    drop = _ESP_ALPHA * delta + _ESP_BETA * delta**_ESP_GAMMA
    # the stretched program's finer verify steps also re-tighten the packed
    # levels' distributions: by the full 0.9x per-level stretch the L^2
    # density penalty is fully recovered, restoring SLC-parity zero-error
    # reads at tESP = 1 + 0.9*L (linear in the margin progress; exactly 0
    # at levels=1, so the paper's single-level anchors are untouched)
    drop += (
        2.0
        * math.log10(levels)
        * min(delta / (ESP_ZERO_TESP - 1.0), 1.0)
    )
    return drop


def rber(
    config: ProgramConfig,
    *,
    pec: int = REF_PEC,
    retention_days: float = REF_RETENTION_DAYS,
    block_quality: float = 1.0,
) -> float:
    """Raw bit-error rate for a page programmed with ``config``.

    ``block_quality`` is a per-block multiplier (1.0 = median; the paper's
    Fig. 11 worst/best blocks are ~5×/0.2×).  Returns 0.0 once the modelled
    RBER falls below the paper's zero-observation threshold.
    """
    r = _mode_base(config.mode) * block_quality
    if not config.randomized:
        r *= _rand_off_factor(config.mode)
    # L-level packing divides the per-level margin by L; RBER ~ margin^-2
    # (at L=2 this IS the paper's 4x MLC-over-SLC anchor)
    r *= float(config.levels) ** 2
    r *= (max(pec, 1) / REF_PEC) ** _PEC_EXP
    r *= (max(retention_days, 1e-3) / REF_RETENTION_DAYS) ** _RET_EXP
    r *= 10.0 ** (-esp_log_drop(config.tesp_ratio, config.levels))
    if r < ESP_ZERO_THRESHOLD:
        return 0.0
    return float(r)


# ---------------------------------------------------------------------------
# Data randomization (the SSD scrambler the paper says MWS cannot use)
# ---------------------------------------------------------------------------


def randomize_words(words: jax.Array, seed: int) -> jax.Array:
    """XOR-scramble packed words with a seeded PRNG sequence (SSD scrambler).

    Involutive: applying twice with the same seed de-randomizes.  Used by
    tests/benchmarks to demonstrate the paper's incompatibility claim:
    MWS over *scrambled* operands, de-randomized afterwards, is garbage.
    """
    key = jax.random.PRNGKey(seed)
    mask = jax.random.bits(key, words.shape, dtype=jnp.uint32).astype(
        words.dtype
    )
    return words ^ mask


def inject_bit_errors(
    words: jax.Array, rber_value: float, seed: int
) -> jax.Array:
    """Flip each stored bit independently with probability ``rber_value``.

    Models the read-out of a non-ESP page.  Exact per-bit Bernoulli on the
    unpacked view — intended for test/benchmark scale vectors.
    """
    if rber_value <= 0.0:
        return words
    key = jax.random.PRNGKey(seed)
    nbits = int(np.prod(words.shape)) * 32
    flips = jax.random.bernoulli(key, rber_value, (nbits,))
    from repro.core.bitops import pack_bits

    flip_words = pack_bits(flips.astype(jnp.uint8)).reshape(words.shape)
    return words ^ flip_words.astype(words.dtype)


def block_quality_quantile(q: float) -> float:
    """Per-block quality multiplier at quantile q (0=best, 0.5=median, 1=worst).

    Lognormal spread matching Fig. 11's ~±0.7-order worst/best band.
    """
    sigma = 1.0  # ln-space; worst(≈q=0.98) ≈ 7.7×, best(≈0.02) ≈ 0.13×
    from statistics import NormalDist

    z = NormalDist().inv_cdf(min(max(q, 1e-6), 1 - 1e-6))
    return math.exp(sigma * z)
