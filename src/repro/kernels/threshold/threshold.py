"""Pallas TPU kernel: k-of-N threshold combine over per-block AND results.

The MCFlash-style dynamic-sensing primitive: after every activated block's
NAND strings have resolved (AND of the block's selected wordlines — the
same first stage as a plain MWS), the cross-block combine compares the
number of conducting blocks per bit position against a programmable
threshold ``k`` instead of the fixed wired-OR.  ``k == 1`` IS the MWS OR.

The per-bit counter never materializes as an integer: counts are held
**bit-sliced** across four uint32 accumulator planes (counts <= 8 blocks
fit in 4 bits), built with a ripple-carry half-adder chain — each block
row costs two vector ops per plane, all on the VPU, and the final
``count >= k`` comparator is a statically-unrolled equality fan-in over
the count planes.  One input streaming pass, one output block, no HBM
round-trip of intermediate counts.

Grid: word-blocks only — the block axis (<= 8 rows, padded with zeros,
which never conduct and never count) fits one sublane tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_WORDS = 2048
MAX_COUNT_BITS = 4  # bit-sliced counter planes; holds counts <= 15


def bitslice_threshold(anded: jax.Array, k: int, n_blocks: int) -> jax.Array:
    """``count >= k`` per bit over the rows of ``anded`` (shared logic).

    ``anded`` is a ``(rows, W)`` uint32 stack (rows beyond ``n_blocks``
    are ignored); returns the ``(1, W)`` threshold bitmap.  Pure jnp —
    the Pallas kernel body calls this on its VMEM tile and the engine's
    emulation path calls it directly, so both paths are bit-identical by
    construction.  Explicit loops only (no ``jnp.bitwise_*.reduce``).
    """
    c = [jnp.zeros_like(anded[:1]) for _ in range(MAX_COUNT_BITS)]
    for r in range(n_blocks):
        carry = anded[r : r + 1]
        for j in range(MAX_COUNT_BITS):
            t = c[j] & carry
            c[j] = c[j] ^ carry
            carry = t
    out = jnp.zeros_like(anded[:1])
    for v in range(k, n_blocks + 1):
        term = None
        for j in range(MAX_COUNT_BITS):
            plane = c[j] if (v >> j) & 1 else ~c[j]
            term = plane if term is None else term & plane
        out = out | term
    return out


def _kernel(x_ref, o_ref, *, k: int, n_blocks: int):
    o_ref[...] = bitslice_threshold(x_ref[...], k, n_blocks)


@functools.partial(
    jax.jit, static_argnames=("k", "n_blocks", "block_words", "interpret")
)
def threshold_pallas(
    anded: jax.Array,
    k: int,
    n_blocks: int,
    *,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    rows, w = anded.shape
    assert n_blocks <= rows and w % block_words == 0
    out = pl.pallas_call(
        functools.partial(_kernel, k=k, n_blocks=n_blocks),
        grid=(w // block_words,),
        in_specs=[pl.BlockSpec((rows, block_words), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, block_words), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, w), jnp.uint32),
        interpret=interpret,
    )(anded)
    return out[0]
