"""Public jit'd API for the k-of-N threshold kernel (padding + slicing).

Word-axis padding uses zeros; block-axis padding also uses zeros — a
padded block never conducts, so it can never count toward the threshold
(the OR-identity dual of the MWS wrappers' AND-identity padding).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.mws.ops import _pad_to
from repro.kernels.threshold.threshold import (
    DEFAULT_BLOCK_WORDS,
    threshold_pallas,
)


@functools.partial(
    jax.jit, static_argnames=("k", "block_words", "interpret")
)
def threshold_reduce(
    anded: jax.Array,
    k: int,
    *,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    """Per-bit ``count-of-set-rows >= k`` over an (N, W) word stack -> (W,)."""
    n, w = anded.shape
    if not 1 <= k <= n:
        raise ValueError(f"threshold k={k} outside 1..{n} rows")
    bw = min(block_words, DEFAULT_BLOCK_WORDS)
    padded = _pad_to(anded, 1, bw, 0)  # word axis: zeros
    padded = _pad_to(padded, 0, 8, 0)  # block axis: zeros (never count)
    out = threshold_pallas(
        padded, k, n, block_words=bw, interpret=interpret
    )
    return out[:w]
