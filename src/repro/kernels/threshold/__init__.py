from repro.kernels.threshold.ops import threshold_reduce
from repro.kernels.threshold.threshold import bitslice_threshold

__all__ = ["threshold_reduce", "bitslice_threshold"]
