"""Public jit'd API for the MWS kernel: padding, dtype handling, serial baseline.

``mws_reduce``      — the Flash-Cosmos path (one fused pass).
``parabit_reduce``  — the ParaBit baseline (serial pairwise ops; one HBM
                      round-trip of the running result per operand), used by
                      benchmarks and as a second correctness oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import BitOp
from repro.kernels.mws.mws import (
    DEFAULT_BLOCK_WORDS,
    DEFAULT_FAN_IN,
    mws_reduce_pallas,
)


def _identity_word(op: BitOp, dtype) -> np.ndarray:
    iinfo = jnp.iinfo(dtype)
    if op.base is BitOp.AND:
        return np.array(iinfo.max if iinfo.min == 0 else -1, dtype=dtype)
    return np.array(0, dtype=dtype)


def _pad_to(x: jax.Array, axis: int, multiple: int, fill) -> jax.Array:
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x
    pad_width = [(0, 0)] * x.ndim
    pad_width[axis] = (0, target - size)
    return jnp.pad(x, pad_width, constant_values=fill)


@functools.partial(
    jax.jit, static_argnames=("op", "fan_in", "block_words", "interpret")
)
def mws_reduce(
    stack: jax.Array,
    op: BitOp,
    *,
    fan_in: int = DEFAULT_FAN_IN,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    """Bitwise ``op``-reduce over axis 0 of an (N, W) packed-word stack.

    Handles arbitrary N/W by padding the operand axis with the reduction
    identity and the word axis with zeros, then slicing the result back.
    """
    if stack.ndim != 2:
        raise ValueError(f"expected (N, W) stack, got {stack.shape}")
    n, w = stack.shape
    fan_in = min(fan_in, max(8, 8 * -(-n // 8)))  # small stacks: shrink block
    ident = _identity_word(op, stack.dtype)
    padded = _pad_to(stack, 0, fan_in, ident)
    padded = _pad_to(padded, 1, block_words, ident)
    out = mws_reduce_pallas(
        padded, op, fan_in=fan_in, block_words=block_words, interpret=interpret
    )
    return out[:w]


@functools.partial(jax.jit, static_argnames=("op",))
def parabit_reduce(stack: jax.Array, op: BitOp) -> jax.Array:
    """ParaBit baseline: serial pairwise reduction (one op per operand).

    Written as a ``lax.fori_loop`` over operands so XLA cannot fuse it into a
    single pass — each iteration reads the full running result and one operand
    and writes the full result, modelling ParaBit's one-sensing-per-operand
    data path.
    """
    base = op.base
    fn = {
        BitOp.AND: jnp.bitwise_and,
        BitOp.OR: jnp.bitwise_or,
        BitOp.XOR: jnp.bitwise_xor,
    }[base]

    def body(i, acc):
        return fn(acc, stack[i])

    out = jax.lax.fori_loop(1, stack.shape[0], body, stack[0])
    if op.inverted:
        out = ~out
    return out
