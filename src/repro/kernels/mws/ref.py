"""Pure-jnp oracle for the MWS (one-shot multi-operand bitwise reduce) kernel."""

from __future__ import annotations

import jax

from repro.core.bitops import BitOp, reduce_words


def mws_reduce_ref(stack: jax.Array, op: BitOp) -> jax.Array:
    """Reference semantics of a Multi-Wordline Sensing operation.

    stack: (N, W) packed words (any unsigned/int dtype); returns (W,) of the
    same dtype = op-reduction over the operand axis, complemented for the
    inverse-read ops (NAND/NOR/XNOR).
    """
    return reduce_words(stack, op)
