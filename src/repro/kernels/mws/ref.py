"""Pure-jnp oracle for the MWS (one-shot multi-operand bitwise reduce) kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitops import BitOp


def mws_reduce_ref(stack: jax.Array, op: BitOp) -> jax.Array:
    """Reference semantics of a Multi-Wordline Sensing operation.

    stack: (N, W) packed words (any unsigned/int dtype); returns (W,) of the
    same dtype = op-reduction over the operand axis, complemented for the
    inverse-read ops (NAND/NOR/XNOR).
    """
    base = op.base
    if base is BitOp.AND:
        out = jnp.bitwise_and.reduce(stack, axis=0)
    elif base is BitOp.OR:
        out = jnp.bitwise_or.reduce(stack, axis=0)
    else:
        out = jnp.bitwise_xor.reduce(stack, axis=0)
    if op.inverted:
        out = ~out
    return out
