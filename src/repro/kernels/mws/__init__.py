from repro.kernels.mws.ops import mws_reduce, parabit_reduce
from repro.kernels.mws.ref import mws_reduce_ref

__all__ = ["mws_reduce", "parabit_reduce", "mws_reduce_ref"]
