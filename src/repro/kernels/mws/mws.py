"""Pallas TPU kernel: one-shot multi-operand bitwise reduction (MWS analogue).

Flash-Cosmos performs bitwise AND/OR of up to ~48 operands with a *single*
sensing operation instead of one sensing per operand (ParaBit).  The TPU
analogue: the serial pairwise baseline re-streams the running result through
HBM for every operand (~``3*(N-1)*W`` bytes of traffic); this kernel streams
every operand tile into VMEM exactly once and reduces it on the VPU with a
static tree, writing the result once (``(N+1)*W`` bytes).

Tiling (the "placement" analogue of the paper's same-block co-location):

* operand axis = sublane axis, blocked at ``fan_in`` rows (the VMEM analogue
  of the 48-wordline NAND-string limit).  When ``N > fan_in`` the grid walks
  operand blocks *innermost* and accumulates into the output block — exactly
  the paper's "accumulate multiple MWS results in the latches" (§6.1).
* word axis = lane axis, blocked at ``block_words`` (multiple of 128).

The inverse-read mode (NAND/NOR/XNOR) is a complement applied once, on the
final operand block — the latch-init ordering rule of §6.2 falls out of this:
an inverted read cannot be *followed* by further accumulation into the same
output, which the command planner enforces.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitops import BitOp

# VMEM budget reasoning (v5e: ~16 MiB usable VMEM/core): one input block of
# (64, 2048) uint32 = 512 KiB + (1, 2048) out + double-buffering head-room.
DEFAULT_FAN_IN = 64
DEFAULT_BLOCK_WORDS = 2048


def _tree_reduce(blk: jax.Array, base: BitOp) -> jax.Array:
    """AND/OR/XOR reduce over axis 0 via a static binary tree (Mosaic-safe)."""
    fn = {
        BitOp.AND: jnp.bitwise_and,
        BitOp.OR: jnp.bitwise_or,
        BitOp.XOR: jnp.bitwise_xor,
    }[base]
    n = blk.shape[0]
    while n > 1:
        half = n // 2
        lo = blk[:half]
        hi = blk[half : 2 * half]
        rest = blk[2 * half : n]
        blk = fn(lo, hi)
        if rest.shape[0]:
            blk = jnp.concatenate([blk, rest], axis=0)
        n = blk.shape[0]
    return blk  # (1, BW)


def _mws_kernel(x_ref, o_ref, *, op: BitOp, n_op_blocks: int):
    i = pl.program_id(1)  # operand-block index (innermost => safe revisits)
    part = _tree_reduce(x_ref[...], op.base)

    fn = {
        BitOp.AND: jnp.bitwise_and,
        BitOp.OR: jnp.bitwise_or,
        BitOp.XOR: jnp.bitwise_xor,
    }[op.base]

    @pl.when(i == 0)
    def _init():
        o_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        o_ref[...] = fn(o_ref[...], part)

    if op.inverted:

        @pl.when(i == n_op_blocks - 1)
        def _invert():
            o_ref[...] = ~o_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("op", "fan_in", "block_words", "interpret"),
)
def mws_reduce_pallas(
    stack: jax.Array,
    op: BitOp,
    *,
    fan_in: int = DEFAULT_FAN_IN,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    """One-shot multi-operand bitwise reduce of a padded operand stack.

    ``stack``: (N, W) packed words with N a multiple of ``fan_in`` and W a
    multiple of ``block_words`` (use :mod:`repro.kernels.mws.ops` for the
    padding/unpadding wrapper).  Returns (W,).
    """
    n, w = stack.shape
    assert n % fan_in == 0 and w % block_words == 0, (n, w, fan_in, block_words)
    n_op_blocks = n // fan_in
    n_w_blocks = w // block_words

    out = pl.pallas_call(
        functools.partial(_mws_kernel, op=op, n_op_blocks=n_op_blocks),
        grid=(n_w_blocks, n_op_blocks),
        in_specs=[
            pl.BlockSpec((fan_in, block_words), lambda j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_words), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, w), stack.dtype),
        interpret=interpret,
    )(stack)
    return out[0]
