"""Pallas TPU kernel: fused multi-operand bitwise reduce + population count.

The complete BMI query ("how many users were active every day?") in ONE
kernel: the AND-reduction happens in VMEM and only a scalar count leaves —
the result bit-vector never round-trips through HBM at all.  This carries
the paper's one-sensing philosophy one level further than `kernels/mws`:
Flash-Cosmos still DMAs the result page to the host for counting (§7, BMI);
on TPU the count collapses into the same pass.

Traffic: N·W bytes in, 4 bytes out — vs (N+1)·W for reduce-then-popcount
and 3(N−1)·W+… for the serial baseline.

Grid: word-blocks outer, operand-blocks inner (same revisit-safe layout as
`kernels/mws`); a VMEM scratch block holds the running reduction, and the
(1,1) int32 output accumulates SWAR popcounts on the final operand block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitops import BitOp
from repro.kernels.mws.mws import _tree_reduce

DEFAULT_FAN_IN = 64
DEFAULT_BLOCK_WORDS = 2048

_M1 = np.uint32(0x55555555)
_M2 = np.uint32(0x33333333)
_M4 = np.uint32(0x0F0F0F0F)
_H01 = np.uint32(0x01010101)


def _swar(v):
    v = v - ((v >> 1) & _M1)
    v = (v & _M2) + ((v >> 2) & _M2)
    v = (v + (v >> 4)) & _M4
    return ((v * _H01) >> 24).astype(jnp.int32)


def _kernel(x_ref, o_ref, acc_ref, *, op: BitOp, n_op_blocks: int):
    j = pl.program_id(0)  # word-block (outer)
    i = pl.program_id(1)  # operand-block (inner; revisit-safe)
    part = _tree_reduce(x_ref[...], op.base)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        fn = {
            BitOp.AND: jnp.bitwise_and,
            BitOp.OR: jnp.bitwise_or,
            BitOp.XOR: jnp.bitwise_xor,
        }[op.base]
        acc_ref[...] = fn(acc_ref[...], part)

    @pl.when(i == n_op_blocks - 1)
    def _count():
        red = acc_ref[...]
        if op.inverted:
            red = ~red
        blk_count = jnp.sum(_swar(red))

        @pl.when(j == 0)
        def _first():
            o_ref[0, 0] = blk_count

        @pl.when(j > 0)
        def _rest():
            o_ref[0, 0] = o_ref[0, 0] + blk_count


@functools.partial(
    jax.jit, static_argnames=("op", "fan_in", "block_words", "interpret")
)
def mws_count_pallas(
    stack: jax.Array,
    op: BitOp,
    *,
    fan_in: int = DEFAULT_FAN_IN,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    n, w = stack.shape
    assert n % fan_in == 0 and w % block_words == 0
    out = pl.pallas_call(
        functools.partial(
            _kernel, op=op, n_op_blocks=n // fan_in
        ),
        grid=(w // block_words, n // fan_in),
        in_specs=[pl.BlockSpec((fan_in, block_words), lambda j, i: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda j, i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, block_words), jnp.uint32)],
        interpret=interpret,
    )(stack)
    return out[0, 0]
