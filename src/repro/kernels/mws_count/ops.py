"""Public jit'd API for the fused reduce+count kernel (padding + corrections).

Word-axis padding uses zeros; operand-axis padding uses the reduction
identity.  A padded column therefore reduces to 0 for AND/OR/XOR (0 & ident
= 0 since real rows are zero-padded) and to ~0 after an inverse read — the
wrapper subtracts the 32·(padded words) over-count for inverted ops.
"""

from __future__ import annotations

import functools

import jax

from repro.core.bitops import BitOp
from repro.kernels.mws.ops import _identity_word, _pad_to
from repro.kernels.mws_count.mws_count import (
    DEFAULT_BLOCK_WORDS,
    DEFAULT_FAN_IN,
    mws_count_pallas,
)


@functools.partial(
    jax.jit, static_argnames=("op", "fan_in", "block_words", "interpret")
)
def mws_count(
    stack: jax.Array,
    op: BitOp,
    *,
    fan_in: int = DEFAULT_FAN_IN,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    """Population count of the op-reduction of an (N, W) word stack -> ()."""
    n, w = stack.shape
    fan_in = min(fan_in, max(8, 8 * -(-n // 8)))
    ident = _identity_word(op, stack.dtype)
    padded = _pad_to(stack, 1, block_words, 0)  # word axis: zeros
    padded = _pad_to(padded, 0, fan_in, ident)  # operand axis: identity
    count = mws_count_pallas(
        padded, op, fan_in=fan_in, block_words=block_words, interpret=interpret
    )
    if op.inverted:
        padded_words = padded.shape[1] - w
        count = count - 32 * padded_words
    return count
