from repro.kernels.mws_count.ops import mws_count
from repro.kernels.mws_count.ref import mws_count_ref

__all__ = ["mws_count", "mws_count_ref"]
