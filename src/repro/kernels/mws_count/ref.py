"""Pure-jnp oracle for the fused MWS-reduce + popcount kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitops import BitOp
from repro.kernels.mws.ref import mws_reduce_ref


def mws_count_ref(stack: jax.Array, op: BitOp) -> jax.Array:
    """Bit-count of the op-reduction over the operand axis: (N, W) -> ()."""
    reduced = mws_reduce_ref(stack, op)
    return jnp.sum(
        jax.lax.population_count(reduced).astype(jnp.int32)
    )
