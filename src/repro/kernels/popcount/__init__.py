from repro.kernels.popcount.ops import popcount
from repro.kernels.popcount.ref import popcount_ref

__all__ = ["popcount", "popcount_ref"]
