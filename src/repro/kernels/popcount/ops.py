"""Public jit'd API for the popcount kernel (padding + shape handling)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.popcount.popcount import (
    DEFAULT_BLOCK_ROWS,
    DEFAULT_BLOCK_WORDS,
    popcount_pallas,
)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_words", "interpret")
)
def popcount(
    words: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    """Population count per row. (W,)->() or (R, W)->(R,); zero-pads freely
    (padding words contribute 0 to the count).

    With ``interpret=True`` (no TPU) the Pallas interpreter walks the grid
    in Python — milliseconds per block, which would dominate batched
    aggregation — so emulation counts with plain XLA ops instead
    (bit-identical to the kernel: the kernel tests assert exactly that);
    on real hardware (``interpret=False``) the Pallas kernel is
    dispatched.
    """
    squeeze = words.ndim == 1
    if squeeze:
        words = words[None]
    if interpret:
        out = jnp.sum(
            jax.lax.population_count(words).astype(jnp.int32), axis=-1
        )
        return out[0] if squeeze else out
    r, w = words.shape
    block_rows = min(block_rows, max(1, r))
    rp = -(-r // block_rows) * block_rows
    wp = -(-w // block_words) * block_words
    padded = jnp.pad(words, ((0, rp - r), (0, wp - w)))
    out = popcount_pallas(
        padded,
        block_rows=block_rows,
        block_words=block_words,
        interpret=interpret,
    )[:r]
    return out[0] if squeeze else out
