"""Pure-jnp oracle for the popcount kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def popcount_ref(words: jax.Array) -> jax.Array:
    """Per-row population count: (R, W) uint32 -> (R,) int32."""
    return jnp.sum(
        jax.lax.population_count(words).astype(jnp.int32), axis=-1
    )
