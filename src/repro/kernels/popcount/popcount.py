"""Pallas TPU kernel: population count over packed bit-planes.

Used by the BMI workload ("how many users were active every day") — the
paper leaves the bit-count on the host CPU, overlapped with the result DMA
(§7); on TPU the count is cheap enough to fuse right after the MWS reduce,
so the result vector never round-trips through HBM unpacked.

SWAR popcount (Hacker's Delight §5-1) on the VPU; per-row partial sums are
accumulated across word-blocks in an SMEM-friendly (R, 1) int32 output block
revisited along the innermost grid axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8
DEFAULT_BLOCK_WORDS = 2048

_M1 = np.uint32(0x55555555)
_M2 = np.uint32(0x33333333)
_M4 = np.uint32(0x0F0F0F0F)
_H01 = np.uint32(0x01010101)


def _swar_popcount(v: jax.Array) -> jax.Array:
    v = v - ((v >> 1) & _M1)
    v = (v & _M2) + ((v >> 2) & _M2)
    v = (v + (v >> 4)) & _M4
    return ((v * _H01) >> 24).astype(jnp.int32)


def _popcount_kernel(x_ref, o_ref):
    j = pl.program_id(1)  # word-block index (innermost => safe revisits)
    part = jnp.sum(_swar_popcount(x_ref[...]), axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = o_ref[...] + part


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_words", "interpret")
)
def popcount_pallas(
    words: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    """(R, W) uint32 -> (R,) int32, R % block_rows == 0, W % block_words == 0."""
    r, w = words.shape
    assert r % block_rows == 0 and w % block_words == 0

    out = pl.pallas_call(
        _popcount_kernel,
        grid=(r // block_rows, w // block_words),
        in_specs=[pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.int32),
        interpret=interpret,
    )(words)
    return out[:, 0]
