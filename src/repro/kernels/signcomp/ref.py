"""Pure-jnp oracles for the sign-compression kernels.

Packing layout: bit ``b`` of ``words[r, w]`` is the sign (1 = non-negative)
of ``x[32*r + b, w]`` — packing along the *sublane* axis, which is the
TPU-friendly orientation (lane dimension untouched by the pack/unpack).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32


def pack_signs_ref(x: jax.Array) -> jax.Array:
    """(32*R, W) float -> (R, W) uint32 of sign bits (1 = x >= 0)."""
    m, w = x.shape
    assert m % WORD_BITS == 0
    bits = (x >= 0).astype(jnp.uint32).reshape(m // WORD_BITS, WORD_BITS, w)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :, None]
    return jnp.sum(bits << shifts, axis=1, dtype=jnp.uint32)


def unpack_signs_ref(words: jax.Array, dtype=jnp.float32) -> jax.Array:
    """(R, W) uint32 -> (32*R, W) of ±1 in ``dtype``."""
    r, w = words.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :, None]
    bits = (words[:, None, :] >> shifts) & jnp.uint32(1)
    signs = bits.astype(jnp.int32) * 2 - 1
    return signs.reshape(r * WORD_BITS, w).astype(dtype)


def majority_ref(stacks: jax.Array) -> jax.Array:
    """(K, R, W) packed sign words -> (R, W) packed majority-vote words.

    Ties (possible only for even K) vote positive: bit = (2*sum >= K).
    """
    k, r, w = stacks.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    acc = jnp.zeros((r, w), jnp.uint32)
    for b in range(WORD_BITS):
        sb = jnp.sum((stacks >> shifts[b]) & jnp.uint32(1), axis=0)
        maj = (2 * sb >= k).astype(jnp.uint32)
        acc = acc | (maj << shifts[b])
    return acc
