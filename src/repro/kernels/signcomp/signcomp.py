"""Pallas TPU kernels: 1-bit sign compression / majority vote on packed planes.

The beyond-paper integration of the Flash-Cosmos op set into distributed
training: signSGD-with-majority-vote gradient aggregation (Bernstein et al.)
implemented *as bulk bitwise operations* on packed bit-planes.  The gradient
all-reduce becomes: pack signs (32× smaller) -> all-gather across the data
axis -> packed bitwise majority -> unpack.  Collective bytes drop ~16×
(vs bf16) and the reduction itself is the paper's multi-operand op pattern.

Pack/unpack work along the sublane axis so the lane dimension (last, 128-wide
on TPU) is never reshaped — Mosaic-friendly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD_BITS = 32
DEFAULT_BLOCK_ROWS = 8  # packed rows per block  (=> 256 unpacked rows)
DEFAULT_BLOCK_WORDS = 512


def _pack_kernel(x_ref, o_ref):
    blk = x_ref[...]  # (32*BR, BW) float
    br = blk.shape[0] // WORD_BITS
    bits = (blk >= 0).astype(jnp.uint32)
    bits = bits.reshape(br, WORD_BITS, blk.shape[1])  # sublane split: legal
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :, None]
    o_ref[...] = jnp.sum(bits << shifts, axis=1, dtype=jnp.uint32)


def _unpack_kernel(w_ref, o_ref, *, dtype):
    blk = w_ref[...]  # (BR, BW) uint32
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :, None]
    bits = (blk[:, None, :] >> shifts) & jnp.uint32(1)
    signs = bits.astype(jnp.int32) * 2 - 1
    o_ref[...] = signs.reshape(blk.shape[0] * WORD_BITS, blk.shape[1]).astype(
        dtype
    )


def _majority_kernel(s_ref, o_ref, *, k: int):
    blk = s_ref[...]  # (K, BR, BW) uint32
    one = jnp.uint32(1)
    acc = jnp.zeros(blk.shape[1:], jnp.uint32)
    for b in range(WORD_BITS):
        sb = jnp.sum((blk >> jnp.uint32(b)) & one, axis=0)
        maj = (2 * sb >= k).astype(jnp.uint32)
        acc = acc | (maj << jnp.uint32(b))
    o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_words", "interpret")
)
def pack_signs_pallas(
    x: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    m, w = x.shape
    assert m % (WORD_BITS * block_rows) == 0 and w % block_words == 0
    r = m // WORD_BITS
    return pl.pallas_call(
        _pack_kernel,
        grid=(r // block_rows, w // block_words),
        in_specs=[
            pl.BlockSpec(
                (WORD_BITS * block_rows, block_words), lambda i, j: (i, j)
            )
        ],
        out_specs=pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, w), jnp.uint32),
        interpret=interpret,
    )(x)


@functools.partial(
    jax.jit,
    static_argnames=("dtype", "block_rows", "block_words", "interpret"),
)
def unpack_signs_pallas(
    words: jax.Array,
    *,
    dtype=jnp.float32,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    r, w = words.shape
    assert r % block_rows == 0 and w % block_words == 0
    return pl.pallas_call(
        functools.partial(_unpack_kernel, dtype=dtype),
        grid=(r // block_rows, w // block_words),
        in_specs=[pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec(
            (WORD_BITS * block_rows, block_words), lambda i, j: (i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((r * WORD_BITS, w), dtype),
        interpret=interpret,
    )(words)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_words", "interpret")
)
def majority_pallas(
    stacks: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_words: int = DEFAULT_BLOCK_WORDS,
    interpret: bool = True,
) -> jax.Array:
    k, r, w = stacks.shape
    assert r % block_rows == 0 and w % block_words == 0
    return pl.pallas_call(
        functools.partial(_majority_kernel, k=k),
        grid=(r // block_rows, w // block_words),
        in_specs=[
            pl.BlockSpec((k, block_rows, block_words), lambda i, j: (0, i, j))
        ],
        out_specs=pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, w), jnp.uint32),
        interpret=interpret,
    )(stacks)
