"""Public jit'd API: flat-gradient sign compression round-trip.

``compress(flat)``  -> packed (R, W) uint32 planes + static layout
``decompress(words, layout)`` -> flat ±1 vector
``majority(stacked)`` -> packed majority vote across K replicas
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.signcomp.signcomp import (
    WORD_BITS,
    majority_pallas,
    pack_signs_pallas,
    unpack_signs_pallas,
)

_LANES = 512  # words per packed row; rows of 32*512 = 16384 grad elements


def _row_block(rows: int) -> int:
    for br in (8, 4, 2, 1):
        if rows % br == 0:
            return br
    return 1


@dataclass(frozen=True)
class SignLayout:
    n: int  # original flat length
    rows: int  # packed rows R
    words: int  # words per row W


def sign_layout(n: int, lanes: int = _LANES) -> SignLayout:
    elems_per_row = WORD_BITS * lanes
    padded = -(-n // elems_per_row) * elems_per_row
    rows_unpacked = padded // lanes
    return SignLayout(n=n, rows=rows_unpacked // WORD_BITS, words=lanes)


@functools.partial(jax.jit, static_argnames=("lanes", "interpret"))
def compress_signs(
    flat: jax.Array, *, lanes: int = _LANES, interpret: bool = True
) -> jax.Array:
    """Flat float vector -> packed (R, lanes) uint32 sign planes (32× smaller).

    Padding elements are compressed from 0.0 (sign bit 1) and ignored at
    decompression time.
    """
    layout = sign_layout(flat.shape[0], lanes)
    padded = jnp.pad(flat, (0, layout.rows * WORD_BITS * lanes - flat.shape[0]))
    x = padded.reshape(layout.rows * WORD_BITS, lanes)
    return pack_signs_pallas(
        x,
        block_rows=_row_block(layout.rows),
        block_words=min(lanes, 512),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("n", "dtype", "interpret"))
def decompress_signs(
    words: jax.Array, n: int, *, dtype=jnp.float32, interpret: bool = True
) -> jax.Array:
    """Packed (R, W) uint32 -> flat (n,) of ±1 in ``dtype``."""
    signs = unpack_signs_pallas(
        words,
        dtype=dtype,
        block_rows=_row_block(words.shape[0]),
        block_words=min(words.shape[1], 512),
        interpret=interpret,
    )
    return signs.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def majority_vote(stacks: jax.Array, *, interpret: bool = True) -> jax.Array:
    """(K, R, W) packed sign planes -> (R, W) packed majority."""
    return majority_pallas(
        stacks,
        block_rows=_row_block(stacks.shape[1]),
        block_words=min(stacks.shape[2], 512),
        interpret=interpret,
    )
