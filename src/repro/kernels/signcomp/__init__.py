from repro.kernels.signcomp.ops import (
    SignLayout,
    compress_signs,
    decompress_signs,
    majority_vote,
    sign_layout,
)
from repro.kernels.signcomp.ref import (
    majority_ref,
    pack_signs_ref,
    unpack_signs_ref,
)

__all__ = [
    "SignLayout",
    "compress_signs",
    "decompress_signs",
    "majority_vote",
    "sign_layout",
    "majority_ref",
    "pack_signs_ref",
    "unpack_signs_ref",
]
