"""command-r-plus-104b [dense]: 64L d=12288 96H GQA kv=8 ff=33792 V=256000.

GQA, no biases, large vocabulary.  [hf:CohereForAI/c4ai-command-r-v01]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    rope_theta=75e5,
    attn_bias=False,
    mlp_bias=False,
    activation="silu",
    norm="layernorm",
    subquadratic=False,
)
