"""recurrentgemma-2b [hybrid]: 26L d=2560 10H MQA kv=1 ff=7680 V=256000.

RG-LRU + local attention (window 2048), pattern (rec, rec, attn) — 8 triples
+ 2 remainder recurrent layers = 26.  Runs long_500k (O(window) decode
state).  [arXiv:2402.19427; hf]
"""

from repro.models.config import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    rope_theta=1e4,
    activation="gelu",
    norm="rmsnorm",
    hybrid=HybridConfig(
        pattern=("recurrent", "recurrent", "attention"),
        window=2048,
        conv_width=4,
    ),
    subquadratic=True,
)
