"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H ff_expert=1408 V=102400.

MLA (kv_lora=512, decoupled-RoPE head 64, nope 128, v 128); MoE with 64
routed experts top-6 + 2 shared experts; first layer dense (ff=10944).

Assigned-table note: the table reads "MoE 64e top-6 … 2 shared+160 routed";
160 routed is the *full* DeepSeek-V2 — per instructions the assigned numbers
(64 experts, top-6) win, recorded in DESIGN.md.  [arXiv:2405.04434; hf]
"""

from repro.models.config import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    rope_theta=1e4,
    activation="silu",
    norm="rmsnorm",
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        num_shared=2,
        d_ff_expert=1408,
        capacity_factor=1.25,
        first_dense_layers=1,
        d_ff_dense=10944,
    ),
    mla=MLAConfig(
        kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128, v_head_dim=128
    ),
    subquadratic=False,
)
