"""xlstm-350m [ssm]: 24L d=1024 4H V=50304, d_ff=0 (no MLP; block-internal
projections only).  Alternating mLSTM/sLSTM blocks (12 pairs), per the
assigned table's "sLSTM + mLSTM blocks".  Runs long_500k (O(1)-state
decode).  [arXiv:2405.04517]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    use_rope=False,
    activation="silu",
    norm="rmsnorm",
    subquadratic=True,
)
