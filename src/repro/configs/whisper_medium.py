"""whisper-medium [audio]: 24L enc + 24L dec, d=1024 16H MHA kv=16 ff=4096
V=51865.  Conv/mel frontend STUBBED — input_specs() provides precomputed
frame embeddings.  Absolute positions (no RoPE), LayerNorm, GELU.
[arXiv:2212.04356]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,  # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    use_rope=False,
    attn_bias=True,
    mlp_bias=True,
    activation="gelu",
    norm="layernorm",
    subquadratic=False,
)
