"""yi-34b [dense]: 60L d=7168 56H GQA kv=8 ff=20480 V=64000.

llama-architecture GQA, RMSNorm, SwiGLU.  [arXiv:2403.04652; hf]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    rope_theta=5e6,
    activation="silu",
    norm="rmsnorm",
    subquadratic=False,
)
