"""Assigned architecture configs (public-literature numbers, per task table).

``get_config(arch_id)`` returns the full-size config; ``--arch`` ids match
the assignment. Each module also provides ``input_specs(cfg, shape)`` via
``repro.launch.specs``.
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "xlstm-350m",
    "starcoder2-3b",
    "yi-34b",
    "granite-8b",
    "command-r-plus-104b",
    "whisper-medium",
    "deepseek-v2-lite-16b",
    "kimi-k2-1t-a32b",
    "internvl2-26b",
    "recurrentgemma-2b",
]


def get_config(arch_id: str):
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_")
    )
    return mod.CONFIG
