"""kimi-k2-1t-a32b [moe]: 61L d=7168 64H GQA kv=8 ff_expert=2048 V=163840.

Trillion-parameter MoE: 384 routed experts top-8 + 1 shared expert; first
layer dense.  The assigned table specifies GQA kv=8 (the released K2 uses
MLA; assigned numbers win — noted in DESIGN.md).  head_dim = d/H = 112.
[arXiv:2501.kimi2 (paper-table)]
"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    rope_theta=5e7,
    activation="silu",
    norm="rmsnorm",
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        num_shared=1,
        d_ff_expert=2048,
        capacity_factor=1.25,
        first_dense_layers=1,
        d_ff_dense=11264,
    ),
    # 1T-scale: bf16 optimizer moments keep state per chip inside HBM on the
    # multi-pod mesh (see EXPERIMENTS.md §Dry-run memory table).
    optimizer_dtype="bfloat16",
    subquadratic=False,
)
