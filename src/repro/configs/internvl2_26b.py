"""internvl2-26b [vlm]: 48L d=6144 48H GQA kv=8 ff=16384 V=92553.

InternLM2-style dense decoder backbone; the InternViT vision frontend is a
STUB — ``input_specs()`` provides precomputed patch embeddings that are
prepended to the token stream.  [arXiv:2404.16821; hf]
"""

from repro.models.config import ArchConfig

NUM_PATCH_TOKENS = 256  # one tile of InternViT-6B output after pixel-shuffle

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    rope_theta=1e6,
    activation="silu",
    norm="rmsnorm",
    num_patch_tokens=NUM_PATCH_TOKENS,
    subquadratic=False,
)
