"""starcoder2-3b [dense]: 30L d=3072 24H GQA kv=2 ff=12288 V=49152.

GQA + RoPE, learned biases on attention/MLP (StarCoder2 uses biases),
gelu MLP.  [arXiv:2402.19173; hf]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    rope_theta=1e5,
    attn_bias=True,
    mlp_bias=True,
    activation="gelu",
    norm="layernorm",
    subquadratic=False,
)
