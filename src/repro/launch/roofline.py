import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline-term derivation for every (arch × shape) cell on the single-pod
mesh (the multi-pod pass in dryrun.py proves the pod axis; the roofline
table is single-pod per the assignment).

Method — affine layer extrapolation: ``cost_analysis`` does not multiply
while-loop bodies by their trip count, so scanned full-depth models
undercount.  Every architecture is a repeated unit (layer / moe-layer /
mLSTM+sLSTM pair / rec-rec-attn triple / enc+dec layer pair) on top of a
fixed entry (embed/unembed/loss/optimizer).  We lower UNROLLED 1-unit and
2-unit variants, so  per_unit = t(2) − t(1)  and
``total = t(1) + per_unit × (units_full − 1)`` — exact for uniform stacks,
affine-approximate for the hybrid remainder (noted in the row).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--arch A] [--shape S]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import math  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.distributed.sharding import active_mesh  # noqa: E402
from repro.launch.dryrun import build_cell, cell_is_skipped  # noqa: E402
from repro.launch.hlo_analysis import (  # noqa: E402
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    model_flops,
    roofline_from_compiled,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.config import SHAPES, ArchConfig  # noqa: E402
from repro.models.registry import get_model  # noqa: E402

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "../../../results/roofline"
)


def unit_variants(cfg: ArchConfig):
    """(cfg_1unit, cfg_2unit, units_full, note)"""
    base = cfg.with_(scan_layers=False)
    if cfg.family == "moe":
        nd = cfg.moe.first_dense_layers
        return (
            base.with_(n_layers=nd + 1),
            base.with_(n_layers=nd + 2),
            cfg.n_layers - nd,
            "unit=moe-layer (dense first layer in entry)",
        )
    if cfg.family == "ssm":
        return (
            base.with_(n_layers=2),
            base.with_(n_layers=4),
            cfg.n_layers // 2,
            "unit=(mLSTM,sLSTM) pair",
        )
    if cfg.family == "hybrid":
        plen = len(cfg.hybrid.pattern)
        return (
            base.with_(n_layers=plen),
            base.with_(n_layers=2 * plen),
            cfg.n_layers / plen,
            "unit=(rec,rec,attn) triple; remainder≈2/3 unit (affine approx)",
        )
    if cfg.family == "audio":
        return (
            base.with_(n_layers=1, encoder_layers=1),
            base.with_(n_layers=2, encoder_layers=2),
            cfg.n_layers,
            "unit=enc+dec layer pair",
        )
    return (
        base.with_(n_layers=1),
        base.with_(n_layers=2),
        cfg.n_layers,
        "unit=decoder layer",
    )


def count_params(cfg: ArchConfig) -> tuple[int, int]:
    """(total, active) parameter counts from eval_shape (no allocation)."""
    model = get_model(cfg)
    shapes = jax.eval_shape(
        lambda: model.init_params(cfg, jax.random.PRNGKey(0))[0]
    )
    flat = jax.tree.flatten_with_path(shapes)[0]
    total = active = 0
    for path, leaf in flat:
        n = math.prod(leaf.shape)
        total += n
        keys = "/".join(str(p) for p in path)
        if cfg.moe and any(
            f"'{w}'" in keys for w in ("w_gate", "w_up", "w_down")
        ) and "'ffn'" in keys:
            active += n * cfg.moe.top_k / cfg.moe.num_experts
        else:
            active += n
    return int(total), int(active)


def _lower_terms(cfg: ArchConfig, shape, mesh) -> RooflineTerms:
    with active_mesh(mesh):
        step, args, in_shardings = build_cell(cfg, shape, mesh)
        compiled = jax.jit(step, in_shardings=in_shardings).lower(
            *args
        ).compile()
        return roofline_from_compiled(compiled, len(mesh.devices.flatten()))


def _extrapolate(t1: RooflineTerms, t2: RooflineTerms, units: float):
    def ext(a, b):
        # affine: total = t1 + (t2 - t1) · (units − 1).  When fusion noise
        # makes t2 < t1 (seen on the hybrid family), fall back to a pure
        # proportional model (entry ≈ 0, per-unit = t2/2) — never negative.
        per_unit = b - a
        if per_unit < 0.05 * max(b, 1e-30):
            return (b / 2.0) * units
        return a + per_unit * (units - 1)

    detail = {
        "bytes": {
            k: int(
                ext(
                    t1.collective_detail["bytes"].get(k, 0),
                    t2.collective_detail["bytes"].get(k, 0),
                )
            )
            for k in set(t1.collective_detail["bytes"])
            | set(t2.collective_detail["bytes"])
        },
        "count": {
            k: int(
                ext(
                    t1.collective_detail["count"].get(k, 0),
                    t2.collective_detail["count"].get(k, 0),
                )
            )
            for k in set(t1.collective_detail["count"])
            | set(t2.collective_detail["count"])
        },
    }
    return RooflineTerms(
        flops=ext(t1.flops, t2.flops),
        bytes_accessed=ext(t1.bytes_accessed, t2.bytes_accessed),
        collective_bytes=ext(t1.collective_bytes, t2.collective_bytes),
        chips=t1.chips,
        collective_detail=detail,
    )


def run_cell(arch: str, shape_name: str, save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_is_skipped(cfg, shape)
    if skip:
        return {
            "arch": arch,
            "shape": shape_name,
            "status": "skip",
            "reason": skip,
        }
    mesh = make_production_mesh(multi_pod=False)
    c1, c2, units, note = unit_variants(cfg)
    t0 = time.time()
    t1 = _lower_terms(c1, shape, mesh)
    t2 = _lower_terms(c2, shape, mesh)
    terms = _extrapolate(t1, t2, units)
    total_p, active_p = count_params(cfg)
    mf = model_flops(cfg, shape, total_p, active_p)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "16x16",
        "status": "ok",
        "method": note,
        "units": units,
        "elapsed_s": round(time.time() - t0, 1),
        "params_total": total_p,
        "params_active": active_p,
        "model_flops": mf,
        "useful_ratio": mf / terms.flops if terms.flops else None,
        "roofline": terms.as_dict(),
    }
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(
            os.path.join(RESULTS_DIR, f"{arch}_{shape_name}.json"), "w"
        ) as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    failures = 0
    for arch in archs:
        for shape in shapes:
            try:
                r = run_cell(arch, shape)
            except Exception as e:
                traceback.print_exc()
                r = {
                    "arch": arch,
                    "shape": shape,
                    "status": "FAIL",
                    "reason": f"{type(e).__name__}: {e}",
                }
                failures += 1
            if r["status"] == "ok":
                ro = r["roofline"]
                print(
                    f"[ok  ] {arch:22s} {shape:12s} "
                    f"compute={ro['compute_s']*1e3:8.2f}ms "
                    f"memory={ro['memory_s']*1e3:8.2f}ms "
                    f"collective={ro['collective_s']*1e3:8.2f}ms "
                    f"dom={ro['dominant']:10s} "
                    f"useful={r['useful_ratio']:.2f}",
                    flush=True,
                )
            else:
                print(
                    f"[{r['status']:4s}] {arch:22s} {shape:12s} "
                    f"({r.get('reason')})",
                    flush=True,
                )
    if failures:
        raise SystemExit(f"{failures} roofline cells FAILED")


if __name__ == "__main__":
    main()
