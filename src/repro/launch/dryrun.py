import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for the 16×16
single-pod mesh and the 2×16×16 multi-pod mesh, every train/prefill/decode
step must lower and compile, and we record memory_analysis(),
cost_analysis(), and the collective schedule (parsed from optimized HLO)
into results/dryrun/*.json for the roofline tables.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    active_mesh,
    resolve_tree,
)
from repro.launch.hlo_analysis import roofline_from_compiled  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (  # noqa: E402
    decode_input_specs,
    prefill_input_specs,
    train_input_specs,
)
from repro.models.config import SHAPES, ArchConfig, ShapeConfig  # noqa: E402
from repro.models.registry import get_model  # noqa: E402
from repro.train.optimizer import (  # noqa: E402
    OptimizerConfig,
    init_opt_state,
    opt_state_specs,
)
from repro.train.steps import (  # noqa: E402
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def cell_is_skipped(cfg: ArchConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "long_500k needs sub-quadratic attention (full-attention arch)"
    return None


def _drop_data_axis(spec_tree):
    """B=1 shapes cannot shard the batch axis — drop data/fsdp entries."""

    def fix(spec):
        entries = []
        for e in spec:
            if e in ("data", "fsdp") or (
                isinstance(e, tuple) and any(x in ("data", "fsdp") for x in e)
            ):
                entries.append(None)
            else:
                entries.append(e)
        return P(*entries)

    return jax.tree.map(fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Returns (step_fn, arg_shapes, in_shardings)."""
    model = get_model(cfg)
    # shapes via eval_shape (no allocation); the spec tree is data-independent
    # so we capture it as a side value during the same trace.
    spec_box = {}

    def _init_and_capture():
        p, s = model.init_params(cfg, jax.random.PRNGKey(0))
        spec_box["specs"] = s
        return p

    params_shapes = jax.eval_shape(_init_and_capture)
    param_specs = spec_box["specs"]

    opt_cfg = OptimizerConfig(state_dtype=cfg.optimizer_dtype)

    if shape.kind == "train":
        batch_shapes, batch_specs = train_input_specs(cfg, shape)
        opt_shapes = jax.eval_shape(
            lambda: init_opt_state(params_shapes, opt_cfg)
        )
        opt_specs = opt_state_specs(param_specs)
        step = make_train_step(cfg, opt_cfg)
        args = (params_shapes, opt_shapes, batch_shapes)
        specs = (param_specs, opt_specs, batch_specs)
    elif shape.kind == "prefill":
        batch_shapes, batch_specs = prefill_input_specs(cfg, shape)
        max_len = shape.seq_len + cfg.num_patch_tokens
        step = make_prefill_step(cfg, max_len)
        args = (params_shapes, batch_shapes)
        specs = (param_specs, batch_specs)
    else:  # decode
        (cache_shapes, tok, off), (cache_spec, tok_spec, off_spec) = (
            decode_input_specs(cfg, shape)
        )
        step = make_decode_step(cfg)
        args = (params_shapes, cache_shapes, tok, off)
        specs = (param_specs, cache_spec, tok_spec, off_spec)

    data_axis = mesh.shape.get("pod", 1) * mesh.shape["data"]
    if shape.global_batch % data_axis != 0:
        specs = _drop_data_axis(specs)

    resolved = resolve_tree(specs, mesh)
    in_shardings = _sanitized_shardings(args, resolved, mesh)
    return step, args, in_shardings


def _sanitized_shardings(args, resolved_specs, mesh):
    """pjit boundary shardings must divide dims evenly (unlike in-body
    constraints) — replicate any axis that doesn't divide (e.g. kv=8 heads
    on a 16-way model axis, batch=1 on the data axis)."""

    def fix(arg, spec):
        entries = []
        for i, e in enumerate(spec):
            if e is None or i >= len(arg.shape):
                entries.append(e)
                continue
            axes = e if isinstance(e, tuple) else (e,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            entries.append(e if arg.shape[i] % size == 0 else None)
        return NamedSharding(mesh, P(*entries))

    flat_args, treedef = jax.tree.flatten(args)
    flat_specs = treedef.flatten_up_to(resolved_specs)
    return jax.tree.unflatten(
        treedef, [fix(a, s) for a, s in zip(flat_args, flat_specs)]
    )


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, save: bool = True
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    skip = cell_is_skipped(cfg, shape)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "skip",
        "reason": skip,
    }
    if skip:
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.flatten())
    t0 = time.time()
    with active_mesh(mesh):
        step, args, in_shardings = build_cell(cfg, shape, mesh)
        lowered = jax.jit(step, in_shardings=in_shardings).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        terms = roofline_from_compiled(compiled, chips)

    result |= {
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None
            ),
        },
        "roofline": terms.as_dict(),
    }
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        fn = f"{arch}_{shape_name}_{mesh_name}.json"
        with open(os.path.join(RESULTS_DIR, fn), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True]
    if args.multi_pod or args.multi_pod_only:
        meshes = [True]
    elif args.single_pod_only:
        meshes = [False]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    r = run_cell(arch, shape, mp)
                except Exception as e:  # a failing cell is a bug — report it
                    traceback.print_exc()
                    r = {
                        "arch": arch,
                        "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "FAIL",
                        "reason": f"{type(e).__name__}: {e}",
                    }
                    failures += 1
                tag = r["status"]
                extra = ""
                if tag == "ok":
                    ro = r["roofline"]
                    extra = (
                        f" compute={ro['compute_s']*1e3:.1f}ms"
                        f" memory={ro['memory_s']*1e3:.1f}ms"
                        f" collective={ro['collective_s']*1e3:.1f}ms"
                        f" dominant={ro['dominant']}"
                        f" (compile {r['compile_s']}s)"
                    )
                elif tag == "skip":
                    extra = f" ({r['reason']})"
                print(f"[{tag:4s}] {arch:22s} {shape:12s} {r['mesh']:8s}{extra}",
                      flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells FAILED")


if __name__ == "__main__":
    main()
