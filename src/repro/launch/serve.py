"""Serving launcher: prefill a batch of synthetic prompts, decode N tokens.

Laptop scale:   PYTHONPATH=src python -m repro.launch.serve --arch yi-34b \
                    --reduced --tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params, _ = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, args.prompt_len, cfg.d_model)),
            cfg.cdtype,
        )
    if cfg.family == "vlm":
        extra["patch_embeds"] = jnp.asarray(
            rng.normal(
                size=(args.batch, cfg.num_patch_tokens, cfg.d_model)
            ),
            cfg.cdtype,
        )
    n_ctx = args.prompt_len + getattr(cfg, "num_patch_tokens", 0)
    max_len = n_ctx + args.tokens + 1

    t0 = time.perf_counter()
    logits, cache = model.prefill(
        cfg, params, tokens, **extra, max_len=max_len
    )
    print(f"prefill {args.prompt_len} tokens: {time.perf_counter()-t0:.2f}s")

    decode = jax.jit(lambda p, c, t, o: model.decode_step(cfg, p, c, t, o))
    nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [nxt]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        logits, cache = decode(params, cache, nxt, jnp.int32(n_ctx + i))
        nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(nxt)
    jax.block_until_ready(out[-1])
    dt = time.perf_counter() - t0
    print(
        f"decoded {args.tokens} tokens x{args.batch}: {dt:.2f}s "
        f"({args.tokens*args.batch/max(dt,1e-9):.1f} tok/s)"
    )
    print("sample:", np.asarray(jnp.concatenate(out, 1))[0][:12].tolist())


if __name__ == "__main__":
    main()
