"""Input specs (ShapeDtypeStruct stand-ins) and demo batches per arch × shape.

``input_specs`` feeds the dry-run (no allocation); ``demo_batch`` builds tiny
real arrays for CPU smoke tests.  The modality frontends are stubs: audio
frames / vision patches arrive as precomputed embeddings, per the assignment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig, ShapeConfig


def train_input_specs(cfg: ArchConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    inputs = {"tokens": tok}
    in_specs = {"tokens": P("data", None)}
    if cfg.family == "audio":
        inputs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.cdtype)
        in_specs["frames"] = P("data", None, None)
    if cfg.family == "vlm":
        inputs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patch_tokens, cfg.d_model), cfg.cdtype
        )
        in_specs["patch_embeds"] = P("data", None, None)
    batch = {"inputs": inputs, "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    specs = {"inputs": in_specs, "labels": P("data", None)}
    return batch, specs


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """(cache, tokens, offset) specs for one decode step at kv-len seq_len."""
    from repro.models.registry import get_model

    B, S = shape.global_batch, shape.seq_len
    model = get_model(cfg)
    kwargs = {"enc_len": S} if cfg.family == "audio" else {}
    cache_shapes = jax.eval_shape(
        lambda: model.init_kv_cache(cfg, B, S, **kwargs)[0]
    )
    small_kwargs = {"enc_len": 1} if cfg.family == "audio" else {}
    _, cache_spec = model.init_kv_cache(cfg, 1, 1, **small_kwargs)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    off = jax.ShapeDtypeStruct((), jnp.int32)
    return (cache_shapes, tok, off), (cache_spec, P("data", None), P())


def prefill_input_specs(cfg: ArchConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    inputs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    in_specs = {"tokens": P("data", None)}
    if cfg.family == "audio":
        inputs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.cdtype)
        in_specs["frames"] = P("data", None, None)
    if cfg.family == "vlm":
        inputs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patch_tokens, cfg.d_model), cfg.cdtype
        )
        in_specs["patch_embeds"] = P("data", None, None)
    return {"inputs": inputs}, {"inputs": in_specs}


def demo_batch(cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
    """Small concrete training batch for CPU tests/examples."""
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq + 1)))
    inputs = {"tokens": tokens[:, :-1].astype(jnp.int32)}
    if cfg.family == "audio":
        inputs["frames"] = jnp.asarray(
            rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32),
            cfg.cdtype,
        )
    if cfg.family == "vlm":
        inputs["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.num_patch_tokens, cfg.d_model)).astype(
                np.float32
            ),
            cfg.cdtype,
        )
    return {"inputs": inputs, "labels": tokens[:, 1:].astype(jnp.int32)}
