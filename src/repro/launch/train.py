"""Training launcher.

Laptop scale:   PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
                    --reduced --steps 20
Production:     same command without --reduced on a real TPU slice; the mesh
                comes from make_production_mesh() and params/optimizer are
                sharded by the logical rules in repro.distributed.sharding.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import SyntheticCorpus
from repro.distributed.sharding import active_mesh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU scale)")
    ap.add_argument("--signsgd", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n_dev = len(jax.devices())
    mesh = (
        make_production_mesh(multi_pod=args.multi_pod)
        if n_dev >= 256
        else make_host_mesh(data=min(2, n_dev), model=1)
    )
    tcfg = TrainerConfig(
        opt=OptimizerConfig(
            lr=args.lr, mode="signsgd" if args.signsgd else "adamw"
        ),
        ckpt_dir=args.ckpt_dir,
        compress_grads="signsgd" if args.signsgd else "none",
    )
    with active_mesh(mesh):
        trainer = Trainer(cfg, tcfg, mesh=mesh)
        if trainer.maybe_restore():
            print(f"restored at step {trainer.step_num}")
        corpus = SyntheticCorpus(
            vocab=cfg.vocab, seq_len=args.seq, num_samples=2048
        )
        hist = trainer.train(
            corpus.batches(args.batch), num_steps=args.steps, log_every=5
        )
    print(f"final loss {hist[-1]:.4f}")


if __name__ == "__main__":
    main()
