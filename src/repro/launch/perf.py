import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver for the three chosen (arch × shape) cells.

1. yi-34b × prefill_32k      (worst compute/bound fraction among baselines)
   H1: the naive attention materializes (Sq,Skv) f32 scores; blockwise
   online-softmax removes the S² traffic from the memory term.
2. kimi-k2-1t-a32b × train_4k (most collective-bound)
   H2: the f32 dispatch scatter-add forces GSPMD to all-reduce the full
   (B,E,C,d) buffer across the EP axis per MoE layer; an int32 slot-index
   scatter + local gather eliminates those all-reduces.
3. starcoder2-3b gradient exchange (most representative of the paper:
   bulk-bitwise ops as a distributed primitive)
   H3: replacing the f32 gradient all-reduce with 1-bit sign planes
   (pack → all-gather → packed bitwise majority → unpack) cuts collective
   bytes ~4× flat and ~32× on the scarce cross-pod links (hierarchical).

Each experiment lowers before/after on the production mesh and records the
three roofline terms.  Results -> results/perf/*.json.
"""

import json  # noqa: E402
import math  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.distributed.sharding import active_mesh  # noqa: E402
from repro.launch.hlo_analysis import roofline_from_compiled  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import (  # noqa: E402
    _extrapolate,
    _lower_terms,
    unit_variants,
)
from repro.models.config import SHAPES  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/perf")


def _terms_for(cfg, shape_name):
    mesh = make_production_mesh(multi_pod=False)
    c1, c2, units, _ = unit_variants(cfg)
    t1 = _lower_terms(c1, SHAPES[shape_name], mesh)
    t2 = _lower_terms(c2, SHAPES[shape_name], mesh)
    return _extrapolate(t1, t2, units)


def _record(name, rows):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1)
    for r in rows:
        ro = r["roofline"]
        print(
            f"  {r['variant']:34s} compute={ro['compute_s']*1e3:9.1f}ms "
            f"memory={ro['memory_s']*1e3:9.1f}ms "
            f"collective={ro['collective_s']*1e3:9.1f}ms dom={ro['dominant']}",
            flush=True,
        )


def exp_yi_prefill():
    print("[exp1] yi-34b x prefill_32k: naive vs blockwise attention")
    rows = []
    for variant, cfg in [
        ("baseline(naive-attn)", get_config("yi-34b")),
        (
            "blockwise-attention",
            get_config("yi-34b").with_(attention_impl="blockwise"),
        ),
    ]:
        t = _terms_for(cfg, "prefill_32k")
        rows.append({"variant": variant, "roofline": t.as_dict()})
    _record("yi34b_prefill32k", rows)


def exp_kimi_train():
    print("[exp2] kimi-k2 x train_4k: scatter vs gather dispatch (+blockwise)")
    rows = []
    for variant, cfg in [
        ("baseline(scatter-dispatch)", get_config("kimi-k2-1t-a32b")),
        (
            "gather-dispatch",
            get_config("kimi-k2-1t-a32b").with_(moe_dispatch="gather"),
        ),
        (
            "gather+blockwise-attn",
            get_config("kimi-k2-1t-a32b").with_(
                moe_dispatch="gather", attention_impl="blockwise"
            ),
        ),
    ]:
        t = _terms_for(cfg, "train_4k")
        rows.append({"variant": variant, "roofline": t.as_dict()})
    _record("kimi_train4k", rows)


# ---------------------------------------------------------------------------
# exp3: gradient exchange — f32 psum vs packed 1-bit majority
# ---------------------------------------------------------------------------


def _grad_exchange_cells(n_params: int):
    from jax.experimental.shard_map import shard_map

    from repro.kernels.signcomp.ref import (
        majority_ref,
        pack_signs_ref,
        unpack_signs_ref,
    )

    lanes = 512
    rows = -(-n_params // (32 * lanes))
    shaped = (rows * 32, lanes)

    def baseline(g):  # g: (D, rows*32, lanes) one grad slice per replica
        return jax.lax.psum(g, "data")

    def compressed(g):
        packed = pack_signs_ref(g[0])  # (rows, lanes) uint32, local signs
        allp = jax.lax.all_gather(packed, "data")  # (D, rows, lanes)
        maj = majority_ref(allp)
        return unpack_signs_ref(maj)

    def hierarchical(g):
        # f32 reduce within the pod, 1-bit majority across pods: only sign
        # planes cross the scarce pod links.  g: (1, …) distinct per device.
        local = jax.lax.psum(g[0], "data")
        packed = pack_signs_ref(local)
        allp = jax.lax.all_gather(packed, "pod")  # (2, rows, lanes)
        maj = majority_ref(allp)
        return unpack_signs_ref(maj)

    return shaped, baseline, compressed, hierarchical


def exp_grad_exchange():
    from jax.experimental.shard_map import shard_map

    print("[exp3] starcoder2-3b-sized gradient exchange (paper-technique)")
    n_params = 3_030_000_000
    shaped, baseline, compressed, hierarchical = _grad_exchange_cells(n_params)
    rows = []

    mesh = make_production_mesh(multi_pod=False)
    g_spec = jax.ShapeDtypeStruct((16, *shaped), jnp.float32)
    with active_mesh(mesh):
        for variant, fn in [
            ("baseline(f32-psum)", baseline),
            ("1bit-majority-allgather", compressed),
        ]:
            sm = shard_map(
                fn,
                mesh=mesh,
                in_specs=P("data"),
                out_specs=P(),
                check_rep=False,
            )
            compiled = (
                jax.jit(sm)
                .lower(g_spec)
                .compile()
            )
            t = roofline_from_compiled(compiled, 256)
            rows.append({"variant": variant, "roofline": t.as_dict()})

    mesh_mp = make_production_mesh(multi_pod=True)
    gs = jax.ShapeDtypeStruct((32, *shaped), jnp.float32)
    with active_mesh(mesh_mp):
        for variant, fn in [
            (
                "multipod-baseline(f32-psum)",
                lambda g: jax.lax.psum(jax.lax.psum(g, "data"), "pod"),
            ),
            ("multipod-hierarchical-1bit", hierarchical),
        ]:
            sm = shard_map(
                fn,
                mesh=mesh_mp,
                in_specs=P(("pod", "data")),
                out_specs=P(),
                check_rep=False,
            )
            compiled = jax.jit(sm).lower(gs).compile()
            t = roofline_from_compiled(compiled, 512)
            rows.append({"variant": variant, "roofline": t.as_dict()})
    _record("grad_exchange", rows)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--exp",
        choices=["yi", "kimi", "grad", "all"],
        default="all",
    )
    args = ap.parse_args()
    t0 = time.time()
    if args.exp in ("yi", "all"):
        exp_yi_prefill()
    if args.exp in ("kimi", "all"):
        exp_kimi_train()
    if args.exp in ("grad", "all"):
        exp_grad_exchange()
    print(f"done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
