"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the smoke tests (1 CPU device) and
the dry-run (512 forced host devices) to coexist in one codebase.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (CPU) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh(
        (data, model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )
