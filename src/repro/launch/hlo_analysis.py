"""Roofline-term extraction from compiled dry-run artifacts.

``cost_analysis()`` supplies HLO FLOPs and bytes; collective bytes are NOT
in cost_analysis, so we parse the optimized HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Hardware constants: TPU v5e-class — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  bf16[256,4096,7168]{2,1,0}
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
# Optimized (post-SPMD) HLO: result type(s) precede the op name; operands
# are %name references, so we meter RESULT types — per-device shard shapes.
#   %all-gather.93 = f32[896,4096]{0,1} all-gather(%fusion), channel_id=...
_OP_LINE_RE = re.compile(
    r"=\s*(\(.*?\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)

# ring-algorithm wire factor per byte of per-device buffer
_WIRE_FACTOR = {
    "all-reduce": 2.0,  # reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    """Per-device wire bytes by collective kind (result-shard sizes × ring
    factor), parsed from the per-device optimized HLO module."""

    bytes_by_kind: dict[str, int] = field(default_factory=dict)
    count_by_kind: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        result_types, kind = m.group(1), m.group(2)
        nbytes = sum(
            _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_types)
        )
        nbytes = int(nbytes * _WIRE_FACTOR[kind])
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int
    collective_detail: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "collective_detail": self.collective_detail,
        }


def roofline_from_compiled(compiled, chips: int) -> RooflineTerms:
    """cost_analysis()/as_text() on the post-SPMD module are PER-DEVICE
    (verified empirically); globalize by × chips so the three-term formulas
    (X_global / (chips × peak)) apply unchanged."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0)) * chips
    nbytes = float(ca.get("bytes accessed", 0.0)) * chips
    stats = collective_bytes(compiled.as_text())
    return RooflineTerms(
        flops=flops,
        bytes_accessed=nbytes,
        collective_bytes=float(stats.total_bytes) * chips,
        chips=chips,
        collective_detail={
            "bytes": stats.bytes_by_kind,
            "count": stats.count_by_kind,
        },
    )


def model_flops(cfg, shape, params_total: int, params_active: int) -> float:
    """6·N·D for train (N = active params, D = tokens); 2·N·B for decode."""
    tokens = shape.global_batch * shape.seq_len
    n = params_active
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
