"""Fault-tolerant checkpointing: atomic commits, async writes, elastic
restore onto a different mesh.

Layout:  <dir>/step_<N>/
             manifest.json     tree structure + dtypes + shapes + specs
             arrays.npz        one entry per leaf (path-encoded keys)
         <dir>/step_<N>.tmp-*  staging (renamed atomically on commit)

Params are saved with their *logical* PartitionSpecs; restore re-resolves
them against whatever mesh is active, so a checkpoint taken on a 2-pod mesh
restores onto a single pod (or 1 CPU device) unchanged — elastic re-mesh.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import resolve_spec

_SEP = "/"


def _flatten(tree: Any, prefix="") -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out |= _flatten(v, f"{prefix}{k}{_SEP}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out |= _flatten(v, f"{prefix}{i}{_SEP}")
    else:
        out[prefix[: -len(_SEP)]] = tree
    return out


def _unflatten_into(skeleton: Any, flat: dict[str, Any], prefix="") -> Any:
    if isinstance(skeleton, dict):
        return {
            k: _unflatten_into(v, flat, f"{prefix}{k}{_SEP}")
            for k, v in skeleton.items()
        }
    if isinstance(skeleton, (list, tuple)):
        vals = [
            _unflatten_into(v, flat, f"{prefix}{i}{_SEP}")
            for i, v in enumerate(skeleton)
        ]
        return type(skeleton)(vals) if isinstance(skeleton, tuple) else vals
    return flat[prefix[: -len(_SEP)]]


def _spec_to_json(spec: P) -> list:
    return [list(e) if isinstance(e, tuple) else e for e in spec]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, spec_tree: Any = None, *, block=True):
        self.wait()  # one in-flight write at a time
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)

        def _write():
            flat = _flatten(host_tree)
            specs = (
                {
                    k: _spec_to_json(v)
                    for k, v in _flatten(spec_tree).items()
                }
                if spec_tree is not None
                else {}
            )
            manifest = {
                "step": step,
                "keys": sorted(flat),
                "specs": specs,
            }
            staging = tempfile.mkdtemp(
                prefix=f"step_{step}.tmp-", dir=self.dir
            )
            np.savez(
                os.path.join(staging, "arrays.npz"),
                **{k: v for k, v in flat.items()},
            )
            with open(os.path.join(staging, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(staging, final)  # atomic commit
            self._gc()

        if block:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"))

    # -- restore ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp-" not in name:
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(
        self, skeleton: Any, step: int | None = None, *, mesh=None,
        spec_tree: Any = None,
    ) -> Any:
        """Restore into the structure of ``skeleton``.  With ``mesh`` and
        ``spec_tree``, leaves are device_put with re-resolved shardings —
        this is what makes restores elastic across mesh shapes."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(skeleton, flat)
        if mesh is not None and spec_tree is not None:
            tree = _device_put_tree(tree, spec_tree, mesh)
        return tree


def _device_put_tree(tree, spec_tree, mesh):
    flat_t = _flatten(tree)
    flat_s = _flatten(spec_tree)
    out = {}
    for k, v in flat_t.items():
        spec = flat_s.get(k)
        if isinstance(spec, P):
            sharding = NamedSharding(mesh, resolve_spec(spec, mesh))
            out[k] = jax.device_put(v, sharding)
        else:
            out[k] = jax.device_put(v)
    return _unflatten_into(tree, out)
