"""AdamW with ZeRO-sharded state (+ optional bf16 moments for 1T-class
configs) and an optional signSGD-majority mode that consumes the Flash-Cosmos
sign-compression kernels' output."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # "bfloat16" halves optimizer memory
    mode: str = "adamw"  # or "signsgd" (majority-voted sign updates)


def init_opt_state(params: Any, cfg: OptimizerConfig) -> Any:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs: Any) -> Any:
    """Optimizer moments shard exactly like their parameters (ZeRO)."""
    from jax.sharding import PartitionSpec as P

    return {
        "mu": param_specs,
        "nu": param_specs,
        "step": P(),
    }


def clip_by_global_norm(grads: Any, max_norm: float) -> Any:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state, cfg: OptimizerConfig):
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        mu_hat = mu32 / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu32 / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - cfg.lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), mu32.astype(sdt), nu32.astype(sdt)

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_mu = jax.tree.unflatten(treedef, [t[1] for t in flat])
    new_nu = jax.tree.unflatten(treedef, [t[2] for t in flat])
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}


def signsgd_update(params, sign_grads, state, cfg: OptimizerConfig):
    """signSGD with majority vote: ``sign_grads`` are ±1 (already voted
    across the data axis via the packed bitwise majority kernel)."""
    step = state["step"] + 1

    def upd(p, s):
        p32 = p.astype(jnp.float32)
        return (p32 - cfg.lr * (s + cfg.weight_decay * p32)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, sign_grads)
    return new_params, {"mu": state["mu"], "nu": state["nu"], "step": step}
