"""Train / prefill / decode step factories, generic over architecture.

Each factory returns a pure function suitable for ``jax.jit`` with explicit
in/out shardings, used by the trainer, the serving engine, and the dry-run.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.registry import get_model
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    clip_by_global_norm,
)


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy; logits (B,S,V) f32, labels (B,S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_loss_fn(cfg: ArchConfig) -> Callable:
    model = get_model(cfg)

    def loss_fn(params, batch):
        logits = model.forward(cfg, params, **batch["inputs"])
        labels = batch["labels"]
        # next-token shift happens in the data pipeline; labels align to
        # logits positions directly.
        return softmax_xent(logits, labels)

    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig) -> Callable:
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_params, new_state = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, max_len: int) -> Callable:
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(cfg, params, max_len=max_len, **batch["inputs"])

    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    model = get_model(cfg)

    def decode_step(params, cache, tokens, offset):
        return model.decode_step(cfg, params, cache, tokens, offset)

    return decode_step
