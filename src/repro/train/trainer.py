"""Training driver: jit'd step with explicit shardings, checkpoint/restart,
straggler watchdog, optional 1-bit sign-compressed gradient aggregation.

Fault-tolerance model (designed for 1000+ nodes, exercised at test scale):

* **checkpoint/restart** — atomic async checkpoints every ``ckpt_every``
  steps; on (re)start the trainer restores the latest complete checkpoint
  and resumes, including onto a *different* mesh (elastic re-mesh).
* **straggler watchdog** — per-step wall time EWMA; steps slower than
  ``straggler_factor ×`` the EWMA fire a callback (production: re-shard away
  from the slow host / trigger preemption-aware rescue; tests assert the
  detection fires).
* **grad compression** — ``compress_grads="signsgd"`` runs signSGD with
  bitwise majority voting: sign planes are packed 1-bit (32× smaller than
  f32) with the Flash-Cosmos pack kernel and combined with the packed
  majority kernel — the paper's multi-operand bulk-bitwise op as a
  distributed-optimization primitive (with error feedback retained locally).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.registry import get_model
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    clip_by_global_norm,
    init_opt_state,
    opt_state_specs,
    signsgd_update,
)
from repro.train.steps import make_loss_fn


@dataclass
class TrainerConfig:
    opt: OptimizerConfig = field(default_factory=OptimizerConfig)
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    ckpt_async: bool = True
    straggler_factor: float = 3.0
    straggler_warmup: int = 3
    compress_grads: str = "none"  # "none" | "signsgd"


class StragglerWatchdog:
    def __init__(self, factor: float, warmup: int, on_straggler: Callable):
        self.factor = factor
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.ewma: float | None = None
        self.count = 0
        self.events: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float):
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
            return
        if self.count > self.warmup and dt > self.factor * self.ewma:
            self.events.append((step, dt, self.ewma))
            self.on_straggler(step, dt, self.ewma)
        else:
            self.ewma = 0.9 * self.ewma + 0.1 * dt


def _signsgd_step(cfg: ArchConfig, opt_cfg: OptimizerConfig):
    """Train step with 1-bit sign compression + packed majority voting.

    The pack→majority→unpack pipeline runs on the gradient *after* psum in
    single-program view; its collective effect (all-gather of packed planes
    instead of f32 grads) is measured in the dry-run roofline — see
    EXPERIMENTS.md §Perf.  Error feedback keeps the residual locally.
    """
    from repro.kernels.signcomp import compress_signs, decompress_signs

    loss_fn = make_loss_fn(cfg)

    def step(params, opt_state, ef, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        acc = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, ef)
        # 1-bit compress/decompress round-trip (the kernels' data path); the
        # per-tensor magnitude rescales the ±1 votes (scaled signSGD).
        signs = jax.tree.map(
            lambda g: decompress_signs(
                compress_signs(g.reshape(-1)), g.size
            ).reshape(g.shape),
            acc,
        )
        scaled = jax.tree.map(
            lambda g, s: s * jnp.mean(jnp.abs(g)), acc, signs
        )
        new_ef = jax.tree.map(lambda g, u: g - u, acc, scaled)  # error fb
        new_params, new_state = signsgd_update(
            params, scaled, opt_state, opt_cfg
        )
        return new_params, new_state, new_ef, {"loss": loss}

    return step


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        tcfg: TrainerConfig,
        *,
        mesh=None,
        rng_seed: int = 0,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.model = get_model(cfg)
        self.params, self.param_specs = self.model.init_params(
            cfg, jax.random.PRNGKey(rng_seed)
        )
        self.opt_state = init_opt_state(self.params, tcfg.opt)
        self.opt_specs = opt_state_specs(self.param_specs)
        self.step_num = 0
        self.ckpt = (
            CheckpointManager(tcfg.ckpt_dir) if tcfg.ckpt_dir else None
        )
        self.watchdog = StragglerWatchdog(
            tcfg.straggler_factor,
            tcfg.straggler_warmup,
            self._on_straggler,
        )
        self.straggler_log: list[int] = []

        if tcfg.compress_grads == "signsgd":
            self.ef = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), self.params
            )
            self._step = jax.jit(_signsgd_step(cfg, tcfg.opt))
        else:
            self.ef = None
            loss_fn = make_loss_fn(cfg)

            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                grads, gnorm = clip_by_global_norm(grads, tcfg.opt.grad_clip)
                new_p, new_s = adamw_update(params, grads, opt_state, tcfg.opt)
                return new_p, new_s, {"loss": loss, "grad_norm": gnorm}

            self._step = jax.jit(train_step)

    def _on_straggler(self, step, dt, ewma):
        self.straggler_log.append(step)

    # -- checkpoint/restart ------------------------------------------------
    def maybe_restore(self) -> bool:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        state = self.ckpt.restore(
            {"params": self.params, "opt": self.opt_state},
            mesh=self.mesh,
            spec_tree={"params": self.param_specs, "opt": self.opt_specs},
        )
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step_num = int(self.ckpt.latest_step())
        return True

    def save(self, block=True):
        if self.ckpt is None:
            return
        self.ckpt.save(
            self.step_num,
            {"params": self.params, "opt": self.opt_state},
            {"params": self.param_specs, "opt": self.opt_specs},
            block=block or not self.tcfg.ckpt_async,
        )

    # -- loop ----------------------------------------------------------------
    def train(self, batches, num_steps: int, log_every: int = 10):
        history = []
        it = iter(batches)
        for _ in range(num_steps):
            batch = next(it)
            t0 = time.perf_counter()
            if self.ef is not None:
                self.params, self.opt_state, self.ef, metrics = self._step(
                    self.params, self.opt_state, self.ef, batch
                )
            else:
                self.params, self.opt_state, metrics = self._step(
                    self.params, self.opt_state, batch
                )
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_num += 1
            self.watchdog.observe(self.step_num, dt)
            history.append(loss)
            if self.ckpt and self.step_num % self.tcfg.ckpt_every == 0:
                self.save(block=not self.tcfg.ckpt_async)
            if log_every and self.step_num % log_every == 0:
                print(
                    f"step {self.step_num:5d}  loss {loss:.4f}  "
                    f"dt {dt*1e3:.1f}ms"
                )
        if self.ckpt:
            self.save(block=True)
            self.ckpt.wait()
        return history
