"""Streaming FlashQL: a live feed appends batches between query flushes.

An order stream lands on a sharded FlashQL fleet in small batches while
dashboards keep querying COUNT / SUM / GROUP BY between appends.  Each
append ESP-programs only its *delta* pages (tail words of the bitmaps the
new rows set, plus fresh pages for first-seen values), and plans over
columns whose index metadata did not change stay warm in every shard's
plan cache — watch the miss counter stop moving after the first tick.

Run:  PYTHONPATH=src python examples/flashql_streaming.py
"""

from __future__ import annotations

import numpy as np

from repro.query import (
    Count,
    Eq,
    GroupBy,
    In,
    Query,
    Range,
    Sum,
    build_sharded_flashql,
)

REGIONS, STATUSES = 5, 3


def order_batch(rng, n, tick):
    return {
        # tick 3 introduces a brand-new region (id 7): GROUP BY grows a
        # group, and only region-sensing plans recompile
        "region": (
            np.full(n, 7) if tick == 3 else rng.integers(0, REGIONS, n)
        ),
        "status": rng.integers(0, STATUSES, n),
        "amount": rng.integers(1, 500, n),
    }


def main() -> None:
    rng = np.random.default_rng(0)
    base = order_batch(rng, 5_000, tick=0)
    fleet = build_sharded_flashql(
        base, num_shards=2, num_planes=2, reserve_rows=2_000
    )

    dashboards = [
        Query(Range("amount", 100, None), tag="big orders"),
        Query(In("status", [0, 1]), agg=Sum("amount"), tag="open value"),
        Query(Eq("status", 2), agg=GroupBy("region", Count()),
              tag="closed by region"),
        # senses the region column: recompiles exactly once, at tick 3,
        # when region 7 first appears (every other plan stays warm)
        Query(Eq("region", 7), tag="launch region"),
    ]

    total = 5_000
    for tick in range(1, 6):
        batch = order_batch(rng, 400, tick)
        pages = fleet.append(batch)
        total += 400
        results = fleet.serve(dashboards)
        s = fleet.stats()
        print(f"tick {tick}: +400 rows (total {total}), "
              f"{pages} delta page programs")
        for r in results:
            print(f"  {r.query.tag:18s} -> {r.value}")
        print(f"  plan cache: {s['plan_cache_hits']} hits / "
              f"{s['plan_cache_misses']} misses; "
              f"delta ESP programs so far: {s['esp_delta_programs']}")

    proj = fleet.projection()
    print(
        f"fleet SSD projection: FC {proj['fc_time_s'] * 1e3:.2f} ms, "
        f"{proj['fc_energy_j']:.3f} J on {proj['num_devices']} chips, "
        f"{sum(p['esp_programs'] for p in proj['per_shard'])} delta ESP "
        f"programs ({proj['speedup_vs_osp']:.1f}x vs OSP)"
    )


if __name__ == "__main__":
    main()
