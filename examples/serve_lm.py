"""Serving example: prefill + batched decode with KV cache.

Loads a small dense LM (random weights — the point is the serving data
path), prefills a batch of prompts, then decodes tokens autoregressively.

Run:  PYTHONPATH=src python examples/serve_lm.py [--tokens 32]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.registry import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    args = ap.parse_args()

    cfg = (
        get_config("granite-8b")
        .with_(
            n_layers=4,
            d_model=512,
            n_heads=8,
            n_kv_heads=2,
            d_ff=1536,
            vocab=32768,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
        )
    )
    model = get_model(cfg)
    params, _ = model.init_params(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    max_len = args.prompt_len + args.tokens

    t0 = time.perf_counter()
    logits, cache = model.prefill(cfg, params, prompts, max_len=max_len)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    print(
        f"prefill: batch={args.batch} len={args.prompt_len} "
        f"in {t_prefill*1e3:.1f} ms"
    )

    decode = jax.jit(
        lambda p, c, t, o: model.decode_step(cfg, p, c, t, o)
    )
    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tokens]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        logits, cache = decode(
            params, cache, tokens, jnp.int32(args.prompt_len + i)
        )
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tokens)
    jax.block_until_ready(out[-1])
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(
        f"decoded {args.tokens} tokens/seq in {dt*1e3:.1f} ms "
        f"({args.tokens*args.batch/dt:.1f} tok/s total)"
    )
    print("sample token ids:", np.asarray(gen[0])[:16].tolist())


if __name__ == "__main__":
    main()
