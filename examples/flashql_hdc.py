"""Hyperdimensional computing (HDC) on the flash array, in three sensings.

HDC's three primitives map 1:1 onto in-flash bulk bitwise operations:

* **bind** (role (x) filler)  = XOR        -> one XOR read
* **bundle** (superposition)  = majority   -> ONE k-of-N threshold sensing
* **similarity** (Hamming)    = XOR + popcount -> one XOR read + kernel

The majority vote is the showpiece: bundling N hypervectors classically
needs per-bit counters over N operands, but the threshold sensing
compares the number of conducting wordlines against k = ceil((N+1)/2)
in a single staircase sense — the bundle never exists as intermediate
per-bit counts anywhere.

The demo builds a tiny item memory of role/filler hypervectors, encodes
records by binding and bundling ON DEVICE, learns class prototypes by
bundling noisy examples, then classifies unseen noisy queries by
on-device Hamming distance — every step asserted against a numpy oracle.

Run:  PYTHONPATH=src python examples/flashql_hdc.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.bitops import pack_bits, unpack_bits
from repro.core.engine import FlashArray
from repro.core.expr import Page, Threshold, xor_
from repro.kernels.popcount import popcount

D = 4096  # hypervector dimensionality (bits)
NUM_CLASSES = 3
EXAMPLES_PER_CLASS = 7  # odd: the majority vote can never tie
NOISE = 0.15  # per-bit flip probability for examples/queries


def majority(k, names):
    """Bundle = per-bit majority: ONE k-of-N threshold sensing."""
    return Threshold(k, tuple(Page(n) for n in names))


def write_hv(arr, name, bits):
    arr.fc_write(name, pack_bits(jnp.asarray(bits)))


def read_bits(arr, expr):
    return np.asarray(unpack_bits(arr.fc_read(expr), D))


def main() -> None:
    rng = np.random.default_rng(0)
    arr = FlashArray()

    # -- item memory: random role/filler hypervectors ---------------------
    roles = {r: rng.integers(0, 2, D, np.uint8) for r in ("role0", "role1")}
    for name, bits in roles.items():
        write_hv(arr, name, bits)

    # -- bind on device: record = role (x) filler (XOR) -------------------
    filler = rng.integers(0, 2, D, np.uint8)
    write_hv(arr, "filler", filler)
    bound = read_bits(arr, xor_(Page("role0"), Page("filler")))
    np.testing.assert_array_equal(bound, roles["role0"] ^ filler)
    print(f"bind: role (x) filler XOR, D={D}, bit-exact")

    # -- learn: class prototype = on-device majority bundle ---------------
    k = (EXAMPLES_PER_CLASS + 1) // 2  # strict majority of 7 => k=4
    bases = [rng.integers(0, 2, D, np.uint8) for _ in range(NUM_CLASSES)]
    protos = []
    for c, base in enumerate(bases):
        names = []
        examples = []
        for i in range(EXAMPLES_PER_CLASS):
            flips = rng.random(D) < NOISE
            ex = base ^ flips.astype(np.uint8)
            name = f"class{c}/ex{i}"
            write_hv(arr, name, ex)
            names.append(name)
            examples.append(ex)
        proto = read_bits(arr, majority(k, names))
        want = (np.sum(examples, axis=0) >= k).astype(np.uint8)
        np.testing.assert_array_equal(proto, want)
        write_hv(arr, f"proto{c}", proto)
        protos.append(proto)
        agree = int((proto == base).sum())
        print(
            f"bundle: class {c} prototype = majority of "
            f"{EXAMPLES_PER_CLASS} noisy examples in ONE threshold "
            f"sensing ({agree}/{D} bits match the hidden base)"
        )

    # -- classify: nearest prototype by on-device Hamming distance --------
    correct = 0
    trials = 12
    for t in range(trials):
        true = int(rng.integers(0, NUM_CLASSES))
        flips = rng.random(D) < NOISE
        query = bases[true] ^ flips.astype(np.uint8)
        write_hv(arr, "query", query)
        dists = []
        for c in range(NUM_CLASSES):
            diff = arr.fc_read(xor_(Page("query"), Page(f"proto{c}")))
            dists.append(int(popcount(diff)))
            want = int((query ^ protos[c]).sum())
            assert dists[-1] == want, (c, dists[-1], want)
        pred = int(np.argmin(dists))
        correct += pred == true
    print(
        f"similarity: {correct}/{trials} noisy queries classified by "
        f"on-device XOR + popcount Hamming distance"
    )
    assert correct == trials, "HDC classification should be exact here"
    print("ok")


if __name__ == "__main__":
    main()
