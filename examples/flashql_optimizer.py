"""The FlashQL query optimizer: sense once, answer many.

A dashboard fleet keeps re-asking a handful of hot filters (plus a MASK
drill-down) over one orders table.  With Flash-Cosmos the unit of device
work is the multi-wordline *sensing*, not the query — so the optimizer's
whole job is to answer the same stream with fewer sensings:

* operand-order variants (``status AND region`` vs ``region AND
  status``) canonicalize into one plan-cache entry and one sensing;
* queries sharing the expensive bit-sliced Range subtree sense it ONCE
  per flush — the shared latch result is spliced into every member of
  the fused flush program (cross-query CSE);
* after ``materialize_after`` compiles, a hot predicate's whole bitmap
  is ESP-programmed as a cached page, and later queries sense two
  wordlines instead of re-running the comparison network.  Appending
  rows invalidates the cached page (watch the counter); deleting rows
  does not — tombstones compose at read time.

Run:  PYTHONPATH=src python examples/flashql_optimizer.py
"""

from __future__ import annotations

import numpy as np

from repro.query import Agg, Eq, In, Query, Range, build_sharded_flashql
from repro.query.ast import and_ as qand

NUM_ROWS = 8_000


def dashboards(tick: int) -> list[Query]:
    big = Range("amount", 150, 800)  # 10-bit BSI comparison network
    qs = [
        Query(qand(Eq("region", 1), big), tag="big in EU"),
        Query(qand(big, Eq("region", 1)), tag="big in EU (commuted)"),
        Query(qand(Eq("region", 3), big), tag="big in APAC"),
        Query(qand(In("status", [0, 1]), big), tag="big open"),
        Query(qand(Eq("region", 1), big), agg=Agg.MASK, tag="EU drill-down"),
    ]
    return qs


def main() -> None:
    rng = np.random.default_rng(0)
    table = {
        "region": rng.integers(0, 5, NUM_ROWS),
        "status": rng.integers(0, 3, NUM_ROWS),
        "amount": rng.integers(0, 1_000, NUM_ROWS),
    }
    fleet = build_sharded_flashql(
        table, num_shards=2, num_planes=2, pipeline=True,
        reserve_rows=2_000, materialize_after=4,
    )
    baseline = build_sharded_flashql(
        table, num_shards=2, num_planes=2, pipeline=True,
        reserve_rows=2_000, optimize=False,
    )

    for tick in range(1, 7):
        qs = dashboards(tick)
        m0, b0 = fleet.stats()["mws_commands"], baseline.stats()["mws_commands"]
        results = fleet.serve(qs)
        ref = baseline.serve(qs)
        for r, b in zip(results, ref):  # optimizer is semantically invisible
            assert r.query.agg is Agg.MASK or r.count == b.count
        spq = (fleet.stats()["mws_commands"] - m0) / len(qs)
        spq_base = (baseline.stats()["mws_commands"] - b0) / len(qs)
        opt = fleet.telemetry.snapshot()["optimizer"]
        print(
            f"tick {tick}: {spq:6.2f} sensings/query "
            f"(baseline {spq_base:6.2f})  "
            f"cse_hits={opt['cse_plan_hits']} "
            f"shared_senses={opt['cse_shared_senses']} "
            f"mat={opt['materializations']}/{opt['materialization_hits']} hits"
        )
        for r in results[:1]:
            print(f"  {r.query.tag:12s} -> {r.count}")

    # appends invalidate materialized pages (their bitmap would zero-miss
    # the new rows); deletes never do
    fleet.append({
        "region": rng.integers(0, 5, 500),
        "status": rng.integers(0, 3, 500),
        "amount": rng.integers(0, 1_000, 500),
    })
    fleet.serve(dashboards(7))
    fleet.delete(np.arange(10))
    fleet.serve(dashboards(8))
    opt = fleet.telemetry.snapshot()["optimizer"]
    print(
        f"after append+delete: invalidations="
        f"{opt['materialization_invalidations']} (append only — deletes "
        f"compose the tombstone page at read time)"
    )


if __name__ == "__main__":
    main()
