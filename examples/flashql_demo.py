"""FlashQL quickstart: table -> bitmap index -> batched queries -> SSD model.

The BMI scenario of the paper's §7 as a *query service*: ingest a user
table, ESP-program its bitmap indexes, serve a mixed batch of COUNT/MASK
queries on the vectorized multi-plane engine, and project the served
traffic onto the full-scale SSD model.

Run:  PYTHONPATH=src python examples/flashql_demo.py
"""

import numpy as np

from repro.query import (
    Agg,
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    In,
    Not,
    Query,
    Range,
)
from repro.query.ast import and_, or_


def main() -> None:
    rng = np.random.default_rng(0)
    n = 100_000
    table = {
        "country": rng.integers(0, 8, n),
        "device": rng.integers(0, 4, n),
        "age": rng.integers(13, 90, n),
    }

    # 1. ingest: equality bitmaps per (column, value) + bit-sliced index
    store = BitmapStore()
    store.ingest(table)

    # 2. program a 4-plane device; warmup queries steer §6.3 placement
    dev = FlashDevice(num_planes=4)
    store.program(dev, warmup=[Query(In("country", [0, 1, 2]))])

    # 3. serve a batch of queries
    sched = BatchScheduler(dev, store)
    queries = [
        Query(Eq("country", 3), tag="users in country 3"),
        Query(
            and_(Eq("country", 3), Eq("device", 1)),
            tag="... on mobile",
        ),
        Query(In("country", [0, 1, 2]), tag="EU countries"),
        Query(Range("age", 18, 35), tag="18-35 year olds"),
        Query(
            and_(Not(Eq("device", 0)), Range("age", None, 17)),
            tag="minors off desktop",
        ),
        Query(
            or_(Eq("device", 2), Eq("device", 3)),
            agg=Agg.MASK,
            tag="tablet/tv bitmap",
        ),
    ]
    for r in sched.serve(queries):
        if r.query.agg is Agg.COUNT:
            print(f"{r.query.tag:24s} -> {r.count:7d} rows")
        else:
            bits = np.asarray(r.mask.to_bits())
            print(f"{r.query.tag:24s} -> bitmap, {int(bits.sum())} set")

    # 4. stats + full-scale time/energy projection (Table-1 SSD)
    s = sched.stats()
    print(
        f"\nserved {s['queries_served']} queries in "
        f"{s['vmap_batches']} vmap batches + {s['eager_plans']} eager; "
        f"plan cache {s['plan_cache_hits']}/{s['plan_cache_misses']} h/m"
    )
    p = sched.projection()
    print(
        f"full-scale SSD projection: {p['fc_time_s'] * 1e3:.2f} ms, "
        f"{p['fc_energy_j']:.3f} J "
        f"({p['speedup_vs_osp']:.1f}x vs OSP, "
        f"{p['energy_ratio_vs_osp']:.1f}x energy)"
    )


if __name__ == "__main__":
    main()
