"""The paper's three workloads (BMI / IMS / KCS) end to end: functional
execution on the TPU engine at reduced scale + full-scale performance/energy
projection on the SSD model (the Fig. 17/18 reproduction).

Run:  PYTHONPATH=src python examples/flash_analytics.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.engine import FlashArray
from repro.core.expr import Page, and_, or_
from repro.flashsim import (
    Platform,
    bmi_workload,
    ims_workload,
    kcs_workload,
    run_workload,
)
from repro.kernels.popcount import popcount


def bmi_demo():
    """Bitmap Index: which of 100k users were active on ALL of 60 days?"""
    rng = np.random.default_rng(1)
    users, days = 100_000, 60
    arr = FlashArray()
    names = [f"day{i}" for i in range(days)]
    arr.layout.place_colocated(names)
    daily = (rng.random((days, users)) < 0.97).astype(np.uint8)
    from repro.core.bitops import pack_bits

    for n, bits in zip(names, daily):
        arr.fc_write(n, pack_bits(jnp.asarray(bits)))
    result = arr.fc_read(and_(*map(Page, names)))
    count = int(popcount(result))
    oracle = int(daily.all(axis=0).sum())
    assert count == oracle
    print(f"BMI: {count} of {users} users active all {days} days (exact)")


def kcs_demo():
    """K-clique star: AND of adjacency vectors OR clique vector, 1 sensing."""
    rng = np.random.default_rng(2)
    vertices, k = 50_000, 12
    arr = FlashArray()
    adj_names = [f"adj{i}" for i in range(k)]
    arr.layout.place_colocated(adj_names)
    arr.layout.place_spread(["clique"])
    from repro.core.bitops import pack_bits

    adj = (rng.random((k, vertices)) < 0.9).astype(np.uint8)
    clique = np.zeros(vertices, np.uint8)
    clique[rng.choice(vertices, k, replace=False)] = 1
    for n, bits in zip(adj_names, adj):
        arr.fc_write(n, pack_bits(jnp.asarray(bits)))
    arr.fc_write("clique", pack_bits(jnp.asarray(clique)))

    expr = or_(and_(*map(Page, adj_names)), Page("clique"))
    from repro.core.planner import Planner

    plan = Planner(arr.layout).compile(expr)
    result = arr.execute(plan)
    oracle = adj.all(axis=0) | clique.astype(bool)
    from repro.core.bitops import unpack_bits

    got = np.asarray(unpack_bits(result, vertices)).astype(bool)
    assert (got == oracle).all()
    print(
        f"KCS: clique star of {int(oracle.sum())} vertices in "
        f"{plan.num_sensing_ops} sensing op(s) (exact)"
    )


def projection():
    print("\nfull-scale projection (Table-1 SSD):")
    print(f"{'workload':14s} {'OSP':>9s} {'ISP':>9s} {'ParaBit':>9s} {'FC':>9s}")
    for wl in (bmi_workload(36), ims_workload(100_000), kcs_workload(32)):
        times = [
            run_workload(wl, p).time_s
            for p in (Platform.OSP, Platform.ISP, Platform.PB, Platform.FC)
        ]
        print(
            f"{wl.name:14s} "
            + " ".join(f"{t:8.3f}s" for t in times)
            + f"   (FC speedup {times[0]/times[3]:.1f}x)"
        )


if __name__ == "__main__":
    bmi_demo()
    kcs_demo()
    projection()
