"""Quickstart: Flash-Cosmos bulk bitwise operations on the TPU engine.

Demonstrates the public API end to end:
  1. fc_write operand pages (ESP mode = guaranteed error-free compute),
  2. build a bitwise expression, let the planner compile it to MWS commands,
  3. execute with one-shot multi-operand sensing (fused Pallas kernel),
  4. compare against the ParaBit serial baseline and a CPU oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.bitops import BitOp
from repro.core.engine import FlashArray, eval_expr
from repro.core.expr import Page, and_, or_
from repro.core.planner import Planner
from repro.kernels.mws import mws_reduce, parabit_reduce
from repro.kernels.popcount import popcount


def main():
    rng = np.random.default_rng(0)
    words_per_page = 4096  # 16 KiB pages, like the paper's chips

    # --- 1. store 48 operand pages (one NAND-string's worth) -------------
    arr = FlashArray()
    logical = {}
    names = [f"day{i}" for i in range(48)]
    arr.layout.place_colocated(names)  # §6.3: co-locate AND operands
    for n in names:
        data = jnp.array(
            rng.integers(0, 2**32, (words_per_page,), dtype=np.uint32)
        )
        logical[n] = data
        arr.fc_write(n, data, esp=True)

    # --- 2./3. one-shot 48-operand AND (the BMI query core) --------------
    expr = and_(*map(Page, names))
    plan = Planner(arr.layout).compile(expr)
    print(f"48-operand AND -> {plan.num_sensing_ops} sensing operation(s)")
    result = arr.execute(plan)
    active = int(popcount(result))
    print(f"bit-count of result: {active}")

    # --- 4. verify against serial baseline + oracle ----------------------
    stack = jnp.stack([logical[n] for n in names])
    assert (result == parabit_reduce(stack, BitOp.AND)).all()
    assert (result == eval_expr(expr, logical)).all()
    print("matches ParaBit serial baseline and CPU oracle: OK")

    # --- bonus: OR via De Morgan inverse storage (one sensing too) -------
    arr2 = FlashArray()
    ors = [f"v{i}" for i in range(32)]
    arr2.layout.place_colocated(ors, inverted=True)
    for n in ors:
        logical[n] = jnp.array(
            rng.integers(0, 2**32, (words_per_page,), dtype=np.uint32)
        )
        arr2.fc_write(n, logical[n])
    plan_or = Planner(arr2.layout).compile(or_(*map(Page, ors)))
    print(f"32-operand OR  -> {plan_or.num_sensing_ops} sensing operation(s)")
    got = arr2.execute(plan_or)
    assert (got == mws_reduce(jnp.stack([logical[n] for n in ors]), BitOp.OR)).all()
    print("De Morgan inverse-storage OR: OK")


if __name__ == "__main__":
    main()
