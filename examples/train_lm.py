"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the full production stack at laptop scale: bitmap-index-filtered data
pipeline (the paper's BMI workload as a real substrate), AdamW, checkpointing
with restart, straggler watchdog.  ~100M params: 12L, d=768, starcoder2-like.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--signsgd]
"""

import argparse
import os
import tempfile

from repro.configs import get_config
from repro.data.pipeline import SyntheticCorpus
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def make_100m_config():
    return (
        get_config("starcoder2-3b")
        .with_(
            n_layers=12,
            d_model=768,
            n_heads=12,
            n_kv_heads=4,
            d_ff=3072,
            vocab=32768,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
        )
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--signsgd", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = make_100m_config()
    ckpt_dir = args.ckpt_dir or os.path.join(
        tempfile.gettempdir(), "repro_train_lm"
    )
    tcfg = TrainerConfig(
        opt=OptimizerConfig(
            lr=3e-4 if not args.signsgd else 3e-3,
            mode="signsgd" if args.signsgd else "adamw",
        ),
        ckpt_dir=ckpt_dir,
        ckpt_every=100,
        compress_grads="signsgd" if args.signsgd else "none",
    )
    trainer = Trainer(cfg, tcfg)
    n_params = sum(
        p.size for p in __import__("jax").tree.leaves(trainer.params)
    )
    print(f"model: {n_params/1e6:.1f}M params; ckpt -> {ckpt_dir}")
    if trainer.maybe_restore():
        print(f"restored from step {trainer.step_num}")

    corpus = SyntheticCorpus(
        vocab=cfg.vocab, seq_len=args.seq, num_samples=4096
    )
    print(
        "bitmap-index filter: "
        f"{corpus.index.count(['lang_en', 'quality_high'])}/4096 samples pass"
    )
    batches = corpus.batches(args.batch, ("lang_en", "quality_high"))
    hist = trainer.train(batches, num_steps=args.steps, log_every=25)
    print(
        f"loss: {hist[0]:.3f} -> {hist[-1]:.3f} over {args.steps} steps; "
        f"stragglers detected: {len(trainer.straggler_log)}"
    )


if __name__ == "__main__":
    main()
