"""FlashQL aggregates: an OLAP-style workload on the bitmap index.

``SELECT SUM(sales) WHERE region IN (...) GROUP BY status`` — the classic
bit-sliced-index trick (Pinatubo/DrAcc lineage): SUM is the weighted
popcount Σ_b 2^b · popcount(mask ∧ slice_b) over the BSI slices the store
already programs, MIN/MAX walk the slices MSB→LSB, and TOP-K / GROUP BY
reduce per-group masks from the equality bitmaps.  Every aggregate is a
pluggable :class:`repro.query.aggregate.Aggregator`, so the same queries
run unchanged on one device or on a sharded fleet — here a range-striped,
``stripe_key``-sorted fleet that routes key-range queries to the few
shards whose stripe can match.

Run:  PYTHONPATH=src python examples/flashql_aggregates.py
"""

import numpy as np

from repro.query import (
    Avg,
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    GroupBy,
    In,
    Max,
    Min,
    Query,
    Range,
    Sum,
    TopK,
    build_sharded_flashql,
)
from repro.query.ast import and_


def main() -> None:
    rng = np.random.default_rng(0)
    n = 50_000
    table = {
        "region": rng.integers(0, 8, n),  # 8 sales regions
        "status": rng.integers(0, 4, n),  # order status
        "sales": rng.integers(0, 1_000, n),  # order value
        "uid": rng.integers(0, 10_000, n),  # customer id
    }

    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=4)
    store.program(dev)
    sched = BatchScheduler(dev, store)

    eu = In("region", [0, 1, 2])
    queries = [
        Query(eu, agg=Sum("sales"), tag="SUM(sales) WHERE region EU"),
        Query(eu, agg=Avg("sales"), tag="AVG(sales) WHERE region EU"),
        Query(eu, agg=Min("sales"), tag="MIN(sales) WHERE region EU"),
        Query(eu, agg=Max("sales"), tag="MAX(sales) WHERE region EU"),
        Query(
            eu,
            agg=TopK("status", 2),
            tag="TOP-2 status WHERE region EU",
        ),
        Query(
            eu,
            agg=GroupBy("status", Sum("sales")),
            tag="SUM(sales) GROUP BY status",
        ),
        Query(
            and_(eu, Eq("status", 1)),
            agg=GroupBy("region", Avg("sales")),
            tag="AVG(sales) GROUP BY region",
        ),
    ]
    for r in sched.serve(queries):
        print(f"{r.query.tag:32s} -> {r.value}")

    # numpy cross-check for the headline query
    sel = np.isin(table["region"], [0, 1, 2])
    assert sched.serve([Query(eu, agg=Sum("sales"))])[0].value == int(
        table["sales"][sel].sum()
    )

    # the same aggregates on a range-striped fleet: Range on the stripe
    # key routes to the shards whose stripe overlaps [2000, 2999]
    sq = build_sharded_flashql(
        table, 4, policy="range", stripe_key="uid", num_planes=4
    )
    (r,) = sq.serve(
        [Query(Range("uid", 2000, 2999), agg=Sum("sales"))]
    )
    sel = (table["uid"] >= 2000) & (table["uid"] <= 2999)
    assert r.value == int(table["sales"][sel].sum())
    st = sq.stats()
    print(
        f"\nsharded fleet: SUM over uid range -> {r.value} "
        f"({st['shards_pruned']} of {st['num_shards']} shards pruned "
        "by range routing)"
    )
    print(sq.projection()["workload"], "projection OK")


if __name__ == "__main__":
    main()
