"""FlashQL observability end to end: serve a mixed workload on a
pipelined 4-shard fleet, read the unified telemetry snapshot, inspect
per-query sensing attribution and the slow-query log, and export a
Chrome trace of the flush lifecycle.

Open the written trace in chrome://tracing or https://ui.perfetto.dev —
the per-shard rows show shard k+1's compile/dispatch overlapping shard
k's in-flight transfer, which IS the pipelined flush.

Run:  PYTHONPATH=src python examples/flashql_telemetry.py
"""

import numpy as np

from repro.query import (
    Avg,
    Count,
    Eq,
    GroupBy,
    In,
    Query,
    Range,
    Sum,
    TopK,
    build_sharded_flashql,
    validate_trace,
)
from repro.query.ast import and_ as qand

TRACE_PATH = "flashql_trace.json"


def main():
    rng = np.random.default_rng(0)
    n = 20_000
    table = {
        "region": rng.integers(0, 8, n),
        "status": rng.integers(0, 4, n),
        "sales": rng.integers(0, 1_000, n),
    }
    queries = [
        Query(Eq("region", 3), agg=Count()),
        Query(qand(Eq("region", 1), Eq("status", 2)), agg=Sum("sales")),
        Query(In("status", [0, 3]), agg=Avg("sales")),
        Query(Range("sales", 120, 740), agg=Count()),  # deep range: spills
        Query(Eq("status", 1), agg=TopK("region", 3)),
        Query(Range("sales", 500, 999), agg=GroupBy("status")),
    ]

    sq = build_sharded_flashql(
        table, 4, num_planes=4, queue_depth=8, pipeline=True
    )
    # log any ticket that costs > 5 ms or > 40 sensing operations
    sq.telemetry.slow_latency_s = 5e-3
    sq.telemetry.slow_sensings = 40

    sq.serve(queries)  # warm: jit + plan/flush-program caches
    results = sq.serve(queries)

    print("== per-query sensing + latency attribution ==")
    for r in results:
        a = r.attribution
        print(
            f"  ticket {r.ticket:2d}  {r.query.where!r:48s} "
            f"sensings={a['sensings']:3d}  wordlines={a['wordlines']:4d}  "
            f"spills={a['spill_steps']}  shards={a['shards']}  "
            f"latency={r.latency_s * 1e3:6.2f}ms"
        )

    snap = sq.telemetry.snapshot()
    c = snap["counters"]
    print("\n== unified snapshot ==")
    print(
        f"  served={c['queries_served']:.0f}  flushes={c['flushes']:.0f}  "
        f"fused_dispatches={c['fused_dispatches']:.0f}  "
        f"host_transfers={c['host_transfers']:.0f}"
    )
    print(
        f"  plan cache: {snap['plan_cache']['hits']} hits / "
        f"{snap['plan_cache']['misses']} misses"
    )
    fl = snap["histograms"]["flush_latency_s"]
    print(
        f"  flush latency: p50={fl['p50'] * 1e3:.2f}ms  "
        f"p95={fl['p95'] * 1e3:.2f}ms  (n={fl['count']})"
    )
    proj = snap["projection"]
    print(
        f"  SSD projection: {proj['fc_time_s'] * 1e3:.2f} ms, "
        f"{proj['fc_energy_j']:.3f} J "
        f"({proj['speedup_vs_osp']:.1f}x vs OSP)"
    )

    print(f"\n== slow-query log ({len(snap['slow_queries'])} entries) ==")
    for entry in snap["slow_queries"][-3:]:
        print(
            f"  ticket {entry['ticket']}: {entry['predicate']} "
            f"({entry['latency_s'] * 1e3:.2f}ms, "
            f"{entry['attribution']['sensings']} sensings)"
        )

    trace = sq.telemetry.export_trace(TRACE_PATH)
    n_spans = validate_trace(trace)
    print(
        f"\nwrote {TRACE_PATH} ({n_spans} spans) — open it in "
        f"chrome://tracing or https://ui.perfetto.dev"
    )


if __name__ == "__main__":
    main()
