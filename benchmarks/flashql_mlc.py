"""Multi-level packing + one-shot threshold sensing benchmark.

Two device-level claims, both gated on deterministic counters (they hold
under ``--smoke`` too):

* **Density** — packing 3 bitmap pages per physical page (TLC-style
  voltage levels) must cut the words physically ESP-programmed by
  >= 1.8x on a full index ingest, SLC vs TLC, and shrink the physical
  wordline footprint to match.  Delta-program traffic on an append
  stream is reported alongside (co-resident pages merge into one ISPP
  pass each).
* **Sensing** — a k-of-N fuzzy-match workload served through the native
  ``AtLeast`` threshold sensing must need >= 2x fewer sensing ops per
  query than the same workload expressed as its equivalent Or-of-And
  combination chains on a packing-off (SLC) system.

Every result is asserted bit-exact against a numpy oracle, and the
threshold side against the chain side, before any counter is read.

Run:  PYTHONPATH=src python benchmarks/flashql_mlc.py [--smoke]
"""

from __future__ import annotations

import sys
from itertools import combinations

import numpy as np

from _harness import REPS, interleaved_best_of
from repro.core.placement import Layout
from repro.query import (
    AtLeast,
    BatchScheduler,
    BitmapStore,
    Count,
    Eq,
    FlashDevice,
    Query,
)
from repro.query.ast import and_ as qand, or_ as qor
from repro.query.oracle import np_select

DENSITY_GATE = 1.8  # words programmed, SLC / TLC, full ingest
SENSING_GATE = 2.0  # sensings per query, chain / threshold

NUM_COLS = 6
CARD = 6  # six-page equality regions: every level count packs differently


def make_table(rng, n):
    return {
        chr(ord("a") + i): rng.integers(0, CARD, n)
        for i in range(NUM_COLS)
    }


def build(table, levels, reserve_rows=0):
    store = BitmapStore()
    store.ingest(table, reserve_rows=reserve_rows)
    dev = FlashDevice(
        num_planes=4, interpret=True, layout=Layout(levels=levels)
    )
    programs, words = store.program(dev)
    sch = BatchScheduler(dev, store)
    return sch, programs, words


def fuzzy_pool(rng, count):
    """k-of-N fuzzy predicates with C(N, k) large enough that the chain
    form explodes: the regime the one-shot threshold sensing exists for."""
    pool = []
    for _ in range(count):
        cols = rng.permutation(NUM_COLS)[: int(rng.integers(5, 7))]
        preds = [
            (chr(ord("a") + c), int(rng.integers(0, CARD))) for c in cols
        ]
        k = len(preds) - int(rng.integers(1, 3))  # k in {N-2, N-1}
        pool.append((k, preds))
    return pool


def threshold_query(k, preds):
    return Query(
        AtLeast(k, [Eq(c, v) for c, v in preds]), agg=Count()
    )


def chain_query(k, preds):
    """The same k-of-N match as its explicit Or over C(N, k) And-combos."""
    return Query(
        qor(
            *(
                qand(*(Eq(c, v) for c, v in combo))
                for combo in combinations(preds, k)
            )
        ),
        agg=Count(),
    )


def oracle_count(k, preds, table, n):
    hits = sum(
        (np.asarray(table[c]) == v).astype(int) for c, v in preds
    )
    return int((hits >= k).sum())


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    num_rows = 4_000 if smoke else 40_000
    num_queries = 16 if smoke else 48
    append_rows = 256 if smoke else 2_048

    rng = np.random.default_rng(0)
    table = make_table(rng, num_rows)
    print(
        f"rows={num_rows}  cols={NUM_COLS}x{CARD}  queries={num_queries}  "
        f"reps={REPS}  (smoke={smoke})"
    )

    # -- density gate: full-ingest programmed words, SLC vs MLC vs TLC ----
    ingest = {}
    systems = {}
    for levels in (1, 2, 3):
        sch, programs, words = build(
            table, levels, reserve_rows=append_rows
        )
        systems[levels] = sch
        ingest[levels] = (programs, words)
        print(
            f"levels={levels}: ingest {programs:5d} page programs, "
            f"{words:7d} words, "
            f"{sch.device.layout.physical_wordlines():4d} physical "
            f"wordlines"
        )
    density = ingest[1][1] / ingest[3][1]

    # the SAME append stream on every packing level: delta traffic shrinks
    # because co-resident page deltas merge into one physical program
    batch = make_table(rng, append_rows)
    for levels, sch in systems.items():
        sch.append(batch)
    delta_ratio = (
        systems[1].words_programmed / systems[3].words_programmed
    )
    print(
        f"append deltas: SLC {systems[1].words_programmed} words vs TLC "
        f"{systems[3].words_programmed} words ({delta_ratio:.2f}x fewer)"
    )

    # -- sensing gate: native k-of-N thresholds vs Or-of-And chains -------
    resident = {
        c: np.concatenate([v, batch[c]]) for c, v in table.items()
    }
    n = num_rows + append_rows
    pool = fuzzy_pool(rng, 8)
    picks = [pool[i % len(pool)] for i in range(num_queries)]
    thr_queries = [threshold_query(k, p) for k, p in picks]
    chain_queries = [chain_query(k, p) for k, p in picks]

    native = systems[3]  # packing on + threshold sensing
    chain, _, _ = build(resident, 1)  # packing off, chain-form queries

    # warm both (jit + plan caches), asserting bit-exactness every round
    for _ in range(2):
        res_thr = native.serve(thr_queries)
        res_chain = chain.serve(chain_queries)
        for (k, p), a, b in zip(picks, res_thr, res_chain):
            want = oracle_count(k, p, resident, n)
            assert a.value == want, (k, p, a.value, want)
            assert b.value == want, (k, p, b.value, want)
    print("threshold == chain == numpy oracle (bit-exact)")

    spq = {}
    for name, sysm, qs in (
        ("threshold", native, thr_queries),
        ("chain", chain, chain_queries),
    ):
        s0 = sysm.stats()["mws_commands"]
        sysm.serve(qs)
        spq[name] = (sysm.stats()["mws_commands"] - s0) / num_queries
    sensing_ratio = spq["chain"] / spq["threshold"]
    print(
        f"sensings/query: chain {spq['chain']:6.2f} vs threshold "
        f"{spq['threshold']:6.2f} ({sensing_ratio:.2f}x fewer), "
        f"threshold_senses={native.stats()['threshold_senses']}"
    )

    best = interleaved_best_of(
        {
            "threshold": lambda: native.serve(thr_queries),
            "chain": lambda: chain.serve(chain_queries),
        }
    )
    print(
        f"wall-clock: chain {num_queries / best['chain']:8.1f} q/s, "
        f"threshold {num_queries / best['threshold']:8.1f} q/s "
        f"({best['chain'] / best['threshold']:.2f}x)"
    )

    # -- deterministic acceptance (counters, not wall-clock) --------------
    assert density >= DENSITY_GATE, (
        f"TLC ingest must program >= {DENSITY_GATE}x fewer words than "
        f"SLC, got {density:.2f}x"
    )
    assert native.stats()["threshold_senses"] > 0, (
        "native side never issued a threshold sensing"
    )
    assert sensing_ratio >= SENSING_GATE, (
        f"k-of-N thresholds must need >= {SENSING_GATE}x fewer sensings "
        f"per query than And/Or chains, got {sensing_ratio:.2f}x"
    )
    print(
        f"acceptance: ingest density {density:.2f}x >= {DENSITY_GATE}x, "
        f"sensings {sensing_ratio:.2f}x >= {SENSING_GATE}x OK"
    )


if __name__ == "__main__":
    main()
