"""Incremental-ingest benchmark: delta-page programming + warm-plan serving.

A live FlashQL index absorbs append batches between query flushes.  Two
acceptance criteria (the first always asserted, the wall-clock gate
skipped under ``--smoke``):

* **append cost scales with delta rows, not total rows** — the SAME
  batch appended to a base store and to a 10x larger store programs the
  SAME number of pages (asserted via the flashsim ESP-program counter),
  and a small fraction of what a full index reprogram pays;
* **warm-plan reuse across appends beats full-rebuild serving** — the
  steady-state update loop (append the batch, serve the query mix on the
  live index, plans warm) must reach >= the baseline that handles every
  update the only way pre-mutable FlashQL could: rebuild the bitmap
  store from scratch, ESP-program a fresh device, recompile every plan,
  then serve.

Timing is best-of-REPS *interleaved* via ``benchmarks/_harness.py`` —
run-to-run noise on shared machines is 3-4x.

Run:  PYTHONPATH=src python benchmarks/flashql_ingest.py [--smoke]
"""

from __future__ import annotations

import sys

import numpy as np

from _harness import REPS, interleaved_best_of
from repro.query import (
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    In,
    Query,
    Sum,
)
from repro.query.ast import and_ as qand

BATCH = 64  # appended rows per update


def build_table(rng, n):
    """OLAP-style table whose value universe is fully populated, so the
    same append batch grows the same pages at every store size."""
    t = {
        "region": rng.integers(0, 8, n),
        "status": rng.integers(0, 4, n),
        "sales": rng.integers(0, 1_000, n),
    }
    for col, card in (("region", 8), ("status", 4), ("sales", 1_000)):
        k = min(card, n)
        t[col][:k] = np.arange(k)
    return t


def build_queries(rng, num_queries) -> list[Query]:
    qs: list[Query] = []
    while len(qs) < num_queries:
        r = int(rng.integers(0, 8))
        s = int(rng.integers(0, 4))
        qs.append(Query(qand(Eq("region", r), Eq("status", s))))
        qs.append(Query(In("status", [s, (s + 1) % 4]), agg=Sum("sales")))
    return qs[:num_queries]


def build_scheduler(table, queries, reserve) -> BatchScheduler:
    store = BitmapStore()
    store.ingest(table, reserve_rows=reserve)
    dev = FlashDevice(num_planes=4)
    store.program(dev, warmup=queries[:2])
    sched = BatchScheduler(dev, store, max_batch=len(queries))
    sched.serve(queries)  # warm: jit + plan caches
    return sched


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    num_rows = 4_000 if smoke else 50_000
    num_queries = 8 if smoke else 32
    reserve = BATCH * (REPS + 6)

    rng = np.random.default_rng(0)
    table = build_table(rng, num_rows)
    queries = build_queries(rng, num_queries)
    batch = {  # values drawn from the (fully populated) base universe
        "region": rng.integers(0, 8, BATCH),
        "status": rng.integers(0, 4, BATCH),
        "sales": rng.integers(0, 1_000, BATCH),
    }
    print(
        f"rows={num_rows}  queries={num_queries}  batch={BATCH}  "
        f"reps={REPS}  (smoke={smoke})"
    )

    # -- criterion 1: O(delta) page programs, independent of store size ----
    sched = build_scheduler(table, queries, reserve)
    big = build_scheduler(
        build_table(np.random.default_rng(1), 10 * num_rows),
        queries,
        reserve,
    )
    rebuild_pages = len(sched.store.logical)  # a full reprogram writes all
    p_base = sched.append(batch)
    p_big = big.append(batch)
    print(
        f"append({BATCH} rows): {p_base} delta page programs at "
        f"{num_rows} rows, {p_big} at {10 * num_rows} rows "
        f"(full reprogram = {rebuild_pages} pages)"
    )
    assert p_base == p_big, (
        f"append cost must scale with delta rows, not total rows: "
        f"{p_base} vs {p_big} pages"
    )
    assert p_base < rebuild_pages / 2, (
        f"delta programs ({p_base}) must stay well below a full "
        f"reprogram ({rebuild_pages})"
    )

    # -- correctness: the live index now equals base + batch; it must
    # serve exactly what a rebuild-from-scratch on the same rows serves
    updated = {c: np.concatenate([table[c], batch[c]]) for c in table}

    def rebuild_and_serve():
        store = BitmapStore()
        store.ingest(updated)
        dev = FlashDevice(num_planes=4)
        store.program(dev)
        return BatchScheduler(dev, store, max_batch=len(queries)).serve(
            queries
        )

    got = [r.value for r in sched.serve(queries)]
    want = [r.value for r in rebuild_and_serve()]
    assert got == want, "incremental serving diverges from rebuild oracle"

    # -- criterion 2a: appends from a stable value universe keep EVERY
    # plan warm (no recompiles across the update)
    misses = sched.compiler.misses
    sched.append(batch)
    sched.serve(queries)
    assert sched.compiler.misses == misses, (
        "value-stable appends must not invalidate any cached plan"
    )

    # -- criterion 2b: live update loop vs full-rebuild serving ------------
    def append_and_serve():
        sched.append(batch)
        return sched.serve(queries)
    best = interleaved_best_of(
        {"incremental": append_and_serve, "rebuild": rebuild_and_serve}
    )
    t_inc, t_reb = best["incremental"], best["rebuild"]
    qps_inc = num_queries / t_inc
    qps_reb = num_queries / t_reb
    print(
        f"incremental (append+serve, warm) : {t_inc:7.3f}s  "
        f"{qps_inc:8.1f} q/s"
    )
    print(
        f"full rebuild (reingest+reprogram): {t_reb:7.3f}s  "
        f"{qps_reb:8.1f} q/s"
    )
    print(f"speedup: {qps_inc / qps_reb:.2f}x")
    # ingest + plan-cache accounting and the SSD projection all read out
    # of one telemetry snapshot (counters + registered provider sections)
    snap = sched.telemetry.snapshot()
    counters, cache = snap["counters"], snap["plan_cache"]
    print(
        f"rows appended: {counters['rows_appended']}  delta ESP programs: "
        f"{counters['esp_delta_programs']}  plan cache: "
        f"{cache['hits']} hits / {cache['misses']} misses"
    )
    proj = snap["projection"]
    print(
        f"SSD projection incl. delta programs: "
        f"{proj['fc_time_s'] * 1e3:.2f} ms, {proj['fc_energy_j']:.3f} J, "
        f"{proj['esp_programs']} ESP programs "
        f"({proj['speedup_vs_osp']:.1f}x vs OSP)"
    )

    if not smoke:
        assert qps_inc >= qps_reb, (
            f"warm-plan incremental serving must reach the full-rebuild "
            f"baseline, got {qps_inc / qps_reb:.2f}x"
        )
        print(f"acceptance: {qps_inc / qps_reb:.2f}x >= 1x OK")


if __name__ == "__main__":
    main()
