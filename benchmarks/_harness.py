"""Shared wall-clock harness: best-of-N *interleaved* timing.

Run-to-run noise on shared machines is 3-4x (see README dev notes), so a
one-shot timing can gate on whichever configuration happened to run
during a quiet spell.  Every benchmark gate in this repo therefore

* times each configuration inside the SAME short rep window — machine-
  load swings hit all sides alike instead of favouring one; and
* gates on the best of ``REPS`` reps — the minimum is the least noisy
  wall-clock estimator for a deterministic workload.

Callers warm every configuration (jit + plan caches) BEFORE handing it
to the harness: these benchmarks measure steady-state serving.

Quantile math lives in :mod:`repro.query.telemetry` (the repo's single
``percentile``/``Histogram`` implementation); this module re-exports
``percentile`` and builds ``latency_summary`` on a ``Histogram`` so
benchmarks and the serving telemetry can never disagree on a tail.
"""

from __future__ import annotations

import time

from repro.query.telemetry import Histogram, percentile  # noqa: F401

REPS = 5  # best-of-N: one-shot wall timings are too noisy for a gate


def timed(fn):
    """Run ``fn()`` once; returns ``(seconds, result)``."""
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def interleaved_best_of(timers: dict, reps: int = REPS) -> dict:
    """Best-of-``reps`` seconds per configuration, interleaved.

    ``timers`` maps a key to a zero-arg callable; each rep times every
    callable once, in insertion order, so all configurations share each
    rep's machine conditions.  Returns ``{key: best_seconds}``.
    """
    best = {k: float("inf") for k in timers}
    for _ in range(reps):
        for k, fn in timers.items():
            t, _ = timed(fn)
            best[k] = min(best[k], t)
    return best


def latency_summary(samples) -> dict | None:
    """p50/p95/mean of per-flush wall-clock samples (seconds), or ``None``
    for an empty sample set (a benchmark path that served nothing has no
    distribution to report — callers skip the line instead of crashing).

    Throughput gates use best-of-N interleaved timing (above); latency
    distributions additionally need tail percentiles, because a pipelined
    flush that overlaps shards can improve the mean while regressing the
    tail (or vice versa) — benchmarks report both.  Built on the
    telemetry ``Histogram`` (capacity sized to the sample set, so nothing
    is dropped here).
    """
    samples = list(samples)
    if not samples:
        return None
    h = Histogram(capacity=len(samples))
    for s in samples:
        h.observe(s)
    summary = h.summary()
    return {
        "p50": summary["p50"],
        "p95": summary["p95"],
        "mean": summary["mean"],
        "n": summary["count"],
    }
