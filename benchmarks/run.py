"""Benchmark harness — one function per paper figure/table.

Prints ``name,value,derived`` CSV.  Run:  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks.paper_figs import (
        fig07_timeline,
        fig08_rber,
        fig11_esp,
        fig12_intra_mws,
        fig13_inter_mws,
        fig14_power,
        fig17_performance,
        fig18_energy,
        table3_overheads,
    )
    from benchmarks.tpu_kernels import (
        fused_count_bench,
        mws_vs_parabit,
        popcount_bench,
        signcomp_bench,
    )

    benches = [
        fig07_timeline,
        fig08_rber,
        fig11_esp,
        fig12_intra_mws,
        fig13_inter_mws,
        fig14_power,
        fig17_performance,
        fig18_energy,
        table3_overheads,
        mws_vs_parabit,
        fused_count_bench,
        popcount_bench,
        signcomp_bench,
    ]
    print("name,value,derived")
    failures = 0
    for bench in benches:
        try:
            for name, value, derived in bench():
                print(f"{name},{value},{derived}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{bench.__name__},ERROR,{type(e).__name__}: {e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
