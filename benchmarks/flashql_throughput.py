"""FlashQL query-throughput benchmark: batched FlashDevice vs sequential.

BMI-style serving (paper §7): a user-activity table is indexed into
bitmaps; clients issue COUNT queries over a handful of recurring predicate
shapes.  We compare:

* **sequential** — one ``Planner.compile`` + ``FlashArray.fc_read`` +
  ``popcount`` per query, the seed repo's only execution mode;
* **flashql** — ``BatchScheduler``: plan-cache compile, shape-grouped
  ``jax.vmap`` batches on the packed multi-plane store, ONE batched
  popcount per flush.

Also prints the full-scale SSD projection of the served traffic (Table-1
geometry) and asserts the acceptance criteria: >= 64 queries per batch,
batched path measurably faster, and every result equal to the numpy oracle.
Timing is best-of-REPS interleaved via ``benchmarks/_harness.py``.

Run:  PYTHONPATH=src python benchmarks/flashql_throughput.py
"""

from __future__ import annotations

import numpy as np

from _harness import interleaved_best_of

from repro.core.engine import FlashArray
from repro.core.planner import Planner
from repro.kernels.popcount import popcount
from repro.query import (
    Agg,
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    In,
    Query,
)
from repro.query.ast import and_ as qand
from repro.query.compile import lower

NUM_ROWS = 200_000
NUM_QUERIES = 64


def build_queries(rng) -> list[Query]:
    """BMI-style COUNT traffic: a few hot shapes, many parameterizations."""
    qs: list[Query] = []
    while len(qs) < NUM_QUERIES:
        c = int(rng.integers(0, 8))
        d = int(rng.integers(0, 4))
        qs.append(Query(qand(Eq("country", c), Eq("device", d))))
        qs.append(Query(Eq("country", c), agg=Agg.COUNT))
        qs.append(
            Query(In("device", [d, (d + 1) % 4]), agg=Agg.COUNT)
        )
    return qs[:NUM_QUERIES]


def np_count(q: Query, table) -> int:
    from repro.query.ast import And, Eq, In

    def m(p):
        if isinstance(p, Eq):
            return table[p.column] == p.value
        if isinstance(p, In):
            return np.isin(table[p.column], p.values)
        assert isinstance(p, And)
        out = np.ones(len(next(iter(table.values()))), bool)
        for c in p.children:
            out &= m(c)
        return out

    return int(m(q.where).sum())


def main() -> None:
    rng = np.random.default_rng(0)
    table = {
        "country": rng.integers(0, 8, NUM_ROWS),
        "device": rng.integers(0, 4, NUM_ROWS),
    }
    store = BitmapStore()
    store.ingest(table)
    queries = build_queries(rng)

    # Both sides get one full warm pass first (jit/plan caches populated),
    # then we time steady-state serving — the regime a query-serving
    # system lives in.
    def run_sequential(arr: FlashArray) -> list[int]:
        counts = []
        for q in queries:
            plan = Planner(arr.layout).compile(lower(q.where, store))
            counts.append(int(popcount(arr.execute(plan))))
        return counts

    # -- sequential baseline: per-query plan + execute + popcount ----------
    arr = FlashArray()
    store.program(arr)
    seq_counts = run_sequential(arr)  # warm + capture for correctness

    # -- FlashQL batched path ---------------------------------------------
    dev = FlashDevice(num_planes=4)
    store.program(dev, warmup=queries[:3])
    sched = BatchScheduler(dev, store, max_batch=NUM_QUERIES)
    results = sched.serve(queries)  # warm + capture for correctness

    # -- correctness (acceptance: bit-exact vs oracle) ----------------------
    for q, r, sc in zip(queries, results, seq_counts):
        want = np_count(q, table)
        assert r.count == want == sc, (q, r.count, sc, want)

    # -- steady-state timing: best-of-REPS, interleaved ---------------------
    best = interleaved_best_of(
        {
            "sequential": lambda: run_sequential(arr),
            "batched": lambda: sched.serve(queries),
        }
    )
    t_seq, t_batch = best["sequential"], best["batched"]

    qps_seq = NUM_QUERIES / t_seq
    qps_batch = NUM_QUERIES / t_batch
    print(f"rows={NUM_ROWS}  queries={NUM_QUERIES}")
    print(
        f"sequential FlashArray.fc_read : {t_seq:7.3f}s  "
        f"{qps_seq:8.1f} q/s"
    )
    print(
        f"FlashQL batched (vmap)        : {t_batch:7.3f}s  "
        f"{qps_batch:8.1f} q/s"
    )
    print(f"speedup: {t_seq / t_batch:.2f}x")
    s = sched.stats()
    print(
        f"plan cache: {s['plan_cache_hits']} hits / "
        f"{s['plan_cache_misses']} misses; "
        f"vmap batches: {s['vmap_batches']}"
    )
    proj = sched.projection()
    print(
        f"full-scale SSD projection: FC {proj['fc_time_s'] * 1e3:.2f} ms, "
        f"{proj['fc_energy_j']:.3f} J  "
        f"({proj['speedup_vs_osp']:.1f}x faster, "
        f"{proj['energy_ratio_vs_osp']:.1f}x less energy than OSP)"
    )
    assert qps_batch > qps_seq, "batched path must beat sequential"


if __name__ == "__main__":
    main()
