"""One benchmark per paper figure/table — each returns CSV rows
(name, value, derived/paper-reference) and is asserted against the paper's
stated anchors where the text gives them."""

from __future__ import annotations


from repro.core.reliability import (
    CellMode,
    ProgramConfig,
    block_quality_quantile,
    rber,
)
from repro.flashsim import (
    DEFAULT_SSD,
    Platform,
    bmi_workload,
    ims_workload,
    inter_block_tmws_ratio,
    intra_block_tmws_ratio,
    kcs_workload,
    mws_power_ratio,
    run_workload,
)
from repro.flashsim.geometry import FIG7_SSD
from repro.flashsim.platforms import fig7_timeline


def fig07_timeline():
    """Fig. 7: per-channel timeline of OSP/ISP/IFP for 3×1 MiB OR."""
    tl = fig7_timeline(FIG7_SSD)
    return [
        ("fig07.tR_us", tl["tR_us"], "paper:60"),
        ("fig07.tDMA_us", round(tl["tDMA_us"], 1), "paper:27"),
        ("fig07.tEXT_us", round(tl["tEXT_us"], 1), "paper:4"),
        ("fig07.osp_round_us", round(tl["osp_round_us"], 1), "ext-bound"),
        ("fig07.isp_round_us", round(tl["isp_round_us"], 1), "int-bound"),
        ("fig07.ifp_round_us", round(tl["ifp_round_us"], 1), "sense-bound"),
    ]


def fig08_rber():
    """Fig. 8: RBER vs mode × randomization × PEC × retention."""
    rows = []
    for mode in (CellMode.SLC, CellMode.MLC):
        for rand in (True, False):
            for pec in (1_000, 10_000):
                for ret in (1, 365):
                    r = rber(
                        ProgramConfig(mode, rand, 1.0),
                        pec=pec,
                        retention_days=ret,
                    )
                    rows.append(
                        (
                            f"fig08.{mode.value}.rand={int(rand)}."
                            f"pec={pec}.ret={ret}d",
                            f"{r:.3e}",
                            "",
                        )
                    )
    rows.append(
        (
            "fig08.norand_factor_slc",
            round(
                rber(ProgramConfig(CellMode.SLC, False, 1.0))
                / rber(ProgramConfig(CellMode.SLC, True, 1.0)),
                3,
            ),
            "paper:1.91",
        )
    )
    rows.append(
        (
            "fig08.norand_factor_mlc",
            round(
                rber(ProgramConfig(CellMode.MLC, False, 1.0))
                / rber(ProgramConfig(CellMode.MLC, True, 1.0)),
                3,
            ),
            "paper:4.92",
        )
    )
    return rows


def fig11_esp():
    """Fig. 11: RBER vs tESP for worst/median/best blocks."""
    rows = []
    for label, q in (("worst", 0.999), ("median", 0.5), ("best", 0.001)):
        bq = block_quality_quantile(q)
        for t in (1.0, 1.2, 1.4, 1.6, 1.8, 1.9, 2.0):
            r = rber(
                ProgramConfig(CellMode.SLC, False, t), block_quality=bq
            )
            rows.append((f"fig11.{label}.tesp={t:.1f}", f"{r:.3e}", ""))
    zero = rber(
        ProgramConfig(CellMode.SLC, False, 1.9),
        block_quality=block_quality_quantile(0.999),
    )
    rows.append(("fig11.zero_at_1.9x", zero, "paper:0 (RBER<2.07e-12)"))
    return rows


def fig12_intra_mws():
    """Fig. 12: intra-block tMWS/tR vs #WLs (1..48)."""
    rows = []
    for n in (1, 2, 4, 8, 16, 32, 48):
        rows.append(
            (
                f"fig12.intra.wls={n}",
                round(intra_block_tmws_ratio(n), 4),
                "paper:1.033@48",
            )
        )
    return rows


def fig13_inter_mws():
    """Fig. 13: inter-block tMWS/tR vs #blocks (1..32)."""
    rows = []
    for n in (1, 2, 4, 8, 16, 32):
        rows.append(
            (
                f"fig13.inter.blocks={n}",
                round(inter_block_tmws_ratio(n), 4),
                "paper:1.033@4,1.363@32",
            )
        )
    return rows


def fig14_power():
    """Fig. 14: inter-block MWS power vs #blocks; energy saving @4 blocks."""
    rows = [
        (
            f"fig14.power.blocks={n}",
            round(mws_power_ratio(n), 3),
            "paper:1.34@2,1.8@4",
        )
        for n in (1, 2, 4, 8, 16, 32)
    ]
    from repro.flashsim.timing import mws_energy_j

    e4 = mws_energy_j(DEFAULT_SSD.t_r_us, DEFAULT_SSD.p_read_w, 4, 1)
    saving = 1 - e4 / (4 * DEFAULT_SSD.e_sense_page)
    rows.append(
        ("fig14.energy_saving_4blk", round(saving, 3), "paper:0.53")
    )
    return rows


WORKLOADS = (
    [("bmi", bmi_workload(m)) for m in (1, 6, 12, 24, 36)]
    + [("ims", ims_workload(i)) for i in (10_000, 50_000, 100_000, 200_000)]
    + [("kcs", kcs_workload(k)) for k in (8, 16, 32, 64)]
)


def fig17_performance():
    """Fig. 17: speedup of ISP/PB/FC over OSP per workload/input."""
    rows = []
    ratios = {p: [] for p in (Platform.ISP, Platform.PB, Platform.FC)}
    for _, wl in WORKLOADS:
        r = {p: run_workload(wl, p) for p in Platform}
        for p in ratios:
            s = r[Platform.OSP].time_s / r[p].time_s
            ratios[p].append(s)
            rows.append((f"fig17.{wl.name}.{p.value}", round(s, 2), ""))
    import statistics

    for p, ref in (
        (Platform.FC, "paper:32x"),
        (Platform.PB, "paper:9.4x"),
        (Platform.ISP, "paper:1.28x"),
    ):
        rows.append(
            (
                f"fig17.geomean.{p.value}",
                round(statistics.geometric_mean(ratios[p]), 2),
                ref,
            )
        )
    rows.append(
        (
            "fig17.fc_over_pb",
            round(
                statistics.geometric_mean(ratios[Platform.FC])
                / statistics.geometric_mean(ratios[Platform.PB]),
                2,
            ),
            "paper:3.5x",
        )
    )
    return rows


def fig18_energy():
    """Fig. 18: energy efficiency (bits/J) of ISP/PB/FC normalized to OSP."""
    rows = []
    ratios = {p: [] for p in (Platform.ISP, Platform.PB, Platform.FC)}
    for _, wl in WORKLOADS:
        r = {p: run_workload(wl, p) for p in Platform}
        for p in ratios:
            s = r[Platform.OSP].energy_j / r[p].energy_j
            ratios[p].append(s)
            rows.append((f"fig18.{wl.name}.{p.value}", round(s, 2), ""))
    import statistics

    for p, ref in (
        (Platform.FC, "paper:95x"),
        (Platform.PB, "paper:28.8x"),
        (Platform.ISP, "paper:7.1x"),
    ):
        rows.append(
            (
                f"fig18.geomean.{p.value}",
                round(statistics.geometric_mean(ratios[p]), 2),
                ref,
            )
        )
    rows.append(
        (
            "fig18.fc_over_pb",
            round(
                statistics.geometric_mean(ratios[Platform.FC])
                / statistics.geometric_mean(ratios[Platform.PB]),
                2,
            ),
            "paper:3.3x",
        )
    )
    rows.append(
        (
            "fig18.bmi36.fc_over_osp",
            round(
                run_workload(bmi_workload(36), Platform.OSP).energy_j
                / run_workload(bmi_workload(36), Platform.FC).energy_j,
                1,
            ),
            "paper:1839x(max)",
        )
    )
    return rows


def table3_overheads():
    """§8.3: ESP write-performance overheads."""
    ssd = DEFAULT_SSD

    def bw(t_us):
        return ssd.num_planes * ssd.page_bytes / (t_us * 1e-6) / 1e9

    return [
        ("tab3.esp_write_gbps", round(bw(ssd.t_esp_us), 2), "paper:4.7"),
        ("tab3.slc_write_gbps", round(bw(ssd.t_prog_slc_us), 2), "paper:6.4"),
        ("tab3.mlc_write_gbps", round(bw(ssd.t_prog_mlc_us), 2), "paper:3.87"),
        ("tab3.tlc_write_gbps", round(bw(ssd.t_prog_tlc_us), 2), "paper:2.82"),
        (
            "tab3.esp_capacity_overhead",
            2.0,
            "paper:2x vs MLC (SLC-mode storage)",
        ),
    ]
