"""Render EXPERIMENTS.md tables from results/dryrun and results/roofline.

Run:  PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

ARCH_ORDER = [
    "xlstm-350m",
    "starcoder2-3b",
    "yi-34b",
    "granite-8b",
    "command-r-plus-104b",
    "whisper-medium",
    "deepseek-v2-lite-16b",
    "kimi-k2-1t-a32b",
    "internvl2-26b",
    "recurrentgemma-2b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(sub):
    out = {}
    for fn in glob.glob(os.path.join(RESULTS, sub, "*.json")):
        with open(fn) as f:
            d = json.load(f)
        out[(d["arch"], d["shape"], d.get("mesh", "16x16"))] = d
    return out


def _skip_reason(arch, shape):
    from repro.configs import get_config
    from repro.launch.dryrun import cell_is_skipped
    from repro.models.config import SHAPES

    return cell_is_skipped(get_config(arch), SHAPES[shape])


def _fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if n < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PiB"


def dryrun_table() -> str:
    data = _load("dryrun")
    lines = [
        "| arch | shape | mesh | status | per-chip args | temps | compile |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                d = data.get((arch, shape, mesh))
                if d is None:
                    reason = _skip_reason(arch, shape)
                    tag = (
                        f"skip: {reason}" if reason else "MISSING"
                    )
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | {tag} | - | - | - |"
                    )
                    continue
                if d["status"] != "ok":
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | {d['status']}: "
                        f"{d.get('reason','')} | - | - | - |"
                    )
                    continue
                mem = d["memory"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | "
                    f"{_fmt_bytes(mem['argument_bytes'])} | "
                    f"{_fmt_bytes(mem['temp_bytes'])} | "
                    f"{d['compile_s']}s |"
                )
    return "\n".join(lines)


def roofline_table() -> str:
    data = _load("roofline")
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS | useful | bound step |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = data.get((arch, shape, "16x16"))
            if d is None:
                reason = _skip_reason(arch, shape)
                tag = f"skip: {reason}" if reason else "MISSING"
                lines.append(f"| {arch} | {shape} | {tag} | | | | | | |")
                continue
            if d["status"] != "ok":
                lines.append(
                    f"| {arch} | {shape} | {d['status']}:"
                    f"{d.get('reason','')[:40]} | | | | | | |"
                )
                continue
            ro = d["roofline"]
            lines.append(
                f"| {arch} | {shape} | {ro['compute_s']*1e3:.1f}ms | "
                f"{ro['memory_s']*1e3:.1f}ms | "
                f"{ro['collective_s']*1e3:.1f}ms | {ro['dominant']} | "
                f"{d['model_flops']:.2e} | "
                f"{d['useful_ratio']:.2f} | {max(ro['compute_s'], ro['memory_s'], ro['collective_s'])*1e3:.1f}ms |"
            )
    return "\n".join(lines)


def perf_section() -> str:
    files = {
        "yi34b_prefill32k": "§Perf-1 yi-34b × prefill_32k (worst fraction)",
        "kimi_train4k": "§Perf-2 kimi-k2-1t × train_4k (most collective-bound)",
        "grad_exchange": "§Perf-3 gradient exchange (paper-technique cell)",
    }
    out = []
    for stem, title in files.items():
        path = os.path.join(RESULTS, "perf", f"{stem}.json")
        if not os.path.exists(path):
            out.append(f"### {title}\n\n(missing)")
            continue
        with open(path) as f:
            rows = json.load(f)
        lines = [
            f"### {title}",
            "",
            "| variant | compute | memory | collective | bound | Δbound |",
            "|---|---|---|---|---|---|",
        ]
        base_bound = None
        for r in rows:
            ro = r["roofline"]
            bound = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
            if base_bound is None or r["variant"].startswith(
                ("baseline", "multipod-baseline")
            ):
                base_bound = bound
            lines.append(
                f"| {r['variant']} | {ro['compute_s']*1e3:.1f}ms | "
                f"{ro['memory_s']*1e3:.1f}ms | {ro['collective_s']*1e3:.1f}ms "
                f"| {bound*1e3:.1f}ms | {base_bound/bound:.2f}× |"
            )
        out.append("\n".join(lines))
    return "\n\n".join(out)


def main():
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table (single-pod 16x16)\n")
    print(roofline_table())
    print("\n## Perf experiments\n")
    print(perf_section())


if __name__ == "__main__":
    main()
