"""One-dispatch flush benchmark: fused + async sharded serving vs lockstep.

A 4-shard fleet serves a mixed-aggregate workload (COUNT/MASK/SUM/AVG/
MIN/MAX/TOP-K/GROUP-BY over recurring predicate shapes, including a
spilling deep-range) two ways:

* **lockstep** — the PR-4 flush: cross-shard jit-of-vmap per signature
  group, then one reduce dispatch + one *synchronous* host transfer per
  reduce signature, all shards barriered;
* **pipelined** — the one-dispatch flush: each shard's batch compiles into
  ONE fused device program (sensing gathers feed every aggregate's
  weighted-popcount reduce device-side) returning a single payload, and
  shards dispatch back-to-back without barriering — shard k+1's sensing
  overlaps shard k's in-flight reduce, with ``block_until_ready`` only at
  the payload gather.

Both sides are asserted exact against a numpy oracle and each other.
Timing follows the dev notes (best-of-``REPS``, interleaved); per-flush
latency is additionally reported as p50/p95 next to the dispatch and
host-transfer counts per flush — the fused path must spend exactly one
transfer per shard program (and the unsharded scheduler exactly one per
flush, asserted in tests/test_query_pipeline.py).

Acceptance (skipped under ``--smoke``): pipelined serving must reach
>= 1.5x the lockstep throughput.

Run:  PYTHONPATH=src python benchmarks/flashql_pipeline.py [--smoke]
"""

from __future__ import annotations

import sys

import numpy as np

from _harness import REPS, interleaved_best_of, latency_summary, timed
from repro.query import (
    Avg,
    Count,
    Eq,
    GroupBy,
    In,
    Mask,
    Max,
    Min,
    Query,
    Range,
    Sum,
    TopK,
    build_sharded_flashql,
)
from repro.query.ast import and_ as qand
from repro.query.oracle import np_select

NUM_SHARDS = 4
QUEUE_DEPTH = 16


def build_queries(rng, num_queries) -> list[Query]:
    """Recurring predicate shapes x a mix of every aggregate kind.

    Aggregates span two target columns, so one flush holds ~12 distinct
    reduce signatures — the lockstep flush pays one blocking host
    transfer per signature, the fused flush one payload per shard.
    """
    aggs = (
        Count(),
        Mask(),
        Sum("sales"),
        Avg("sales"),
        Min("sales"),
        Max("sales"),
        Sum("region"),
        Avg("region"),
        Min("region"),
        TopK("status", 3),
        GroupBy("status", Sum("sales")),
        GroupBy("region"),
    )
    qs: list[Query] = []
    i = 0
    while len(qs) < num_queries:
        r = int(rng.integers(0, 8))
        s = int(rng.integers(0, 4))
        preds = (
            Eq("region", r),
            qand(Eq("region", r), Eq("status", s)),
            In("status", [s, (s + 1) % 4]),
            Range("sales", 100 + r, 700 + 10 * s),  # spills: deep BSI range
        )
        qs.append(Query(preds[i % 4], agg=aggs[i % len(aggs)]))
        i += 1
    return qs[:num_queries]


def check_exact(results, queries, table, n) -> None:
    for q, r in zip(queries, results):
        sel = np_select(q.where, table, n)
        if isinstance(q.agg, Count):
            assert r.value == int(sel.sum()), q
        elif isinstance(q.agg, Sum):
            assert r.value == int(table[q.agg.column][sel].sum()), q
        elif isinstance(q.agg, Mask):
            got = np.asarray(r.value.to_bits()).astype(bool)
            np.testing.assert_array_equal(got, sel)


def flush_latencies(sq, queries) -> list[float]:
    """Serve ``queries`` timing every flush() individually."""
    for q in queries:
        sq.submit(q)
    lats = []
    while sq.pending:
        t, _ = timed(sq.flush)
        lats.append(t)
    sq.flush()  # fully-pruned tickets, if any
    return lats


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    num_rows = 4_000 if smoke else 60_000
    num_queries = 16 if smoke else 48

    rng = np.random.default_rng(0)
    table = {
        "region": rng.integers(0, 8, num_rows),
        "status": rng.integers(0, 4, num_rows),
        "sales": rng.integers(0, 1_000, num_rows),
    }
    queries = build_queries(rng, num_queries)
    print(
        f"rows={num_rows}  queries={num_queries}  shards={NUM_SHARDS}  "
        f"queue_depth={QUEUE_DEPTH}  reps={REPS}  (smoke={smoke})"
    )

    lock = build_sharded_flashql(
        table, NUM_SHARDS, num_planes=4, queue_depth=QUEUE_DEPTH
    )
    pipe = build_sharded_flashql(
        table,
        NUM_SHARDS,
        num_planes=4,
        queue_depth=QUEUE_DEPTH,
        pipeline=True,
    )

    # warm both (jit + plan/exec/flush-program caches) and assert exactness
    res_lock = lock.serve(queries)
    res_pipe = pipe.serve(queries)
    check_exact(res_lock, queries, table, num_rows)
    check_exact(res_pipe, queries, table, num_rows)
    for a, b in zip(res_lock, res_pipe):
        if isinstance(a.query.agg, Mask):
            np.testing.assert_array_equal(
                np.asarray(a.value.words), np.asarray(b.value.words)
            )
        else:
            assert a.value == b.value, (a.query, a.value, b.value)
    print("lockstep == pipelined == numpy oracle")

    # dispatch + host-transfer accounting per flush (warm steady state),
    # read from the unified telemetry registry rather than scheduler fields
    for sq, name in ((lock, "lockstep"), (pipe, "pipelined")):
        c0 = sq.telemetry.snapshot()["counters"]
        sq.serve(queries)
        c1 = sq.telemetry.snapshot()["counters"]
        flushes = c1["flushes"] - c0.get("flushes", 0)
        transfers = c1["host_transfers"] - c0.get("host_transfers", 0)
        dispatches = c1.get("fused_dispatches", 0) - c0.get(
            "fused_dispatches", 0
        )
        print(
            f"{name:9s}: {flushes} flushes, "
            f"{transfers / flushes:.1f} host transfers and "
            f"{dispatches / flushes:.1f} fused dispatches "
            f"per flush"
        )
    active = len(pipe.store.active)
    c0 = pipe.telemetry.snapshot()["counters"]
    pipe.serve(queries)
    c1 = pipe.telemetry.snapshot()["counters"]
    assert c1["host_transfers"] - c0["host_transfers"] == (
        c1["flushes"] - c0["flushes"]
    ) * active, (
        "pipelined flush must spend exactly one transfer per shard program"
    )

    best = interleaved_best_of(
        {
            "pipelined": lambda: pipe.serve(queries),
            "lockstep": lambda: lock.serve(queries),
        }
    )
    t_pipe, t_lock = best["pipelined"], best["lockstep"]
    qps_pipe, qps_lock = num_queries / t_pipe, num_queries / t_lock
    print(
        f"lockstep : {t_lock:7.3f}s  {qps_lock:8.1f} q/s\n"
        f"pipelined: {t_pipe:7.3f}s  {qps_pipe:8.1f} q/s\n"
        f"speedup: {qps_pipe / qps_lock:.2f}x"
    )

    # per-flush latency distribution (p50/p95), interleaved across reps
    lats: dict[str, list[float]] = {"lockstep": [], "pipelined": []}
    for _ in range(REPS):
        lats["lockstep"].extend(flush_latencies(lock, queries))
        lats["pipelined"].extend(flush_latencies(pipe, queries))
    for name, samples in lats.items():
        s = latency_summary(samples)
        print(
            f"{name:9s} per-flush latency: p50={s['p50'] * 1e3:7.2f}ms  "
            f"p95={s['p95'] * 1e3:7.2f}ms  (n={s['n']})"
        )

    if not smoke:
        assert qps_pipe >= 1.5 * qps_lock, (
            f"fused + async flush must serve >= 1.5x the lockstep flush, "
            f"got {qps_pipe / qps_lock:.2f}x"
        )
        print(f"acceptance: {qps_pipe / qps_lock:.2f}x >= 1.5x OK")


if __name__ == "__main__":
    main()
