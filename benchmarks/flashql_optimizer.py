"""Multi-query optimizer benchmark: sense once, answer many.

A Zipf-skewed dashboard workload — a few hot predicate shapes repeated
many times, most sharing one expensive bit-sliced Range subtree — is
served by twin systems over the same table with the optimizer on
(canonicalization + cost-based reordering + cross-query CSE + hot-
predicate materialization) and off:

* **unsharded** — one ``BatchScheduler`` per side;
* **pipelined fleet** — a 2-shard async ``ShardedFlashQL`` per side (the
  headline path: per-shard CSE inside each fused flush program).

Both sides are asserted bit-exact against each other and a numpy oracle,
then steady-state *sensings per query* are read from the telemetry
counters: with Flash-Cosmos a single multi-wordline sensing evaluates a
many-operand bitwise op, so sensings — not queries — are the unit of
device work, and the optimizer's whole job is to need fewer of them for
the same answers.  Wall-clock serving throughput is reported best-of-
``REPS`` (interleaved) for context.

Acceptance (deterministic, enforced even under ``--smoke``): the
optimizer must cut sensings per query by >= 1.5x on both systems.

Run:  PYTHONPATH=src python benchmarks/flashql_optimizer.py [--smoke]
"""

from __future__ import annotations

import sys

import numpy as np

from _harness import REPS, interleaved_best_of
from repro.query import (
    Agg,
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    In,
    Query,
    Range,
    build_sharded_flashql,
)
from repro.query.ast import and_ as qand
from repro.query.oracle import np_select

NUM_SHARDS = 2
ZIPF_A = 1.4
MATERIALIZE_AFTER = 6


def build_pool(rng) -> list:
    """Hot predicate pool: most entries AND a distinct Eq with one of two
    recurring deep Range subtrees (the CSE candidates); the tail entries
    are cheap standalone shapes."""
    deep_a = Range("sales", 120, 710)
    deep_b = qand(Range("sales", 50, 400), In("status", [0, 1]))
    pool = [qand(Eq("region", r), deep_a) for r in range(4)]
    pool += [qand(Eq("region", r), deep_b) for r in range(3)]
    pool += [Eq("status", 2), In("region", [1, 5]), Range("sales", 0, 80)]
    return pool


def build_queries(rng, pool, num_queries) -> list[Query]:
    """Zipf-ranked draws over the pool (rank 1 -> hottest entry), with a
    MASK sprinkled in so un-striping rides the measured path."""
    ranks = (rng.zipf(ZIPF_A, size=num_queries).astype(int) - 1) % len(pool)
    return [
        Query(pool[r], agg=Agg.MASK if i % 8 == 7 else Agg.COUNT)
        for i, r in enumerate(ranks)
    ]


def check_exact(queries, results, table, n) -> None:
    for q, r in zip(queries, results):
        sel = np_select(q.where, table, n)
        if q.agg is Agg.MASK:
            got = np.asarray(r.mask.to_bits()).astype(bool)
            np.testing.assert_array_equal(got, sel, err_msg=f"{q}")
        else:
            assert r.count == int(sel.sum()), q


def check_match(res_on, res_off) -> None:
    for a, b in zip(res_on, res_off):
        if a.query.agg is Agg.MASK:
            np.testing.assert_array_equal(
                np.asarray(a.mask.words), np.asarray(b.mask.words)
            )
        else:
            assert a.count == b.count, (a.query, a.count, b.count)


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    num_rows = 4_000 if smoke else 40_000
    num_queries = 24 if smoke else 64

    rng = np.random.default_rng(0)
    table = {
        "region": rng.integers(0, 8, num_rows),
        "status": rng.integers(0, 4, num_rows),
        "sales": rng.integers(0, 1_000, num_rows),
    }
    pool = build_pool(rng)
    queries = build_queries(rng, pool, num_queries)
    print(
        f"rows={num_rows}  queries={num_queries}  pool={len(pool)}  "
        f"zipf_a={ZIPF_A}  reps={REPS}  (smoke={smoke})"
    )

    def build_unsharded(optimize):
        store = BitmapStore()
        store.ingest(table)
        dev = FlashDevice(num_planes=4)
        store.program(dev)
        return BatchScheduler(
            dev, store, optimize=optimize,
            materialize_after=MATERIALIZE_AFTER,
        )

    def build_fleet(optimize):
        return build_sharded_flashql(
            table, NUM_SHARDS, num_planes=4, pipeline=True,
            optimize=optimize, materialize_after=MATERIALIZE_AFTER,
        )

    systems = {
        "unsharded": (build_unsharded(True), build_unsharded(False)),
        "pipelined": (build_fleet(True), build_fleet(False)),
    }

    # warm both sides of both systems (jit + plan/flush-program caches +
    # the materialization threshold) and assert exactness every round
    for _ in range(3):
        for on, off in systems.values():
            res_on, res_off = on.serve(queries), off.serve(queries)
            check_exact(queries, res_on, table, num_rows)
            check_match(res_on, res_off)
    print("optimizer on == off == numpy oracle (bit-exact)")

    ratios = {}
    for name, (on, off) in systems.items():
        spq = {}
        for side, sysm in (("on", on), ("off", off)):
            s0 = sysm.stats()["mws_commands"]
            sysm.serve(queries)
            spq[side] = (sysm.stats()["mws_commands"] - s0) / num_queries
        opt = on.telemetry.snapshot()["optimizer"]
        ratios[name] = spq["off"] / spq["on"]
        print(
            f"{name:9s}: {spq['off']:6.2f} -> {spq['on']:6.2f} sensings/"
            f"query ({ratios[name]:.2f}x fewer)  "
            f"[cse_plan_hits={opt['cse_plan_hits']}, "
            f"cse_shared_senses={opt['cse_shared_senses']}, "
            f"materializations={opt['materializations']}, "
            f"mat_hits={opt['materialization_hits']}]"
        )

    on, off = systems["pipelined"]
    best = interleaved_best_of(
        {
            "optimizer-on": lambda: on.serve(queries),
            "optimizer-off": lambda: off.serve(queries),
        }
    )
    t_on, t_off = best["optimizer-on"], best["optimizer-off"]
    print(
        f"pipelined wall-clock: off {num_queries / t_off:8.1f} q/s, "
        f"on {num_queries / t_on:8.1f} q/s ({t_off / t_on:.2f}x)"
    )

    # deterministic device-work acceptance: counters, not wall-clock, so
    # it holds under --smoke too
    for name, ratio in ratios.items():
        assert ratio >= 1.5, (
            f"{name}: optimizer must cut sensings/query by >= 1.5x, "
            f"got {ratio:.2f}x"
        )
    print(
        "acceptance: "
        + ", ".join(f"{n} {r:.2f}x" for n, r in ratios.items())
        + " >= 1.5x OK"
    )


if __name__ == "__main__":
    main()
