"""Telemetry-overhead benchmark: enabled serving within 10% of disabled.

Two identical pipelined 4-shard fleets serve the same mixed-aggregate
workload, one with full telemetry (trace spans, histograms, per-ticket
attribution, slow-query log) and one with ``Telemetry(enabled=False)``
(counters only — they are ``stats()``/projection inputs and cost one dict
update per event).  The gate asserts the enabled fleet's steady-state
serve stays within ``OVERHEAD_BUDGET`` of the disabled fleet — this is
the contract behind "cheap-by-default" instrumentation, and it is
asserted in ``--smoke`` runs too (CI).

Both fleets must also return identical results (telemetry can never
change an answer), and the exported Chrome trace must parse and pass the
span-nesting validator (:func:`repro.query.telemetry.validate_trace`);
the trace file is uploaded as a CI artifact.

Run:  PYTHONPATH=src python benchmarks/flashql_telemetry.py [--smoke]
"""

from __future__ import annotations

import json
import sys

import numpy as np

from _harness import REPS, interleaved_best_of
from flashql_pipeline import build_queries, check_exact
from repro.query import Mask, build_sharded_flashql, validate_trace

NUM_SHARDS = 4
QUEUE_DEPTH = 16
OVERHEAD_BUDGET = 1.10  # enabled serve <= 1.10x disabled serve
TRACE_PATH = "flashql_trace.json"
# serves per timed rep: one serve is a few ms, so a longer window keeps
# the relative overhead measurement out of the timer noise floor
SERVES_PER_REP = 4


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    num_rows = 4_000 if smoke else 60_000
    num_queries = 16 if smoke else 48

    rng = np.random.default_rng(0)
    table = {
        "region": rng.integers(0, 8, num_rows),
        "status": rng.integers(0, 4, num_rows),
        "sales": rng.integers(0, 1_000, num_rows),
    }
    queries = build_queries(rng, num_queries)
    print(
        f"rows={num_rows}  queries={num_queries}  shards={NUM_SHARDS}  "
        f"queue_depth={QUEUE_DEPTH}  reps={REPS}  (smoke={smoke})"
    )

    fleets = {}
    for name, enabled in (("enabled", True), ("disabled", False)):
        sq = build_sharded_flashql(
            table,
            NUM_SHARDS,
            num_planes=4,
            queue_depth=QUEUE_DEPTH,
            pipeline=True,
        )
        sq.telemetry.enabled = enabled
        fleets[name] = sq

    # warm both (jit + plan/exec/flush-program caches) and assert the
    # differential contract: telemetry can never change an answer
    res_on = fleets["enabled"].serve(queries)
    res_off = fleets["disabled"].serve(queries)
    check_exact(res_on, queries, table, num_rows)
    for a, b in zip(res_on, res_off):
        if isinstance(a.query.agg, Mask):
            np.testing.assert_array_equal(
                np.asarray(a.value.words), np.asarray(b.value.words)
            )
        else:
            assert a.value == b.value, (a.query, a.value, b.value)
    assert all(r.attribution is not None for r in res_on)
    assert all(r.attribution is None for r in res_off)
    print("enabled == disabled == numpy oracle")

    def serve_rep(sq):
        def fn():
            for _ in range(SERVES_PER_REP):
                sq.serve(queries)

        return fn

    best = interleaved_best_of(
        {
            "enabled": serve_rep(fleets["enabled"]),
            "disabled": serve_rep(fleets["disabled"]),
        }
    )
    t_on, t_off = best["enabled"], best["disabled"]
    ratio = t_on / t_off
    n_q = num_queries * SERVES_PER_REP
    print(
        f"disabled: {t_off:7.4f}s  {n_q / t_off:8.1f} q/s\n"
        f"enabled : {t_on:7.4f}s  {n_q / t_on:8.1f} q/s\n"
        f"overhead: {ratio:.3f}x (budget {OVERHEAD_BUDGET:.2f}x)"
    )

    # trace export: must parse as JSON and pass the span-nesting validator
    tele = fleets["enabled"].telemetry
    tele.export_trace(TRACE_PATH)
    with open(TRACE_PATH) as f:
        trace = json.load(f)
    n_spans = validate_trace(trace)
    assert n_spans > 0, "trace export recorded no spans"
    print(f"trace: {n_spans} spans validated -> {TRACE_PATH}")

    snap = tele.snapshot()
    c = snap["counters"]
    print(
        f"snapshot: {c['queries_served']:.0f} served, "
        f"{c['host_transfers']:.0f} transfers, "
        f"{c['fused_dispatches']:.0f} fused dispatches, "
        f"plan cache {snap['plan_cache']['hits']} hits / "
        f"{snap['plan_cache']['misses']} misses"
    )
    fl = snap["histograms"]["flush_latency_s"]
    print(
        f"flush latency: p50={fl['p50'] * 1e3:.2f}ms  "
        f"p95={fl['p95'] * 1e3:.2f}ms  p99={fl['p99'] * 1e3:.2f}ms  "
        f"(n={fl['count']})"
    )

    # the overhead gate holds in smoke runs too: "cheap by default" is a
    # CI contract, not a full-run-only property
    assert ratio <= OVERHEAD_BUDGET, (
        f"telemetry-enabled serving must stay within "
        f"{OVERHEAD_BUDGET:.2f}x of disabled, got {ratio:.3f}x"
    )
    print(f"acceptance: {ratio:.3f}x <= {OVERHEAD_BUDGET:.2f}x OK")


if __name__ == "__main__":
    main()
