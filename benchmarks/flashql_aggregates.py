"""Aggregate-throughput benchmark: batched vs sequential weighted popcounts.

OLAP-style SUM traffic (``SUM(sales) WHERE region/status ...``) served two
ways on one FlashDevice:

* **batched** — the :class:`BatchScheduler` path: one flush compiles and
  executes every predicate under jit-of-vmap, then the pluggable
  aggregation pipeline reduces ALL queries' BSI slices with one jit'd
  weighted popcount per reduce signature and ONE host transfer;
* **sequential** — the pre-pipeline baseline: each query executes alone,
  then a Python loop popcounts ``mask ∧ slice_b`` one slice at a time —
  one kernel dispatch and one host sync per slice per query.

Both sides are asserted exact against a numpy oracle.  Timing follows the
dev notes: best-of-``REPS`` with every configuration measured inside the
same rep window (interleaved), because run-to-run noise on shared machines
is 3-4x.  Acceptance (skipped under ``--smoke``): batched SUM serving must
reach >= 1.5x the sequential throughput.

Run:  PYTHONPATH=src python benchmarks/flashql_aggregates.py [--smoke]
"""

from __future__ import annotations

import sys

import jax.numpy as jnp
import numpy as np

from _harness import REPS, interleaved_best_of
from repro.kernels.popcount import popcount
from repro.query import (
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    In,
    Query,
    Sum,
)
from repro.query.ast import and_ as qand
from repro.query.bitmap import bsi_pages
from repro.query.compile import QueryCompiler


def build_queries(rng, num_queries) -> list[Query]:
    """Recurring predicate shapes, SUM aggregate, many parameterizations."""
    qs: list[Query] = []
    while len(qs) < num_queries:
        r = int(rng.integers(0, 8))
        s = int(rng.integers(0, 4))
        qs.append(Query(Eq("region", r), agg=Sum("sales")))
        qs.append(
            Query(
                qand(Eq("region", r), Eq("status", s)), agg=Sum("sales")
            )
        )
        qs.append(
            Query(In("status", [s, (s + 1) % 4]), agg=Sum("sales"))
        )
    return qs[:num_queries]


def np_sum(q: Query, table) -> int:
    from repro.query.ast import And, Eq, In

    def m(p):
        if isinstance(p, Eq):
            return table[p.column] == p.value
        if isinstance(p, In):
            return np.isin(table[p.column], p.values)
        assert isinstance(p, And)
        out = np.ones(len(table["sales"]), bool)
        for c in p.children:
            out &= m(c)
        return out

    return int(table["sales"][m(q.where)].sum())


def sequential_sums(dev, compiler, queries, valid, slices) -> list[int]:
    """One query at a time; one popcount dispatch + host sync per slice."""
    out = []
    for q in queries:
        cq = compiler.compile(q)
        mask = (
            dev.execute_batch_stacked([cq.plan], batch_key=(cq.key,))[0]
            & valid
        )
        total = 0
        for b in range(slices.shape[0]):
            total += (
                int(popcount(mask & slices[b], interpret=dev.interpret))
                << b
            )
        out.append(total)
    return out


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    num_rows = 5_000 if smoke else 200_000
    num_queries = 8 if smoke else 32

    rng = np.random.default_rng(0)
    table = {
        "region": rng.integers(0, 8, num_rows),
        "status": rng.integers(0, 4, num_rows),
        "sales": rng.integers(0, 1_000, num_rows),
    }
    queries = build_queries(rng, num_queries)
    want = [np_sum(q, table) for q in queries]
    print(
        f"rows={num_rows}  queries={num_queries}  reps={REPS}  "
        f"(smoke={smoke})"
    )

    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=4)
    store.program(dev, warmup=queries[:3])

    sched = BatchScheduler(dev, store, max_batch=num_queries)
    got = [r.value for r in sched.serve(queries)]  # warm: jit + caches
    assert got == want, "batched SUM diverges from numpy oracle"

    seq_compiler = QueryCompiler(store, dev)
    valid = jnp.asarray(store.valid_words_mask())
    slices = jnp.stack(
        [store.logical[p] for p in bsi_pages(store, "sales")]
    )
    got = sequential_sums(dev, seq_compiler, queries, valid, slices)
    assert got == want, "sequential SUM diverges from numpy oracle"

    # interleaved best-of-REPS (benchmarks/_harness.py): both
    # configurations timed inside the same short window each rep so
    # machine-load swings hit both sides alike
    best = interleaved_best_of(
        {
            "batched": lambda: sched.serve(queries),
            "sequential": lambda: sequential_sums(
                dev, seq_compiler, queries, valid, slices
            ),
        }
    )
    t_batch, t_seq = best["batched"], best["sequential"]

    qps_batch = num_queries / t_batch
    qps_seq = num_queries / t_seq
    print(
        f"batched    (aggregate pipeline): {t_batch:7.3f}s  "
        f"{qps_batch:8.1f} q/s"
    )
    print(
        f"sequential (per-slice popcount): {t_seq:7.3f}s  "
        f"{qps_seq:8.1f} q/s"
    )
    print(f"speedup: {qps_batch / qps_seq:.2f}x")

    proj = sched.projection()
    print(
        f"SSD projection incl. slice reads: "
        f"{proj['fc_time_s'] * 1e3:.2f} ms, {proj['fc_energy_j']:.3f} J "
        f"({proj['speedup_vs_osp']:.1f}x vs OSP)"
    )

    if not smoke:
        assert qps_batch >= 1.5 * qps_seq, (
            f"batched SUM must serve >= 1.5x the sequential per-query "
            f"popcount loop, got {qps_batch / qps_seq:.2f}x"
        )
        print(f"acceptance: {qps_batch / qps_seq:.2f}x >= 1.5x OK")


if __name__ == "__main__":
    main()
