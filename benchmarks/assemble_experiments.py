"""Insert the generated dry-run/roofline/perf tables into EXPERIMENTS.md.

Run:  PYTHONPATH=src python -m benchmarks.assemble_experiments
"""

import os

from benchmarks.report import dryrun_table, perf_section, roofline_table

DOC = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def main():
    with open(DOC) as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table())
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
    text = text.replace("<!-- PERF_TABLES -->", perf_section())
    with open(DOC, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md assembled")


if __name__ == "__main__":
    main()
