"""Compaction benchmark: tombstone deletes, stripe rebuild, serving parity.

A live FlashQL index absorbs a heavy delete wave (40% of rows), compacts
the tombstoned capacity away, and keeps serving.  Three acceptance
criteria (all asserted, in ``--smoke`` too — the wall-clock gate is a
ratio between two sides timed in the same interleaved rep window, so it
is robust to machine-load swings):

* **bit-exact serving across the rebuild** — the compacted index must
  serve exactly what a fresh ingest of the surviving rows serves, before
  AND after follow-up appends into the reclaimed headroom;
* **post-compaction serving within 1.1x of fresh-ingest serving** — a
  rebuilt stripe is a first-class stripe: same layout, same fused plans,
  no lingering tombstone overhead beyond the one valid-page wordline
  every plan (fresh or compacted) already senses;
* **capacity actually reclaimed** — ``capacity_rows - live_rows``
  headroom is restored to at least the pre-delete reserve, and the
  flashsim projection charges the erases + ESP reprograms the rebuild
  paid (write amplification is reported from the same counters).

Timing is best-of-REPS *interleaved* via ``benchmarks/_harness.py``.

Run:  PYTHONPATH=src python benchmarks/flashql_compaction.py [--smoke]
"""

from __future__ import annotations

import sys

import numpy as np

from _harness import interleaved_best_of
from repro.query import (
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    In,
    Query,
    Range,
    Sum,
)
from repro.query.ast import and_ as qand

DELETE_FRAC = 0.4  # rows tombstoned before the compaction under test


def build_table(rng, n):
    return {
        "region": rng.integers(0, 8, n),
        "status": rng.integers(0, 4, n),
        "sales": rng.integers(0, 1_000, n),
    }


def build_queries(rng, num_queries) -> list[Query]:
    qs: list[Query] = []
    while len(qs) < num_queries:
        r = int(rng.integers(0, 8))
        s = int(rng.integers(0, 4))
        qs.append(Query(qand(Eq("region", r), Eq("status", s))))
        qs.append(Query(In("status", [s, (s + 1) % 4]), agg=Sum("sales")))
        qs.append(Query(Range("sales", 100, 700), agg=Sum("sales")))
    return qs[:num_queries]


def build_scheduler(table, queries, reserve) -> BatchScheduler:
    store = BitmapStore()
    store.ingest(table, reserve_rows=reserve)
    dev = FlashDevice(num_planes=4)
    store.program(dev, warmup=queries[:2])
    sched = BatchScheduler(dev, store, max_batch=len(queries))
    sched.serve(queries)  # warm: jit + plan caches
    return sched


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    num_rows = 4_000 if smoke else 50_000
    num_queries = 9 if smoke else 30
    reserve = max(256, num_rows // 8)

    rng = np.random.default_rng(7)
    table = build_table(rng, num_rows)
    queries = build_queries(rng, num_queries)
    doomed = rng.choice(num_rows, int(num_rows * DELETE_FRAC), replace=False)
    print(
        f"rows={num_rows}  queries={num_queries}  "
        f"deletes={doomed.size}  (smoke={smoke})"
    )

    # -- mutate: delete wave, then compact the tombstones away -------------
    sched = build_scheduler(table, queries, reserve)
    sched.delete(doomed)
    assert sched.store.tombstone_density > 0.35
    stats = sched.compact()
    print(
        f"compact: dropped {stats['rows_dropped']} rows, "
        f"{stats['blocks_erased']} block erases, "
        f"{stats['words_reprogrammed']} words reprogrammed "
        f"in {stats['seconds']:.3f}s"
    )
    headroom = sched.store.capacity_rows - sched.store.live_rows
    assert headroom >= reserve, (
        f"compaction must restore reserve headroom: {headroom} < {reserve}"
    )

    # -- baseline: fresh ingest of exactly the surviving rows, at the SAME
    # capacity the compacted store kept (identical page widths — the gate
    # isolates rebuild artifacts, not reserve-sizing choices)
    live = np.setdiff1d(np.arange(num_rows), doomed)
    fresh = build_scheduler(
        {c: v[live] for c, v in table.items()},
        queries,
        sched.store.capacity_rows - live.size,
    )

    # -- correctness: compacted serving == fresh-ingest serving, and the
    # reclaimed headroom absorbs appends identically on both sides
    def check_parity():
        got = sched.serve(queries)
        want = fresh.serve(queries)
        for q, g, w in zip(queries, got, want):
            assert g.count == w.count and g.value == w.value, (
                f"compacted index diverges from fresh ingest on {q}"
            )

    check_parity()
    batch = build_table(rng, 128)
    sched.append(batch)
    fresh.append(batch)
    check_parity()
    print("parity: compacted serving == fresh-ingest serving OK")

    # -- gate: post-compaction serving within 1.1x of fresh ingest ---------
    rounds = 20 if smoke else 5  # amortise fixed per-serve overhead

    def serve_rounds(s):
        for _ in range(rounds):
            s.serve(queries)

    best = interleaved_best_of(
        {
            "compacted": lambda: serve_rounds(sched),
            "fresh": lambda: serve_rounds(fresh),
        }
    )
    t_c = best["compacted"] / rounds
    t_f = best["fresh"] / rounds
    print(
        f"compacted    : {t_c:7.3f}s  {num_queries / t_c:8.1f} q/s\n"
        f"fresh ingest : {t_f:7.3f}s  {num_queries / t_f:8.1f} q/s"
    )
    assert t_c <= 1.1 * t_f, (
        f"post-compaction serving must stay within 1.1x of fresh-ingest "
        f"serving, got {t_c / t_f:.2f}x"
    )
    print(f"acceptance: {t_c / t_f:.2f}x <= 1.1x OK")

    # -- wear accounting: WA + erases out of one telemetry snapshot --------
    snap = sched.telemetry.snapshot()
    counters = snap["counters"]
    s = sched.stats()
    print(
        f"write amplification: {s['write_amplification']:.2f} "
        f"({counters['words_programmed']} words programmed / "
        f"{counters['words_written']} logical)  "
        f"block erases: {counters['block_erases']}"
    )
    assert s["write_amplification"] > 1.0, (
        "a compaction that reprograms live pages must show up as WA > 1"
    )
    proj = snap["projection"]
    assert proj["block_erases"] == counters["block_erases"]
    print(
        f"SSD projection incl. rebuild: {proj['fc_time_s'] * 1e3:.2f} ms, "
        f"{proj['fc_energy_j']:.3f} J, {proj['esp_programs']} ESP "
        f"programs, {proj['block_erases']} erases"
    )


if __name__ == "__main__":
    main()
