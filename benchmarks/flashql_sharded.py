"""Sharded FlashQL scaling benchmark: 1 -> N simulated FlashDevices.

The same BMI-style COUNT traffic as ``benchmarks/flashql_throughput.py``
(200k-row table, 64 recurring-shape queries) served three ways:

* **1 device** — the unsharded ``BatchScheduler`` steady state (this is
  the single-device number ``flashql_throughput.py`` reports);
* **N-device fleet (per-chip)** — rows striped round-robin over N
  ``FlashDevice``s; each chip executes its own shard batch + popcount.
  Chips are independent hardware, so the fleet's serving time is the MAX
  over per-device times (measured per device, steady state) — this is the
  scaling number;
* **N-device fused host simulation** — ``ShardedFlashQL.serve``: the
  whole fleet in one process under one ``jit(vmap)`` per signature group,
  used for correctness (counts asserted against a numpy oracle) and for
  the plan-aware-batching criterion: signature groups must stay BELOW
  shards x distinct plan shapes.

Also prints the fleet-level SSD projection (per-chip traffic replayed
through the Table-1 timing/energy model; time = max over chips, energy =
sum).

Run:  PYTHONPATH=src python benchmarks/flashql_sharded.py [--smoke]

``--smoke`` shrinks to a tiny geometry (2 shards, small store, CI-speed)
and skips the wall-clock scaling assertion — timing on shared CI runners
is noise — while still exercising every scatter/gather path.
"""

from __future__ import annotations

import sys

import numpy as np

from _harness import REPS, interleaved_best_of
from repro.query import (
    Agg,
    BatchScheduler,
    BitmapStore,
    Eq,
    FlashDevice,
    In,
    Query,
    build_sharded_flashql,
)
from repro.query.ast import and_ as qand


def build_queries(rng, num_queries) -> list[Query]:
    """BMI-style COUNT traffic: a few hot shapes, many parameterizations."""
    qs: list[Query] = []
    while len(qs) < num_queries:
        c = int(rng.integers(0, 8))
        d = int(rng.integers(0, 4))
        qs.append(Query(qand(Eq("country", c), Eq("device", d))))
        qs.append(Query(Eq("country", c), agg=Agg.COUNT))
        qs.append(Query(In("device", [d, (d + 1) % 4]), agg=Agg.COUNT))
    return qs[:num_queries]


def np_count(q: Query, table) -> int:
    from repro.query.ast import And, Eq, In

    def m(p):
        if isinstance(p, Eq):
            return table[p.column] == p.value
        if isinstance(p, In):
            return np.isin(table[p.column], p.values)
        assert isinstance(p, And)
        out = np.ones(len(next(iter(table.values()))), bool)
        for c in p.children:
            out &= m(c)
        return out

    return int(m(q.where).sum())


def single_device_scheduler(table, queries) -> BatchScheduler:
    """The unsharded flashql_throughput configuration, warmed."""
    store = BitmapStore()
    store.ingest(table)
    dev = FlashDevice(num_planes=4)
    store.program(dev, warmup=queries[:3])
    sched = BatchScheduler(dev, store, max_batch=len(queries))
    sched.serve(queries)  # warm: jit + plan caches
    return sched


def per_chip_schedulers(sq, queries) -> list[BatchScheduler]:
    """One BatchScheduler per shard device — the same serving software the
    single-device baseline runs, each on its own stripe.  A real fleet
    runs these on independent chips, so fleet batch time is the max over
    shards (plus the host-side merge, measured separately)."""
    scheds = []
    for s in sq.store.active:
        sched = BatchScheduler(
            sq.devices[s],
            sq.store.shards[s],
            max_batch=len(queries),
            compiler=sq.compilers[s],
        )
        sched.serve(queries)  # warm
        scheds.append(sched)
    return scheds


def serve_counts(sched: BatchScheduler, queries) -> list[int]:
    return [r.count for r in sched.serve(queries)]


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    # 1M rows keeps the non-smoke gate compute-bound: the one-dispatch
    # flush (PR 5) cut per-flush host overhead to ~1.5 ms, so at the old
    # 200k rows serving was overhead-dominated and row striping could not
    # show its scaling (chips must be measurably faster on 1/N the rows)
    num_rows = 5_000 if smoke else 1_000_000
    num_queries = 16 if smoke else 64
    fleet_sizes = [2] if smoke else [2, 4]

    rng = np.random.default_rng(0)
    table = {
        "country": rng.integers(0, 8, num_rows),
        "device": rng.integers(0, 4, num_rows),
    }
    queries = build_queries(rng, num_queries)
    want = [np_count(q, table) for q in queries]
    print(f"rows={num_rows}  queries={num_queries}  reps={REPS}  "
          f"(smoke={smoke})")

    sched_1 = single_device_scheduler(table, queries)
    fleets = {}
    for n_shards in fleet_sizes:
        sq = build_sharded_flashql(
            table,
            n_shards,
            policy="roundrobin",
            num_planes=4,
            warmup=queries[:3],
            queue_depth=num_queries,
        )
        # correctness + batching criterion via the fused host simulation
        got = [r.count for r in sq.serve(queries)]
        assert got == want, "sharded counts diverge from oracle"
        st = sq.stats()
        groups, shapes = st["vmap_batches"], st["distinct_signatures"]
        assert groups < n_shards * shapes, (
            f"plan-aware batching failed: {groups} groups for "
            f"{n_shards} shards x {shapes} shapes"
        )
        chips = per_chip_schedulers(sq, queries)
        merged = [
            sum(c) for c in zip(*(serve_counts(ch, queries) for ch in chips))
        ]
        assert merged == want, "per-device merge diverges from oracle"
        fleets[n_shards] = (sq, chips, groups, shapes)

    # interleaved best-of-REPS (benchmarks/_harness.py): every
    # configuration is timed inside the same short window each rep, so
    # machine-load swings hit all sides alike instead of gating on
    # whichever ran during a quiet spell
    timers = {"1dev": lambda: sched_1.serve(queries)}
    for n, (sq, chips, _, _) in fleets.items():
        for i, ch in enumerate(chips):
            timers[("chip", n, i)] = (
                lambda c=ch: c.serve(queries)
            )
        timers[("fused", n)] = (lambda s=sq: s.serve(queries))
    best = interleaved_best_of(timers)
    t_1 = best["1dev"]
    t_chip = {
        n: [best[("chip", n, i)] for i in range(len(f[1]))]
        for n, f in fleets.items()
    }
    t_fused = {n: best[("fused", n)] for n in fleets}

    qps_1 = num_queries / t_1
    print(f"1 device  (BatchScheduler)    : {t_1:7.3f}s  {qps_1:8.1f} q/s")
    qps_fleet = {}
    for n_shards, (sq, chips, groups, shapes) in fleets.items():
        t_fleet = max(t_chip[n_shards])  # chips serve concurrently
        qps_fleet[n_shards] = num_queries / t_fleet
        print(
            f"{n_shards} devices (per-chip max)     : {t_fleet:7.3f}s  "
            f"{qps_fleet[n_shards]:8.1f} q/s  "
            f"({qps_fleet[n_shards] / qps_1:4.2f}x vs 1 device)"
        )
        print(
            f"{n_shards} devices (fused host sim)   : "
            f"{t_fused[n_shards]:7.3f}s  "
            f"{num_queries / t_fused[n_shards]:8.1f} q/s  "
            f"[{groups} vmap groups for {shapes} shapes x "
            f"{n_shards} shards]"
        )
        proj = sq.projection()
        print(
            f"  fleet SSD projection: FC {proj['fc_time_s'] * 1e3:.2f} ms, "
            f"{proj['fc_energy_j']:.3f} J on {proj['num_devices']} chips "
            f"({proj['speedup_vs_osp']:.1f}x faster, "
            f"{proj['energy_ratio_vs_osp']:.1f}x less energy than OSP)"
        )

    if not smoke:
        top = max(fleet_sizes)
        assert qps_fleet[top] >= 2.0 * qps_1, (
            f"{top}-device fleet must serve >= 2x the single-device "
            f"throughput, got {qps_fleet[top] / qps_1:.2f}x"
        )
        print(
            f"scaling: {qps_fleet[top] / qps_1:.2f}x with {top} devices "
            f"(acceptance: >= 2x)"
        )


if __name__ == "__main__":
    main()
