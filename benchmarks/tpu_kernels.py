"""TPU-adaptation benchmarks: fused MWS kernel vs serial ParaBit baseline.

Wall-clock on this CPU container is *not* the score (kernels run in
interpret mode); the decisive metric is the modelled HBM traffic — the TPU
analogue of the paper's sensing count — plus measured interpret-mode time
as a correctness-of-trend check.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import BitOp
from repro.kernels.mws import mws_reduce, parabit_reduce
from repro.kernels.popcount import popcount
from repro.kernels.signcomp import compress_signs


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()  # compile+warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda a: a.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def hbm_traffic_model(n_operands: int, words: int, dtype_bytes: int = 4):
    """Bytes moved for fused (MWS) vs serial pairwise (ParaBit) reduce."""
    fused = (n_operands + 1) * words * dtype_bytes
    serial = 3 * (n_operands - 1) * words * dtype_bytes
    return fused, serial


def mws_vs_parabit():
    rows = []
    rng = np.random.default_rng(0)
    W = 1 << 16
    for n in (2, 4, 8, 16, 32, 48, 64):
        x = jnp.array(rng.integers(0, 2**32, (n, W), dtype=np.uint32))
        t_fused = _time(lambda a: mws_reduce(a, BitOp.AND), x)
        t_serial = _time(lambda a: parabit_reduce(a, BitOp.AND), x)
        fused_b, serial_b = hbm_traffic_model(n, W)
        rows.append(
            (
                f"tpu_mws.and.n={n}.traffic_ratio",
                round(serial_b / fused_b, 2),
                f"fused={fused_b>>10}KiB serial={serial_b>>10}KiB",
            )
        )
        rows.append(
            (
                f"tpu_mws.and.n={n}.interp_us",
                round(t_fused, 1),
                f"serial={t_serial:.1f}us",
            )
        )
    return rows


def fused_count_bench():
    """Fused reduce+count (one-pass BMI query): traffic model vs two-pass."""
    rows = []
    rng = np.random.default_rng(3)
    from repro.kernels.mws_count import mws_count

    W = 1 << 16
    for n in (8, 48):
        x = jnp.array(rng.integers(0, 2**32, (n, W), dtype=np.uint32))
        t = _time(lambda a: mws_count(a, BitOp.AND), x)
        fused_b = n * W * 4 + 4  # operands in, scalar out
        twopass_b = (n + 1) * W * 4 + (W * 4 + 4)  # reduce out + count in
        rows.append(
            (
                f"tpu_mws_count.n={n}.traffic_ratio",
                round(twopass_b / fused_b, 3),
                f"fused={fused_b>>10}KiB two-pass={twopass_b>>10}KiB",
            )
        )
        rows.append((f"tpu_mws_count.n={n}.interp_us", round(t, 1), ""))
    return rows


def popcount_bench():
    rng = np.random.default_rng(1)
    rows = []
    for w in (1 << 12, 1 << 16):
        x = jnp.array(rng.integers(0, 2**32, (8, w), dtype=np.uint32))
        t = _time(popcount, x)
        rows.append((f"tpu_popcount.w={w}.interp_us", round(t, 1), ""))
    return rows


def signcomp_bench():
    rng = np.random.default_rng(2)
    rows = []
    for n in (1 << 16, 1 << 20):
        g = jnp.array(rng.normal(size=(n,)).astype(np.float32))
        t_c = _time(compress_signs, g)
        packed = compress_signs(g)
        ratio = g.size * 4 / (packed.size * 4)
        rows.append(
            (
                f"tpu_signcomp.n={n}.compress_us",
                round(t_c, 1),
                f"compression={ratio:.0f}x",
            )
        )
    return rows
